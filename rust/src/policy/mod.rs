//! Scheduling policies: ARCAS and every baseline the paper compares
//! against.
//!
//! A [`Policy`] answers four questions for the executor:
//! 1. where does each task rank start (`initial_placement`),
//! 2. how does placement react to profiling windows (`on_timer`),
//! 3. where may an idle core steal from (`steal_order`),
//! 4. what does a context switch cost (`switch_model`).
//!
//! | policy | stands in for | signature behaviour |
//! |---|---|---|
//! | [`ArcasPolicy`]            | the paper's system     | Algorithms 1+2, chiplet-first stealing |
//! | [`RingPolicy`]             | RING [26]              | NUMA round-robin, chiplet-agnostic, NUMA-confined stealing |
//! | [`ShoalPolicy`]            | Shoal [17]             | strict sequential task→core order (fills chiplets one by one) |
//! | [`LocalCachePolicy`]       | §2.3 LocalCache        | static compaction on fewest chiplets |
//! | [`DistributedCachePolicy`] | §2.3 DistributedCache  | static max spread across chiplets |
//! | [`OsAsyncPolicy`]          | std::async baseline    | OS threads, no affinity, OS switch costs |
//! | [`SloPolicy`]              | SLO-aware serving      | p99-driven spread from queue-wait vs service feedback |

use std::sync::Arc;

use crate::controller::{placement_map, placement_map_bounded, AdaptiveController, Approach};
use crate::engine::dispatch::SloSignal;
use crate::mem::{Placement, RegionId};
use crate::profiler::WindowSample;
use crate::topology::Topology;

/// One region's windowed heat, handed to [`Policy::plan_region_moves`] at
/// every adaptive tick: where its accessors ran during the last window.
#[derive(Clone, Debug)]
pub struct RegionHeat {
    pub region: RegionId,
    /// Current placement (from the region book at tick time).
    pub placement: Placement,
    pub size: u64,
    /// Classified ops issued against the region from each chiplet during
    /// the window, in chiplet order.
    pub per_chiplet: Vec<f64>,
}

/// A policy's decision to re-home one region ("data follows tasks").
/// Applied by the executor via `Machine::move_region`, which charges the
/// one-time DDR copy to the ticking core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RegionMove {
    pub region: RegionId,
    pub to_numa: usize,
}

/// One routing window's hash-slot heat, handed to
/// [`Policy::plan_shard_moves`] by the cluster front-end at every
/// window boundary: how many requests each slot attracted, and which
/// machine shard each slot currently homes on. The cluster-level mirror
/// of [`RegionHeat`].
#[derive(Clone, Debug)]
pub struct ShardHeat {
    /// Requests routed to each hash slot during the window (slot order).
    pub slot_load: Vec<f64>,
    /// Current slot → shard table.
    pub table: Vec<usize>,
    /// Number of machine shards in the cluster.
    pub shards: usize,
}

/// A policy's decision to re-home one hash slot onto another machine
/// shard ("keys follow load"), the cluster-level mirror of
/// [`RegionMove`]. Applied by the cluster front-end, which charges the
/// slot's state transfer to the inter-machine links.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardMove {
    pub slot: usize,
    pub to_shard: usize,
}

/// Context-switch cost regime.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchModel {
    /// User-space coroutine switch (~tens of ns).
    Coroutine,
    /// OS thread switch (~µs) + spawn cost on first dispatch.
    OsThread,
}

/// A scheduling policy.
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    /// Rank → core map at spawn time.
    fn initial_placement(&mut self, topo: &Topology, group_size: usize) -> Vec<usize>;

    /// Periodic adaptation; returns a new rank → core map to migrate to.
    fn on_timer(
        &mut self,
        _topo: &Topology,
        _now_ns: u64,
        _sample: &WindowSample,
        _group_size: usize,
    ) -> Option<Vec<usize>> {
        None
    }

    /// Periodic memory adaptation, the second half of an adaptive tick:
    /// given the window's per-region heat, which regions should be
    /// re-homed to the NUMA node their accessors now run on? The default
    /// never moves data — only policies that close the memory loop
    /// (currently [`ArcasPolicy`]) override this.
    fn plan_region_moves(
        &mut self,
        _topo: &Topology,
        _now_ns: u64,
        _heat: &[RegionHeat],
        _group_size: usize,
    ) -> Vec<RegionMove> {
        Vec::new()
    }

    /// Periodic cluster adaptation, one level above
    /// [`Policy::plan_region_moves`]: given a routing window's per-slot
    /// request heat, which hash slots should re-home onto a colder
    /// machine shard? Called by the cluster front-end dispatcher at
    /// every window boundary. The default never moves keys — only
    /// policies that close the loop (currently [`ArcasPolicy`])
    /// override this.
    fn plan_shard_moves(&mut self, _now_ns: u64, _heat: &ShardHeat) -> Vec<ShardMove> {
        Vec::new()
    }

    /// Cores an idle `thief` may steal from, in preference order.
    /// Default: same chiplet, then same NUMA, then everywhere.
    fn steal_order(&self, topo: &Topology, thief: usize, active: &[usize]) -> Vec<usize> {
        chiplet_first_steal_order(topo, thief, active)
    }

    fn switch_model(&self) -> SwitchModel {
        SwitchModel::Coroutine
    }

    /// The controller's current spread rate (diagnostics; static policies
    /// report their fixed value).
    fn spread_rate(&self) -> usize {
        1
    }

    /// The policy's preferred profiling-window length; the executor adopts
    /// it so Algorithm 1 and the profiler sample on the same cadence.
    fn timer_ns(&self) -> Option<u64> {
        None
    }

    /// Wire a serving scenario's per-chiplet queue-wait/service feedback
    /// channel into the policy. The engine driver calls this before the
    /// run when the scenario publishes an [`SloSignal`]; policies that
    /// don't react to tail latency keep the default no-op.
    fn connect_slo(&mut self, _signal: Arc<SloSignal>) {}
}

/// ARCAS's steal order (§4.4): same chiplet first, then same NUMA, then
/// other chiplets — preserving cache locality.
pub fn chiplet_first_steal_order(topo: &Topology, thief: usize, active: &[usize]) -> Vec<usize> {
    let my_chiplet = topo.chiplet_of(thief);
    let my_numa = topo.numa_of_core(thief);
    let mut order: Vec<usize> = active.iter().copied().filter(|&c| c != thief).collect();
    order.sort_by_key(|&c| {
        let tier = if topo.chiplet_of(c) == my_chiplet {
            0
        } else if topo.numa_of_core(c) == my_numa {
            1
        } else {
            2
        };
        (tier, c)
    });
    order
}

/// NUMA-confined steal order (RING/Shoal: never steal across sockets).
pub fn numa_confined_steal_order(topo: &Topology, thief: usize, active: &[usize]) -> Vec<usize> {
    let my_numa = topo.numa_of_core(thief);
    let mut order: Vec<usize> = active
        .iter()
        .copied()
        .filter(|&c| c != thief && topo.numa_of_core(c) == my_numa)
        .collect();
    order.sort_unstable();
    order
}

// =====================================================================
// ARCAS
// =====================================================================

/// The paper's adaptive chiplet-aware policy (Algorithms 1 + 2).
pub struct ArcasPolicy {
    pub controller: AdaptiveController,
    /// Last applied rank→core map (to skip no-benefit reshuffles).
    last_map: Vec<usize>,
    /// Chiplets the group is confined to (minimal socket span).
    avail_chiplets: usize,
    /// Online memory re-placement: when enabled (the default), adaptive
    /// ticks also re-home bound regions toward their accessors
    /// ([`ArcasPolicy::plan_region_moves`]). Disabled for the
    /// task-move-only baseline (`--no-region-moves`).
    region_moves_enabled: bool,
}

impl ArcasPolicy {
    /// Minimum window heat (classified ops) before a region is worth
    /// re-homing — below this, the signal is noise and the one-time DDR
    /// copy can't amortize.
    const MIN_MOVE_HEAT: f64 = 512.0;
    /// Fraction of a region's window heat one NUMA node must *exceed*
    /// before the region follows it (strict majority; an even spread
    /// across nodes never clears it, so spread-out phases don't thrash).
    const HOT_NUMA_FRAC: f64 = 0.5;
    /// A machine shard is "hot" when its window load exceeds this
    /// multiple of the mean shard load — below it, the imbalance is not
    /// worth shipping slot state across the cluster links.
    const HOT_SHARD_FRAC: f64 = 1.15;
    /// Minimum per-slot window heat before a slot is worth re-homing
    /// (cluster mirror of `MIN_MOVE_HEAT`, scaled to slot granularity).
    const MIN_SLOT_HEAT: f64 = 16.0;
    /// Re-homings per window boundary, bounded so one tick never ships
    /// more slot state than the links can absorb inside a window.
    const MAX_SHARD_MOVES: usize = 8;

    pub fn new(topo: &Topology) -> Self {
        Self {
            controller: AdaptiveController::new(topo),
            last_map: Vec::new(),
            avail_chiplets: topo.num_chiplets(),
            region_moves_enabled: true,
        }
    }

    /// Enable/disable online region re-placement (the task-move-only
    /// baseline keeps everything else identical).
    pub fn with_region_moves(mut self, enabled: bool) -> Self {
        self.region_moves_enabled = enabled;
        self
    }

    pub fn with_approach(mut self, a: Approach) -> Self {
        self.controller = self.controller.with_approach(a);
        self
    }

    pub fn with_threshold(mut self, rate: f64) -> Self {
        self.controller = self.controller.with_threshold(rate);
        self
    }

    pub fn with_timer(mut self, timer_ns: u64) -> Self {
        self.controller = self.controller.with_timer(timer_ns);
        self
    }

    /// Start from a spread rate matched to the group size: use the fewest
    /// *sockets* that can host the group (§5.2: "ARCAS fully occupies all
    /// cores in a single socket"), but all chiplets *within* those sockets
    /// for maximal aggregate L3 (§5.3: 16 tasks across all 8 chiplets).
    /// Algorithm 1 then adapts from there.
    fn initial_spread(&self, topo: &Topology, group_size: usize) -> usize {
        let sockets_needed = crate::util::div_ceil(
            group_size as u64,
            topo.cores_per_socket() as u64,
        ) as usize;
        let avail_chiplets = (sockets_needed * topo.numa_per_socket * topo.chiplets_per_numa)
            .min(topo.num_chiplets());
        // Spread s puts the group on ~ group*s/cores_per_chiplet chiplets;
        // choose s so that covers all the available chiplets (round up:
        // prefer touching every chiplet's L3 over perfect packing).
        let want = crate::util::div_ceil(
            (avail_chiplets * topo.cores_per_chiplet) as u64,
            group_size.max(1) as u64,
        ) as usize;
        want.clamp(1, topo.num_chiplets())
    }
}

impl Policy for ArcasPolicy {
    fn name(&self) -> &'static str {
        "ARCAS"
    }

    fn initial_placement(&mut self, topo: &Topology, group_size: usize) -> Vec<usize> {
        let sockets_needed = crate::util::div_ceil(
            group_size as u64,
            topo.cores_per_socket() as u64,
        ) as usize;
        self.avail_chiplets = (sockets_needed * topo.numa_per_socket * topo.chiplets_per_numa)
            .min(topo.num_chiplets());
        let s = self.initial_spread(topo, group_size);
        self.controller = self.controller.clone().with_spread(s).with_warmup(4);
        self.controller.max_chiplets = self.avail_chiplets;
        let map = placement_map_bounded(topo, s, group_size, self.avail_chiplets);
        self.last_map = map.clone();
        map
    }

    fn on_timer(
        &mut self,
        topo: &Topology,
        now_ns: u64,
        sample: &WindowSample,
        group_size: usize,
    ) -> Option<Vec<usize>> {
        let s = self.controller.tick(now_ns, sample.rate)?;
        let map = placement_map_bounded(topo, s, group_size, self.avail_chiplets);
        // Migrating is only worth it when the *chiplet occupancy* changes
        // (more or fewer L3 slices in play). A spread step that merely
        // reshuffles ranks across the same chiplet histogram would throw
        // away warmed residency for nothing — skip it.
        let hist = |m: &[usize]| -> Vec<usize> {
            let mut h = vec![0usize; topo.num_chiplets()];
            for &c in m {
                h[topo.chiplet_of(c)] += 1;
            }
            h
        };
        if !self.last_map.is_empty() && hist(&map) == hist(&self.last_map) {
            return None;
        }
        self.last_map = map.clone();
        Some(map)
    }

    /// Algorithm 2 closed online: a `Bind` region whose window heat is
    /// dominated by chiplets of some *other* NUMA node follows its
    /// accessors there. Interleaved/replicated regions are left alone
    /// (they have no single home to strand), as are regions with too
    /// little heat to amortize the copy. Deterministic: heat arrives
    /// sorted by region id and ties break toward the lower NUMA node.
    fn plan_region_moves(
        &mut self,
        topo: &Topology,
        _now_ns: u64,
        heat: &[RegionHeat],
        _group_size: usize,
    ) -> Vec<RegionMove> {
        if !self.region_moves_enabled || topo.num_numa() < 2 {
            return Vec::new();
        }
        let mut moves = Vec::new();
        for h in heat {
            let Placement::Bind(home) = h.placement else {
                continue;
            };
            let total: f64 = h.per_chiplet.iter().sum();
            if total < Self::MIN_MOVE_HEAT {
                continue;
            }
            let (mut hot, mut hot_heat) = (0usize, f64::NEG_INFINITY);
            for numa in 0..topo.num_numa() {
                let s: f64 = topo
                    .chiplets_of_numa(numa)
                    .map(|ch| h.per_chiplet.get(ch).copied().unwrap_or(0.0))
                    .sum();
                if s > hot_heat {
                    (hot, hot_heat) = (numa, s);
                }
            }
            if hot != home && hot_heat > Self::HOT_NUMA_FRAC * total {
                moves.push(RegionMove {
                    region: h.region,
                    to_numa: hot,
                });
            }
        }
        moves
    }

    /// Algorithm 2 one level up: hot shards shed their hottest slots to
    /// the coldest shard, greedily, as long as the receiver stays below
    /// the hot threshold itself — so a single giant slot is never
    /// ping-ponged between shards, the tail of warm slots drains
    /// instead. Deterministic: slots are visited in descending-load
    /// order with ties broken toward the lower slot id, and the
    /// receiver ties break toward the lower shard id.
    fn plan_shard_moves(&mut self, _now_ns: u64, heat: &ShardHeat) -> Vec<ShardMove> {
        if heat.shards < 2 {
            return Vec::new();
        }
        let mut shard_load = vec![0.0; heat.shards];
        for (slot, &load) in heat.slot_load.iter().enumerate() {
            shard_load[heat.table[slot]] += load;
        }
        let mean = shard_load.iter().sum::<f64>() / heat.shards as f64;
        if mean <= 0.0 {
            return Vec::new();
        }
        let mut order: Vec<usize> = (0..heat.slot_load.len()).collect();
        order.sort_by(|&a, &b| {
            heat.slot_load[b]
                .partial_cmp(&heat.slot_load[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut moves = Vec::new();
        for slot in order {
            if moves.len() >= Self::MAX_SHARD_MOVES {
                break;
            }
            let load = heat.slot_load[slot];
            if load < Self::MIN_SLOT_HEAT {
                break; // descending order: everything after is colder
            }
            let from = heat.table[slot];
            if shard_load[from] <= Self::HOT_SHARD_FRAC * mean {
                continue;
            }
            let to = (0..heat.shards)
                .min_by(|&a, &b| {
                    shard_load[a]
                        .partial_cmp(&shard_load[b])
                        .unwrap_or(std::cmp::Ordering::Equal)
                        .then(a.cmp(&b))
                })
                .expect("shards >= 2");
            // Only move when the receiver stays cold after absorbing
            // the slot — otherwise the move just relocates the hotspot
            // (and would thrash back next window).
            if to == from || shard_load[to] + load > Self::HOT_SHARD_FRAC * mean {
                continue;
            }
            shard_load[from] -= load;
            shard_load[to] += load;
            moves.push(ShardMove {
                slot,
                to_shard: to,
            });
        }
        moves
    }

    fn spread_rate(&self) -> usize {
        self.controller.spread_rate
    }

    fn timer_ns(&self) -> Option<u64> {
        Some(self.controller.timer_ns)
    }
}

// =====================================================================
// RING baseline
// =====================================================================

/// RING [26]: NUMA-aware message-batching runtime. Placement is
/// NUMA-balanced but *chiplet-agnostic*: ranks are split evenly across
/// NUMA domains, then assigned to cores sequentially within each domain —
/// RING avoids remote-NUMA memory but does nothing about the partitioned
/// L3 (the effect Tab. 1 quantifies). Like the OS scheduler underneath
/// it, RING periodically rebalances tasks over cores with no notion of
/// chiplet boundaries ("unrestricted core/task replacement", §5.3) —
/// every rebalance walks warmed state across chiplets and sockets.
pub struct RingPolicy {
    base_map: Vec<usize>,
    rotation: usize,
    /// Rebalance cadence (the OS scheduler ticks regardless of what the
    /// runtime wants; ~200 us matches the scaled experiments' ratio of
    /// rebalances to run length).
    timer_ns: u64,
}

impl Default for RingPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl RingPolicy {
    pub fn new() -> Self {
        Self {
            base_map: Vec::new(),
            rotation: 0,
            timer_ns: 200_000,
        }
    }

    pub fn with_timer(mut self, timer_ns: u64) -> Self {
        self.timer_ns = timer_ns;
        self
    }
}

impl Policy for RingPolicy {
    fn name(&self) -> &'static str {
        "RING"
    }

    fn initial_placement(&mut self, topo: &Topology, group_size: usize) -> Vec<usize> {
        let numa = topo.num_numa();
        let per_numa = crate::util::div_ceil(group_size as u64, numa as u64) as usize;
        let map: Vec<usize> = (0..group_size)
            .map(|rank| {
                let node = rank / per_numa;
                let idx = rank % per_numa;
                let base = node * topo.cores_per_numa();
                base + (idx % topo.cores_per_numa())
            })
            .collect();
        self.base_map = map.clone();
        map
    }

    fn on_timer(
        &mut self,
        _topo: &Topology,
        _now_ns: u64,
        _sample: &WindowSample,
        group_size: usize,
    ) -> Option<Vec<usize>> {
        if self.base_map.len() != group_size || group_size < 2 {
            return None;
        }
        // Chiplet-agnostic rebalance: rotate ranks over the in-use cores.
        self.rotation += 1;
        let n = self.base_map.len();
        Some(
            (0..n)
                .map(|rank| self.base_map[(rank + self.rotation) % n])
                .collect(),
        )
    }

    fn steal_order(&self, topo: &Topology, thief: usize, active: &[usize]) -> Vec<usize> {
        numa_confined_steal_order(topo, thief, active)
    }

    fn timer_ns(&self) -> Option<u64> {
        Some(self.timer_ns)
    }
}

// =====================================================================
// Shoal baseline
// =====================================================================

/// Shoal [17]: strictly sequential task→core assignment (task 0 → core 0,
/// task 1 → core 1, ...). NUMA-aware memory via array replication, but at
/// 16 cores it confines compute to 2 of 8 chiplets (§5.3's pathology).
/// Within its core span, tasks are periodically rebalanced with no
/// chiplet awareness (§5.3: "unrestricted core/task replacement").
pub struct ShoalPolicy {
    span: usize,
    rotation: usize,
    timer_ns: u64,
}

impl Default for ShoalPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl ShoalPolicy {
    pub fn new() -> Self {
        Self {
            span: 0,
            rotation: 0,
            timer_ns: 200_000,
        }
    }

    pub fn with_timer(mut self, timer_ns: u64) -> Self {
        self.timer_ns = timer_ns;
        self
    }
}

impl Policy for ShoalPolicy {
    fn name(&self) -> &'static str {
        "Shoal"
    }

    fn initial_placement(&mut self, topo: &Topology, group_size: usize) -> Vec<usize> {
        self.span = group_size.min(topo.num_cores());
        (0..group_size).map(|r| r % topo.num_cores()).collect()
    }

    fn on_timer(
        &mut self,
        topo: &Topology,
        _now_ns: u64,
        _sample: &WindowSample,
        group_size: usize,
    ) -> Option<Vec<usize>> {
        if self.span < 2 {
            return None;
        }
        // Rebalance within the sequential span only when it crosses a
        // chiplet boundary (a single-chiplet span has nothing to lose).
        if self.span <= topo.cores_per_chiplet {
            return None;
        }
        self.rotation += 1;
        Some(
            (0..group_size)
                .map(|rank| (rank + self.rotation) % self.span)
                .collect(),
        )
    }

    fn steal_order(&self, topo: &Topology, thief: usize, active: &[usize]) -> Vec<usize> {
        numa_confined_steal_order(topo, thief, active)
    }

    fn timer_ns(&self) -> Option<u64> {
        Some(self.timer_ns)
    }
}

// =====================================================================
// Static LocalCache / DistributedCache (§2.3, Fig. 5, Fig. 13)
// =====================================================================

/// Confine tasks to the fewest chiplets (maximize locality, minimize
/// aggregate L3).
pub struct LocalCachePolicy;

impl Policy for LocalCachePolicy {
    fn name(&self) -> &'static str {
        "LocalCache"
    }

    fn initial_placement(&mut self, topo: &Topology, group_size: usize) -> Vec<usize> {
        placement_map(topo, 1, group_size)
    }

    fn spread_rate(&self) -> usize {
        1
    }
}

/// Spread tasks across the maximum number of chiplets (maximize aggregate
/// L3, pay inter-chiplet latency).
pub struct DistributedCachePolicy;

impl Policy for DistributedCachePolicy {
    fn name(&self) -> &'static str {
        "DistributedCache"
    }

    fn initial_placement(&mut self, topo: &Topology, group_size: usize) -> Vec<usize> {
        placement_map(topo, topo.num_chiplets().min(topo.cores_per_chiplet), group_size)
    }

    fn spread_rate(&self) -> usize {
        8
    }
}

// =====================================================================
// std::async baseline
// =====================================================================

/// OS-thread-per-task execution (the DimmWitted+std::async baseline of
/// Fig. 10/11): no affinity (round-robin), OS context-switch and
/// thread-spawn costs, free-for-all stealing (the OS load balancer).
/// `confined(n)` restricts threads to the first `n` cores (the taskset
/// the paper's per-core-count sweep implies).
#[derive(Default)]
pub struct OsAsyncPolicy {
    span: Option<usize>,
}

impl OsAsyncPolicy {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn confined(span: usize) -> Self {
        Self { span: Some(span) }
    }
}

impl Policy for OsAsyncPolicy {
    fn name(&self) -> &'static str {
        "std::async"
    }

    fn initial_placement(&mut self, topo: &Topology, group_size: usize) -> Vec<usize> {
        // The OS spreads runnable threads over the allowed cores with no
        // notion of chiplets; oversubscription wraps around.
        let span = self.span.unwrap_or(topo.num_cores()).clamp(1, topo.num_cores());
        (0..group_size).map(|r| r % span).collect()
    }

    fn steal_order(&self, topo: &Topology, thief: usize, active: &[usize]) -> Vec<usize> {
        // Models the kernel's CFS migrating threads anywhere.
        let mut order: Vec<usize> = active.iter().copied().filter(|&c| c != thief).collect();
        // Rotate by thief to avoid herd behaviour.
        if !order.is_empty() {
            let pivot = thief % order.len();
            order.rotate_left(pivot);
        }
        let _ = topo;
        order
    }

    fn switch_model(&self) -> SwitchModel {
        SwitchModel::OsThread
    }
}

// =====================================================================
// SLO-aware serving policy (p99-driven placement)
// =====================================================================

/// p99-driven placement for serving scenarios: watches the per-chiplet
/// queue-wait vs service-time windows a serve scenario publishes through
/// an [`SloSignal`] (wired by `Policy::connect_slo`) and adapts the
/// spread rate — queue wait dominating service means requests pile up
/// behind busy chiplets, so spread hot tenants' tasks across more
/// chiplets (more aggregate L3 + more claim bandwidth); queue wait far
/// below service means the spread buys nothing, so compact back for
/// locality. Without a connected signal it behaves like
/// [`LocalCachePolicy`] (spread 1, never migrates).
pub struct SloPolicy {
    signal: Option<Arc<SloSignal>>,
    spread: usize,
    max_spread: usize,
    timer_ns: u64,
    /// Chiplet histogram of the last emitted map (skip no-op reshuffles).
    last_hist: Vec<usize>,
}

impl SloPolicy {
    /// Spread doubles when mean queue wait exceeds `SPREAD_FACTOR` ×
    /// mean service time, halves when it drops below service/`4`.
    const SPREAD_FACTOR: f64 = 2.0;

    pub fn new(topo: &Topology) -> Self {
        Self {
            signal: None,
            spread: 1,
            max_spread: topo.num_chiplets().max(1),
            timer_ns: 100_000,
            last_hist: Vec::new(),
        }
    }

    pub fn with_timer(mut self, timer_ns: u64) -> Self {
        self.timer_ns = timer_ns;
        self
    }

    pub fn spread(&self) -> usize {
        self.spread
    }
}

impl Policy for SloPolicy {
    fn name(&self) -> &'static str {
        "SLO"
    }

    fn initial_placement(&mut self, topo: &Topology, group_size: usize) -> Vec<usize> {
        // Start compact (the LocalCache posture): the signal, not a
        // static guess, decides whether the workload earns more chiplets.
        self.spread = 1;
        let map = placement_map(topo, self.spread, group_size);
        self.last_hist = chiplet_hist(topo, &map);
        map
    }

    fn on_timer(
        &mut self,
        topo: &Topology,
        _now_ns: u64,
        _sample: &WindowSample,
        group_size: usize,
    ) -> Option<Vec<usize>> {
        let windows = self.signal.as_ref()?.drain();
        let served: u64 = windows.iter().map(|w| w.count).sum();
        if served == 0 {
            return None;
        }
        let queue: u64 = windows.iter().map(|w| w.queue_ns).sum();
        let service: u64 = windows.iter().map(|w| w.service_ns).sum();
        let mean_queue = queue as f64 / served as f64;
        let mean_service = (service as f64 / served as f64).max(1.0);
        let want = if mean_queue > Self::SPREAD_FACTOR * mean_service {
            (self.spread * 2).min(self.max_spread)
        } else if mean_queue * 4.0 < mean_service {
            (self.spread / 2).max(1)
        } else {
            self.spread
        };
        if want == self.spread {
            return None;
        }
        self.spread = want;
        let map = placement_map(topo, self.spread, group_size);
        // Migrate only when the chiplet occupancy actually changes.
        let hist = chiplet_hist(topo, &map);
        if hist == self.last_hist {
            return None;
        }
        self.last_hist = hist;
        Some(map)
    }

    fn spread_rate(&self) -> usize {
        self.spread
    }

    fn timer_ns(&self) -> Option<u64> {
        Some(self.timer_ns)
    }

    fn connect_slo(&mut self, signal: Arc<SloSignal>) {
        self.signal = Some(signal);
    }
}

fn chiplet_hist(topo: &Topology, map: &[usize]) -> Vec<usize> {
    let mut h = vec![0usize; topo.num_chiplets()];
    for &c in map {
        h[topo.chiplet_of(c)] += 1;
    }
    h
}

/// Construct a policy by name (CLI surface).
pub fn by_name(name: &str, topo: &Topology) -> Option<Box<dyn Policy>> {
    match name {
        // "adaptive" is the ISSUE-8 CLI spelling for the online
        // migration loop; both names build the same policy — the
        // backend decides whether its timer runs on virtual (sim) or
        // real (host) elapsed time.
        "arcas" | "adaptive" => Some(Box::new(ArcasPolicy::new(topo))),
        "ring" => Some(Box::new(RingPolicy::new())),
        "shoal" => Some(Box::new(ShoalPolicy::new())),
        "local" => Some(Box::new(LocalCachePolicy)),
        "distributed" => Some(Box::new(DistributedCachePolicy)),
        "os_async" => Some(Box::new(OsAsyncPolicy::new())),
        "slo" => Some(Box::new(SloPolicy::new(topo))),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::ClassCounts;

    fn topo() -> Topology {
        Topology::milan_2s()
    }

    fn chiplets_used(topo: &Topology, map: &[usize]) -> usize {
        map.iter()
            .map(|&c| topo.chiplet_of(c))
            .collect::<std::collections::BTreeSet<_>>()
            .len()
    }

    #[test]
    fn shoal_confines_16_tasks_to_2_chiplets() {
        let t = topo();
        let map = ShoalPolicy::new().initial_placement(&t, 16);
        assert_eq!(chiplets_used(&t, &map), 2, "the §5.3 pathology");
    }

    #[test]
    fn arcas_spreads_16_tasks_across_8_chiplets() {
        let t = Topology::milan_1s();
        let mut p = ArcasPolicy::new(&t);
        let map = p.initial_placement(&t, 16);
        assert_eq!(chiplets_used(&t, &map), 8, "§5.3: ARCAS uses all chiplets");
    }

    #[test]
    fn local_vs_distributed_chiplet_counts() {
        let t = Topology::milan_1s();
        let local = LocalCachePolicy.initial_placement(&t, 8);
        let dist = DistributedCachePolicy.initial_placement(&t, 8);
        assert_eq!(chiplets_used(&t, &local), 1);
        assert_eq!(chiplets_used(&t, &dist), 8);
    }

    #[test]
    fn ring_balances_across_numa_ignoring_chiplets() {
        let t = topo();
        let map = RingPolicy::new().initial_placement(&t, 64);
        let numa0 = map.iter().filter(|&&c| t.numa_of_core(c) == 0).count();
        let numa1 = map.iter().filter(|&&c| t.numa_of_core(c) == 1).count();
        assert_eq!(numa0, 32);
        assert_eq!(numa1, 32);
        // Within a NUMA node, cores are sequential => chiplets fill in
        // order (chiplet-agnostic compaction).
        assert_eq!(chiplets_used(&t, &map[..32]), 4);
    }

    #[test]
    fn steal_order_prefers_chiplet_then_numa() {
        let t = topo();
        let active: Vec<usize> = vec![1, 9, 70, 3];
        let order = chiplet_first_steal_order(&t, 0, &active);
        assert_eq!(order, vec![1, 3, 9, 70]);
    }

    #[test]
    fn numa_confined_steal_never_crosses_socket() {
        let t = topo();
        let active: Vec<usize> = vec![1, 9, 70, 100];
        let order = numa_confined_steal_order(&t, 0, &active);
        assert_eq!(order, vec![1, 9]);
    }

    #[test]
    fn arcas_timer_adapts_placement() {
        let t = Topology::milan_1s();
        let mut p = ArcasPolicy::new(&t).with_timer(1_000_000);
        let _ = p.initial_placement(&t, 8); // spread = 8 initially
        let sample_low = WindowSample {
            at_ns: 1_000_000,
            fill_events: 0.0,
            rate: 0.0,
            counts: ClassCounts::default(),
            live_tasks: 8,
        };
        // Low remote-traffic: compacts by one step. Spread 8→7 does not
        // change the chiplet histogram for 8 tasks (block stays 1), so no
        // migration map is emitted yet.
        // The warmup grace suppresses immediate compaction; spread holds.
        let new_map = p.on_timer(&t, 1_000_000, &sample_low, 8);
        assert!(new_map.is_none());
        assert_eq!(p.spread_rate(), 8, "warmup grace holds the spread");
        // After the grace period, sustained low traffic compacts; the
        // first *migration* comes when the chiplet histogram changes
        // (spread 4: 2 ranks per chiplet).
        let mut emitted = None;
        for k in 2..24u64 {
            let s = WindowSample {
                at_ns: k * 1_000_000,
                ..sample_low
            };
            if let Some(m) = p.on_timer(&t, k * 1_000_000, &s, 8) {
                emitted = Some((p.spread_rate(), m));
                break;
            }
        }
        let (spread, map) = emitted.expect("compaction must eventually migrate");
        assert_eq!(spread, 4);
        let chiplets: std::collections::BTreeSet<_> =
            map.iter().map(|&c| t.chiplet_of(c)).collect();
        assert_eq!(chiplets.len(), 4);
    }

    #[test]
    fn arcas_plans_region_moves_toward_hot_numa() {
        let t = topo(); // milan_2s: 2 NUMA nodes, 8 chiplets each
        let mut p = ArcasPolicy::new(&t);
        let heat_at = |ch: usize, ops: f64| {
            let mut v = vec![0.0; t.num_chiplets()];
            v[ch] = ops;
            v
        };
        let mk = |placement: Placement, per_chiplet: Vec<f64>| RegionHeat {
            region: RegionId(1),
            placement,
            size: 1 << 20,
            per_chiplet,
        };
        // Bound to numa 1, accessed from chiplet 0 (numa 0): follows.
        let stranded = mk(Placement::Bind(1), heat_at(0, 10_000.0));
        assert_eq!(
            p.plan_region_moves(&t, 0, &[stranded.clone()], 8),
            vec![RegionMove {
                region: RegionId(1),
                to_numa: 0
            }]
        );
        // Already home: stays.
        let home = mk(Placement::Bind(0), heat_at(0, 10_000.0));
        assert!(p.plan_region_moves(&t, 0, &[home], 8).is_empty());
        // Too cold to amortize the copy: stays.
        let cold = mk(Placement::Bind(1), heat_at(0, 10.0));
        assert!(p.plan_region_moves(&t, 0, &[cold], 8).is_empty());
        // Interleaved regions have no single home to strand: stays.
        let spread = mk(Placement::Interleave, heat_at(0, 10_000.0));
        assert!(p.plan_region_moves(&t, 0, &[spread], 8).is_empty());
        // Heat split exactly evenly clears no strict majority: stays.
        let mut even = mk(Placement::Bind(1), heat_at(0, 10_000.0));
        even.per_chiplet[8] = 10_000.0;
        assert!(p.plan_region_moves(&t, 0, &[even], 8).is_empty());
        // The task-move-only baseline never moves data.
        let mut off = ArcasPolicy::new(&t).with_region_moves(false);
        assert!(off.plan_region_moves(&t, 0, &[stranded], 8).is_empty());
    }

    #[test]
    fn arcas_plans_shard_moves_off_hot_shards() {
        let t = topo();
        let mut p = ArcasPolicy::new(&t);
        // 8 slots over 2 shards, interleaved (slot % 2). Shard 0 holds a
        // hot head on slot 0 plus warm slots; shard 1 is cold.
        let table: Vec<usize> = (0..8).map(|s| s % 2).collect();
        let heat = ShardHeat {
            slot_load: vec![400.0, 50.0, 100.0, 50.0, 100.0, 50.0, 100.0, 50.0],
            table: table.clone(),
            shards: 2,
        };
        // shard 0 = 700, shard 1 = 200, mean = 450: shard 0 is hot.
        let moves = p.plan_shard_moves(0, &heat);
        assert!(!moves.is_empty(), "a hot shard must shed slots");
        for m in &moves {
            assert_eq!(table[m.slot], 0, "only the hot shard donates");
            assert_eq!(m.to_shard, 1, "slots land on the cold shard");
        }
        // The giant slot (400) is never moved — absorbing it would push
        // the receiver past the hot threshold (200 + 400 > 1.15 x 450)
        // and the hotspot would just relocate. The warm 100-slots drain
        // instead, strictly improving balance at each step.
        assert!(
            moves.iter().all(|m| m.slot != 0),
            "the giant slot must stay put: {moves:?}"
        );
        let mut from_load = 700.0;
        let mut to_load = 200.0;
        for m in &moves {
            let l = heat.slot_load[m.slot];
            assert!(to_load + l < from_load, "move must strictly improve");
            from_load -= l;
            to_load += l;
        }

        // A balanced table plans nothing.
        let even = ShardHeat {
            slot_load: vec![100.0; 8],
            table: table.clone(),
            shards: 2,
        };
        assert!(p.plan_shard_moves(0, &even).is_empty());

        // Slots below the heat floor are never shipped.
        let cold = ShardHeat {
            slot_load: vec![10.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0],
            table,
            shards: 2,
        };
        assert!(p.plan_shard_moves(0, &cold).is_empty());

        // A single shard has nowhere to move to.
        let solo = ShardHeat {
            slot_load: vec![1000.0; 4],
            table: vec![0; 4],
            shards: 1,
        };
        assert!(p.plan_shard_moves(0, &solo).is_empty());

        // Every other policy keeps the default no-op.
        let mut ring = RingPolicy::new();
        let hot = ShardHeat {
            slot_load: vec![1000.0, 0.0],
            table: vec![0, 1],
            shards: 2,
        };
        assert!(ring.plan_shard_moves(0, &hot).is_empty());
    }

    #[test]
    fn os_async_allows_oversubscription() {
        let t = topo();
        let map = OsAsyncPolicy::new().initial_placement(&t, 641); // Fig. 11's 641 threads
        assert_eq!(map.len(), 641);
        assert!(map.iter().all(|&c| c < t.num_cores()));
    }

    #[test]
    fn by_name_resolves_all() {
        let t = topo();
        for n in [
            "arcas",
            "adaptive",
            "ring",
            "shoal",
            "local",
            "distributed",
            "os_async",
            "slo",
        ] {
            assert!(by_name(n, &t).is_some(), "{n}");
        }
        assert!(by_name("nope", &t).is_none());
    }

    #[test]
    fn slo_policy_spreads_on_queue_pressure_and_compacts_when_idle() {
        let t = Topology::milan_1s();
        let mut p = SloPolicy::new(&t);
        let map = p.initial_placement(&t, 8);
        assert_eq!(chiplets_used(&t, &map), 1, "starts compact");
        let sample = WindowSample {
            at_ns: 100_000,
            fill_events: 0.0,
            rate: 0.0,
            counts: ClassCounts::default(),
            live_tasks: 8,
        };
        // No signal connected: never migrates.
        assert!(p.on_timer(&t, 100_000, &sample, 8).is_none());

        let sig = SloSignal::new(t.num_chiplets());
        p.connect_slo(sig.clone());
        // Queue wait dominating service -> spread doubles.
        for _ in 0..100 {
            sig.record(0, 10_000, 1_000);
        }
        let m = p.on_timer(&t, 200_000, &sample, 8).expect("must spread");
        assert_eq!(p.spread_rate(), 2);
        assert_eq!(chiplets_used(&t, &m), 2);
        // Sustained pressure keeps doubling toward every chiplet.
        for _ in 0..3 {
            for _ in 0..100 {
                sig.record(1, 10_000, 1_000);
            }
            p.on_timer(&t, 300_000, &sample, 8);
        }
        assert_eq!(p.spread_rate(), t.num_chiplets());
        // Queue wait far below service -> compacts back one step.
        for _ in 0..100 {
            sig.record(0, 10, 1_000);
        }
        p.on_timer(&t, 400_000, &sample, 8).expect("must compact");
        assert_eq!(p.spread_rate(), t.num_chiplets() / 2);
        // An empty window is a no-op, not a divide-by-zero.
        assert!(p.on_timer(&t, 500_000, &sample, 8).is_none());
    }

    #[test]
    fn switch_models() {
        assert_eq!(OsAsyncPolicy::new().switch_model(), SwitchModel::OsThread);
        assert_eq!(RingPolicy::new().switch_model(), SwitchModel::Coroutine);
    }
}
