//! Lightweight coroutine task model (§4.4 "fine-grained task parallelism").
//!
//! ARCAS tasks combine user-level-thread features (own state, per-task
//! scheduling, migration across chiplets) with coroutine behaviour:
//! suspension at developer-defined points. Rust has no stable stackful
//! coroutines, so a task is an explicit state machine implementing
//! [`Coroutine::step`]; returning [`Step::Yield`] is the `yield` point at
//! which the integrated profiler runs and the scheduler may migrate the
//! task — exactly the suspend-at-defined-points semantics of the paper.
//!
//! A context switch is one virtual dispatch plus queue traffic, which is
//! what gives ARCAS its advantage over the OS-thread baseline (Fig. 10/11).

use crate::cachesim::{Access, Outcome};
use crate::mem::RegionId;
use crate::sim::{Machine, MachineView, ProbeCache, RegionBookCache};

pub type TaskId = usize;

/// What a coroutine step tells the scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Step {
    /// Suspend; reschedule me (possibly elsewhere).
    Yield,
    /// Suspend until every task in my group reaches the same barrier.
    Barrier,
    /// Finished.
    Done,
}

/// A suspendable unit of work.
pub trait Coroutine: Send {
    fn step(&mut self, ctx: &mut TaskCtx<'_>) -> Step;
}

/// Execution context handed to a coroutine step: the gateway through which
/// tasks touch the simulated machine (and the PJRT runtime, via
/// workloads that capture an executable).
///
/// Since the sharded-accounting refactor the machine reference is
/// *shared*: all charging goes through [`MachineView`] onto per-chiplet
/// shards, so steps on different chiplets run (and charge) concurrently
/// on the host backend instead of serializing behind one `&mut Machine`.
pub struct TaskCtx<'a> {
    pub machine: &'a Machine,
    /// Core the task is currently running on.
    pub core: usize,
    pub task_id: TaskId,
    /// Rank within the spawn group (Algorithm 2's `rank`).
    pub rank: usize,
    /// Spawn-group size (`THREAD_SIZE`).
    pub group_size: usize,
    /// Virtual time at step entry.
    pub now_ns: u64,
    /// Accumulated per-step outcome (for task stats).
    pub step_outcome: Outcome,
    /// Per-step cache of remote residency probes: accesses in this step
    /// probe each `(region, remote chiplet)` pair once instead of once
    /// per access (bit-identical on the Sim backend — writes evict; see
    /// [`ProbeCache`]). Fresh per step on the Sim backend; the host
    /// backend carries it across the consecutive steps of a
    /// run-until-yield batch (the rank stays on one core for the whole
    /// batch, so the carry is exact — `shard_equivalence` pins this).
    pub probe_cache: ProbeCache,
    /// Generation-validated snapshot of the region book: every access
    /// resolves region size + DRAM home from this with one atomic load
    /// instead of the book's read lock — the zero-lock steady-state path.
    /// Carried alongside the [`ProbeCache`] (fresh per step on Sim,
    /// across a batch on the host backend); a generation change re-reads
    /// the snapshot and drops the probe cache.
    pub book: RegionBookCache,
    /// Current core of every rank in the spawn group, kept live by the
    /// executor (atomics because adaptive migration re-homes ranks while
    /// other ranks are mid-step on the host backend). `None` when the
    /// executor does not track peers (e.g. hand-built test contexts) —
    /// then [`TaskCtx::send_to_rank`] is a no-op.
    pub peer_cores: Option<&'a [std::sync::atomic::AtomicUsize]>,
}

impl<'a> TaskCtx<'a> {
    /// The charging handle this step works through: the task's current
    /// core bound to its chiplet shard.
    pub fn view(&self) -> MachineView<'a> {
        self.machine.view(self.core)
    }

    /// Model a memory access; charges virtual time on the current core.
    /// Routed through the step's [`ProbeCache`] (repeated accesses to a
    /// region within one step probe remote shards only once) and the
    /// lock-free region-book snapshot ([`Machine::access_task`]).
    pub fn access(&mut self, acc: Access) -> Outcome {
        let out =
            self.machine
                .access_task(self.core, acc, &mut self.probe_cache, &mut self.book);
        self.step_outcome.local_hits += out.local_hits;
        self.step_outcome.near_hits += out.near_hits;
        self.step_outcome.far_hits += out.far_hits;
        self.step_outcome.dram_lines += out.dram_lines;
        self.step_outcome.latency_ns += out.latency_ns;
        out
    }

    pub fn seq_read(&mut self, region: RegionId, bytes: u64) -> Outcome {
        self.access(Access::seq_read(region, bytes))
    }

    pub fn seq_write(&mut self, region: RegionId, bytes: u64) -> Outcome {
        self.access(Access::seq_write(region, bytes))
    }

    pub fn rand_read(&mut self, region: RegionId, ops: u64, span: u64) -> Outcome {
        self.access(Access::rand_read(region, ops, span))
    }

    pub fn rand_write(&mut self, region: RegionId, ops: u64, span: u64) -> Outcome {
        self.access(Access::rand_write(region, ops, span))
    }

    /// Pure compute for `ns` virtual nanoseconds.
    pub fn compute_ns(&mut self, ns: u64) {
        self.view().compute(ns);
    }

    /// Compute cost modeled from FLOPs (Milan core ≈ 32 SP FLOP/cycle at
    /// ~2.45 GHz sustained ⇒ ~78 FLOP/ns vectorized; we use a conservative
    /// 48 FLOP/ns to account for real-world efficiency).
    pub fn compute_flops(&mut self, flops: u64) {
        const FLOPS_PER_NS: f64 = 48.0;
        let ns = (flops as f64 / FLOPS_PER_NS).ceil() as u64;
        self.view().compute(ns.max(1));
    }

    /// Point-to-point message to the group peer at `rank` (charges this
    /// core as the sender; `Machine::message` latency follows the core
    /// distance, so intra-chiplet neighbors are ~8× cheaper than
    /// cross-chiplet ones). The destination core is read from the
    /// executor's live placement map, so migrations re-route messages
    /// mid-run. Returns the charged latency (0 when the executor tracks
    /// no peers or `rank` is out of range).
    pub fn send_to_rank(&mut self, rank: usize, bytes: u64) -> u64 {
        let Some(peers) = self.peer_cores else {
            return 0;
        };
        let Some(dest) = peers.get(rank) else {
            return 0;
        };
        let dest = dest.load(std::sync::atomic::Ordering::Relaxed);
        self.view().message_to(dest, bytes)
    }

    /// Which chiplet the task currently runs on.
    pub fn chiplet(&self) -> usize {
        self.machine.topo.chiplet_of(self.core)
    }

    pub fn numa(&self) -> usize {
        self.machine.topo.numa_of_core(self.core)
    }
}

/// Lifecycle state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    Ready,
    Running,
    /// Parked at a barrier.
    Blocked,
    Finished,
}

/// Per-task statistics (fed to the profiler at yield points).
#[derive(Clone, Copy, Debug, Default)]
pub struct TaskStats {
    pub steps: u64,
    pub yields: u64,
    pub barriers: u64,
    pub migrations: u64,
    pub ns_run: u64,
}

/// A schedulable task: coroutine + placement + stats.
pub struct Task {
    pub id: TaskId,
    pub rank: usize,
    pub group_size: usize,
    pub state: TaskState,
    /// Current core assignment.
    pub core: usize,
    pub stats: TaskStats,
    pub coro: Box<dyn Coroutine>,
}

impl Task {
    pub fn new(id: TaskId, rank: usize, group_size: usize, coro: Box<dyn Coroutine>) -> Self {
        Self {
            id,
            rank,
            group_size,
            state: TaskState::Ready,
            core: 0,
            stats: TaskStats::default(),
            coro,
        }
    }
}

// --- common coroutine shapes ------------------------------------------

/// Runs a closure once and finishes.
pub struct FnTask<F: FnMut(&mut TaskCtx<'_>) + Send>(pub F);

impl<F: FnMut(&mut TaskCtx<'_>) + Send> Coroutine for FnTask<F> {
    fn step(&mut self, ctx: &mut TaskCtx<'_>) -> Step {
        (self.0)(ctx);
        Step::Done
    }
}

/// Runs a closure `iters` times, yielding between iterations — the
/// bread-and-butter shape for chunked workloads (each chunk is a
/// scheduling + profiling point).
pub struct IterTask<F: FnMut(&mut TaskCtx<'_>, u64) + Send> {
    iters: u64,
    next: u64,
    f: F,
}

impl<F: FnMut(&mut TaskCtx<'_>, u64) + Send> IterTask<F> {
    pub fn new(iters: u64, f: F) -> Self {
        Self { iters, next: 0, f }
    }
}

impl<F: FnMut(&mut TaskCtx<'_>, u64) + Send> Coroutine for IterTask<F> {
    fn step(&mut self, ctx: &mut TaskCtx<'_>) -> Step {
        if self.next >= self.iters {
            return Step::Done;
        }
        (self.f)(ctx, self.next);
        self.next += 1;
        if self.next >= self.iters {
            Step::Done
        } else {
            Step::Yield
        }
    }
}

/// Runs `iters` iterations with a barrier after each one (bulk-synchronous
/// algorithms: PageRank sweeps, SGD epochs, BFS levels).
pub struct BspTask<F: FnMut(&mut TaskCtx<'_>, u64) + Send> {
    iters: u64,
    next: u64,
    f: F,
}

impl<F: FnMut(&mut TaskCtx<'_>, u64) + Send> BspTask<F> {
    pub fn new(iters: u64, f: F) -> Self {
        Self { iters, next: 0, f }
    }
}

impl<F: FnMut(&mut TaskCtx<'_>, u64) + Send> Coroutine for BspTask<F> {
    fn step(&mut self, ctx: &mut TaskCtx<'_>) -> Step {
        if self.next >= self.iters {
            return Step::Done;
        }
        (self.f)(ctx, self.next);
        self.next += 1;
        if self.next >= self.iters {
            Step::Done
        } else {
            Step::Barrier
        }
    }
}

/// A generic state-machine driver: the closure returns the next [`Step`]
/// explicitly (full control for irregular coroutines).
pub struct StateTask<F: FnMut(&mut TaskCtx<'_>, u64) -> Step + Send> {
    step_no: u64,
    f: F,
}

impl<F: FnMut(&mut TaskCtx<'_>, u64) -> Step + Send> StateTask<F> {
    pub fn new(f: F) -> Self {
        Self { step_no: 0, f }
    }
}

impl<F: FnMut(&mut TaskCtx<'_>, u64) -> Step + Send> Coroutine for StateTask<F> {
    fn step(&mut self, ctx: &mut TaskCtx<'_>) -> Step {
        let s = (self.f)(ctx, self.step_no);
        self.step_no += 1;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Placement;
    use crate::topology::Topology;

    fn ctx_on(machine: &Machine, core: usize) -> TaskCtx<'_> {
        TaskCtx {
            machine,
            core,
            task_id: 0,
            rank: 0,
            group_size: 1,
            now_ns: 0,
            step_outcome: Outcome::default(),
            probe_cache: Default::default(),
            book: Default::default(),
            peer_cores: None,
        }
    }

    #[test]
    fn fn_task_runs_once() {
        let m = Machine::new(Topology::milan_1s());
        let mut hits = 0u32;
        let mut t = FnTask(|ctx: &mut TaskCtx<'_>| {
            ctx.compute_ns(10);
            hits += 1;
        });
        let mut c = ctx_on(&m, 0);
        assert_eq!(t.step(&mut c), Step::Done);
        drop(c);
        assert_eq!(hits, 1);
        assert_eq!(m.now(0), 10);
    }

    #[test]
    fn iter_task_yields_then_finishes() {
        let m = Machine::new(Topology::milan_1s());
        let mut t = IterTask::new(3, |ctx, _i| ctx.compute_ns(5));
        let mut c = ctx_on(&m, 0);
        assert_eq!(t.step(&mut c), Step::Yield);
        assert_eq!(t.step(&mut c), Step::Yield);
        assert_eq!(t.step(&mut c), Step::Done);
        drop(c);
        assert_eq!(m.now(0), 15);
    }

    #[test]
    fn bsp_task_barriers_between_iterations() {
        let m = Machine::new(Topology::milan_1s());
        let mut t = BspTask::new(2, |ctx, _| ctx.compute_ns(1));
        let mut c = ctx_on(&m, 0);
        assert_eq!(t.step(&mut c), Step::Barrier);
        assert_eq!(t.step(&mut c), Step::Done);
    }

    #[test]
    fn zero_iter_tasks_finish_immediately() {
        let m = Machine::new(Topology::milan_1s());
        let mut t = IterTask::new(0, |_, _| {});
        let mut b = BspTask::new(0, |_, _| {});
        let mut c = ctx_on(&m, 0);
        assert_eq!(t.step(&mut c), Step::Done);
        assert_eq!(b.step(&mut c), Step::Done);
    }

    #[test]
    fn ctx_access_charges_and_records() {
        let m = Machine::new(Topology::milan_1s());
        let r = m.alloc("d", 1 << 20, Placement::Bind(0));
        let mut c = ctx_on(&m, 0);
        let out = c.seq_read(r, 1 << 20);
        assert!(out.total_ops() > 0.0);
        assert!(c.step_outcome.latency_ns > 0.0);
        drop(c);
        assert!(m.now(0) > 0);
    }

    #[test]
    fn compute_flops_scales() {
        let m = Machine::new(Topology::milan_1s());
        let mut c = ctx_on(&m, 0);
        c.compute_flops(48_000);
        drop(c);
        assert_eq!(m.now(0), 1_000);
    }

    #[test]
    fn chiplet_and_numa_helpers() {
        let m = Machine::new(Topology::milan_2s());
        let c = ctx_on(&m, 70);
        assert_eq!(c.chiplet(), 8);
        assert_eq!(c.numa(), 1);
    }

    #[test]
    fn send_to_rank_follows_the_live_placement() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let m = Machine::new(Topology::milan_1s());
        // Rank 1 starts on core 1 (same chiplet as the sender on core 0).
        let peers: Vec<AtomicUsize> = vec![AtomicUsize::new(0), AtomicUsize::new(1)];
        let mut c = ctx_on(&m, 0);
        c.peer_cores = Some(&peers);
        let intra = c.send_to_rank(1, 64);
        // "Migrate" rank 1 to another chiplet: the same send gets dearer.
        peers[1].store(9, Ordering::Relaxed);
        let inter = c.send_to_rank(1, 64);
        assert!(
            inter > intra,
            "cross-chiplet send ({inter} ns) must cost more than intra ({intra} ns)"
        );
        // Out-of-range rank and untracked peers are charged-nothing no-ops.
        let t = m.now(0);
        assert_eq!(c.send_to_rank(99, 64), 0);
        c.peer_cores = None;
        assert_eq!(c.send_to_rank(1, 64), 0);
        drop(c);
        assert_eq!(m.now(0), t);
    }
}
