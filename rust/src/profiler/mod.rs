//! Low-overhead performance profiler (§4.5).
//!
//! The profiler is the component that turns raw cache-model counters into
//! the signals the adaptive controller consumes:
//!
//! - **windowed cache-fill event rate** — `getEventCounter()` /
//!   `resetEventCounter()` from Algorithm 1,
//! - **concurrency timeline** — live thread/task samples (Fig. 11),
//! - **per-window hierarchy mix** — local / near / far / DRAM shares used
//!   by the approach selection (location-centric vs cache-size-centric).
//!
//! In the real system this is libpfm reads at coroutine yield points; here
//! the counters come from the cache model, sampled at the same points.

use std::collections::BTreeMap;

use crate::cachesim::ClassCounts;
use crate::mem::RegionId;

/// One profiling window snapshot.
#[derive(Clone, Copy, Debug, Default)]
pub struct WindowSample {
    pub at_ns: u64,
    /// Remote-chiplet fill events in this window.
    pub fill_events: f64,
    /// Event rate normalized to events per `timer_ns`.
    pub rate: f64,
    pub counts: ClassCounts,
    /// Live tasks/threads at sample time.
    pub live_tasks: usize,
}

/// Windowed profiler state.
#[derive(Clone, Debug, Default)]
pub struct Profiler {
    last_total: ClassCounts,
    last_ns: u64,
    /// Per-region heat baseline (cumulative per-chiplet ops at the last
    /// window boundary) for [`Profiler::heat_window`].
    last_heat: BTreeMap<RegionId, Vec<f64>>,
    pub samples: Vec<WindowSample>,
    /// Concurrency timeline (Fig. 11): (t_ns, live threads).
    pub concurrency: Vec<(u64, usize)>,
}

impl Profiler {
    pub fn new() -> Self {
        Self::default()
    }

    /// `getEventCounter()` + window bookkeeping: returns the sample for
    /// the window ending at `now_ns`, computing the fill-event *rate*
    /// normalized to `timer_ns` (Algorithm 1 line 6:
    /// `rate ← counter × SCHEDULER_TIMER / elapsed`).
    ///
    /// `total` is the machine-wide class-count snapshot at `now_ns`
    /// (`Machine::class_totals()` — the sharded machine merges its
    /// per-chiplet counter slices on demand instead of keeping one
    /// global counter object).
    pub fn sample_window(
        &mut self,
        now_ns: u64,
        total: ClassCounts,
        timer_ns: u64,
        live_tasks: usize,
    ) -> WindowSample {
        let fills = (total.fill_events() - self.last_total.fill_events()).max(0.0);
        let elapsed = now_ns.saturating_sub(self.last_ns).max(1);
        let rate = fills * timer_ns as f64 / elapsed as f64;
        let mut delta = total;
        // Window delta per class, clamped at zero like `fills` above: a
        // `Machine::reset()` between repetitions rewinds the absolute
        // counters below the baseline, and a negative class count would
        // poison `recent_remote_share`.
        delta.local = (delta.local - self.last_total.local).max(0.0);
        delta.near = (delta.near - self.last_total.near).max(0.0);
        delta.far = (delta.far - self.last_total.far).max(0.0);
        delta.dram = (delta.dram - self.last_total.dram).max(0.0);
        let sample = WindowSample {
            at_ns: now_ns,
            fill_events: fills,
            rate,
            counts: delta,
            live_tasks,
        };
        self.samples.push(sample);
        // `resetEventCounter()`: we keep absolute counters and move the
        // baseline instead (non-destructive for other readers).
        self.last_total = total;
        self.last_ns = now_ns;
        sample
    }

    /// Re-anchor the window baseline to a (possibly warm) machine
    /// without discarding collected samples. Executors call this at run
    /// start: with `--repeat`, rep N starts on rep N-1's counters and
    /// clocks, and a zero baseline would attribute all of them to the
    /// first window.
    pub fn rebaseline(&mut self, now_ns: u64, total: ClassCounts) {
        self.last_total = total;
        self.last_ns = now_ns;
    }

    /// Windowed per-region, per-chiplet heat: the delta of
    /// `Machine::region_heat`'s cumulative ops since the previous call,
    /// clamped at zero (a region move or reset drops the raw counters).
    /// Regions with no activity this window are omitted. Moves the
    /// baseline, like `sample_window` does for class counts.
    pub fn heat_window(&mut self, snapshot: &[(RegionId, Vec<f64>)]) -> Vec<(RegionId, Vec<f64>)> {
        let mut out = Vec::new();
        for (region, per_chiplet) in snapshot {
            let base = self.last_heat.get(region);
            let delta: Vec<f64> = per_chiplet
                .iter()
                .enumerate()
                .map(|(ch, &v)| (v - base.and_then(|b| b.get(ch)).copied().unwrap_or(0.0)).max(0.0))
                .collect();
            if delta.iter().any(|&d| d > 0.0) {
                out.push((*region, delta));
            }
        }
        self.last_heat = snapshot.iter().cloned().collect();
        out
    }

    /// Re-anchor the heat baseline to a (possibly warm) machine — the
    /// region-heat analogue of [`Profiler::rebaseline`], called at the
    /// same run-start points.
    pub fn seed_heat(&mut self, snapshot: &[(RegionId, Vec<f64>)]) {
        self.last_heat = snapshot.iter().cloned().collect();
    }

    /// Record a concurrency sample (Fig. 11 timeline).
    pub fn sample_concurrency(&mut self, now_ns: u64, live: usize) {
        self.concurrency.push((now_ns, live));
    }

    /// Average live threads over the run (the paper quotes 16.23 vs 31.16).
    pub fn avg_concurrency(&self) -> f64 {
        if self.concurrency.is_empty() {
            return 0.0;
        }
        self.concurrency.iter().map(|(_, l)| *l as f64).sum::<f64>()
            / self.concurrency.len() as f64
    }

    /// Fraction of window accesses served outside the local chiplet,
    /// across the most recent `k` windows.
    pub fn recent_remote_share(&self, k: usize) -> f64 {
        let tail = &self.samples[self.samples.len().saturating_sub(k)..];
        let (mut remote, mut total) = (0.0, 0.0);
        for s in tail {
            remote += s.counts.fill_events() + s.counts.dram;
            total += s.counts.total_ops();
        }
        if total <= 0.0 {
            0.0
        } else {
            remote / total
        }
    }

    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn totals_with(local: f64, near: f64, far: f64, dram: f64) -> ClassCounts {
        ClassCounts {
            local,
            near,
            far,
            dram,
        }
    }

    #[test]
    fn window_rate_normalizes_to_timer() {
        let mut p = Profiler::new();
        let c = totals_with(0.0, 600.0, 0.0, 0.0);
        // 600 fills over 20 ms with a 10 ms timer => rate 300.
        let s = p.sample_window(20_000_000, c, 10_000_000, 8);
        assert!((s.rate - 300.0).abs() < 1e-9, "rate={}", s.rate);
        assert_eq!(s.fill_events, 600.0);
    }

    #[test]
    fn second_window_sees_only_delta() {
        let mut p = Profiler::new();
        let c1 = totals_with(10.0, 100.0, 0.0, 5.0);
        p.sample_window(10_000_000, c1, 10_000_000, 4);
        let c2 = totals_with(20.0, 150.0, 0.0, 9.0);
        let s = p.sample_window(20_000_000, c2, 10_000_000, 4);
        assert!((s.fill_events - 50.0).abs() < 1e-9);
        assert!((s.counts.local - 10.0).abs() < 1e-9);
        assert!((s.counts.dram - 4.0).abs() < 1e-9);
    }

    #[test]
    fn per_class_deltas_clamp_after_a_counter_rewind() {
        let mut p = Profiler::new();
        p.sample_window(10_000, totals_with(100.0, 50.0, 10.0, 40.0), 10_000, 4);
        // Counters rewound (e.g. `Machine::reset()` between reps): the
        // next window must clamp at zero instead of going negative.
        let s = p.sample_window(20_000, totals_with(5.0, 2.0, 0.0, 1.0), 10_000, 4);
        assert!(s.counts.local >= 0.0, "local={}", s.counts.local);
        assert!(s.counts.near >= 0.0, "near={}", s.counts.near);
        assert!(s.counts.far >= 0.0, "far={}", s.counts.far);
        assert!(s.counts.dram >= 0.0, "dram={}", s.counts.dram);
        assert!(s.fill_events >= 0.0);
        let share = p.recent_remote_share(2);
        assert!((0.0..=1.0).contains(&share), "share={share}");
    }

    #[test]
    fn rebaseline_absorbs_warm_counters() {
        let mut p = Profiler::new();
        p.rebaseline(5_000, totals_with(1000.0, 1000.0, 0.0, 1000.0));
        let s = p.sample_window(15_000, totals_with(1010.0, 1005.0, 0.0, 1002.0), 10_000, 2);
        assert!((s.counts.local - 10.0).abs() < 1e-9, "local={}", s.counts.local);
        assert!((s.fill_events - 5.0).abs() < 1e-9, "fills={}", s.fill_events);
        assert!((s.counts.dram - 2.0).abs() < 1e-9, "dram={}", s.counts.dram);
    }

    #[test]
    fn concurrency_average() {
        let mut p = Profiler::new();
        p.sample_concurrency(0, 30);
        p.sample_concurrency(10, 32);
        p.sample_concurrency(20, 34);
        assert!((p.avg_concurrency() - 32.0).abs() < 1e-9);
    }

    #[test]
    fn remote_share_bounded() {
        let mut p = Profiler::new();
        let c = totals_with(50.0, 25.0, 0.0, 25.0);
        p.sample_window(1000, c, 1000, 1);
        let share = p.recent_remote_share(4);
        assert!((share - 0.5).abs() < 1e-9, "share={share}");
    }

    #[test]
    fn heat_window_deltas_and_clamps() {
        let mut p = Profiler::new();
        let r = RegionId(1);
        let w1 = p.heat_window(&[(r, vec![100.0, 0.0])]);
        assert_eq!(w1, vec![(r, vec![100.0, 0.0])]);
        // Second window sees only the delta.
        let w2 = p.heat_window(&[(r, vec![150.0, 30.0])]);
        assert_eq!(w2, vec![(r, vec![50.0, 30.0])]);
        // A region move dropped the raw counters: clamp, don't go
        // negative; all-zero windows are omitted entirely.
        let w3 = p.heat_window(&[(r, vec![10.0, 5.0])]);
        assert!(w3.is_empty(), "{w3:?}");
        // seed_heat absorbs a warm machine without emitting a window.
        let mut q = Profiler::new();
        q.seed_heat(&[(r, vec![1000.0, 1000.0])]);
        let w = q.heat_window(&[(r, vec![1010.0, 1000.0])]);
        assert_eq!(w, vec![(r, vec![10.0, 0.0])]);
    }

    #[test]
    fn empty_profiler_is_safe() {
        let p = Profiler::new();
        assert_eq!(p.avg_concurrency(), 0.0);
        assert_eq!(p.recent_remote_share(3), 0.0);
    }
}
