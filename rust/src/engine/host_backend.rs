//! Host-threaded execution backend behind [`crate::engine::execute_on`].
//!
//! [`execute_host`] runs a spawn group's per-rank coroutines to
//! completion on the [`HostExecutor`] work-stealing pool. A pool job is
//! a **run-until-yield batch**, not a single step: the worker that picks
//! up a rank steps its coroutine repeatedly — up to a `batch_steps`
//! budget (the `--batch-steps` CLI knob, default
//! [`DEFAULT_BATCH_STEPS`]) — and only goes back through the queues when
//! the rank parks at a barrier, finishes, or exhausts the budget. That
//! amortizes the submit/park/wake round-trip across the batch, so the
//! steady-state cost of a fine-grained step approaches a function call;
//! the budget keeps the quantum moldable — thieves can still rebalance
//! at every batch boundary (`--batch-steps 1` recovers the old
//! step-per-job pipeline exactly).
//!
//! ## Semantics vs the simulator
//!
//! - **Placement**: the policy's `initial_placement` maps each rank to a
//!   home core; jobs are submitted to that core's worker inbox (worker
//!   *i* = core *i*; the pool covers up to the highest home core, so
//!   spread-out policies keep their spread). Steals move a batch — and
//!   its virtual-time charges — to the thief's core, like the
//!   simulator's migration-on-steal.
//! - **Yield**: within the batch budget, a yield just loops to the next
//!   step on the same worker (charging the same core). When the budget
//!   is exhausted the job ends and the rank is resubmitted to its home
//!   worker, so thieves can rebalance at every batch boundary.
//! - **Barrier**: breaks the batch immediately; non-blocking. A rank
//!   parking at a barrier releases its worker thread (no thread ever
//!   blocks inside a job, so groups larger than the pool cannot
//!   deadlock); the last arrival advances every worker core's virtual
//!   clock to the epoch maximum (the simulator's `release_barrier` rule,
//!   keeping BSP makespans comparable) and resubmits every parked rank
//!   in one burst (one pool wake-up for the whole epoch).
//! - **Machine model**: the [`Machine`] is shared *without any
//!   whole-machine lock*. Accounting state is sharded per chiplet /
//!   per socket ([`crate::coordinator`]): a step charges its worker
//!   core's own chiplet shard directly and only touches remote shards
//!   for sibling/remote-NUMA residency, coherence invalidations and the
//!   shared DDR channels — so steps on different chiplets proceed
//!   **truly concurrently**, workload computation included, and
//!   cross-chiplet traffic is the only contention (mirroring the
//!   hardware). A worker's shard is `worker_shard(topo, worker)`
//!   (worker *i* = core *i* = chiplet *i / cores_per_chiplet*). One
//!   [`ProbeCache`] is carried across the whole batch (same core,
//!   consecutive steps), so remote-residency probes are paid once per
//!   batch rather than once per step — exact for the single-core case
//!   (pinned by `rust/tests/shard_equivalence.rs`) and the same
//!   accepted-staleness class as concurrent fills for the rest. The
//!   host-scaling smoke (`micro_runtime --workers …`, asserted in CI)
//!   pins that multi-worker runs beat single-worker wall time on a
//!   memory-bound scenario; the scheduler-overhead microbench
//!   (`micro_runtime --overhead-only`) pins the batching speedup
//!   itself.
//! - **Adaptation**: with a timer armed (`execute_host(.., Some(ns))`,
//!   i.e. `Run::timer_ns` on the Host backend / `--timer-us` with an
//!   adaptive policy), the policy-timer/migration loop fires here too —
//!   on **real elapsed time**, not virtual time. Whichever worker first
//!   crosses a batch boundary past the deadline wins a CAS and ticks:
//!   it samples the shared [`Profiler`] window over the machine's merged
//!   `ClassCounts` (virtual fill events per real timer window), runs
//!   `policy.on_timer`, and applies the returned rank→core map by
//!   swapping the atomic placement slots — the next batch of a migrated
//!   rank is submitted through the targeted-inbox path to its new home,
//!   and its fresh per-batch [`ProbeCache`] starts empty, so post-move
//!   charging is exact. In-flight batches finish on their old core
//!   (migration cost is charged as a fabric message, like the sim). The
//!   same tick also samples the per-region heat window and may rebind
//!   hot regions toward their accessors (`plan_region_moves` → data
//!   follows tasks): the ticking worker pays the one-time DDR copy, and
//!   every in-flight batch picks up the new placement at its next
//!   access via the region-book generation bump. With
//!   the timer off (`None`, the default) the loop never runs, placement
//!   is static, and batching equivalence is untouched — sim goldens and
//!   the conformance suite see byte-identical behavior.
//! - **Determinism**: batch interleaving is *not* deterministic, and
//!   with concurrent charging the *virtual-time* interleaving of
//!   accesses is not either (residency probes may observe concurrent
//!   fills — exactly like real cores racing on a shared L3). Scenario
//!   results still verify because workload state is atomics/locks and
//!   barrier rounds are properly synchronized; virtual-time totals
//!   remain conserved (every charge lands on exactly one shard — pinned
//!   by `rust/tests/shard_equivalence.rs`). The conformance suite in
//!   `rust/tests/backend_conformance.rs` runs every registry scenario on
//!   both backends and pins `--batch-steps 1` ≡ default outcomes.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::cachesim::Outcome;
use crate::policy::{Policy, RegionHeat};
use crate::profiler::Profiler;
use crate::sched::{current_worker, worker_core, HostExecutor, RunReport, Submitter};
use crate::sim::{Machine, ProbeCache, RegionBookCache};
use crate::task::{Coroutine, Step, TaskCtx};

/// Default run-until-yield batch budget: coroutine steps a worker runs
/// per pool job before the rank goes back through the queues. Large
/// enough to amortize the pool round-trip on fine-grained scenarios,
/// small enough that thieves can still rebalance skewed work (`1`
/// recovers the old step-per-job pipeline; tune with `--batch-steps`).
pub const DEFAULT_BATCH_STEPS: usize = 16;

/// Ranks parked at the group barrier, plus finished count: the barrier
/// releases when every unfinished rank is parked (same rule as the
/// simulator's `release_barrier`).
struct BarrierState {
    waiting: Vec<usize>,
    finished: usize,
    epochs: u64,
}

/// A rank's parking slot: `None` while a batch is in flight on a worker.
type RankSlot = Mutex<Option<Box<dyn Coroutine>>>;

/// The adaptive-loop half of a host run, present only when a timer is
/// armed. The hot path touches just `started`/`next_tick_ns`; the
/// policy + profiler live behind a mutex only the winning ticker takes
/// (`try_lock`, so a slow tick never stalls a worker).
struct AdaptState {
    inner: Mutex<AdaptInner>,
    /// Real-time epoch of the run; ticks fire on elapsed wall time.
    started: std::time::Instant,
    /// Next tick deadline in real ns since `started`; the worker that
    /// CASes it forward owns the tick.
    next_tick_ns: AtomicU64,
    timer_ns: u64,
}

struct AdaptInner {
    policy: Box<dyn Policy>,
    profiler: Profiler,
    /// Controller decision log (t_real_ns, window rate, spread) —
    /// `RunReport::decisions`, the host's adaptation counters.
    decisions: Vec<(u64, f64, usize)>,
    /// Region-move log (t_real_ns, region id, dest NUMA) —
    /// `RunReport::region_decisions`.
    region_decisions: Vec<(u64, u32, usize)>,
}

/// Shared state of one host-backed run. The machine itself carries no
/// run-wide lock — its shards are the synchronization.
struct HostRun {
    machine: Machine,
    /// Per-rank coroutine parking slots.
    ranks: Vec<RankSlot>,
    /// rank → *current* home core: the policy's initial placement,
    /// re-pointed by adaptive migration mid-run. Atomic because a tick
    /// swaps entries while other workers read them (for resubmission and
    /// peer messaging); also handed to every step as
    /// `TaskCtx::peer_cores`.
    placement: Vec<AtomicUsize>,
    /// Ranks that have finished (a tick must not "migrate" them).
    done: Vec<AtomicBool>,
    barrier: Mutex<BarrierState>,
    dispatches: AtomicU64,
    /// Rank migrations applied by adaptive ticks (→ `RunReport`).
    migrations: AtomicU64,
    /// Region rebinds applied by adaptive ticks (→ `RunReport`) — the
    /// "data follows tasks" counterpart of `migrations`.
    region_moves: AtomicU64,
    /// `Some` iff the policy-timer loop is armed for this run.
    adapt: Option<AdaptState>,
    n_workers: usize,
    /// Run-until-yield budget (>= 1): max coroutine steps per pool job.
    batch_steps: usize,
}

impl HostRun {
    /// The worker that owns `rank`'s next batch under the current
    /// placement (worker *i* = core *i*, wrapped onto the pool).
    fn home_worker(&self, rank: usize) -> usize {
        self.placement[rank].load(Ordering::Relaxed) % self.n_workers
    }
}

/// Run `n` coroutines over `machine` on a [`HostExecutor`] pool sized to
/// cover the policy's placement — highest home core + 1 (worker *i* =
/// core *i*, so a rank homed on core 48 really lands on worker 48 and
/// spread-out policies stay spread out on real threads). Returns the
/// report and hands the machine back (cache residency carries across
/// runs, as on the sim backend).
///
/// `timer_ns: Some(t)` arms the adaptive policy-timer loop on **real
/// elapsed time**: every `t` wall-clock ns (checked at batch
/// boundaries, so a long batch delays a tick but never loses it) the
/// policy's `on_timer` sees a fresh profiler window and may emit a new
/// rank→core map, applied by re-targeting each migrated rank's next
/// batch. `None` (the default) keeps placement static — the
/// pre-adaptive behavior, byte for byte.
pub(crate) fn execute_host(
    machine: Machine,
    mut policy: Box<dyn Policy>,
    timer_ns: Option<u64>,
    n: usize,
    mut make: impl FnMut(usize) -> Box<dyn Coroutine>,
    batch_steps: usize,
) -> (RunReport, Machine) {
    assert!(n > 0, "spawn at least one rank");
    let wall_start = std::time::Instant::now();
    let topo = machine.topo.clone();
    let placement = policy.initial_placement(&topo, n);
    assert_eq!(placement.len(), n);
    let policy_name = policy.name().to_string();
    // Static runs size the pool to the initial placement; adaptive runs
    // cover the whole topology, so any core a migration targets maps to
    // its own worker (worker i = core i) instead of wrapping onto a
    // different chiplet's worker.
    let n_workers = if timer_ns.is_some() {
        topo.num_cores()
    } else {
        (placement.iter().copied().max().unwrap_or(0) + 1)
            .min(topo.num_cores())
            .max(1)
    };

    // The timer loop owns the policy for the run's duration; static runs
    // keep it out here for the final report.
    let mut static_policy = None;
    let adapt = match timer_ns {
        Some(t) => {
            let mut profiler = Profiler::new();
            // Re-anchor on the (possibly warm) machine so the first
            // window sees only this run's fills.
            profiler.rebaseline(0, machine.class_totals());
            profiler.seed_heat(&machine.region_heat());
            Some(AdaptState {
                inner: Mutex::new(AdaptInner {
                    policy,
                    profiler,
                    decisions: Vec::new(),
                    region_decisions: Vec::new(),
                }),
                started: std::time::Instant::now(),
                next_tick_ns: AtomicU64::new(t.max(1)),
                timer_ns: t.max(1),
            })
        }
        None => {
            static_policy = Some(policy);
            None
        }
    };

    let run = Arc::new(HostRun {
        machine,
        ranks: (0..n).map(|rank| Mutex::new(Some(make(rank)))).collect(),
        placement: placement.into_iter().map(AtomicUsize::new).collect(),
        done: (0..n).map(|_| AtomicBool::new(false)).collect(),
        barrier: Mutex::new(BarrierState {
            waiting: Vec::new(),
            finished: 0,
            epochs: 0,
        }),
        dispatches: AtomicU64::new(0),
        migrations: AtomicU64::new(0),
        region_moves: AtomicU64::new(0),
        adapt,
        n_workers,
        batch_steps: batch_steps.max(1),
    });

    let pool = HostExecutor::new(n_workers, &topo, false);
    let sub = pool.submitter();
    // One burst (and one pool wake-up) for the whole spawn group.
    sub.execute_on_many((0..n).map(|rank| {
        let worker = run.home_worker(rank);
        let run = run.clone();
        let sub2 = sub.clone();
        (worker, move || step_rank(run, sub2, rank))
    }));
    pool.wait_all();
    let host_steals = pool.steal_count() as u64;
    drop(pool);
    drop(sub);

    let Ok(run) = Arc::try_unwrap(run) else {
        panic!("pool drained but a worker still holds the run");
    };
    let machine = run.machine;
    let barrier = run.barrier.into_inner().unwrap();
    assert_eq!(barrier.finished, n, "every rank must run to completion");
    // Recover the policy (and the tick logs) from whichever side owned it.
    let (policy, decisions, region_decisions) = match run.adapt {
        Some(state) => {
            let inner = state.inner.into_inner().unwrap();
            (inner.policy, inner.decisions, inner.region_decisions)
        }
        None => (
            static_policy.take().expect("static run keeps its policy"),
            Vec::new(),
            Vec::new(),
        ),
    };

    let report = RunReport {
        policy: policy_name,
        makespan_ns: machine.max_time(),
        counts: machine.class_totals(),
        dispatches: run.dispatches.load(Ordering::Relaxed),
        steals: host_steals,
        migrations: run.migrations.load(Ordering::Relaxed),
        region_moves: run.region_moves.load(Ordering::Relaxed),
        region_decisions,
        barrier_epochs: barrier.epochs,
        avg_concurrency: n_workers as f64,
        peak_concurrency: n_workers,
        concurrency: Vec::new(),
        decisions,
        dram_bytes: machine.dram_total_bytes(),
        spread_rate: policy.spread_rate(),
        wall_ns: wall_start.elapsed().as_nanos() as u64,
        host_steals,
        request_latency: None,
        request_shed: 0,
        class_latency: Vec::new(),
        machines: 0,
        cross_link_hops: 0,
        cross_link_bytes: 0,
        shard_moves: 0,
        shard_decisions: Vec::new(),
        per_shard: Vec::new(),
    };
    (report, machine)
}

/// Fire the adaptive tick if its real-time deadline has passed. Called
/// at every batch boundary; cheap when idle (one Instant read + one
/// atomic load). The worker that CASes the deadline forward owns the
/// tick; everyone else returns immediately. `try_lock` on the inner
/// state means a tick can never stall a worker behind another tick.
fn maybe_tick(run: &HostRun) {
    let Some(adapt) = &run.adapt else { return };
    let now = adapt.started.elapsed().as_nanos() as u64;
    let due = adapt.next_tick_ns.load(Ordering::Relaxed);
    if now < due {
        return;
    }
    if adapt
        .next_tick_ns
        .compare_exchange(due, now + adapt.timer_ns, Ordering::Relaxed, Ordering::Relaxed)
        .is_err()
    {
        return;
    }
    let Ok(mut inner) = adapt.inner.try_lock() else {
        return;
    };
    let n = run.ranks.len();
    let live = n - run.barrier.lock().unwrap().finished;
    // The profiler window: *virtual* fill events per *real* timer
    // window — the host analogue of Algorithm 1's counter read.
    let totals = run.machine.class_totals();
    let sample = inner
        .profiler
        .sample_window(now, totals, adapt.timer_ns, live);
    inner.profiler.sample_concurrency(now, live);
    if let Some(new_map) = inner.policy.on_timer(&run.machine.topo, now, &sample, n) {
        for (rank, &new) in new_map.iter().enumerate().take(run.placement.len()) {
            if run.done[rank].load(Ordering::Relaxed) {
                continue;
            }
            let old = run.placement[rank].load(Ordering::Relaxed);
            if old == new {
                continue;
            }
            // Migration cost: task state crosses the fabric (same charge
            // as the simulator's `apply_placement`). The in-flight batch,
            // if any, finishes on the old core; the rank's *next* batch
            // is submitted to the new home, where its fresh per-batch
            // ProbeCache starts empty — post-move charging is exact.
            run.machine.message(old, new, 256);
            run.placement[rank].store(new, Ordering::Relaxed);
            run.migrations.fetch_add(1, Ordering::Relaxed);
        }
    }
    // Data follows tasks: sample the per-region heat window and let the
    // policy rebind hot regions toward their accessors. The move itself
    // (rebind + generation bump + L3 drop + DDR copy charge) happens on
    // the ticking worker's core — the mover pays the one-time copy, the
    // same accounting rule as the simulator's tick site. In-flight
    // batches notice the generation bump at their next access and
    // refresh their region-book snapshot.
    let heat_deltas = inner.profiler.heat_window(&run.machine.region_heat());
    if !heat_deltas.is_empty() {
        let heat: Vec<RegionHeat> = heat_deltas
            .into_iter()
            .map(|(region, per_chiplet)| RegionHeat {
                region,
                placement: run.machine.placement_of(region),
                size: run.machine.region_size(region),
                per_chiplet,
            })
            .collect();
        let mover = worker_core(
            &run.machine.topo,
            current_worker().expect("maybe_tick runs on a pool worker"),
        );
        for mv in inner.policy.plan_region_moves(&run.machine.topo, now, &heat, n) {
            if run.machine.move_region(mv.region, mv.to_numa, mover) {
                run.region_moves.fetch_add(1, Ordering::Relaxed);
                inner.region_decisions.push((now, mv.region.0, mv.to_numa));
            }
        }
    }
    let spread = inner.policy.spread_rate();
    inner.decisions.push((now, sample.rate, spread));
}

/// Enqueue one batch of `rank` on its *current* home worker — the
/// targeted-inbox path adaptive migration re-targets: a tick that moved
/// the rank's placement slot re-routes this very submission.
fn submit_rank(run: &Arc<HostRun>, sub: &Submitter, rank: usize) {
    let worker = run.home_worker(rank);
    let run = run.clone();
    let sub2 = sub.clone();
    sub.execute_on(worker, move || step_rank(run, sub2, rank));
}

/// One pool job: a run-until-yield batch. Step `rank`'s coroutine up to
/// `batch_steps` times on this worker — yields inside the budget loop
/// straight to the next step; a barrier, completion, or an exhausted
/// budget ends the batch. Steps charge the sharded machine directly —
/// no run-wide lock is taken around the step body — and one
/// [`ProbeCache`] is carried across the batch's steps (same core), so
/// remote-residency probes are paid once per batch.
fn step_rank(run: Arc<HostRun>, sub: Submitter, rank: usize) {
    let mut coro = run.ranks[rank]
        .lock()
        .unwrap()
        .take()
        .expect("rank stepped while already in flight");
    // Charge the worker actually running the batch (worker i = core i,
    // the `worker_core` map), so steals move virtual-time charges
    // exactly like the simulator — and the charges land on the worker's
    // own chiplet shard (`worker_shard`).
    let worker = current_worker().expect("step_rank runs on a pool worker");
    let core = worker_core(&run.machine.topo, worker);
    let mut cache = ProbeCache::default();
    let mut book = RegionBookCache::default();
    let mut steps_done: u64 = 0;
    let step = loop {
        let step = {
            let machine = &run.machine;
            let mut ctx = TaskCtx {
                machine,
                core,
                task_id: rank,
                rank,
                group_size: run.ranks.len(),
                now_ns: machine.now(core),
                step_outcome: Outcome::default(),
                probe_cache: cache,
                book,
                peer_cores: Some(&run.placement),
            };
            let step = coro.step(&mut ctx);
            // Carry the probe cache and region-book snapshot into the
            // batch's next step (the context itself stays per-step).
            cache = ctx.probe_cache;
            book = ctx.book;
            step
        };
        steps_done += 1;
        match step {
            Step::Yield if (steps_done as usize) < run.batch_steps => continue,
            other => break other,
        }
    };
    // `dispatches` counts coroutine *steps* (batching must not change
    // it — pinned by the batching-equivalence conformance test), so one
    // add covers the whole batch.
    run.dispatches.fetch_add(steps_done, Ordering::Relaxed);
    // A batch boundary is the adaptive loop's tick point: real elapsed
    // time is checked here, so a long batch delays a tick but the next
    // boundary always catches up (no-op when no timer is armed).
    maybe_tick(&run);
    match step {
        Step::Yield => {
            // Budget exhausted: back through the queues so thieves can
            // rebalance.
            *run.ranks[rank].lock().unwrap() = Some(coro);
            submit_rank(&run, &sub, rank);
        }
        Step::Barrier => {
            // Park the coroutine *before* registering at the barrier: a
            // racing release must find the slot occupied.
            *run.ranks[rank].lock().unwrap() = Some(coro);
            let woken = {
                let mut b = run.barrier.lock().unwrap();
                b.waiting.push(rank);
                barrier_release(&mut b, run.ranks.len())
            };
            release_ranks(&run, &sub, woken);
        }
        Step::Done => {
            drop(coro);
            // Mark the rank dead *before* bumping `finished`: a tick
            // that observes the new count must already skip the rank.
            run.done[rank].store(true, Ordering::Relaxed);
            let woken = {
                let mut b = run.barrier.lock().unwrap();
                b.finished += 1;
                barrier_release(&mut b, run.ranks.len())
            };
            release_ranks(&run, &sub, woken);
        }
    }
}

/// Resume a released barrier epoch: synchronize the worker cores'
/// virtual clocks to the epoch max (every rank resumes at the latest
/// clock, like the simulator's `release_barrier`), then resubmit every
/// parked rank in one burst — one pool wake-up for the whole epoch.
///
/// Runs lock-free over the clock atomics: a barrier only releases once
/// every unfinished rank is parked, so no step is concurrently charging
/// any worker core's clock.
fn release_ranks(run: &Arc<HostRun>, sub: &Submitter, woken: Vec<usize>) {
    if woken.is_empty() {
        return;
    }
    let t_max = (0..run.n_workers)
        .map(|c| run.machine.now(c))
        .max()
        .unwrap_or(0);
    for c in 0..run.n_workers {
        run.machine.advance_to(c, t_max);
    }
    sub.execute_on_many(woken.into_iter().map(|r| {
        let worker = run.home_worker(r);
        let run = run.clone();
        let sub2 = sub.clone();
        (worker, move || step_rank(run, sub2, r))
    }));
}

/// If every unfinished rank is parked, take them all for resubmission.
fn barrier_release(b: &mut BarrierState, n: usize) -> Vec<usize> {
    if !b.waiting.is_empty() && b.waiting.len() + b.finished == n {
        b.epochs += 1;
        std::mem::take(&mut b.waiting)
    } else {
        Vec::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::LocalCachePolicy;
    use crate::task::{BspTask, FnTask, IterTask};
    use crate::topology::Topology;

    fn machine() -> Machine {
        Machine::new(Topology::milan_1s())
    }

    #[test]
    fn single_task_completes_on_host() {
        let (report, _) = execute_host(
            machine(),
            Box::new(LocalCachePolicy),
            None,
            1,
            |_| Box::new(FnTask(|ctx: &mut TaskCtx<'_>| ctx.compute_ns(1000))),
            DEFAULT_BATCH_STEPS,
        );
        assert_eq!(report.dispatches, 1);
        assert!(report.makespan_ns >= 1000);
        assert!(report.wall_ns > 0);
    }

    #[test]
    fn yields_step_the_expected_number_of_times() {
        let (report, _) = execute_host(
            machine(),
            Box::new(LocalCachePolicy),
            None,
            4,
            |_| Box::new(IterTask::new(10, |ctx, _| ctx.compute_ns(100))),
            DEFAULT_BATCH_STEPS,
        );
        // 4 tasks x 10 steps: dispatches counts steps, not batches.
        assert_eq!(report.dispatches, 40);
    }

    #[test]
    fn batch_budget_one_matches_default_step_counts() {
        // --batch-steps 1 is exactly the old step-per-job pipeline; the
        // observable outcome (steps run, barrier structure) must match
        // the batched default.
        let run_with = |batch: usize| {
            execute_host(
                machine(),
                Box::new(LocalCachePolicy),
                None,
                4,
                |_| Box::new(BspTask::new(3, |ctx, _| ctx.compute_ns(100))),
                batch,
            )
            .0
        };
        let per_step = run_with(1);
        let batched = run_with(DEFAULT_BATCH_STEPS);
        assert_eq!(per_step.dispatches, batched.dispatches);
        assert_eq!(per_step.barrier_epochs, batched.barrier_epochs);
    }

    #[test]
    fn a_barrier_breaks_the_batch() {
        // Budget far above the phase length: barriers must still fire
        // per phase (a batch never runs through a barrier), so epochs
        // and hits match the step-per-job pipeline.
        use std::sync::atomic::AtomicUsize;
        let hits = Arc::new(AtomicUsize::new(0));
        let (report, _) = execute_host(
            machine(),
            Box::new(LocalCachePolicy),
            None,
            4,
            |_| {
                let hits = hits.clone();
                Box::new(BspTask::new(2, move |ctx, _| {
                    hits.fetch_add(1, Ordering::Relaxed);
                    ctx.compute_ns(10);
                }))
            },
            1_000,
        );
        assert_eq!(hits.load(Ordering::Relaxed), 4 * 2);
        assert_eq!(report.barrier_epochs, 1);
    }

    #[test]
    fn barriers_release_groups_larger_than_the_pool() {
        // 32 ranks on an 8-core (1-chiplet) machine bound the pool at 8
        // workers: blocking barriers would deadlock; the parking barrier
        // must release every epoch.
        use std::sync::atomic::AtomicUsize;
        let mut topo = Topology::milan_1s();
        topo.chiplets_per_numa = 1;
        assert_eq!(topo.num_cores(), 8);
        let hits = Arc::new(AtomicUsize::new(0));
        let (report, _) = execute_host(
            Machine::new(topo),
            Box::new(LocalCachePolicy),
            None,
            32,
            |_| {
                let hits = hits.clone();
                Box::new(BspTask::new(3, move |ctx, _| {
                    hits.fetch_add(1, Ordering::Relaxed);
                    ctx.compute_ns(10);
                }))
            },
            DEFAULT_BATCH_STEPS,
        );
        assert_eq!(hits.load(Ordering::Relaxed), 32 * 3);
        assert_eq!(report.barrier_epochs, 2);
    }

    #[test]
    fn barrier_synchronizes_virtual_clocks_like_the_simulator() {
        // Phase 1: rank 0 slow; phase 2: rank 1 slow. With clock sync at
        // the barrier the phases cannot overlap in virtual time, so the
        // makespan must cover both slow phases (the simulator's rule).
        let (report, _) = execute_host(
            machine(),
            Box::new(LocalCachePolicy),
            None,
            2,
            |rank| {
                Box::new(BspTask::new(2, move |ctx, iter| {
                    let slow = (iter == 0) == (rank == 0);
                    ctx.compute_ns(if slow { 1_000_000 } else { 1_000 });
                }))
            },
            DEFAULT_BATCH_STEPS,
        );
        assert_eq!(report.barrier_epochs, 1);
        assert!(
            report.makespan_ns >= 2_000_000,
            "phases overlapped in virtual time: makespan={}",
            report.makespan_ns
        );
    }

    #[test]
    fn machine_comes_back_warm() {
        let (_, machine) = execute_host(
            machine(),
            Box::new(LocalCachePolicy),
            None,
            2,
            |_| Box::new(FnTask(|ctx: &mut TaskCtx<'_>| ctx.compute_ns(50))),
            DEFAULT_BATCH_STEPS,
        );
        assert!(machine.max_time() >= 50);
    }

    /// Counts adaptive ticks without ever asking for a migration.
    struct TickCountPolicy {
        ticks: Arc<AtomicUsize>,
    }

    impl Policy for TickCountPolicy {
        fn name(&self) -> &'static str {
            "tick-count"
        }
        fn initial_placement(&mut self, _topo: &Topology, n: usize) -> Vec<usize> {
            vec![0; n]
        }
        fn on_timer(
            &mut self,
            _topo: &Topology,
            _now_ns: u64,
            _sample: &crate::profiler::WindowSample,
            _group_size: usize,
        ) -> Option<Vec<usize>> {
            self.ticks.fetch_add(1, Ordering::Relaxed);
            None
        }
    }

    /// Moves every rank to `target` on the first tick (and keeps asking,
    /// which must be a no-op once applied).
    struct HopPolicy {
        target: usize,
    }

    impl Policy for HopPolicy {
        fn name(&self) -> &'static str {
            "hop"
        }
        fn initial_placement(&mut self, _topo: &Topology, n: usize) -> Vec<usize> {
            vec![0; n]
        }
        fn on_timer(
            &mut self,
            _topo: &Topology,
            _now_ns: u64,
            _sample: &crate::profiler::WindowSample,
            group_size: usize,
        ) -> Option<Vec<usize>> {
            Some(vec![self.target; group_size])
        }
    }

    /// Two-chiplet cut of milan_1s so adaptive pools (sized to the whole
    /// topology) stay small in tests.
    fn small_topo() -> Topology {
        let mut topo = Topology::milan_1s();
        topo.chiplets_per_numa = 2;
        topo
    }

    #[test]
    fn timer_fires_at_batch_boundaries_even_under_long_batches() {
        // Budget far above the run length: each rank runs its whole life
        // as one long batch, so the only tick points are the few batch
        // boundaries at completion. A 1 ns real timer is always past due
        // there — the tick must not be lost, only delayed.
        let ticks = Arc::new(AtomicUsize::new(0));
        let (report, _) = execute_host(
            Machine::new(small_topo()),
            Box::new(TickCountPolicy {
                ticks: ticks.clone(),
            }),
            Some(1),
            2,
            |_| Box::new(IterTask::new(64, |ctx, _| ctx.compute_ns(200))),
            1_000,
        );
        let fired = ticks.load(Ordering::Relaxed);
        assert!(fired >= 1, "long batches must still reach the tick point");
        assert_eq!(
            report.decisions.len(),
            fired,
            "one decision-log entry per tick"
        );
        assert_eq!(report.migrations, 0, "on_timer returned no map");
    }

    #[test]
    fn no_timer_means_no_ticks_and_no_migrations() {
        let ticks = Arc::new(AtomicUsize::new(0));
        let (report, _) = execute_host(
            Machine::new(small_topo()),
            Box::new(TickCountPolicy {
                ticks: ticks.clone(),
            }),
            None,
            2,
            |_| Box::new(IterTask::new(16, |ctx, _| ctx.compute_ns(100))),
            DEFAULT_BATCH_STEPS,
        );
        assert_eq!(ticks.load(Ordering::Relaxed), 0);
        assert_eq!(report.migrations, 0);
        assert!(report.decisions.is_empty());
    }

    #[test]
    fn a_migrated_rank_charges_its_new_core_from_the_next_batch() {
        // Step-per-job batches make every step a tick point: the first
        // tick migrates the rank from core 0 to the first core of the
        // other chiplet, and every later batch must be re-targeted
        // through the inbox path — charging the new core's clock with a
        // fresh per-batch ProbeCache.
        let topo = small_topo();
        let target = topo.cores_per_chiplet;
        let (report, machine) = execute_host(
            Machine::new(topo),
            Box::new(HopPolicy { target }),
            Some(1),
            1,
            |_| Box::new(IterTask::new(64, |ctx, _| ctx.compute_ns(1_000))),
            1,
        );
        assert_eq!(report.migrations, 1, "the hop applies exactly once");
        assert!(
            machine.now(target) >= 1_000,
            "post-migration batches must charge the new core: now={}",
            machine.now(target)
        );
    }

    #[test]
    fn concurrent_steps_charge_disjoint_shards_without_loss() {
        // 8 ranks spread over 8 chiplets by DistributedCachePolicy, each
        // charging its own clock: with sharded accounting every charge
        // must land exactly once even though no global lock exists.
        use crate::policy::{DistributedCachePolicy, Policy};
        use crate::sched::worker_shard;
        // Premise check: the policy really homes the 8 ranks' workers on
        // 8 distinct shards (worker i = core i = chiplet i/8).
        let topo = Topology::milan_1s();
        let placement = DistributedCachePolicy.initial_placement(&topo, 8);
        let shards: std::collections::BTreeSet<usize> = placement
            .iter()
            .map(|&home| worker_shard(&topo, home))
            .collect();
        assert_eq!(shards.len(), 8, "placement must span 8 chiplet shards");
        let steps = 20u64;
        let (report, machine) = execute_host(
            machine(),
            Box::new(DistributedCachePolicy),
            None,
            8,
            |_| Box::new(IterTask::new(20, |ctx, _| ctx.compute_ns(1_000))),
            DEFAULT_BATCH_STEPS,
        );
        assert_eq!(report.dispatches, 8 * steps);
        // Total charged virtual time is conserved: 8 ranks x 20 x 1µs
        // (steals can concentrate it on fewer cores, never lose it).
        let total: u64 = (0..machine.topo.num_cores())
            .map(|c| machine.now(c))
            .sum();
        assert!(
            total >= 8 * steps * 1_000,
            "charges lost under concurrency: {total}"
        );
    }
}
