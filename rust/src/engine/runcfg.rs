//! Parsed configuration of `arcas run` — kept in the library (not
//! `main.rs`) so argument validation is unit-testable: unknown backends
//! and `--repeat 0` are rejected here with actionable messages.

use super::{registry, ExecBackend, ScenarioParams, DEFAULT_BATCH_STEPS};
use crate::util::cli::Cli;
use crate::workloads::serve::PriorityMix;

/// Everything `arcas run` needs, validated.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub scenario: String,
    pub policy: String,
    pub cores: usize,
    /// Executor backend (`--backend sim|host`).
    pub backend: ExecBackend,
    /// Warm-cache repetitions over one machine (`--repeat N`, N >= 1).
    pub repeat: usize,
    /// Host-backend run-until-yield batch budget (`--batch-steps N`,
    /// N >= 1; 1 = the old step-per-job pipeline). Ignored by sim.
    pub batch_steps: usize,
    /// Machine-shard fan-out for serve scenarios (`--machines N`,
    /// N >= 1; 1 = the ordinary single-machine run).
    pub machines: usize,
    pub verify: bool,
    pub topology: String,
    pub timer_us: u64,
    /// Online region re-placement on adaptive ticks (`true` unless
    /// `--no-region-moves`; only the arcas/adaptive policy acts on it).
    pub region_moves: bool,
    pub params: ScenarioParams,
    /// Set when the deprecated `--workload` alias was used.
    pub deprecated_workload: bool,
}

impl RunConfig {
    /// The `arcas run` option set (also the `--help` source of truth).
    pub fn cli() -> Cli {
        let names: Vec<&str> = registry().iter().map(|s| s.name).collect();
        Cli::new("arcas run", "run one scenario under a policy")
            .opt("scenario", "bfs", &names.join("|"))
            .opt_nodefault("workload", "deprecated alias for --scenario")
            .opt(
                "policy",
                "arcas",
                "arcas|adaptive|ring|shoal|local|distributed|os_async|slo (adaptive = arcas; on --backend host it arms the real-time migration loop)",
            )
            .opt("cores", "16", "worker count")
            .opt("backend", "sim", "executor backend: sim (virtual time) | host (real threads)")
            .opt("repeat", "1", "run N times on one machine (warm caches after run 1)")
            .opt(
                "batch-steps",
                "16",
                "host backend: max coroutine steps per pool job (run-until-yield batching; 1 = step-per-job)",
            )
            .opt(
                "machines",
                "1",
                "serve-*: fan the run out over N key-sharded machine shards behind a cluster link tier",
            )
            .opt("scale", "0.02", "dataset scale factor vs the paper's sizes")
            .opt_nodefault("iters", "intensity knob (PR iterations, txns/core, SGD epochs)")
            .opt_nodefault(
                "variant",
                "scenario variant (tpch q1..q22, sgd percore|pernode|permachine, serve poisson|uniform|diurnal|bursty)",
            )
            .opt_nodefault(
                "trace",
                "request trace file for serve-* scenarios (text: \"<arrival_ns> <op> <key> [priority]\" lines)",
            )
            .opt_nodefault(
                "priority-mix",
                "serve-* priority shares \"<critical>,<background>\" in [0,1] (rest is normal)",
            )
            .opt_nodefault(
                "slo-p99",
                "serve-* queue-wait SLO budget in us: past it, background requests are shed",
            )
            .opt_nodefault(
                "closed-loop",
                "serve-* closed-loop client think time in ns (replaces open-loop trace arrivals)",
            )
            .opt("topology", "milan_2s", "machine preset")
            .opt(
                "timer-us",
                "100",
                "ARCAS controller timer (us): virtual time on sim; real elapsed time between host adaptation ticks",
            )
            .opt("seed", "42", "PRNG seed")
            .flag("verify", "check results against the serial references")
            .flag(
                "no-region-moves",
                "adaptive policy: keep task migration but never re-home regions (the task-move-only baseline)",
            )
    }

    /// Parse + validate `arcas run` arguments.
    pub fn from_args<I: IntoIterator<Item = String>>(args: I) -> Result<Self, String> {
        let a = Self::cli().parse_from(args)?;
        let backend: ExecBackend = a.str("backend").parse()?;
        let repeat: usize = a
            .str("repeat")
            .parse()
            .map_err(|_| format!("--repeat {} is not a number", a.str("repeat")))?;
        if repeat == 0 {
            return Err("--repeat must be >= 1 (each repetition reuses the warm machine)".into());
        }
        let batch_steps: usize = a
            .str("batch-steps")
            .parse()
            .map_err(|_| format!("--batch-steps {} is not a number", a.str("batch-steps")))?;
        if batch_steps == 0 {
            return Err(
                "--batch-steps must be >= 1 (1 disables run-until-yield batching)".into(),
            );
        }
        let machines: usize = a
            .str("machines")
            .parse()
            .map_err(|_| format!("--machines {} is not a number", a.str("machines")))?;
        if machines == 0 {
            return Err("--machines must be >= 1 (1 = the single-machine run)".into());
        }
        if machines > 1 && repeat > 1 {
            return Err(
                "--machines and --repeat don't compose: warm-machine repetition is per shard \
                 (run the cluster sweep in the fig_cluster bench instead)"
                    .into(),
            );
        }
        let cores: usize = a
            .str("cores")
            .parse()
            .map_err(|_| format!("--cores {} is not a number", a.str("cores")))?;
        if cores == 0 {
            return Err("--cores must be >= 1".into());
        }
        let iters = match a.get("iters") {
            Some(v) => Some(
                v.parse()
                    .map_err(|_| format!("--iters {v} is not a number"))?,
            ),
            None => None,
        };
        let scale: f64 = a
            .str("scale")
            .parse()
            .map_err(|_| format!("--scale {} is not a number", a.str("scale")))?;
        let priority_mix = match a.get("priority-mix") {
            Some(v) => Some(PriorityMix::parse(v)?),
            None => None,
        };
        let slo_p99_ns = match a.get("slo-p99") {
            Some(v) => {
                let us: f64 = v
                    .parse()
                    .ok()
                    .filter(|us: &f64| *us > 0.0)
                    .ok_or_else(|| format!("--slo-p99 {v} is not a positive microsecond count"))?;
                Some((us * 1_000.0) as u64)
            }
            None => None,
        };
        let closed_loop_think_ns = match a.get("closed-loop") {
            Some(v) => Some(
                v.parse()
                    .map_err(|_| format!("--closed-loop {v} is not a think time in ns"))?,
            ),
            None => None,
        };
        if closed_loop_think_ns.is_some() && slo_p99_ns.is_some() {
            return Err(
                "--closed-loop and --slo-p99 conflict: a closed loop has no arrival queue to shed from"
                    .into(),
            );
        }
        let (scenario, deprecated_workload) = match a.get("workload") {
            Some(w) => (w.to_string(), true),
            None => (a.str("scenario"), false),
        };
        Ok(Self {
            scenario,
            policy: a.str("policy"),
            cores,
            backend,
            repeat,
            batch_steps,
            machines,
            verify: a.flag("verify"),
            topology: a.str("topology"),
            timer_us: a.u64("timer-us"),
            region_moves: !a.flag("no-region-moves"),
            params: ScenarioParams {
                scale,
                seed: a.u64("seed"),
                iters,
                variant: a.get("variant").map(str::to_string),
                trace: a.get("trace").map(str::to_string),
                priority_mix,
                slo_p99_ns,
                closed_loop_think_ns,
            },
            deprecated_workload,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn from(args: &[&str]) -> Result<RunConfig, String> {
        RunConfig::from_args(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults() {
        let c = from(&[]).unwrap();
        assert_eq!(c.scenario, "bfs");
        assert_eq!(c.backend, ExecBackend::Sim);
        assert_eq!(c.repeat, 1);
        assert_eq!(c.cores, 16);
        // The CLI default string must track the engine constant.
        assert_eq!(c.batch_steps, DEFAULT_BATCH_STEPS);
        assert!(!c.verify);
        assert!(!c.deprecated_workload);
        assert!(c.region_moves, "region moves are on by default");
    }

    #[test]
    fn no_region_moves_flag_disables_them() {
        let c = from(&["--no-region-moves"]).unwrap();
        assert!(!c.region_moves);
        let help = RunConfig::cli()
            .parse_from(["--help".to_string()])
            .unwrap_err();
        assert!(help.contains("--no-region-moves"), "{help}");
    }

    #[test]
    fn batch_steps_parses_and_rejects_zero() {
        let c = from(&["--batch-steps", "4"]).unwrap();
        assert_eq!(c.batch_steps, 4);
        let err = from(&["--batch-steps", "0"]).unwrap_err();
        assert!(err.contains("--batch-steps must be >= 1"), "{err}");
        let err = from(&["--batch-steps", "lots"]).unwrap_err();
        assert!(err.contains("--batch-steps"), "{err}");
    }

    #[test]
    fn machines_parses_and_rejects_zero() {
        assert_eq!(from(&[]).unwrap().machines, 1);
        let c = from(&["--scenario", "serve-cluster", "--machines", "4"]).unwrap();
        assert_eq!(c.machines, 4);
        let err = from(&["--machines", "0"]).unwrap_err();
        assert!(err.contains("--machines must be >= 1"), "{err}");
        let err = from(&["--machines", "fleet"]).unwrap_err();
        assert!(err.contains("--machines"), "{err}");
        let err = from(&["--machines", "4", "--repeat", "2"]).unwrap_err();
        assert!(
            err.contains("--machines") && err.contains("--repeat"),
            "{err}"
        );
        let help = RunConfig::cli()
            .parse_from(["--help".to_string()])
            .unwrap_err();
        assert!(help.contains("--machines"), "{help}");
    }

    #[test]
    fn backend_and_repeat_parse() {
        let c = from(&["--backend", "host", "--repeat", "5", "--verify"]).unwrap();
        assert_eq!(c.backend, ExecBackend::Host);
        assert_eq!(c.repeat, 5);
        assert!(c.verify);
    }

    #[test]
    fn unknown_backend_is_rejected() {
        let err = from(&["--backend", "gpu"]).unwrap_err();
        assert!(err.contains("unknown backend"), "{err}");
    }

    #[test]
    fn repeat_zero_is_rejected() {
        let err = from(&["--repeat", "0"]).unwrap_err();
        assert!(err.contains("--repeat must be >= 1"), "{err}");
        assert!(from(&["--repeat", "many"]).is_err());
    }

    #[test]
    fn trace_option_threads_into_params() {
        let c = from(&["--scenario", "serve-kv", "--trace", "/tmp/t.txt"]).unwrap();
        assert_eq!(c.params.trace.as_deref(), Some("/tmp/t.txt"));
        assert_eq!(from(&[]).unwrap().params.trace, None);
    }

    #[test]
    fn slo_knobs_thread_into_params() {
        let c = from(&[
            "--scenario",
            "serve-kv",
            "--priority-mix",
            "0.2,0.3",
            "--slo-p99",
            "150",
        ])
        .unwrap();
        let m = c.params.priority_mix.unwrap();
        assert!((m.critical - 0.2).abs() < 1e-12 && (m.background - 0.3).abs() < 1e-12);
        assert_eq!(c.params.slo_p99_ns, Some(150_000)); // 150 us -> ns
        assert_eq!(c.params.closed_loop_think_ns, None);

        let c = from(&["--scenario", "serve-kv", "--closed-loop", "500"]).unwrap();
        assert_eq!(c.params.closed_loop_think_ns, Some(500));
    }

    #[test]
    fn malformed_slo_knobs_are_rejected_with_the_flag_name() {
        let err = from(&["--priority-mix", "0.2"]).unwrap_err();
        assert!(err.contains("--priority-mix"), "{err}");
        let err = from(&["--priority-mix", "0.9,0.9"]).unwrap_err();
        assert!(err.contains("--priority-mix"), "{err}");
        let err = from(&["--slo-p99", "-3"]).unwrap_err();
        assert!(err.contains("--slo-p99"), "{err}");
        let err = from(&["--closed-loop", "soon"]).unwrap_err();
        assert!(err.contains("--closed-loop"), "{err}");
    }

    #[test]
    fn closed_loop_conflicts_with_the_shedding_budget() {
        let err = from(&["--closed-loop", "500", "--slo-p99", "100"]).unwrap_err();
        assert!(
            err.contains("--closed-loop") && err.contains("--slo-p99"),
            "{err}"
        );
    }

    #[test]
    fn workload_alias_flags_deprecation() {
        let c = from(&["--workload", "gups"]).unwrap();
        assert_eq!(c.scenario, "gups");
        assert!(c.deprecated_workload);
    }

    #[test]
    fn help_documents_backend_and_repeat() {
        let help = RunConfig::cli()
            .parse_from(["--help".to_string()])
            .unwrap_err();
        assert!(help.contains("--backend"));
        assert!(help.contains("--repeat"));
        assert!(help.contains("--batch-steps"));
        assert!(help.contains("run-until-yield"));
        assert!(help.contains("sim (virtual time) | host (real threads)"));
        assert!(help.contains("--priority-mix"));
        assert!(help.contains("--slo-p99"));
        assert!(help.contains("--closed-loop"));
    }
}
