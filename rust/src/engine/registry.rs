//! Name-keyed scenario registry.
//!
//! One catalogue of every workload the runtime can drive, so the CLI
//! (`arcas run --scenario <name>`), the harness and the benches
//! enumerate workload×policy combinations through a single code path.
//! Adding a workload = implementing [`Scenario`] and appending one entry
//! here (see `rust/src/engine/README.md`).
//!
//! Build functions regenerate their dataset on every call (scenarios are
//! single-run). That is fine for the CLI and cheap workloads; sweeps
//! over heavy shared data (a big Kronecker graph across 12 core counts)
//! should construct the typed scenario directly with an `Arc`'d dataset,
//! as `fig07_graph_scaling` does.

use std::sync::Arc;

use super::Scenario;
use crate::workloads::graph::{
    kronecker::kronecker, BfsRandomRootsScenario, BfsScenario, CcScenario, GupsScenario,
    PagerankScenario, SsspScenario,
};
use crate::workloads::mixed::MixedScenario;
use crate::workloads::olap::{all_queries, Db, OlapScenario, QuerySpec};
use crate::workloads::oltp::{OltpScenario, OltpWorkload};
use crate::workloads::phaseshift::{MemFollowScenario, PhaseShiftScenario};
use crate::workloads::serve::{
    ArrivalModel, PriorityMix, ServeKvScenario, ServeMixedScenario, ServeOpts, Trace, TraceConfig,
};
use crate::workloads::sgd::{
    generate_data, DwStrategy, RustGrad, SgdConfig, SgdMode, SgdScenario,
};
use crate::workloads::streamcluster::{generate_points, ScConfig, ScScenario};

/// Knobs every registry build function understands. `scale` follows the
/// harness convention: a fraction of the paper's dataset sizes (1.0 =
/// paper scale), not an absolute size.
#[derive(Clone, Debug)]
pub struct ScenarioParams {
    /// Dataset scale factor vs the paper's sizes.
    pub scale: f64,
    /// PRNG seed for data generation.
    pub seed: u64,
    /// Workload-specific intensity knob: PageRank iterations, GUPS
    /// updates/core, OLTP transactions/core, SGD epochs. `None` = the
    /// scenario's default.
    pub iters: Option<u64>,
    /// Workload-specific selector: TPC-H query (`"q6"`), SGD replication
    /// strategy (`"percore"|"pernode"|"permachine"`), serve arrival
    /// model (`"poisson"|"uniform"|"diurnal"|"bursty"`).
    pub variant: Option<String>,
    /// Request trace file for the serve scenarios (`--trace`; text
    /// format, see `workloads::serve::trace`). `None` = seeded synthetic
    /// trace.
    pub trace: Option<String>,
    /// Per-tenant priority shares for synthetic serve traces
    /// (`--priority-mix <critical>,<background>`). `None` = all-Normal.
    pub priority_mix: Option<PriorityMix>,
    /// Queue-wait budget in ns after which Background requests are shed
    /// (`--slo-p99`, given in µs on the CLI). `None` = never shed.
    pub slo_p99_ns: Option<u64>,
    /// Closed-loop client think time in ns (`--closed-loop`). `None` =
    /// open-loop trace replay.
    pub closed_loop_think_ns: Option<u64>,
}

impl Default for ScenarioParams {
    fn default() -> Self {
        Self {
            scale: 0.02,
            seed: 42,
            iters: None,
            variant: None,
            trace: None,
            priority_mix: None,
            slo_p99_ns: None,
            closed_loop_think_ns: None,
        }
    }
}

/// One registry entry: a named, documented scenario constructor.
pub struct ScenarioSpec {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    /// Workload family (graph | streamcluster | sgd | olap | oltp).
    pub family: &'static str,
    pub about: &'static str,
    /// Optional [`ScenarioParams`] knobs this scenario understands,
    /// named by CLI flag. `scale`, `seed` and `iters` are universal and
    /// never listed. [`ScenarioSpec::validate`] rejects anything else.
    pub accepts: &'static [&'static str],
    build: fn(&ScenarioParams) -> Box<dyn Scenario>,
}

impl ScenarioSpec {
    /// Reject `Some`-valued optional knobs this scenario does not
    /// understand, naming the offending flag and what *is* accepted —
    /// running a serve-only flag against e.g. PageRank would otherwise
    /// silently ignore it and corrupt a sweep.
    pub fn validate(&self, params: &ScenarioParams) -> Result<(), String> {
        let given: &[(&str, bool)] = &[
            ("--variant", params.variant.is_some()),
            ("--trace", params.trace.is_some()),
            ("--priority-mix", params.priority_mix.is_some()),
            ("--slo-p99", params.slo_p99_ns.is_some()),
            ("--closed-loop", params.closed_loop_think_ns.is_some()),
        ];
        for (flag, set) in given {
            if *set && !self.accepts.contains(flag) {
                let accepted = if self.accepts.is_empty() {
                    "--scale/--seed/--iters only".to_string()
                } else {
                    format!("--scale/--seed/--iters and {}", self.accepts.join(", "))
                };
                return Err(format!(
                    "scenario {:?} does not accept {flag} (accepted: {accepted})",
                    self.name
                ));
            }
        }
        Ok(())
    }

    /// Validate `params` against this scenario, then construct it.
    pub fn try_build(&self, params: &ScenarioParams) -> Result<Box<dyn Scenario>, String> {
        self.validate(params)?;
        Ok((self.build)(params))
    }

    /// Construct a fresh (single-run) scenario for `params`, panicking
    /// on knobs the scenario rejects. Prefer [`ScenarioSpec::try_build`]
    /// where the error can be reported (the CLI does).
    pub fn build(&self, params: &ScenarioParams) -> Box<dyn Scenario> {
        self.try_build(params).unwrap_or_else(|e| panic!("{e}"))
    }
}

/// Graph scale exponent for a dataset fraction (paper: 2^24 vertices).
fn graph_scale(p: &ScenarioParams) -> u32 {
    ((16_777_216.0 * p.scale) as u64).max(1024).ilog2()
}

fn build_bfs(p: &ScenarioParams) -> Box<dyn Scenario> {
    let g = Arc::new(kronecker(graph_scale(p), 16, p.seed));
    let src = g.max_degree_vertex();
    Box::new(BfsScenario::new(g, src))
}

fn build_bfs_random_roots(p: &ScenarioParams) -> Box<dyn Scenario> {
    let g = Arc::new(kronecker(graph_scale(p), 16, p.seed));
    // Graph500 runs 64 search keys at full scale; default to a small
    // sample and let `--iters` set the key count.
    let roots = p.iters.unwrap_or(4).clamp(1, 64) as usize;
    Box::new(BfsRandomRootsScenario::new(g, roots, p.seed))
}

fn build_pagerank(p: &ScenarioParams) -> Box<dyn Scenario> {
    let g = Arc::new(kronecker(graph_scale(p), 16, p.seed));
    let iters = p.iters.unwrap_or(10) as usize;
    Box::new(PagerankScenario::new(g, iters))
}

fn build_cc(p: &ScenarioParams) -> Box<dyn Scenario> {
    let g = Arc::new(kronecker(graph_scale(p), 16, p.seed));
    Box::new(CcScenario::new(g))
}

fn build_sssp(p: &ScenarioParams) -> Box<dyn Scenario> {
    let g = Arc::new(kronecker(graph_scale(p), 16, p.seed));
    let src = g.max_degree_vertex();
    Box::new(SsspScenario::new(g, src))
}

fn build_gups(p: &ScenarioParams) -> Box<dyn Scenario> {
    let table_words = 1usize << graph_scale(p);
    let updates = p.iters.unwrap_or(100_000);
    Box::new(GupsScenario::new(table_words, updates, p.seed))
}

fn build_streamcluster(p: &ScenarioParams) -> Box<dyn Scenario> {
    let mut cfg = ScConfig::bench(p.scale);
    cfg.seed = p.seed;
    cfg.n_points = cfg.n_points.max(256);
    cfg.batch_size = cfg.batch_size.clamp(64, cfg.n_points);
    if let Some(it) = p.iters {
        cfg.local_iters = (it as usize).max(1);
    }
    let pts = Arc::new(generate_points(&cfg));
    Box::new(ScScenario::new(cfg, pts))
}

fn sgd_strategy(p: &ScenarioParams) -> DwStrategy {
    match p.variant.as_deref() {
        Some("pernode") => DwStrategy::PerNode,
        Some("permachine") => DwStrategy::PerMachine,
        _ => DwStrategy::PerCore,
    }
}

fn build_sgd(p: &ScenarioParams) -> Box<dyn Scenario> {
    let mut cfg = SgdConfig::bench(p.scale);
    cfg.seed = p.seed;
    if let Some(it) = p.iters {
        cfg.epochs = (it as usize).max(1);
    }
    let data = generate_data(&cfg);
    Box::new(SgdScenario::new(
        cfg,
        &data,
        sgd_strategy(p),
        SgdMode::Grad,
        Arc::new(RustGrad),
    ))
}

fn build_sgd_loss(p: &ScenarioParams) -> Box<dyn Scenario> {
    let mut cfg = SgdConfig::bench(p.scale);
    cfg.seed = p.seed;
    if let Some(it) = p.iters {
        cfg.epochs = (it as usize).max(1);
    }
    let data = generate_data(&cfg);
    Box::new(SgdScenario::new(
        cfg,
        &data,
        sgd_strategy(p),
        SgdMode::Loss,
        Arc::new(RustGrad),
    ))
}

/// Resolve a `--variant qN` selector to a query shape. Strict: running
/// a different query than requested would silently corrupt recorded
/// results, so malformed/out-of-range selectors panic.
fn query_variant(variant: Option<&str>, what: &str, default_id: usize) -> QuerySpec {
    let queries = all_queries();
    let id = match variant {
        None => default_id,
        Some(v) => {
            let parsed = v
                .trim_start_matches(|c| c == 'q' || c == 'Q')
                .parse::<usize>()
                .ok()
                .filter(|id| (1..=queries.len()).contains(id));
            parsed.unwrap_or_else(|| {
                panic!("{what} variant {v:?} is not q1..q{}", queries.len())
            })
        }
    };
    queries[id - 1].clone()
}

fn build_tpch(p: &ScenarioParams) -> Box<dyn Scenario> {
    let db = Arc::new(Db::generate(p.scale, p.seed));
    let spec = query_variant(p.variant.as_deref(), "tpch", 6);
    Box::new(OlapScenario::new(db, spec))
}

fn build_ycsb(p: &ScenarioParams) -> Box<dyn Scenario> {
    let wl = OltpWorkload::ycsb_scaled(p.scale);
    Box::new(OltpScenario::new(wl, p.iters.unwrap_or(20_000), p.seed))
}

fn build_tpcc(p: &ScenarioParams) -> Box<dyn Scenario> {
    let wl = OltpWorkload::tpcc_scaled(p.scale);
    Box::new(OltpScenario::new(wl, p.iters.unwrap_or(20_000), p.seed))
}

fn build_phase_shift(p: &ScenarioParams) -> Box<dyn Scenario> {
    // Phase-B stream: 6.4 GB at paper scale, floored well past twice a
    // chiplet's L3 (2 x 32 MB on milan_1s) so no compact placement can
    // ever cache it — the bandwidth phase must stay bandwidth-bound at
    // any --scale. `iters` sets the per-phase step count per rank.
    let bytes = ((6.4e9 * p.scale) as u64).max(96 << 20);
    let steps = p.iters.unwrap_or(60);
    Box::new(PhaseShiftScenario::new(bytes, steps, steps))
}

fn build_mem_follow(p: &ScenarioParams) -> Box<dyn Scenario> {
    // Stranded stream: 6.4 GB at paper scale, floored far past the whole
    // machine's aggregate L3 (8 x 32 MB on milan_1s) so phase B stays
    // DRAM-bound — both so the stranded home actually hurts and so the
    // low fill rate keeps the group compact (DRAM lines are not fill
    // events). `iters` sets the phase-B step count per rank; phase A is
    // 2x that, long enough to cover the controller's warmup + ramp-down.
    let bytes = ((6.4e9 * p.scale) as u64).max(2 << 30);
    let steps = p.iters.unwrap_or(60);
    Box::new(MemFollowScenario::new(bytes, steps * 2, steps))
}

fn build_mixed(p: &ScenarioParams) -> Box<dyn Scenario> {
    // YCSB table at the pure-OLTP scenario's scale convention, TPC-H
    // database at the OLAP one, co-resident. `iters` = transactions per
    // OLTP rank; `variant` picks the (join-free) scan query — Q1
    // pricing summary by default.
    let OltpWorkload::Ycsb { records, read_frac } = OltpWorkload::ycsb_scaled(p.scale) else {
        unreachable!("ycsb_scaled always builds a Ycsb workload")
    };
    let db = Arc::new(Db::generate(p.scale, p.seed));
    let spec = query_variant(p.variant.as_deref(), "mixed", 1);
    Box::new(MixedScenario::new(
        records,
        read_frac,
        p.iters.unwrap_or(10_000),
        p.seed,
        db,
        spec,
    ))
}

/// Default offered load of the synthetic serving traces, requests per
/// second of virtual time (the bench sweeps this; `--iters` scales the
/// request count).
const SERVE_RATE_RPS: f64 = 2.0e6;

/// Resolve the serve scenarios' trace: `params.trace` replays a text
/// trace file; otherwise a seeded synthetic trace (`variant` picks the
/// arrival process, Poisson by default; `iters` the request count).
fn serve_trace(
    p: &ScenarioParams,
    keyspace: u64,
    read_frac: f64,
    default_requests: u64,
) -> Arc<Trace> {
    if let Some(path) = &p.trace {
        let trace = Trace::load(std::path::Path::new(path))
            .unwrap_or_else(|e| panic!("cannot replay --trace {path}: {e}"));
        return Arc::new(trace);
    }
    let arrivals = match p.variant.as_deref() {
        None | Some("poisson") => ArrivalModel::Poisson,
        Some("uniform") => ArrivalModel::Uniform,
        // Diurnal swing compressed to simulation timescales: one "day"
        // every 2 ms of virtual time, ±80% around the mean rate.
        Some("diurnal") => ArrivalModel::Diurnal {
            period_ns: 2_000_000,
            depth: 0.8,
        },
        Some("bursty") => ArrivalModel::Bursty { burst: 64 },
        Some(v) => panic!("serve variant {v:?} is not poisson|uniform|diurnal|bursty"),
    };
    Arc::new(Trace::synth(&TraceConfig {
        requests: p.iters.unwrap_or(default_requests) as usize,
        rate_rps: SERVE_RATE_RPS,
        keyspace,
        zipf_theta: 0.99,
        read_frac,
        arrivals,
        seed: p.seed,
        priority_mix: p.priority_mix,
    }))
}

/// SLO / load-generation knobs shared by both serve builders.
fn serve_opts(p: &ScenarioParams) -> ServeOpts {
    ServeOpts {
        slo_shed_ns: p.slo_p99_ns,
        closed_loop_think_ns: p.closed_loop_think_ns,
    }
}

fn build_serve_kv(p: &ScenarioParams) -> Box<dyn Scenario> {
    let OltpWorkload::Ycsb { records, read_frac } = OltpWorkload::ycsb_scaled(p.scale) else {
        unreachable!("ycsb_scaled always builds a Ycsb workload")
    };
    let trace = serve_trace(p, records as u64, read_frac, 20_000);
    Box::new(ServeKvScenario::new(records, trace).with_opts(serve_opts(p)))
}

fn build_serve_cluster(p: &ScenarioParams) -> Box<dyn Scenario> {
    let OltpWorkload::Ycsb { records, read_frac } = OltpWorkload::ycsb_scaled(p.scale) else {
        unreachable!("ycsb_scaled always builds a Ycsb workload")
    };
    // Same KV serving as serve-kv, but the key hotspot *drifts*: the
    // keyspace rotates by ~a quarter every 500 µs, so a static
    // key→shard table goes stale and `Policy::plan_shard_moves` has
    // something to chase under `--machines N`. With stride locked to
    // the keyspace the pass stays deterministic per (scale, seed).
    let ks = records as u64;
    let trace = serve_trace(p, ks, read_frac, 20_000);
    let trace = Arc::new((*trace).clone().with_hotspot_drift(500_000, ks / 4 + 1, ks));
    Box::new(ServeKvScenario::new(records, trace).with_opts(serve_opts(p)))
}

fn build_serve_mixed(p: &ScenarioParams) -> Box<dyn Scenario> {
    let OltpWorkload::Ycsb { records, read_frac } = OltpWorkload::ycsb_scaled(p.scale) else {
        unreachable!("ycsb_scaled always builds a Ycsb workload")
    };
    let trace = serve_trace(p, records as u64, read_frac, 10_000);
    let db = Arc::new(Db::generate(p.scale, p.seed));
    // The scan tenant is fixed to Q1 (the join-free pricing summary):
    // `variant` selects the serve arrival model here, not the query.
    let spec = all_queries()[0].clone();
    Box::new(ServeMixedScenario::new(records, trace, db, spec).with_opts(serve_opts(p)))
}

/// The serve scenarios take every optional knob.
const SERVE_ACCEPTS: &[&str] = &[
    "--variant",
    "--trace",
    "--priority-mix",
    "--slo-p99",
    "--closed-loop",
];

static REGISTRY: &[ScenarioSpec] = &[
    ScenarioSpec {
        name: "bfs",
        aliases: &[],
        family: "graph",
        about: "level-synchronous BFS on a Kronecker graph (TEPS)",
        accepts: &[],
        build: build_bfs,
    },
    ScenarioSpec {
        name: "pagerank",
        aliases: &["pr"],
        family: "graph",
        about: "push-based PageRank, 3 BSP phases/iteration",
        accepts: &[],
        build: build_pagerank,
    },
    ScenarioSpec {
        name: "bfs-random-roots",
        aliases: &["bfs-rr"],
        family: "graph",
        about: "Graph500-style BFS from seeded random roots (--iters = search keys)",
        accepts: &[],
        build: build_bfs_random_roots,
    },
    ScenarioSpec {
        name: "cc",
        aliases: &[],
        family: "graph",
        about: "connected components via label propagation",
        accepts: &[],
        build: build_cc,
    },
    ScenarioSpec {
        name: "sssp",
        aliases: &[],
        family: "graph",
        about: "chunked Bellman-Ford single-source shortest paths",
        accepts: &[],
        build: build_sssp,
    },
    ScenarioSpec {
        name: "gups",
        aliases: &[],
        family: "graph",
        about: "HPCC RandomAccess XOR updates (GUPS)",
        accepts: &[],
        build: build_gups,
    },
    ScenarioSpec {
        name: "streamcluster",
        aliases: &["sc"],
        family: "streamcluster",
        about: "PARSEC streaming k-median clustering",
        accepts: &[],
        build: build_streamcluster,
    },
    ScenarioSpec {
        name: "sgd",
        aliases: &[],
        family: "sgd",
        about: "DimmWitted-style SGD, logistic regression (gradient mode)",
        accepts: &["--variant"],
        build: build_sgd,
    },
    ScenarioSpec {
        name: "sgd-loss",
        aliases: &[],
        family: "sgd",
        about: "DimmWitted-style SGD, forward pass only (loss mode)",
        accepts: &["--variant"],
        build: build_sgd_loss,
    },
    ScenarioSpec {
        name: "tpch",
        aliases: &["olap"],
        family: "olap",
        about: "one TPC-H-shaped query on the mini OLAP engine (--variant q1..q22)",
        accepts: &["--variant"],
        build: build_tpch,
    },
    ScenarioSpec {
        name: "ycsb",
        aliases: &[],
        family: "oltp",
        about: "YCSB key-value mix on the ERMIA-style OLTP engine",
        accepts: &[],
        build: build_ycsb,
    },
    ScenarioSpec {
        name: "tpcc",
        aliases: &[],
        family: "oltp",
        about: "TPC-C-lite transaction mix on the OLTP engine",
        accepts: &[],
        build: build_tpcc,
    },
    ScenarioSpec {
        name: "mixed-oltp-olap",
        aliases: &["mixed"],
        family: "mixed",
        about: "YCSB + TPC-H scan co-resident: cross-tenant cache/bandwidth contention",
        accepts: &["--variant"],
        build: build_mixed,
    },
    ScenarioSpec {
        name: "phase-shift",
        aliases: &["phaseshift"],
        family: "adaptive",
        about: "message-bound phase then bandwidth-bound phase: adaptive migration beats every static placement",
        accepts: &[],
        build: build_phase_shift,
    },
    ScenarioSpec {
        name: "mem-follow",
        aliases: &["memfollow"],
        family: "adaptive",
        about: "message-bound phase then a DRAM stream on a mis-homed region: only online region moves fix it",
        accepts: &[],
        build: build_mem_follow,
    },
    ScenarioSpec {
        name: "serve-kv",
        aliases: &["serve"],
        family: "serve",
        about: "open-loop trace-replay KV serving with per-request p50/p95/p99 latency",
        accepts: SERVE_ACCEPTS,
        build: build_serve_kv,
    },
    ScenarioSpec {
        name: "serve-mixed",
        aliases: &[],
        family: "serve",
        about: "KV serving co-resident with a TPC-H scan tenant (tail under interference)",
        accepts: SERVE_ACCEPTS,
        build: build_serve_mixed,
    },
    ScenarioSpec {
        name: "serve-cluster",
        aliases: &[],
        family: "serve",
        about: "KV serving with a drifting key hotspot, built for --machines N shard fan-out",
        accepts: SERVE_ACCEPTS,
        build: build_serve_cluster,
    },
];

/// Every registered scenario.
pub fn registry() -> &'static [ScenarioSpec] {
    REGISTRY
}

/// Resolve a scenario by canonical name or alias.
pub fn by_name(name: &str) -> Option<&'static ScenarioSpec> {
    REGISTRY
        .iter()
        .find(|s| s.name == name || s.aliases.contains(&name))
}

/// The `arcas scenarios` listing: one row per registry entry. Rendered
/// here (not in `main.rs`) so tests can pin that every registered name
/// shows up in the CLI output.
pub fn scenarios_table() -> String {
    let mut tab = crate::util::table::Table::new(
        "scenario registry (arcas run --scenario <name>)",
        &["name", "family", "aliases", "params", "description"],
    );
    for s in registry() {
        tab.row(vec![
            s.name.to_string(),
            s.family.to_string(),
            s.aliases.join(","),
            s.accepts.join(","),
            s.about.to_string(),
        ]);
    }
    let mut out = tab.render();
    out.push_str(
        "\nevery scenario also accepts the engine-wide knobs: --policy, --cores, \
         --backend sim|host, --repeat, --batch-steps (host run-until-yield batch \
         budget; 1 = step-per-job), --topology, --timer-us, --seed, --verify\n\
         with --policy arcas|adaptive, --timer-us is the adaptation cadence: \
         virtual time on sim, real elapsed time on host; adaptive runs report \
         migrations and per-window decisions (t_ns, fill rate, spread) in the \
         run report\n\
         adaptive ticks also re-home Bind regions toward their accessors' \
         NUMA node (data follows tasks): runs report region-moves and \
         per-move decisions (t_ns, region, dest numa); --no-region-moves \
         keeps the task-move-only behavior\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_resolve_and_aliases_map() {
        for spec in registry() {
            assert!(by_name(spec.name).is_some(), "{}", spec.name);
            for a in spec.aliases {
                assert_eq!(by_name(a).unwrap().name, spec.name);
            }
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn names_are_unique() {
        let mut seen = std::collections::BTreeSet::new();
        for spec in registry() {
            assert!(seen.insert(spec.name), "duplicate name {}", spec.name);
            for a in spec.aliases {
                assert!(seen.insert(*a), "duplicate alias {a}");
            }
        }
    }

    #[test]
    fn serve_kv_replays_a_trace_file() {
        let path = std::env::temp_dir().join(format!(
            "arcas_registry_trace_{}.txt",
            std::process::id()
        ));
        std::fs::write(&path, "# tiny trace\n0 r 1\n100 u 2\n200 r 3\n").unwrap();
        let p = ScenarioParams {
            trace: Some(path.to_string_lossy().into_owned()),
            ..Default::default()
        };
        let topo = crate::topology::Topology::milan_1s();
        let mut s = by_name("serve-kv").unwrap().build(&p);
        let run = crate::engine::Driver::new(
            &topo,
            crate::policy::by_name("local", &topo).unwrap(),
            2,
        )
        .with_verify(true)
        .run(s.as_mut());
        std::fs::remove_file(&path).ok();
        let lat = run.report.request_latency.expect("trace replay must report latency");
        assert_eq!(lat.count, 3);
        assert_eq!(run.metrics.items, 3.0);
    }

    #[test]
    #[should_panic(expected = "cannot replay --trace")]
    fn serve_kv_missing_trace_file_panics_with_context() {
        let p = ScenarioParams {
            trace: Some("/nonexistent/arcas-trace.txt".into()),
            ..Default::default()
        };
        let _ = by_name("serve-kv").unwrap().build(&p);
    }

    #[test]
    #[should_panic(expected = "serve variant")]
    fn serve_rejects_unknown_arrival_models() {
        let p = ScenarioParams {
            variant: Some("warp-speed".into()),
            iters: Some(4),
            ..Default::default()
        };
        let _ = by_name("serve-kv").unwrap().build(&p);
    }

    #[test]
    fn serve_variants_build_distinct_arrival_processes() {
        // Same seed/count, different arrival models: the traces the
        // scenarios run must differ (and each build is deterministic).
        let build_trace = |variant: Option<&str>| {
            let p = ScenarioParams {
                scale: 0.002,
                iters: Some(64),
                variant: variant.map(str::to_string),
                ..Default::default()
            };
            // Build twice to check determinism of the constructor path.
            let _ = by_name("serve-kv").unwrap().build(&p);
            super::serve_trace(&p, 1_000, 0.45, 64)
        };
        let poisson = build_trace(None);
        assert_eq!(poisson, build_trace(Some("poisson")));
        for v in ["uniform", "diurnal", "bursty"] {
            assert_ne!(poisson, build_trace(Some(v)), "{v} must differ from poisson");
        }
    }

    #[test]
    fn validate_rejects_unaccepted_knobs_naming_the_flag() {
        let spec = by_name("pagerank").unwrap();
        let p = ScenarioParams {
            priority_mix: Some(PriorityMix {
                critical: 0.1,
                background: 0.1,
            }),
            ..Default::default()
        };
        let err = spec.try_build(&p).err().expect("pagerank must reject --priority-mix");
        assert!(err.contains("--priority-mix"), "{err}");
        assert!(err.contains("pagerank"), "{err}");
        assert!(err.contains("--scale/--seed/--iters"), "{err}");

        // tpch takes --variant but not --trace; the error names the
        // accepted extras.
        let spec = by_name("tpch").unwrap();
        let p = ScenarioParams {
            trace: Some("/tmp/t.txt".into()),
            ..Default::default()
        };
        let err = spec.try_build(&p).err().unwrap();
        assert!(err.contains("--trace") && err.contains("--variant"), "{err}");
    }

    #[test]
    #[should_panic(expected = "does not accept --closed-loop")]
    fn build_panics_on_knobs_the_scenario_rejects() {
        let p = ScenarioParams {
            closed_loop_think_ns: Some(1_000),
            ..Default::default()
        };
        let _ = by_name("gups").unwrap().build(&p);
    }

    #[test]
    fn serve_accepts_every_slo_knob_and_threads_the_mix() {
        let p = ScenarioParams {
            iters: Some(64),
            priority_mix: Some(PriorityMix {
                critical: 0.5,
                background: 0.5,
            }),
            slo_p99_ns: Some(100_000),
            ..Default::default()
        };
        for name in ["serve-kv", "serve-mixed"] {
            let spec = by_name(name).unwrap();
            assert!(spec.validate(&p).is_ok(), "{name} must accept SLO knobs");
            let _ = spec.try_build(&p).unwrap();
        }
        // The mix reaches the generated trace: with critical+background
        // at 1.0, no request stays Normal.
        let trace = serve_trace(&p, 1_000, 0.45, 64);
        assert!(trace
            .requests
            .iter()
            .all(|r| r.priority != crate::engine::Priority::Normal));
    }

    #[test]
    fn scenarios_table_lists_accepted_params() {
        let t = scenarios_table();
        assert!(t.contains("params"));
        assert!(t.contains("--priority-mix"));
        // The footer documents the engine-wide knobs every scenario takes.
        assert!(t.contains("--batch-steps"));
        assert!(t.contains("--backend sim|host"));
    }

    #[test]
    fn graph_scale_tracks_the_paper_size() {
        let p = ScenarioParams {
            scale: 1.0,
            ..Default::default()
        };
        assert_eq!(graph_scale(&p), 24);
        let tiny = ScenarioParams {
            scale: 1e-9,
            ..Default::default()
        };
        assert_eq!(graph_scale(&tiny), 10); // floor at 1024 vertices
    }
}
