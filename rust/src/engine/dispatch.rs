//! Open-loop request dispatch + per-request latency accounting: the
//! engine-side machinery of the serving subsystem.
//!
//! **Open loop** means the arrival process is fixed ahead of time (a
//! trace), and requests keep arriving whether or not the servers keep
//! up — the difference between "how fast can we drain work" (batch
//! throughput) and "how long did each user wait" (serving latency). The
//! pieces here are workload-agnostic; `workloads::serve` instantiates
//! them with KV requests:
//!
//! - [`OpenLoopQueue`] — a lock-free FCFS admission queue over a
//!   time-ordered item list. Server coroutines `pop()` the next
//!   undispatched request; a request whose arrival timestamp is still in
//!   the future makes the server *wait for it* (advance its virtual
//!   clock), never the other way round. On the Sim backend the executor
//!   always steps the earliest-clock core, so pops follow virtual time
//!   deterministically (an M/G/k-style multi-server queue); on the Host
//!   backend workers race on the same atomic cursor and every request is
//!   still dispatched exactly once.
//! - [`LatencyRecorder`] — folds each request's sojourn
//!   (queue wait + service) into a [`LogHistogram`], with queue/service
//!   mean breakdowns; mergeable so each worker records locally and
//!   merges once at the end. [`LatencyRecorder::report`] produces the
//!   [`LatencyReport`] carried in [`RunReport::request_latency`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::sched::LatencyReport;
use crate::util::stats::{LogHistogram, Summary};

/// Lock-free FCFS admission over a fixed, time-ordered item list.
///
/// `T` is the request type (kept generic so the engine layer stays free
/// of workload types); items must be sorted by arrival time for the
/// FCFS claim to mean anything — the serve trace constructors enforce
/// that.
#[derive(Debug)]
pub struct OpenLoopQueue<T> {
    items: Vec<T>,
    next: AtomicUsize,
}

impl<T: Copy> OpenLoopQueue<T> {
    pub fn new(items: Vec<T>) -> Arc<Self> {
        Arc::new(Self {
            items,
            next: AtomicUsize::new(0),
        })
    }

    /// Claim the next undispatched item (exactly-once across all
    /// workers); `None` once the trace is drained.
    #[inline]
    pub fn pop(&self) -> Option<T> {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        self.items.get(i).copied()
    }

    /// Total number of items in the trace.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Items not yet claimed (racy snapshot under concurrency).
    pub fn remaining(&self) -> usize {
        self.items
            .len()
            .saturating_sub(self.next.load(Ordering::Relaxed))
    }
}

/// Per-request latency accounting: sojourn = queue wait + service.
#[derive(Clone, Debug)]
pub struct LatencyRecorder {
    sojourn: LogHistogram,
    queue: Summary,
    service: Summary,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self {
            sojourn: LogHistogram::new(),
            queue: Summary::new(),
            service: Summary::new(),
        }
    }

    /// Record one served request.
    #[inline]
    pub fn record(&mut self, queue_ns: u64, service_ns: u64) {
        self.sojourn.record(queue_ns + service_ns);
        self.queue.add(queue_ns as f64);
        self.service.add(service_ns as f64);
    }

    /// Fold another recorder in (workers record locally, merge once).
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.sojourn.merge(&other.sojourn);
        self.queue.merge(&other.queue);
        self.service.merge(&other.service);
    }

    pub fn count(&self) -> u64 {
        self.sojourn.count()
    }

    /// The sojourn histogram (CDF/quantile source for benches).
    pub fn histogram(&self) -> &LogHistogram {
        &self.sojourn
    }

    /// The aggregate carried in `RunReport::request_latency` (`None`
    /// when nothing was recorded).
    pub fn report(&self) -> Option<LatencyReport> {
        if self.sojourn.is_empty() {
            return None;
        }
        Some(LatencyReport {
            count: self.sojourn.count(),
            mean_ns: self.sojourn.mean(),
            p50_ns: self.sojourn.quantile(0.50),
            p95_ns: self.sojourn.quantile(0.95),
            p99_ns: self.sojourn.quantile(0.99),
            max_ns: self.sojourn.max(),
            mean_queue_ns: self.queue.mean(),
            mean_service_ns: self.service.mean(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_dispatches_each_item_exactly_once_in_order() {
        let q = OpenLoopQueue::new((0..100u64).collect());
        assert_eq!(q.len(), 100);
        assert_eq!(q.remaining(), 100);
        let mut seen = Vec::new();
        while let Some(v) = q.pop() {
            seen.push(v);
        }
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
        assert_eq!(q.remaining(), 0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn queue_is_exactly_once_under_concurrency() {
        use std::sync::Mutex;
        let q = OpenLoopQueue::new((0..10_000u64).collect());
        let seen = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let q = q.clone();
            let seen = seen.clone();
            handles.push(std::thread::spawn(move || {
                let mut local = Vec::new();
                while let Some(v) = q.pop() {
                    local.push(v);
                }
                seen.lock().unwrap().extend(local);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut all = Arc::try_unwrap(seen).unwrap().into_inner().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..10_000).collect::<Vec<_>>());
    }

    #[test]
    fn empty_queue_and_empty_recorder() {
        let q: Arc<OpenLoopQueue<u64>> = OpenLoopQueue::new(Vec::new());
        assert!(q.is_empty());
        assert!(q.pop().is_none());
        assert!(LatencyRecorder::new().report().is_none());
    }

    #[test]
    fn recorder_aggregates_sojourn_and_breakdown() {
        let mut r = LatencyRecorder::new();
        r.record(100, 900); // sojourn 1000
        r.record(0, 500);
        r.record(2_000, 1_000); // tail: 3000
        let rep = r.report().unwrap();
        assert_eq!(rep.count, 3);
        assert_eq!(rep.max_ns, 3_000);
        assert!(rep.p50_ns <= rep.p95_ns && rep.p95_ns <= rep.p99_ns);
        assert!(rep.p99_ns <= rep.max_ns);
        assert!((rep.mean_ns - 1500.0).abs() < 1e-9);
        assert!((rep.mean_queue_ns - 700.0).abs() < 1e-9);
        assert!((rep.mean_service_ns - 800.0).abs() < 1e-9);
    }

    #[test]
    fn recorder_merge_equals_combined() {
        let mut a = LatencyRecorder::new();
        let mut b = LatencyRecorder::new();
        let mut all = LatencyRecorder::new();
        for i in 0..1000u64 {
            let (q, s) = (i * 7 % 5000, 200 + i % 800);
            all.record(q, s);
            if i % 2 == 0 {
                a.record(q, s);
            } else {
                b.record(q, s);
            }
        }
        a.merge(&b);
        assert_eq!(a.report(), all.report());
    }
}
