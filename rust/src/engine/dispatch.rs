//! Open-loop request dispatch + per-request latency accounting: the
//! engine-side machinery of the serving subsystem.
//!
//! **Open loop** means the arrival process is fixed ahead of time (a
//! trace), and requests keep arriving whether or not the servers keep
//! up — the difference between "how fast can we drain work" (batch
//! throughput) and "how long did each user wait" (serving latency). The
//! pieces here are workload-agnostic; `workloads::serve` instantiates
//! them with KV requests:
//!
//! - [`OpenLoopQueue`] — a lock-free FCFS admission queue over a
//!   time-ordered item list. Server coroutines `pop()` the next
//!   undispatched request; a request whose arrival timestamp is still in
//!   the future makes the server *wait for it* (advance its virtual
//!   clock), never the other way round. On the Sim backend the executor
//!   always steps the earliest-clock core, so pops follow virtual time
//!   deterministically (an M/G/k-style multi-server queue); on the Host
//!   backend workers race on the same atomic cursor and every request is
//!   still dispatched exactly once.
//! - [`TieredQueue`] — the SLO-aware admission front: three per-class
//!   FCFS queues ([`Priority::Critical`] / `Normal` / `Background`).
//!   `pop(now)` serves the highest-priority class *among requests that
//!   have already arrived* (never idling a server on a future Critical
//!   arrival while queued lower-class work waits), with a
//!   promoted-after-N-streak anti-starvation rule and optional
//!   Background load shedding once queue wait exceeds an SLO target.
//!   With a single class it degenerates to [`OpenLoopQueue`] exactly.
//! - [`LatencyRecorder`] — folds each request's sojourn
//!   (queue wait + service) into a [`LogHistogram`], with queue/service
//!   mean breakdowns; mergeable so each worker records locally and
//!   merges once at the end. [`LatencyRecorder::report`] produces the
//!   [`LatencyReport`] carried in [`RunReport::request_latency`].
//!   [`ClassLatencyRecorder`] keeps the same aggregate plus one recorder
//!   per priority class for per-class quantiles.
//! - [`SloSignal`] — the monitoring→placement feedback channel:
//!   serve workers publish per-chiplet queue-wait/service windows here,
//!   and a policy connected via `Policy::connect_slo` drains them on its
//!   timer to decide spreading vs compaction (`policy::SloPolicy`).

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use crate::sched::LatencyReport;
use crate::util::stats::{LogHistogram, Summary};

/// Request priority class, Critical first. Dispatch order under the
/// [`TieredQueue`]: among *arrived* requests, lower value wins.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-sensitive foreground traffic (served first).
    Critical = 0,
    /// Ordinary traffic.
    #[default]
    Normal = 1,
    /// Best-effort traffic: served last, shed first under overload.
    Background = 2,
}

impl Priority {
    /// Every class, dispatch order (Critical first).
    pub const ALL: [Priority; 3] = [Priority::Critical, Priority::Normal, Priority::Background];

    pub fn as_str(self) -> &'static str {
        match self {
            Priority::Critical => "critical",
            Priority::Normal => "normal",
            Priority::Background => "background",
        }
    }

    /// Index into per-class arrays.
    #[inline]
    pub fn idx(self) -> usize {
        self as usize
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for Priority {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "c" | "crit" | "critical" => Ok(Priority::Critical),
            "n" | "normal" => Ok(Priority::Normal),
            "b" | "bg" | "background" => Ok(Priority::Background),
            other => Err(format!(
                "unknown priority {other:?} (c|crit|critical, n|normal, b|bg|background)"
            )),
        }
    }
}

/// What the [`TieredQueue`] needs to know about an item: when it arrives
/// and which class it belongs to. `workloads::serve::Request` implements
/// this; the queue itself stays workload-agnostic.
pub trait Prioritized: Copy {
    fn arrival_ns(&self) -> u64;
    fn priority(&self) -> Priority;
}

/// Lock-free FCFS admission over a fixed, time-ordered item list.
///
/// `T` is the request type (kept generic so the engine layer stays free
/// of workload types); items must be sorted by arrival time for the
/// FCFS claim to mean anything — the serve trace constructors enforce
/// that.
#[derive(Debug)]
pub struct OpenLoopQueue<T> {
    items: Vec<T>,
    next: AtomicUsize,
}

impl<T: Copy> OpenLoopQueue<T> {
    pub fn new(items: Vec<T>) -> Arc<Self> {
        Arc::new(Self {
            items,
            next: AtomicUsize::new(0),
        })
    }

    /// Claim the next undispatched item (exactly-once across all
    /// workers); `None` once the trace is drained.
    #[inline]
    pub fn pop(&self) -> Option<T> {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        self.items.get(i).copied()
    }

    /// Total number of items in the trace.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Items not yet claimed (racy snapshot under concurrency).
    pub fn remaining(&self) -> usize {
        self.items
            .len()
            .saturating_sub(self.next.load(Ordering::Relaxed))
    }
}

/// Per-request latency accounting: sojourn = queue wait + service.
#[derive(Clone, Debug)]
pub struct LatencyRecorder {
    sojourn: LogHistogram,
    queue: Summary,
    service: Summary,
}

impl Default for LatencyRecorder {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyRecorder {
    pub fn new() -> Self {
        Self {
            sojourn: LogHistogram::new(),
            queue: Summary::new(),
            service: Summary::new(),
        }
    }

    /// Record one served request.
    #[inline]
    pub fn record(&mut self, queue_ns: u64, service_ns: u64) {
        self.sojourn.record(queue_ns + service_ns);
        self.queue.add(queue_ns as f64);
        self.service.add(service_ns as f64);
    }

    /// Fold another recorder in (workers record locally, merge once).
    pub fn merge(&mut self, other: &LatencyRecorder) {
        self.sojourn.merge(&other.sojourn);
        self.queue.merge(&other.queue);
        self.service.merge(&other.service);
    }

    pub fn count(&self) -> u64 {
        self.sojourn.count()
    }

    /// The sojourn histogram (CDF/quantile source for benches).
    pub fn histogram(&self) -> &LogHistogram {
        &self.sojourn
    }

    /// The aggregate carried in `RunReport::request_latency` (`None`
    /// when nothing was recorded).
    pub fn report(&self) -> Option<LatencyReport> {
        if self.sojourn.is_empty() {
            return None;
        }
        Some(LatencyReport {
            count: self.sojourn.count(),
            mean_ns: self.sojourn.mean(),
            p50_ns: self.sojourn.quantile(0.50),
            p95_ns: self.sojourn.quantile(0.95),
            p99_ns: self.sojourn.quantile(0.99),
            max_ns: self.sojourn.max(),
            mean_queue_ns: self.queue.mean(),
            mean_service_ns: self.service.mean(),
        })
    }
}

/// Consecutive higher-class dispatches after which an *arrived*
/// Background request is force-promoted to the front — the streak-based
/// anti-starvation rule: under sustained Critical/Normal load, at least
/// one in every `BACKGROUND_STARVATION_LIMIT + 1` dispatches is
/// Background (when one is waiting).
pub const BACKGROUND_STARVATION_LIMIT: u32 = 100;

/// SLO-aware admission front: one FCFS queue per [`Priority`] class over
/// a fixed, time-ordered trace.
///
/// `pop(now_ns)` claims exactly-once across workers (per-class CAS
/// cursors), choosing:
/// 1. among classes whose head has **arrived** (`arrival_ns <= now`),
///    the highest-priority one — except when the anti-starvation streak
///    has hit [`BACKGROUND_STARVATION_LIMIT`], in which case an arrived
///    Background head is served first;
/// 2. when nothing has arrived yet, the earliest-arriving head across
///    classes (plain FCFS — a server never idles on a future
///    high-priority arrival while another class's request is due
///    sooner).
///
/// With `shed_after_ns` set, Background requests whose queue wait
/// already exceeds the target at claim time are dropped instead of
/// served (load shedding; counted per class in [`TieredQueue::shed`]).
/// Critical and Normal requests are never shed.
#[derive(Debug)]
pub struct TieredQueue<T> {
    classes: [Vec<T>; 3],
    next: [AtomicUsize; 3],
    streak: AtomicU32,
    shed: [AtomicU64; 3],
    shed_after_ns: Option<u64>,
    total: usize,
}

impl<T: Prioritized> TieredQueue<T> {
    /// Partition `items` (time-ordered) into per-class FCFS queues.
    /// `shed_after_ns`: queue-wait budget after which Background
    /// requests are shed (`None` = never shed; the default path).
    pub fn new(items: Vec<T>, shed_after_ns: Option<u64>) -> Arc<Self> {
        let total = items.len();
        let mut classes: [Vec<T>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for item in items {
            classes[item.priority().idx()].push(item);
        }
        Arc::new(Self {
            classes,
            next: [AtomicUsize::new(0), AtomicUsize::new(0), AtomicUsize::new(0)],
            streak: AtomicU32::new(0),
            shed: [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)],
            shed_after_ns,
            total,
        })
    }

    /// Claim the next request to serve as of virtual time `now_ns`
    /// (exactly-once across workers); `None` once every class is
    /// drained. Shed Background requests are consumed internally (the
    /// caller never sees them) and counted.
    pub fn pop(&self, now_ns: u64) -> Option<T> {
        loop {
            // Snapshot the per-class heads (racy; claims re-validate via
            // CAS below).
            let mut heads: [Option<(usize, T)>; 3] = [None, None, None];
            for (c, class) in self.classes.iter().enumerate() {
                let i = self.next[c].load(Ordering::Acquire);
                heads[c] = class.get(i).map(|&item| (i, item));
            }
            // Pick a class: highest priority among arrived heads, with
            // the starvation override; else the earliest future arrival.
            let arrived = |h: Option<(usize, T)>| {
                h.is_some_and(|(_, item)| item.arrival_ns() <= now_ns)
            };
            let pick = if self.streak.load(Ordering::Relaxed) >= BACKGROUND_STARVATION_LIMIT
                && arrived(heads[Priority::Background.idx()])
            {
                Priority::Background.idx()
            } else if let Some(c) = (0..3).find(|&c| arrived(heads[c])) {
                c
            } else {
                // Nothing due yet: plain FCFS on the earliest arrival.
                (0..3)
                    .filter(|&c| heads[c].is_some())
                    .min_by_key(|&c| heads[c].map(|(_, item)| item.arrival_ns()))?
            };
            let (i, item) = heads[pick].expect("picked class has a head");
            if self.next[pick]
                .compare_exchange(i, i + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue; // lost the claim race; re-snapshot
            }
            if pick == Priority::Background.idx() {
                self.streak.store(0, Ordering::Relaxed);
            } else {
                // Saturating streak: plain add could wrap u32 on
                // pathological all-Critical traces.
                let s = self.streak.load(Ordering::Relaxed);
                self.streak
                    .store(s.saturating_add(1), Ordering::Relaxed);
            }
            // Load shedding: a Background request whose wait already
            // blew the budget is dropped, not served.
            if let Some(budget) = self.shed_after_ns {
                if item.priority() == Priority::Background
                    && now_ns.saturating_sub(item.arrival_ns()) > budget
                {
                    self.shed[pick].fetch_add(1, Ordering::Relaxed);
                    continue;
                }
            }
            return Some(item);
        }
    }

    /// Total items in the trace (served + shed + unclaimed).
    pub fn len(&self) -> usize {
        self.total
    }

    pub fn is_empty(&self) -> bool {
        self.total == 0
    }

    /// Items of `class` in the trace.
    pub fn class_len(&self, class: Priority) -> usize {
        self.classes[class.idx()].len()
    }

    /// Requests shed per class (only Background can be non-zero).
    pub fn shed_counts(&self) -> [u64; 3] {
        [
            self.shed[0].load(Ordering::Relaxed),
            self.shed[1].load(Ordering::Relaxed),
            self.shed[2].load(Ordering::Relaxed),
        ]
    }

    /// Total requests shed.
    pub fn shed_total(&self) -> u64 {
        self.shed_counts().iter().sum()
    }

    /// Items not yet claimed (racy snapshot under concurrency).
    pub fn remaining(&self) -> usize {
        (0..3)
            .map(|c| {
                self.classes[c]
                    .len()
                    .saturating_sub(self.next[c].load(Ordering::Relaxed))
            })
            .sum()
    }
}

/// [`LatencyRecorder`] per priority class plus the all-classes
/// aggregate. Workers record locally and merge once at drain, exactly
/// like the single-class recorder.
#[derive(Clone, Debug, Default)]
pub struct ClassLatencyRecorder {
    total: LatencyRecorder,
    classes: [LatencyRecorder; 3],
}

impl ClassLatencyRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one served request of `class`.
    #[inline]
    pub fn record(&mut self, class: Priority, queue_ns: u64, service_ns: u64) {
        self.total.record(queue_ns, service_ns);
        self.classes[class.idx()].record(queue_ns, service_ns);
    }

    pub fn merge(&mut self, other: &ClassLatencyRecorder) {
        self.total.merge(&other.total);
        for (mine, theirs) in self.classes.iter_mut().zip(&other.classes) {
            mine.merge(theirs);
        }
    }

    pub fn count(&self) -> u64 {
        self.total.count()
    }

    /// The all-classes sojourn histogram (CDF source for benches).
    pub fn histogram(&self) -> &LogHistogram {
        self.total.histogram()
    }

    /// The all-classes aggregate (what `RunReport::request_latency`
    /// carries).
    pub fn report(&self) -> Option<LatencyReport> {
        self.total.report()
    }

    /// One class's aggregate (`None` when that class saw no traffic).
    pub fn class_report(&self, class: Priority) -> Option<LatencyReport> {
        self.classes[class.idx()].report()
    }

    /// `(class name, aggregate)` for every class that saw traffic —
    /// the `RunReport::class_latency` payload.
    pub fn class_reports(&self) -> Vec<(&'static str, LatencyReport)> {
        Priority::ALL
            .iter()
            .filter_map(|&p| self.class_report(p).map(|r| (p.as_str(), r)))
            .collect()
    }
}

/// Feedback channel from serve workers to an SLO-aware placement policy:
/// per-chiplet queue-wait and service-time accumulators for the current
/// profiling window. Workers [`SloSignal::record`] after each request;
/// the policy [`SloSignal::drain`]s on its timer (sums + resets), so each
/// window is independent. Plain atomics: recording on the hot path is a
/// few relaxed adds, and the sim backend's deterministic stepping makes
/// window contents reproducible.
#[derive(Debug)]
pub struct SloSignal {
    queue_ns: Vec<AtomicU64>,
    service_ns: Vec<AtomicU64>,
    count: Vec<AtomicU64>,
}

/// One drained per-chiplet window.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SloWindow {
    pub queue_ns: u64,
    pub service_ns: u64,
    pub count: u64,
}

impl SloSignal {
    pub fn new(num_chiplets: usize) -> Arc<Self> {
        let mk = || (0..num_chiplets.max(1)).map(|_| AtomicU64::new(0)).collect();
        Arc::new(Self {
            queue_ns: mk(),
            service_ns: mk(),
            count: mk(),
        })
    }

    pub fn num_chiplets(&self) -> usize {
        self.count.len()
    }

    /// Publish one served request from `chiplet`.
    #[inline]
    pub fn record(&self, chiplet: usize, queue_ns: u64, service_ns: u64) {
        let c = chiplet.min(self.count.len() - 1);
        self.queue_ns[c].fetch_add(queue_ns, Ordering::Relaxed);
        self.service_ns[c].fetch_add(service_ns, Ordering::Relaxed);
        self.count[c].fetch_add(1, Ordering::Relaxed);
    }

    /// Take and reset the current window, one entry per chiplet.
    pub fn drain(&self) -> Vec<SloWindow> {
        (0..self.count.len())
            .map(|c| SloWindow {
                queue_ns: self.queue_ns[c].swap(0, Ordering::Relaxed),
                service_ns: self.service_ns[c].swap(0, Ordering::Relaxed),
                count: self.count[c].swap(0, Ordering::Relaxed),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn queue_dispatches_each_item_exactly_once_in_order() {
        let q = OpenLoopQueue::new((0..100u64).collect());
        assert_eq!(q.len(), 100);
        assert_eq!(q.remaining(), 100);
        let mut seen = Vec::new();
        while let Some(v) = q.pop() {
            seen.push(v);
        }
        assert_eq!(seen, (0..100).collect::<Vec<_>>());
        assert_eq!(q.remaining(), 0);
        assert!(q.pop().is_none());
    }

    #[test]
    fn queue_is_exactly_once_under_concurrency() {
        use std::sync::Mutex;
        let q = OpenLoopQueue::new((0..10_000u64).collect());
        let seen = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let q = q.clone();
            let seen = seen.clone();
            handles.push(std::thread::spawn(move || {
                let mut local = Vec::new();
                while let Some(v) = q.pop() {
                    local.push(v);
                }
                seen.lock().unwrap().extend(local);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut all = Arc::try_unwrap(seen).unwrap().into_inner().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..10_000).collect::<Vec<_>>());
    }

    #[test]
    fn empty_queue_and_empty_recorder() {
        let q: Arc<OpenLoopQueue<u64>> = OpenLoopQueue::new(Vec::new());
        assert!(q.is_empty());
        assert!(q.pop().is_none());
        assert!(LatencyRecorder::new().report().is_none());
    }

    #[test]
    fn recorder_aggregates_sojourn_and_breakdown() {
        let mut r = LatencyRecorder::new();
        r.record(100, 900); // sojourn 1000
        r.record(0, 500);
        r.record(2_000, 1_000); // tail: 3000
        let rep = r.report().unwrap();
        assert_eq!(rep.count, 3);
        assert_eq!(rep.max_ns, 3_000);
        assert!(rep.p50_ns <= rep.p95_ns && rep.p95_ns <= rep.p99_ns);
        assert!(rep.p99_ns <= rep.max_ns);
        assert!((rep.mean_ns - 1500.0).abs() < 1e-9);
        assert!((rep.mean_queue_ns - 700.0).abs() < 1e-9);
        assert!((rep.mean_service_ns - 800.0).abs() < 1e-9);
    }

    #[test]
    fn recorder_merge_equals_combined() {
        let mut a = LatencyRecorder::new();
        let mut b = LatencyRecorder::new();
        let mut all = LatencyRecorder::new();
        for i in 0..1000u64 {
            let (q, s) = (i * 7 % 5000, 200 + i % 800);
            all.record(q, s);
            if i % 2 == 0 {
                a.record(q, s);
            } else {
                b.record(q, s);
            }
        }
        a.merge(&b);
        assert_eq!(a.report(), all.report());
    }

    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    struct Item {
        at: u64,
        pri: Priority,
        id: u64,
    }

    impl Prioritized for Item {
        fn arrival_ns(&self) -> u64 {
            self.at
        }

        fn priority(&self) -> Priority {
            self.pri
        }
    }

    fn item(at: u64, pri: Priority, id: u64) -> Item {
        Item { at, pri, id }
    }

    #[test]
    fn priority_parses_and_orders() {
        assert_eq!("c".parse::<Priority>().unwrap(), Priority::Critical);
        assert_eq!("BG".parse::<Priority>().unwrap(), Priority::Background);
        assert_eq!("normal".parse::<Priority>().unwrap(), Priority::Normal);
        assert!("urgent".parse::<Priority>().is_err());
        assert!(Priority::Critical < Priority::Normal);
        assert!(Priority::Normal < Priority::Background);
        for p in Priority::ALL {
            assert_eq!(p.as_str().parse::<Priority>().unwrap(), p);
        }
    }

    /// A single-class trace through the tiered queue is byte-for-byte
    /// the FCFS OpenLoopQueue — the compatibility contract that keeps
    /// default serve runs golden.
    #[test]
    fn tiered_all_normal_degenerates_to_fcfs() {
        let items: Vec<Item> = (0..200).map(|i| item(i * 10, Priority::Normal, i)).collect();
        let fcfs = OpenLoopQueue::new(items.clone());
        let tiered = TieredQueue::new(items, None);
        // Pop with a clock far behind the arrivals: the not-yet-arrived
        // fallback must still hand out the FCFS head.
        let mut now = 0;
        while let Some(expect) = fcfs.pop() {
            let got = tiered.pop(now).unwrap();
            assert_eq!(got, expect);
            now = got.at; // clock follows arrivals, like a sim worker
        }
        assert_eq!(tiered.pop(u64::MAX), None);
        assert_eq!(tiered.shed_total(), 0);
    }

    #[test]
    fn tiered_serves_arrived_critical_before_queued_normal() {
        let q = TieredQueue::new(
            vec![
                item(0, Priority::Normal, 0),
                item(50, Priority::Critical, 1),
                item(60, Priority::Normal, 2),
            ],
            None,
        );
        // At t=10 only the normal head has arrived: a server must not
        // idle-wait for the future critical arrival.
        assert_eq!(q.pop(10).unwrap().id, 0);
        // At t=100 both remaining heads have arrived: critical wins.
        assert_eq!(q.pop(100).unwrap().id, 1);
        assert_eq!(q.pop(100).unwrap().id, 2);
        assert_eq!(q.pop(u64::MAX), None);
    }

    #[test]
    fn tiered_falls_back_to_earliest_future_arrival() {
        let q = TieredQueue::new(
            vec![
                item(50, Priority::Background, 0),
                item(100, Priority::Critical, 1),
            ],
            None,
        );
        // Nothing arrived at t=0: FCFS on arrival time, not priority.
        assert_eq!(q.pop(0).unwrap().id, 0);
        assert_eq!(q.pop(0).unwrap().id, 1);
    }

    #[test]
    fn tiered_promotes_background_after_the_starvation_streak() {
        let n_crit = 400u64;
        let mut items: Vec<Item> =
            (0..n_crit).map(|i| item(0, Priority::Critical, i)).collect();
        items.push(item(0, Priority::Background, 1000));
        items.push(item(0, Priority::Background, 1001));
        let q = TieredQueue::new(items, None);
        let mut bg_positions = Vec::new();
        let mut pos = 0u64;
        while let Some(it) = q.pop(u64::MAX) {
            if it.pri == Priority::Background {
                bg_positions.push(pos);
            }
            pos += 1;
        }
        // The streak hits the limit after LIMIT critical pops, so the
        // first background request is dispatch #LIMIT (0-based), the
        // second one a full streak later — progress under sustained
        // critical load instead of waiting for the trace to drain.
        let limit = BACKGROUND_STARVATION_LIMIT as u64;
        assert_eq!(bg_positions, vec![limit, 2 * limit + 1]);
        assert_eq!(pos, n_crit + 2);
    }

    #[test]
    fn tiered_sheds_only_background_past_the_budget() {
        let q = TieredQueue::new(
            vec![
                item(0, Priority::Background, 0),
                item(0, Priority::Normal, 1),
                item(0, Priority::Critical, 2),
                item(490, Priority::Background, 3),
            ],
            Some(100),
        );
        // t=500: critical and normal are long past the budget but are
        // never shed; background 0 (wait 500) is shed, background 3
        // (wait 10) is within budget and served.
        assert_eq!(q.pop(500).unwrap().id, 2);
        assert_eq!(q.pop(500).unwrap().id, 1);
        assert_eq!(q.pop(500).unwrap().id, 3);
        assert_eq!(q.pop(500), None);
        assert_eq!(q.shed_counts(), [0, 0, 1]);
        assert_eq!(q.shed_total(), 1);
        // Conservation: served + shed == trace length.
        assert_eq!(3 + q.shed_total() as usize, q.len());
    }

    #[test]
    fn tiered_is_exactly_once_under_concurrency() {
        use std::sync::Mutex;
        let items: Vec<Item> = (0..9_000)
            .map(|i| item(0, Priority::ALL[(i % 3) as usize], i))
            .collect();
        let q = TieredQueue::new(items, None);
        let seen = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let q = q.clone();
            let seen = seen.clone();
            handles.push(std::thread::spawn(move || {
                let mut local = Vec::new();
                while let Some(it) = q.pop(u64::MAX) {
                    local.push(it.id);
                }
                seen.lock().unwrap().extend(local);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut all = Arc::try_unwrap(seen).unwrap().into_inner().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..9_000).collect::<Vec<_>>());
        assert_eq!(q.shed_total(), 0);
        assert_eq!(q.remaining(), 0);
    }

    #[test]
    fn class_recorder_reports_per_class_and_total() {
        let mut r = ClassLatencyRecorder::new();
        r.record(Priority::Critical, 10, 100);
        r.record(Priority::Critical, 20, 100);
        r.record(Priority::Background, 5_000, 100);
        let total = r.report().unwrap();
        assert_eq!(total.count, 3);
        let crit = r.class_report(Priority::Critical).unwrap();
        assert_eq!(crit.count, 2);
        assert!((crit.mean_queue_ns - 15.0).abs() < 1e-9);
        assert!(r.class_report(Priority::Normal).is_none());
        let names: Vec<&str> = r.class_reports().iter().map(|(n, _)| *n).collect();
        assert_eq!(names, vec!["critical", "background"]);
        // Merge matches combined recording.
        let mut a = ClassLatencyRecorder::new();
        a.record(Priority::Critical, 10, 100);
        let mut b = ClassLatencyRecorder::new();
        b.record(Priority::Critical, 20, 100);
        b.record(Priority::Background, 5_000, 100);
        a.merge(&b);
        assert_eq!(a.report(), r.report());
        assert_eq!(
            a.class_report(Priority::Background),
            r.class_report(Priority::Background)
        );
    }

    #[test]
    fn slo_signal_windows_drain_and_reset() {
        let s = SloSignal::new(4);
        s.record(0, 100, 50);
        s.record(0, 300, 50);
        s.record(3, 10, 20);
        s.record(99, 1, 2); // out-of-range chiplets clamp to the last
        let w = s.drain();
        assert_eq!(w.len(), 4);
        assert_eq!(w[0], SloWindow { queue_ns: 400, service_ns: 100, count: 2 });
        assert_eq!(w[3], SloWindow { queue_ns: 11, service_ns: 22, count: 2 });
        assert_eq!(w[1].count, 0);
        // Drained: the next window starts empty.
        assert!(s.drain().iter().all(|w| w.count == 0));
    }
}
