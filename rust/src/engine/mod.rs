//! Unified workload engine: the scenario-driver layer.
//!
//! Every evaluation workload used to hand-roll the same executor
//! boilerplate — build a [`Machine`], allocate regions, spawn a task
//! group, run, extract a [`RunReport`]. This module extracts that
//! skeleton once:
//!
//! - [`Scenario`] — what a workload *is*: region setup on a machine,
//!   a coroutine per rank, optional result verification, and
//!   workload-level metrics derived from the run report.
//! - [`Run`] — what the runtime *does* with one: a builder that owns
//!   topology → machine construction, policy wiring, backend selection,
//!   repetition, verification, and report collection in one place:
//!
//!   ```ignore
//!   let run = engine::Run::new(&topo)
//!       .policy(by_name("arcas", &topo).unwrap())
//!       .tasks(16)
//!       .backend(ExecBackend::Host)
//!       .verify(true)
//!       .run(scenario.as_mut());
//!   ```
//!
//!   The executor backend is chosen at the [`execute_on`] seam:
//!   [`ExecBackend::Sim`] (the deterministic [`SimExecutor`]) or
//!   [`ExecBackend::Host`] (real threads on the `HostExecutor`
//!   work-stealing pool), without touching workloads. [`Driver`],
//!   [`execute`] and the free [`run_repeated`] survive as thin wrappers
//!   over `Run` for older call sites.
//! - [`registry`] — a name-keyed catalogue of every scenario
//!   (`bfs`, `pagerank`, …, `tpch`, `ycsb`) so the CLI, harness and
//!   benches enumerate workload×policy combinations through one code
//!   path: `arcas run --scenario bfs --policy arcas --cores 32`.
//!
//! The legacy per-workload entry points (`run_bfs`, `run_query`,
//! `run_oltp`, …) survive as thin wrappers over scenarios, so their
//! deterministic reports are unchanged. See `rust/src/engine/README.md`
//! for the architecture notes and a porting guide.

pub mod dispatch;
mod host_backend;
pub mod registry;
pub mod runcfg;

pub use dispatch::{
    ClassLatencyRecorder, LatencyRecorder, OpenLoopQueue, Prioritized, Priority, SloSignal,
    TieredQueue,
};
pub use host_backend::DEFAULT_BATCH_STEPS;
pub use registry::{by_name, registry, scenarios_table, ScenarioParams, ScenarioSpec};
pub use runcfg::RunConfig;

use std::sync::Arc;

use crate::policy::{LocalCachePolicy, Policy};
use crate::sched::{LatencyReport, RunReport, SimExecutor};
use crate::sim::Machine;
use crate::task::Coroutine;
use crate::topology::Topology;

/// Which executor runs a spawn group — the choice made at the
/// [`execute_on`] seam and threaded through [`Driver::with_backend`],
/// `arcas run --backend`, [`crate::api::ArcasConfig::backend`] and the
/// bench harness.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecBackend {
    /// Deterministic virtual-time simulator ([`SimExecutor`]) — the
    /// paper-figure path, byte-for-byte reproducible reports.
    #[default]
    Sim,
    /// Real OS threads: the `HostExecutor` work-stealing pool steps each
    /// coroutine on a worker thread (chiplet-aware steal order); reports
    /// add real `wall_ns` / `host_steals` next to the simulated makespan.
    Host,
}

impl ExecBackend {
    /// Every selectable backend, in CLI order.
    pub const ALL: [ExecBackend; 2] = [ExecBackend::Sim, ExecBackend::Host];

    pub fn as_str(self) -> &'static str {
        match self {
            ExecBackend::Sim => "sim",
            ExecBackend::Host => "host",
        }
    }
}

impl std::fmt::Display for ExecBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for ExecBackend {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "sim" => Ok(ExecBackend::Sim),
            "host" => Ok(ExecBackend::Host),
            other => Err(format!("unknown backend {other:?} (expected sim|host)")),
        }
    }
}

/// Workload-level metrics extracted from a finished run: the primary
/// work-item count (edges, bytes, commits, rows…) that turns a makespan
/// into a throughput, plus named workload-specific extras.
#[derive(Clone, Debug, Default)]
pub struct ScenarioMetrics {
    /// Primary work-item count processed by the run.
    pub items: f64,
    /// Human-readable unit for `items` (e.g. "edges", "commits").
    pub unit: &'static str,
    /// Named workload-specific extras (final loss, abort count, …).
    pub extras: Vec<(&'static str, f64)>,
}

impl ScenarioMetrics {
    pub fn new(items: f64, unit: &'static str) -> Self {
        Self {
            items,
            unit,
            extras: Vec::new(),
        }
    }

    pub fn with(mut self, key: &'static str, value: f64) -> Self {
        self.extras.push((key, value));
        self
    }

    pub fn get(&self, key: &str) -> Option<f64> {
        self.extras.iter().find(|(k, _)| *k == key).map(|&(_, v)| v)
    }

    /// Items per second of virtual time.
    pub fn throughput(&self, report: &RunReport) -> f64 {
        report.throughput(self.items)
    }
}

/// A runnable workload: the four hooks the [`Driver`] needs.
///
/// Scenarios are single-shot: `setup` → one `spawn` per rank → run →
/// (`verify`) → `metrics`. Build a fresh scenario per run when sweeping
/// policies or core counts.
pub trait Scenario {
    /// Short kebab-case name (diagnostics; the registry holds the
    /// canonical names).
    fn name(&self) -> &'static str;

    /// Allocate regions and initialize shared state on the machine.
    /// `tasks` is the spawn-group size the driver will use.
    fn setup(&mut self, machine: &mut Machine, tasks: usize);

    /// Build the coroutine for `rank`. Called once per rank, in rank
    /// order, after `setup`.
    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine>;

    /// Post-run correctness hook: assert the parallel result against the
    /// workload's serial reference. Only called when the driver was
    /// configured with [`Driver::with_verify`].
    fn verify(&self) {}

    /// Per-request latency aggregate for request-serving scenarios
    /// (sojourn = queue wait + service; see [`dispatch`]). The driver
    /// attaches it to [`RunReport::request_latency`] after the run.
    /// Batch workloads keep the default `None`.
    fn latency(&self) -> Option<LatencyReport> {
        None
    }

    /// Requests dropped by load shedding (serving scenarios under
    /// overload); attached to [`RunReport::request_shed`]. Batch
    /// workloads keep the default 0.
    fn shed(&self) -> u64 {
        0
    }

    /// Per-priority-class latency aggregates (critical first); attached
    /// to [`RunReport::class_latency`]. Empty unless the scenario serves
    /// a priority-tiered trace.
    fn class_latency(&self) -> Vec<(&'static str, LatencyReport)> {
        Vec::new()
    }

    /// The per-chiplet queue-wait/service feedback channel a serving
    /// scenario publishes for SLO-aware policies. Called after `setup`;
    /// when `Some`, the driver hands it to `Policy::connect_slo` before
    /// the run so a feedback policy (e.g. `policy::SloPolicy`) can drain
    /// it on its timer.
    fn slo_signal(&self) -> Option<Arc<SloSignal>> {
        None
    }

    /// The raw ingredients a cluster run needs to rebuild this scenario
    /// per machine shard ([`Run::cluster`] / `--machines N`): the
    /// request trace to route and the serving knobs to replay on each
    /// shard. Scenarios that keep the default `None` don't support
    /// cluster fan-out (`Run::cluster` panics with a clear message).
    fn cluster_parts(&self) -> Option<crate::cluster::ClusterParts> {
        None
    }

    /// Workload-level metrics for the finished run.
    fn metrics(&self, report: &RunReport) -> ScenarioMetrics;
}

/// Report + metrics of one driven run, plus the machine the run left
/// behind (warm caches, registered regions) for repetition runs via
/// [`Driver::on_machine`].
#[derive(Clone, Debug)]
pub struct ScenarioRun {
    pub report: RunReport,
    pub metrics: ScenarioMetrics,
    pub machine: Machine,
}

impl ScenarioRun {
    /// Items per second of virtual time (primary throughput).
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput(&self.report)
    }
}

/// The consolidated run builder: machine construction, policy wiring,
/// backend selection, repetition and verification for scenario
/// executions — the one place executor boilerplate lives.
///
/// Defaults: fresh machine from the topology, [`LocalCachePolicy`],
/// 1 task, [`ExecBackend::Sim`], no verification, 1 repetition.
///
/// Three terminal methods:
/// - [`Run::run`] — one scenario execution → [`ScenarioRun`];
/// - [`Run::run_repeated`] — `repeat` back-to-back executions over one
///   warm machine (fresh policy + scenario per repetition);
/// - [`Run::run_group`] — a bare coroutine group without a [`Scenario`]
///   (the `api::Arcas` / bench-closure path) → `(RunReport, Machine)`.
pub struct Run {
    pub(crate) machine: Machine,
    pub(crate) policy: Option<Box<dyn Policy>>,
    pub(crate) tasks: usize,
    pub(crate) backend: ExecBackend,
    pub(crate) timer_ns: Option<u64>,
    pub(crate) verify: bool,
    repeat: usize,
    pub(crate) batch_steps: usize,
    /// `Some(n)` fans the run out over `n` machine shards
    /// ([`crate::cluster`]); `None` keeps the single-machine path.
    pub(crate) machines: Option<usize>,
    /// Per-shard policy factory for cluster runs (each shard consumes
    /// its own policy box); `None` gives every shard the engine default.
    pub(crate) policy_each: Option<Box<dyn Fn() -> Box<dyn Policy>>>,
}

impl Run {
    /// Start a run on a fresh machine built from `topo`.
    pub fn new(topo: &Topology) -> Self {
        Self::on_machine(Machine::new(topo.clone()))
    }

    /// Start a run on an existing machine (warm caches / pre-allocated
    /// regions). Reports from warm machines are per-run: the engine
    /// subtracts the machine's pre-run clock, access counters and DRAM
    /// totals.
    pub fn on_machine(machine: Machine) -> Self {
        Self {
            machine,
            policy: None,
            tasks: 1,
            backend: ExecBackend::Sim,
            timer_ns: None,
            verify: false,
            repeat: 1,
            batch_steps: DEFAULT_BATCH_STEPS,
            machines: None,
            policy_each: None,
        }
    }

    /// Scheduling policy (default [`LocalCachePolicy`]). Ignored by
    /// [`Run::run_repeated`], which takes a per-repetition factory.
    pub fn policy(mut self, policy: Box<dyn Policy>) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Size of the coroutine task group (default 1).
    pub fn tasks(mut self, tasks: usize) -> Self {
        self.tasks = tasks;
        self
    }

    /// Executor backend (default [`ExecBackend::Sim`]).
    pub fn backend(mut self, backend: ExecBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Override the scheduler timer (policies with their own preferred
    /// cadence still win, as in the executor).
    pub fn timer_ns(mut self, timer_ns: u64) -> Self {
        self.timer_ns = Some(timer_ns);
        self
    }

    /// Run the scenario's `verify` hook after the run (default off).
    pub fn verify(mut self, on: bool) -> Self {
        self.verify = on;
        self
    }

    /// Number of back-to-back repetitions for [`Run::run_repeated`]
    /// (default 1); later repetitions see the warm machine.
    pub fn repeat(mut self, repeat: usize) -> Self {
        assert!(repeat >= 1, "repeat must be >= 1");
        self.repeat = repeat;
        self
    }

    /// Host backend run-until-yield budget: max coroutine steps a pool
    /// worker runs per job before the rank goes back through the queues
    /// (default [`DEFAULT_BATCH_STEPS`]; `1` recovers the old
    /// step-per-job pipeline — same outcomes, more pool round-trips).
    /// The deterministic sim backend has no pool round-trip to amortize
    /// and ignores it, so sim reports stay byte-identical.
    pub fn batch_steps(mut self, batch_steps: usize) -> Self {
        assert!(batch_steps >= 1, "batch_steps must be >= 1");
        self.batch_steps = batch_steps;
        self
    }

    /// Fan the run out over `n` independent machine shards (the
    /// [`crate::cluster`] tier): requests are key-sharded across `n`
    /// machines built from the same topology, cross-shard hops pay the
    /// inter-machine link ([`crate::topology::ClusterLink`]), and the
    /// builder's [`Run::policy`] becomes the *front-end* policy whose
    /// [`crate::policy::Policy::plan_shard_moves`] re-homes hot key
    /// ranges between shards. `n = 1` routes nothing and reproduces the
    /// single-machine run byte-for-byte. Only scenarios that implement
    /// [`Scenario::cluster_parts`] (the serve family) support this.
    pub fn cluster(mut self, n: usize) -> Self {
        assert!(n >= 1, "cluster size must be >= 1");
        self.machines = Some(n);
        self
    }

    /// Per-shard policy factory for [`Run::cluster`] runs: each machine
    /// shard consumes its own `factory()` box (policies aren't
    /// cloneable). Default: every shard runs the engine default
    /// ([`LocalCachePolicy`]); the front-end planner stays whatever
    /// [`Run::policy`] chose.
    pub fn cluster_policy(mut self, factory: impl Fn() -> Box<dyn Policy> + 'static) -> Self {
        self.policy_each = Some(Box::new(factory));
        self
    }

    pub(crate) fn take_policy(&mut self) -> Box<dyn Policy> {
        self.policy.take().unwrap_or_else(|| Box::new(LocalCachePolicy))
    }

    /// Set up, spawn and run `scenario` to completion.
    pub fn run(mut self, scenario: &mut dyn Scenario) -> ScenarioRun {
        if let Some(n) = self.machines {
            return crate::cluster::run_cluster(self, n, scenario);
        }
        let policy = self.take_policy();
        run_once(
            self.machine,
            policy,
            self.tasks,
            self.timer_ns,
            self.verify,
            self.backend,
            self.batch_steps,
            scenario,
        )
    }

    /// Drive `repeat` back-to-back runs of a (freshly built each time)
    /// scenario over **one** machine, so later repetitions see warm
    /// caches — the story behind `arcas run --repeat`.
    ///
    /// `policy` and `scenario` are factories because both are consumed
    /// per run. Returns one [`ScenarioRun`] per repetition, each with
    /// its own per-run makespan. Each run retains its machine (callers
    /// inspect residency), so repetitions clone it forward — between
    /// runs, outside both the virtual and wall-clock timed windows.
    pub fn run_repeated(
        self,
        mut policy: impl FnMut() -> Box<dyn Policy>,
        mut scenario: impl FnMut() -> Box<dyn Scenario>,
    ) -> Vec<ScenarioRun> {
        let Run {
            machine,
            policy: _,
            tasks,
            backend,
            timer_ns,
            verify,
            repeat,
            batch_steps,
            machines: _,
            policy_each: _,
        } = self;
        let mut machine = Some(machine);
        let mut runs = Vec::with_capacity(repeat);
        for i in 0..repeat {
            let mut s = scenario();
            let run = run_once(
                machine.take().unwrap(),
                policy(),
                tasks,
                timer_ns,
                verify,
                backend,
                batch_steps,
                s.as_mut(),
            );
            // The run keeps its machine (callers inspect residency);
            // clone it forward only while more repetitions need it.
            if i + 1 < repeat {
                machine = Some(run.machine.clone());
            }
            runs.push(run);
        }
        runs
    }

    /// Run a bare coroutine group (no [`Scenario`] hooks) and hand the
    /// machine back — the closure path used by `api::Arcas` and the
    /// bench harness.
    pub fn run_group(
        mut self,
        make: impl FnMut(usize) -> Box<dyn Coroutine>,
    ) -> (RunReport, Machine) {
        let policy = self.take_policy();
        execute_on_with(
            self.backend,
            self.machine,
            policy,
            self.timer_ns,
            self.tasks,
            make,
            self.batch_steps,
        )
    }
}

/// One scenario execution: setup → SLO wiring → execute → verify →
/// report decoration. Shared by [`Run`], the legacy [`Driver`] and the
/// per-shard executions of [`crate::cluster`].
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_once(
    mut machine: Machine,
    mut policy: Box<dyn Policy>,
    tasks: usize,
    timer_ns: Option<u64>,
    verify: bool,
    backend: ExecBackend,
    batch_steps: usize,
    scenario: &mut dyn Scenario,
) -> ScenarioRun {
    // Warm machines carry virtual time and counters from earlier
    // runs; report this run's makespan / accesses / DRAM traffic,
    // not the cumulative totals (all-zero baselines on fresh
    // machines, so cold reports are unchanged).
    let t0 = machine.max_time();
    let counts0 = machine.class_totals();
    let dram0 = machine.dram_total_bytes();
    scenario.setup(&mut machine, tasks);
    // Serving scenarios publish a queue-wait/service feedback channel;
    // SLO-aware policies subscribe to it (no-op for every other pair).
    if let Some(signal) = scenario.slo_signal() {
        policy.connect_slo(signal);
    }
    let (mut report, machine) = execute_on_with(
        backend,
        machine,
        policy,
        timer_ns,
        tasks,
        |rank| scenario.spawn(rank),
        batch_steps,
    );
    report.makespan_ns = report.makespan_ns.saturating_sub(t0);
    report.counts.local -= counts0.local;
    report.counts.near -= counts0.near;
    report.counts.far -= counts0.far;
    report.counts.dram -= counts0.dram;
    report.dram_bytes -= dram0;
    if verify {
        scenario.verify();
    }
    // Serving scenarios carry their per-request aggregate on the
    // report (attached before `metrics`, which may read it).
    report.request_latency = scenario.latency();
    report.request_shed = scenario.shed();
    report.class_latency = scenario.class_latency();
    let metrics = scenario.metrics(&report);
    ScenarioRun {
        report,
        metrics,
        machine,
    }
}

/// Legacy builder over one scenario execution. Prefer [`Run`]: this
/// type survives as a thin wrapper so older call sites keep compiling
/// (same defaults, same report bytes).
pub struct Driver {
    machine: Machine,
    policy: Box<dyn Policy>,
    tasks: usize,
    timer_ns: Option<u64>,
    verify: bool,
    backend: ExecBackend,
}

impl Driver {
    /// Fresh machine from `topo`; `tasks` coroutine workers under
    /// `policy`.
    pub fn new(topo: &Topology, policy: Box<dyn Policy>, tasks: usize) -> Self {
        Self::on_machine(Machine::new(topo.clone()), policy, tasks)
    }

    /// Drive an existing machine (warm caches / pre-allocated regions).
    /// Reports from warm machines are per-run: the driver subtracts the
    /// machine's pre-run clock, access counters and DRAM totals, so
    /// `--repeat` repetitions each report their own makespan,
    /// throughput and traffic.
    pub fn on_machine(machine: Machine, policy: Box<dyn Policy>, tasks: usize) -> Self {
        Self {
            machine,
            policy,
            tasks,
            timer_ns: None,
            verify: false,
            backend: ExecBackend::Sim,
        }
    }

    /// Select the executor backend (default [`ExecBackend::Sim`]).
    pub fn with_backend(mut self, backend: ExecBackend) -> Self {
        self.backend = backend;
        self
    }

    /// Override the scheduler timer (policies with their own preferred
    /// cadence still win, as in the executor).
    pub fn with_timer(mut self, timer_ns: u64) -> Self {
        self.timer_ns = Some(timer_ns);
        self
    }

    /// Run the scenario's `verify` hook after the run.
    pub fn with_verify(mut self, on: bool) -> Self {
        self.verify = on;
        self
    }

    /// Set up, spawn and run `scenario` to completion.
    pub fn run(self, scenario: &mut dyn Scenario) -> ScenarioRun {
        let Driver {
            machine,
            policy,
            tasks,
            timer_ns,
            verify,
            backend,
        } = self;
        run_once(
            machine,
            policy,
            tasks,
            timer_ns,
            verify,
            backend,
            DEFAULT_BATCH_STEPS,
            scenario,
        )
    }
}

/// Run `n` coroutines over `machine` under `policy` on the chosen
/// backend and hand the machine back (cache residency carries across
/// runs for callers that reuse it).
///
/// This is the **only** executor construction site: [`ExecBackend::Sim`]
/// builds the deterministic [`SimExecutor`]; [`ExecBackend::Host`] runs
/// the group on the real `HostExecutor` thread pool. On the host,
/// `timer_ns` measures **real elapsed time**: `Some(t)` arms the
/// adaptive controller tick (`policy.on_timer` over merged profiler
/// windows, migrations applied to each rank's next batch), `None`
/// keeps the legacy static-placement behavior byte-identical. A future
/// sharded multi-machine driver slots in here.
pub fn execute_on(
    backend: ExecBackend,
    machine: Machine,
    policy: Box<dyn Policy>,
    timer_ns: Option<u64>,
    n: usize,
    make: impl FnMut(usize) -> Box<dyn Coroutine>,
) -> (RunReport, Machine) {
    execute_on_with(
        backend,
        machine,
        policy,
        timer_ns,
        n,
        make,
        DEFAULT_BATCH_STEPS,
    )
}

/// [`execute_on`] with an explicit host `batch_steps` budget (the
/// `Run::batch_steps` / `--batch-steps` knob). The sim backend ignores
/// it — the deterministic executor has no pool round-trip to amortize.
fn execute_on_with(
    backend: ExecBackend,
    machine: Machine,
    policy: Box<dyn Policy>,
    timer_ns: Option<u64>,
    n: usize,
    make: impl FnMut(usize) -> Box<dyn Coroutine>,
    batch_steps: usize,
) -> (RunReport, Machine) {
    match backend {
        ExecBackend::Sim => {
            let mut ex = SimExecutor::new(machine, policy);
            if let Some(t) = timer_ns {
                ex = ex.with_timer(t);
            }
            ex.spawn_group(n, make);
            let report = ex.run();
            (report, ex.machine)
        }
        ExecBackend::Host => {
            host_backend::execute_host(machine, policy, timer_ns, n, make, batch_steps)
        }
    }
}

/// [`execute_on`] pinned to the simulator backend — the historical seam
/// signature, kept so `sched::run_group`, `api::Arcas::run` and the
/// benches stay byte-for-byte reproducible by default.
pub fn execute(
    machine: Machine,
    policy: Box<dyn Policy>,
    timer_ns: Option<u64>,
    n: usize,
    make: impl FnMut(usize) -> Box<dyn Coroutine>,
) -> (RunReport, Machine) {
    execute_on(ExecBackend::Sim, machine, policy, timer_ns, n, make)
}

/// Legacy free-function form of [`Run::run_repeated`]; prefer the
/// builder. Kept as a thin wrapper so older call sites keep compiling.
#[allow(clippy::too_many_arguments)]
pub fn run_repeated(
    topo: &Topology,
    repeat: usize,
    tasks: usize,
    backend: ExecBackend,
    verify: bool,
    timer_ns: Option<u64>,
    policy: impl FnMut() -> Box<dyn Policy>,
    scenario: impl FnMut() -> Box<dyn Scenario>,
) -> Vec<ScenarioRun> {
    let mut run = Run::new(topo)
        .tasks(tasks)
        .backend(backend)
        .verify(verify)
        .repeat(repeat);
    if let Some(t) = timer_ns {
        run = run.timer_ns(t);
    }
    run.run_repeated(policy, scenario)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::LocalCachePolicy;
    use crate::task::{FnTask, TaskCtx};

    struct NoopScenario {
        ran_setup: bool,
        verified: std::cell::Cell<bool>,
    }

    impl Scenario for NoopScenario {
        fn name(&self) -> &'static str {
            "noop"
        }

        fn setup(&mut self, _machine: &mut Machine, _tasks: usize) {
            self.ran_setup = true;
        }

        fn spawn(&mut self, _rank: usize) -> Box<dyn Coroutine> {
            Box::new(FnTask(|ctx: &mut TaskCtx<'_>| ctx.compute_ns(100)))
        }

        fn verify(&self) {
            self.verified.set(true);
        }

        fn metrics(&self, _report: &RunReport) -> ScenarioMetrics {
            ScenarioMetrics::new(4.0, "noops").with("answer", 42.0)
        }
    }

    #[test]
    fn driver_runs_setup_spawn_verify_metrics() {
        let topo = Topology::milan_1s();
        let mut s = NoopScenario {
            ran_setup: false,
            verified: std::cell::Cell::new(false),
        };
        let run = Driver::new(&topo, Box::new(LocalCachePolicy), 4)
            .with_verify(true)
            .run(&mut s);
        assert!(s.ran_setup);
        assert!(s.verified.get());
        assert_eq!(run.report.dispatches, 4);
        assert!(run.report.makespan_ns >= 100);
        assert_eq!(run.metrics.items, 4.0);
        assert_eq!(run.metrics.get("answer"), Some(42.0));
        assert!(run.throughput() > 0.0);
    }

    #[test]
    fn verify_is_opt_in() {
        let topo = Topology::milan_1s();
        let mut s = NoopScenario {
            ran_setup: false,
            verified: std::cell::Cell::new(false),
        };
        let _ = Driver::new(&topo, Box::new(LocalCachePolicy), 2).run(&mut s);
        assert!(!s.verified.get());
    }

    #[test]
    fn driver_runs_on_the_host_backend() {
        let topo = Topology::milan_1s();
        let mut s = NoopScenario {
            ran_setup: false,
            verified: std::cell::Cell::new(false),
        };
        let run = Driver::new(&topo, Box::new(LocalCachePolicy), 4)
            .with_backend(ExecBackend::Host)
            .with_verify(true)
            .run(&mut s);
        assert!(s.verified.get());
        assert_eq!(run.report.dispatches, 4);
        assert!(run.report.wall_ns > 0);
    }

    #[test]
    fn repeated_runs_reuse_the_machine_and_report_per_run_makespans() {
        let topo = Topology::milan_1s();
        let runs = run_repeated(
            &topo,
            3,
            4,
            ExecBackend::Sim,
            true,
            None,
            || Box::new(LocalCachePolicy),
            || {
                Box::new(NoopScenario {
                    ran_setup: false,
                    verified: std::cell::Cell::new(false),
                })
            },
        );
        assert_eq!(runs.len(), 3);
        for run in &runs {
            // Per-run makespan (~100ns of compute), not the cumulative
            // warm-machine clock.
            assert!(run.report.makespan_ns >= 100);
            assert!(run.report.makespan_ns < 100_000);
        }
        // The machine really was reused: its clock accumulates.
        assert!(runs[2].machine.max_time() > runs[0].report.makespan_ns);
    }

    #[test]
    fn backend_parses_and_prints() {
        assert_eq!("sim".parse::<ExecBackend>().unwrap(), ExecBackend::Sim);
        assert_eq!("HOST".parse::<ExecBackend>().unwrap(), ExecBackend::Host);
        assert!("gpu".parse::<ExecBackend>().is_err());
        for b in ExecBackend::ALL {
            assert_eq!(b.to_string().parse::<ExecBackend>().unwrap(), b);
        }
    }

    #[test]
    fn execute_hands_the_machine_back() {
        let machine = Machine::new(Topology::milan_1s());
        let (report, machine) = execute(machine, Box::new(LocalCachePolicy), None, 2, |_| {
            Box::new(FnTask(|ctx: &mut TaskCtx<'_>| ctx.compute_ns(50)))
        });
        assert_eq!(report.dispatches, 2);
        assert!(machine.max_time() >= 50);
    }

    /// The consolidated builder and the legacy `Driver` are the same
    /// engine: identical deterministic reports for the same inputs.
    #[test]
    fn run_builder_matches_the_legacy_driver() {
        let topo = Topology::milan_1s();
        let mut a = NoopScenario {
            ran_setup: false,
            verified: std::cell::Cell::new(false),
        };
        let via_run = Run::new(&topo)
            .policy(Box::new(LocalCachePolicy))
            .tasks(4)
            .verify(true)
            .run(&mut a);
        let mut b = NoopScenario {
            ran_setup: false,
            verified: std::cell::Cell::new(false),
        };
        let via_driver = Driver::new(&topo, Box::new(LocalCachePolicy), 4)
            .with_verify(true)
            .run(&mut b);
        assert!(a.verified.get() && b.verified.get());
        assert_eq!(via_run.report.makespan_ns, via_driver.report.makespan_ns);
        assert_eq!(via_run.report.dispatches, via_driver.report.dispatches);
        assert_eq!(via_run.report.request_shed, 0);
        assert!(via_run.report.class_latency.is_empty());
    }

    #[test]
    fn run_builder_repeats_on_a_warm_machine() {
        let topo = Topology::milan_1s();
        let runs = Run::new(&topo)
            .tasks(4)
            .repeat(3)
            .verify(true)
            .run_repeated(
                || Box::new(LocalCachePolicy),
                || {
                    Box::new(NoopScenario {
                        ran_setup: false,
                        verified: std::cell::Cell::new(false),
                    })
                },
            );
        assert_eq!(runs.len(), 3);
        for run in &runs {
            assert!(run.report.makespan_ns >= 100);
            assert!(run.report.makespan_ns < 100_000);
        }
        assert!(runs[2].machine.max_time() > runs[0].report.makespan_ns);
    }

    #[test]
    fn run_builder_drives_bare_groups() {
        let topo = Topology::milan_1s();
        let (report, machine) = Run::new(&topo).tasks(2).run_group(|_| {
            Box::new(FnTask(|ctx: &mut TaskCtx<'_>| ctx.compute_ns(50)))
        });
        assert_eq!(report.dispatches, 2);
        assert!(machine.max_time() >= 50);
    }

    #[test]
    #[should_panic(expected = "repeat must be >= 1")]
    fn run_builder_rejects_zero_repeat() {
        let _ = Run::new(&Topology::milan_1s()).repeat(0);
    }

    #[test]
    #[should_panic(expected = "batch_steps must be >= 1")]
    fn run_builder_rejects_zero_batch_steps() {
        let _ = Run::new(&Topology::milan_1s()).batch_steps(0);
    }

    #[test]
    fn batch_steps_one_matches_the_batched_default_on_host() {
        use crate::task::IterTask;
        let run_with = |batch: usize| {
            Run::new(&Topology::milan_1s())
                .tasks(4)
                .backend(ExecBackend::Host)
                .batch_steps(batch)
                .run_group(|_| Box::new(IterTask::new(10, |ctx, _| ctx.compute_ns(100))))
                .0
        };
        let per_step = run_with(1);
        let batched = run_with(DEFAULT_BATCH_STEPS);
        // dispatches counts coroutine steps, not pool jobs, so the
        // budget must not change it.
        assert_eq!(per_step.dispatches, 40);
        assert_eq!(batched.dispatches, 40);
    }

    #[test]
    fn batch_steps_is_ignored_by_the_sim_backend() {
        use crate::task::IterTask;
        let run_with = |batch: usize| {
            Run::new(&Topology::milan_1s())
                .tasks(4)
                .policy(Box::new(LocalCachePolicy))
                .batch_steps(batch)
                .run_group(|_| Box::new(IterTask::new(10, |ctx, _| ctx.compute_ns(100))))
                .0
        };
        let a = run_with(1);
        let b = run_with(64);
        // Deterministic sim: reports must be byte-identical regardless
        // of the host-only knob.
        assert_eq!(a.makespan_ns, b.makespan_ns);
        assert_eq!(a.dispatches, b.dispatches);
        assert_eq!(a.steals, b.steals);
    }
}
