//! Unified workload engine: the scenario-driver layer.
//!
//! Every evaluation workload used to hand-roll the same executor
//! boilerplate — build a [`Machine`], allocate regions, spawn a task
//! group, run, extract a [`RunReport`]. This module extracts that
//! skeleton once:
//!
//! - [`Scenario`] — what a workload *is*: region setup on a machine,
//!   a coroutine per rank, optional result verification, and
//!   workload-level metrics derived from the run report.
//! - [`Driver`] — what the runtime *does* with one: owns topology →
//!   machine construction, policy wiring, `spawn_group`, the run loop,
//!   and report collection. It is the single seam where an executor
//!   backend is chosen (today [`SimExecutor`] via [`execute`]; a future
//!   `HostExecutor` backend slots in here without touching workloads).
//! - [`registry`] — a name-keyed catalogue of every scenario
//!   (`bfs`, `pagerank`, …, `tpch`, `ycsb`) so the CLI, harness and
//!   benches enumerate workload×policy combinations through one code
//!   path: `arcas run --scenario bfs --policy arcas --cores 32`.
//!
//! The legacy per-workload entry points (`run_bfs`, `run_query`,
//! `run_oltp`, …) survive as thin wrappers over scenarios, so their
//! deterministic reports are unchanged. See `rust/src/engine/README.md`
//! for the architecture notes and a porting guide.

pub mod registry;

pub use registry::{by_name, registry, ScenarioParams, ScenarioSpec};

use crate::policy::Policy;
use crate::sched::{RunReport, SimExecutor};
use crate::sim::Machine;
use crate::task::Coroutine;
use crate::topology::Topology;

/// Workload-level metrics extracted from a finished run: the primary
/// work-item count (edges, bytes, commits, rows…) that turns a makespan
/// into a throughput, plus named workload-specific extras.
#[derive(Clone, Debug, Default)]
pub struct ScenarioMetrics {
    /// Primary work-item count processed by the run.
    pub items: f64,
    /// Human-readable unit for `items` (e.g. "edges", "commits").
    pub unit: &'static str,
    /// Named workload-specific extras (final loss, abort count, …).
    pub extras: Vec<(&'static str, f64)>,
}

impl ScenarioMetrics {
    pub fn new(items: f64, unit: &'static str) -> Self {
        Self {
            items,
            unit,
            extras: Vec::new(),
        }
    }

    pub fn with(mut self, key: &'static str, value: f64) -> Self {
        self.extras.push((key, value));
        self
    }

    pub fn get(&self, key: &str) -> Option<f64> {
        self.extras.iter().find(|(k, _)| *k == key).map(|&(_, v)| v)
    }

    /// Items per second of virtual time.
    pub fn throughput(&self, report: &RunReport) -> f64 {
        report.throughput(self.items)
    }
}

/// A runnable workload: the four hooks the [`Driver`] needs.
///
/// Scenarios are single-shot: `setup` → one `spawn` per rank → run →
/// (`verify`) → `metrics`. Build a fresh scenario per run when sweeping
/// policies or core counts.
pub trait Scenario {
    /// Short kebab-case name (diagnostics; the registry holds the
    /// canonical names).
    fn name(&self) -> &'static str;

    /// Allocate regions and initialize shared state on the machine.
    /// `tasks` is the spawn-group size the driver will use.
    fn setup(&mut self, machine: &mut Machine, tasks: usize);

    /// Build the coroutine for `rank`. Called once per rank, in rank
    /// order, after `setup`.
    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine>;

    /// Post-run correctness hook: assert the parallel result against the
    /// workload's serial reference. Only called when the driver was
    /// configured with [`Driver::with_verify`].
    fn verify(&self) {}

    /// Workload-level metrics for the finished run.
    fn metrics(&self, report: &RunReport) -> ScenarioMetrics;
}

/// Report + metrics of one driven run, plus the machine the run left
/// behind (warm caches, registered regions) for repetition runs via
/// [`Driver::on_machine`].
#[derive(Clone, Debug)]
pub struct ScenarioRun {
    pub report: RunReport,
    pub metrics: ScenarioMetrics,
    pub machine: Machine,
}

impl ScenarioRun {
    /// Items per second of virtual time (primary throughput).
    pub fn throughput(&self) -> f64 {
        self.metrics.throughput(&self.report)
    }
}

/// Owns machine construction, policy wiring and the run loop for one
/// scenario execution — the one place executor boilerplate lives.
pub struct Driver {
    machine: Machine,
    policy: Box<dyn Policy>,
    tasks: usize,
    timer_ns: Option<u64>,
    verify: bool,
}

impl Driver {
    /// Fresh machine from `topo`; `tasks` coroutine workers under
    /// `policy`.
    pub fn new(topo: &Topology, policy: Box<dyn Policy>, tasks: usize) -> Self {
        Self::on_machine(Machine::new(topo.clone()), policy, tasks)
    }

    /// Drive an existing machine (warm caches / pre-allocated regions).
    pub fn on_machine(machine: Machine, policy: Box<dyn Policy>, tasks: usize) -> Self {
        Self {
            machine,
            policy,
            tasks,
            timer_ns: None,
            verify: false,
        }
    }

    /// Override the scheduler timer (policies with their own preferred
    /// cadence still win, as in the executor).
    pub fn with_timer(mut self, timer_ns: u64) -> Self {
        self.timer_ns = Some(timer_ns);
        self
    }

    /// Run the scenario's `verify` hook after the run.
    pub fn with_verify(mut self, on: bool) -> Self {
        self.verify = on;
        self
    }

    /// Set up, spawn and run `scenario` to completion.
    pub fn run(self, scenario: &mut dyn Scenario) -> ScenarioRun {
        let Driver {
            mut machine,
            policy,
            tasks,
            timer_ns,
            verify,
        } = self;
        scenario.setup(&mut machine, tasks);
        let (report, machine) =
            execute(machine, policy, timer_ns, tasks, |rank| scenario.spawn(rank));
        if verify {
            scenario.verify();
        }
        let metrics = scenario.metrics(&report);
        ScenarioRun {
            report,
            metrics,
            machine,
        }
    }
}

/// Run `n` coroutines over `machine` under `policy` and hand the machine
/// back (cache residency carries across runs for callers that reuse it).
///
/// This is the **only** `SimExecutor` construction site: the seam where
/// a different executor backend (e.g. a host-thread pool or a sharded
/// multi-machine driver) would be selected.
pub fn execute(
    machine: Machine,
    policy: Box<dyn Policy>,
    timer_ns: Option<u64>,
    n: usize,
    make: impl FnMut(usize) -> Box<dyn Coroutine>,
) -> (RunReport, Machine) {
    let mut ex = SimExecutor::new(machine, policy);
    if let Some(t) = timer_ns {
        ex = ex.with_timer(t);
    }
    ex.spawn_group(n, make);
    let report = ex.run();
    (report, ex.machine)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::LocalCachePolicy;
    use crate::task::{FnTask, TaskCtx};

    struct NoopScenario {
        ran_setup: bool,
        verified: std::cell::Cell<bool>,
    }

    impl Scenario for NoopScenario {
        fn name(&self) -> &'static str {
            "noop"
        }

        fn setup(&mut self, _machine: &mut Machine, _tasks: usize) {
            self.ran_setup = true;
        }

        fn spawn(&mut self, _rank: usize) -> Box<dyn Coroutine> {
            Box::new(FnTask(|ctx: &mut TaskCtx<'_>| ctx.compute_ns(100)))
        }

        fn verify(&self) {
            self.verified.set(true);
        }

        fn metrics(&self, _report: &RunReport) -> ScenarioMetrics {
            ScenarioMetrics::new(4.0, "noops").with("answer", 42.0)
        }
    }

    #[test]
    fn driver_runs_setup_spawn_verify_metrics() {
        let topo = Topology::milan_1s();
        let mut s = NoopScenario {
            ran_setup: false,
            verified: std::cell::Cell::new(false),
        };
        let run = Driver::new(&topo, Box::new(LocalCachePolicy), 4)
            .with_verify(true)
            .run(&mut s);
        assert!(s.ran_setup);
        assert!(s.verified.get());
        assert_eq!(run.report.dispatches, 4);
        assert!(run.report.makespan_ns >= 100);
        assert_eq!(run.metrics.items, 4.0);
        assert_eq!(run.metrics.get("answer"), Some(42.0));
        assert!(run.throughput() > 0.0);
    }

    #[test]
    fn verify_is_opt_in() {
        let topo = Topology::milan_1s();
        let mut s = NoopScenario {
            ran_setup: false,
            verified: std::cell::Cell::new(false),
        };
        let _ = Driver::new(&topo, Box::new(LocalCachePolicy), 2).run(&mut s);
        assert!(!s.verified.get());
    }

    #[test]
    fn execute_hands_the_machine_back() {
        let machine = Machine::new(Topology::milan_1s());
        let (report, machine) = execute(machine, Box::new(LocalCachePolicy), None, 2, |_| {
            Box::new(FnTask(|ctx: &mut TaskCtx<'_>| ctx.compute_ns(50)))
        });
        assert_eq!(report.dispatches, 2);
        assert!(machine.max_time() >= 50);
    }
}
