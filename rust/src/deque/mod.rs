//! Chase–Lev lock-free work-stealing deque (§4.4: "local task queue ...
//! using lock-free mechanisms based on atomic operations").
//!
//! The owner pushes/pops at the bottom without contention; thieves steal
//! from the top with a CAS. This is a real implementation of the
//! Chase–Lev algorithm (with the Le/Pop/Cohen/Nardelli fences), usable
//! both from the deterministic simulator (single thread) and the host
//! executor (real threads). Elements are `Copy` ids — the task table owns
//! the payloads.
//!
//! §Perf: the buffer is published through an `AtomicPtr` (retired buffers
//! are parked until drop), not a lock — the original `RwLock<Arc<_>>`
//! version cost ~430 ns per push+pop; this one is ~25 ns.

use std::sync::atomic::{AtomicIsize, AtomicPtr, AtomicUsize, Ordering};
use std::sync::Mutex;

const MIN_CAP: usize = 64;

/// Fixed-capacity ring buffer; the deque grows by publishing a bigger
/// buffer while the old one is parked in the graveyard (thieves may still
/// be reading it).
struct Buffer {
    data: Vec<AtomicUsize>,
    mask: usize,
}

impl Buffer {
    fn new(cap: usize) -> Box<Self> {
        assert!(cap.is_power_of_two());
        Box::new(Self {
            data: (0..cap).map(|_| AtomicUsize::new(0)).collect(),
            mask: cap - 1,
        })
    }

    #[inline]
    fn get(&self, i: isize) -> usize {
        self.data[(i as usize) & self.mask].load(Ordering::Relaxed)
    }

    #[inline]
    fn put(&self, i: isize, v: usize) {
        self.data[(i as usize) & self.mask].store(v, Ordering::Relaxed);
    }

    #[inline]
    fn cap(&self) -> usize {
        self.data.len()
    }
}

/// Shared state of one deque.
pub struct Deque {
    top: AtomicIsize,
    bottom: AtomicIsize,
    buf: AtomicPtr<Buffer>,
    /// Retired buffers: kept alive until the deque drops, because a slow
    /// thief may still hold a pointer into one (bounded: one per grow,
    /// log2(max_len) total).
    graveyard: Mutex<Vec<*mut Buffer>>,
}

// SAFETY: all shared mutation goes through atomics; the graveyard is
// mutex-protected and raw pointers in it are only freed on drop.
unsafe impl Send for Deque {}
unsafe impl Sync for Deque {}

impl Default for Deque {
    fn default() -> Self {
        Self::new()
    }
}

impl Deque {
    pub fn new() -> Self {
        Self {
            top: AtomicIsize::new(0),
            bottom: AtomicIsize::new(0),
            buf: AtomicPtr::new(Box::into_raw(Buffer::new(MIN_CAP))),
            graveyard: Mutex::new(Vec::new()),
        }
    }

    #[inline]
    fn buffer(&self) -> &Buffer {
        // SAFETY: the pointer is always valid — buffers are only retired
        // to the graveyard, never freed before drop.
        unsafe { &*self.buf.load(Ordering::Acquire) }
    }

    /// Owner-side push at the bottom.
    pub fn push(&self, v: usize) {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Acquire);
        let mut buf = self.buffer();
        if (b - t) as usize >= buf.cap() - 1 {
            // Grow: copy live range into a buffer twice the size and
            // publish it; retire the old one.
            let bigger = Buffer::new(buf.cap() * 2);
            for i in t..b {
                bigger.put(i, buf.get(i));
            }
            let old = self.buf.swap(Box::into_raw(bigger), Ordering::AcqRel);
            self.graveyard.lock().unwrap().push(old);
            buf = self.buffer();
        }
        buf.put(b, v);
        self.bottom.store(b + 1, Ordering::Release);
    }

    /// Owner-side pop at the bottom (LIFO — cache-warm tasks first).
    pub fn pop(&self) -> Option<usize> {
        let b = self.bottom.load(Ordering::Relaxed) - 1;
        let buf = self.buffer();
        self.bottom.store(b, Ordering::Relaxed);
        std::sync::atomic::fence(Ordering::SeqCst);
        let t = self.top.load(Ordering::Relaxed);
        if t > b {
            // Empty: restore.
            self.bottom.store(b + 1, Ordering::Relaxed);
            return None;
        }
        let v = buf.get(b);
        if t == b {
            // Last element: race with thieves via CAS on top.
            let won = self
                .top
                .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
                .is_ok();
            self.bottom.store(b + 1, Ordering::Relaxed);
            return if won { Some(v) } else { None };
        }
        Some(v)
    }

    /// Thief-side steal from the top (FIFO — oldest, coldest tasks).
    pub fn steal(&self) -> Steal {
        let t = self.top.load(Ordering::Acquire);
        std::sync::atomic::fence(Ordering::SeqCst);
        let b = self.bottom.load(Ordering::Acquire);
        if t >= b {
            return Steal::Empty;
        }
        let buf = self.buffer();
        let v = buf.get(t);
        match self
            .top
            .compare_exchange(t, t + 1, Ordering::SeqCst, Ordering::Relaxed)
        {
            Ok(_) => Steal::Success(v),
            Err(_) => Steal::Retry,
        }
    }

    /// Approximate length (racy under concurrency, exact when quiescent).
    pub fn len(&self) -> usize {
        let b = self.bottom.load(Ordering::Relaxed);
        let t = self.top.load(Ordering::Relaxed);
        (b - t).max(0) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Drop for Deque {
    fn drop(&mut self) {
        // SAFETY: exclusive access on drop; free the live buffer and every
        // retired one exactly once.
        unsafe {
            drop(Box::from_raw(self.buf.load(Ordering::Relaxed)));
            for p in self.graveyard.lock().unwrap().drain(..) {
                drop(Box::from_raw(p));
            }
        }
    }
}

/// Outcome of a steal attempt.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Steal {
    Success(usize),
    Empty,
    Retry,
}

impl Steal {
    pub fn success(self) -> Option<usize> {
        match self {
            Steal::Success(v) => Some(v),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Arc;

    #[test]
    fn lifo_pop_fifo_steal() {
        let d = Deque::new();
        d.push(1);
        d.push(2);
        d.push(3);
        assert_eq!(d.steal(), Steal::Success(1)); // oldest
        assert_eq!(d.pop(), Some(3)); // newest
        assert_eq!(d.pop(), Some(2));
        assert_eq!(d.pop(), None);
        assert_eq!(d.steal(), Steal::Empty);
    }

    #[test]
    fn grows_past_initial_capacity() {
        let d = Deque::new();
        for i in 0..10_000 {
            d.push(i);
        }
        assert_eq!(d.len(), 10_000);
        for i in (0..10_000).rev() {
            assert_eq!(d.pop(), Some(i));
        }
    }

    #[test]
    fn single_element_race_semantics() {
        let d = Deque::new();
        d.push(42);
        assert_eq!(d.pop(), Some(42));
        assert_eq!(d.pop(), None);
        d.push(7);
        assert_eq!(d.steal(), Steal::Success(7));
        assert_eq!(d.pop(), None);
    }

    #[test]
    fn concurrent_producer_thieves_no_loss_no_dup() {
        // Owner pushes N items and pops; 4 thieves steal concurrently.
        // Every item must be consumed exactly once.
        const N: usize = 50_000;
        let d = Arc::new(Deque::new());
        let consumed: Arc<Vec<AtomicU64>> =
            Arc::new((0..N).map(|_| AtomicU64::new(0)).collect());
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));

        let mut thieves = Vec::new();
        for _ in 0..4 {
            let d = d.clone();
            let consumed = consumed.clone();
            let done = done.clone();
            thieves.push(std::thread::spawn(move || {
                while !done.load(Ordering::Acquire) || !d.is_empty() {
                    if let Steal::Success(v) = d.steal() {
                        consumed[v].fetch_add(1, Ordering::Relaxed);
                    } else {
                        std::thread::yield_now();
                    }
                }
            }));
        }

        // Owner: push all, then pop what's left.
        for i in 0..N {
            d.push(i);
            if i % 3 == 0 {
                if let Some(v) = d.pop() {
                    consumed[v].fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        while let Some(v) = d.pop() {
            consumed[v].fetch_add(1, Ordering::Relaxed);
        }
        done.store(true, Ordering::Release);
        for t in thieves {
            t.join().unwrap();
        }
        while let Some(v) = d.pop() {
            consumed[v].fetch_add(1, Ordering::Relaxed);
        }
        for (i, c) in consumed.iter().enumerate() {
            assert_eq!(
                c.load(Ordering::Relaxed),
                1,
                "item {i} consumed {} times",
                c.load(Ordering::Relaxed)
            );
        }
    }

    #[test]
    fn grow_during_concurrent_steal_is_safe() {
        // Thieves keep stealing while the owner forces repeated growth.
        let d = Arc::new(Deque::new());
        let stolen = Arc::new(AtomicU64::new(0));
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let mut thieves = Vec::new();
        for _ in 0..2 {
            let d = d.clone();
            let stolen = stolen.clone();
            let done = done.clone();
            thieves.push(std::thread::spawn(move || {
                while !done.load(Ordering::Acquire) {
                    if d.steal().success().is_none() {
                        std::thread::yield_now();
                    } else {
                        stolen.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }));
        }
        let mut popped = 0u64;
        for round in 0..50 {
            for i in 0..(MIN_CAP * (round % 4 + 1)) {
                d.push(i);
            }
            while d.pop().is_some() {
                popped += 1;
            }
        }
        done.store(true, Ordering::Release);
        for t in thieves {
            t.join().unwrap();
        }
        while d.pop().is_some() {
            popped += 1;
        }
        let total: u64 = stolen.load(Ordering::Relaxed) + popped;
        let pushed: u64 = (0..50).map(|r| (MIN_CAP * (r % 4 + 1)) as u64).sum();
        assert_eq!(total, pushed, "no item lost or duplicated across grows");
    }
}
