//! Experiment harness shared by the per-figure/table benches.
//!
//! Each bench binary (rust/benches/*.rs) regenerates one figure or table
//! of the paper; this module holds the common surface: the standard CLI,
//! policy construction, scaled controller timers, and curated data for
//! Fig. 4 (the cores-vs-memory-channels trend).

use crate::controller::Approach;
use crate::engine::{self, ExecBackend, Scenario, ScenarioParams};
use crate::policy::{self, ArcasPolicy, Policy};
use crate::topology::Topology;
use crate::util::cli::{Args, Cli};

/// Standard bench CLI: every figure bench accepts the same knobs.
pub fn bench_cli(name: &str, about: &str) -> Cli {
    Cli::new(name, about)
        .opt("scale", "0.02", "dataset scale factor vs the paper's sizes")
        .opt("cache-scale", "0.05", "L3 capacity scale factor (keeps crossovers aligned)")
        .opt("cores", "", "comma-separated core counts (empty = figure default)")
        .opt("seed", "42", "PRNG seed")
        .opt("timer-us", "50", "ARCAS controller timer, microseconds")
        .opt("topology", "milan_2s", "machine preset (milan_2s|milan_1s|genoa_1s|monolithic_64)")
        .flag("quick", "smaller sweep for smoke runs")
        .flag("bench", "(passed by `cargo bench`; ignored)")
}

/// Add `--backend sim|host` to a bench CLI. Opt-in per bench: only
/// benches that actually route execution through the backend seam
/// declare it (a bench that ignored the flag would silently lie).
pub fn with_backend_opt(cli: Cli) -> Cli {
    cli.opt(
        "backend",
        "sim",
        "executor backend: sim (virtual time) | host (real threads)",
    )
}

/// Executor backend from bench args: `--backend sim|host` where the
/// bench declared it (see [`with_backend_opt`]), sim otherwise.
pub fn backend(args: &Args) -> ExecBackend {
    match args.get("backend") {
        Some(s) => s.parse().unwrap_or_else(|e: String| panic!("{e}")),
        None => ExecBackend::Sim,
    }
}

/// Resolve topology + cache scaling from bench args.
pub fn bench_topology(args: &Args) -> Topology {
    let t = Topology::preset(&args.str("topology")).unwrap_or_else(Topology::milan_2s);
    let cs = args.f64("cache-scale");
    if (cs - 1.0).abs() > 1e-9 {
        t.scale_caches(cs)
    } else {
        t
    }
}

/// Core counts: CLI override or the figure's default sweep.
pub fn core_sweep(args: &Args, default: &[usize]) -> Vec<usize> {
    let s = args.str("cores");
    if s.is_empty() {
        if args.flag("quick") {
            default
                .iter()
                .copied()
                .filter(|&c| c <= 16)
                .collect()
        } else {
            default.to_vec()
        }
    } else {
        args.u64_list("cores").iter().map(|&c| c as usize).collect()
    }
}

/// ARCAS policy with the bench-configured timer.
pub fn arcas(topo: &Topology, args: &Args) -> Box<dyn Policy> {
    Box::new(ArcasPolicy::new(topo).with_timer(args.u64("timer-us") * 1_000))
}

pub fn arcas_with(topo: &Topology, args: &Args, approach: Approach) -> Box<dyn Policy> {
    Box::new(
        ArcasPolicy::new(topo)
            .with_timer(args.u64("timer-us") * 1_000)
            .with_approach(approach),
    )
}

/// Any baseline by name.
pub fn baseline(name: &str, topo: &Topology) -> Box<dyn Policy> {
    policy::by_name(name, topo).unwrap_or_else(|| panic!("unknown policy {name}"))
}

/// Registry parameters derived from the standard bench CLI
/// (`--scale`/`--seed`; intensity and variant stay per-bench).
pub fn scenario_params(args: &Args) -> ScenarioParams {
    ScenarioParams {
        scale: args.f64("scale"),
        seed: args.u64("seed"),
        ..Default::default()
    }
}

/// Build a fresh registry scenario for the bench CLI args. Scenarios are
/// single-run: call once per (policy, core-count) point.
pub fn scenario(name: &str, args: &Args) -> Box<dyn Scenario> {
    scenario_with(name, &scenario_params(args))
}

/// Build a fresh registry scenario from explicit params.
pub fn scenario_with(name: &str, params: &ScenarioParams) -> Box<dyn Scenario> {
    engine::by_name(name)
        .unwrap_or_else(|| panic!("unknown scenario {name}"))
        .build(params)
}

/// Fig. 4 curated data: (year, representative high-end server CPU,
/// cores, memory channels). Sources are public vendor specs; the 2026
/// row is the paper's projection.
pub fn cores_vs_channels() -> Vec<(u32, &'static str, u32, u32)> {
    vec![
        (2010, "Xeon X7560", 8, 4),
        (2012, "Xeon E5-2690", 8, 4),
        (2014, "Xeon E5-2699 v3", 18, 4),
        (2016, "Xeon E5-2699 v4", 22, 4),
        (2017, "EPYC 7601 (Naples)", 32, 8),
        (2019, "EPYC 7742 (Rome)", 64, 8),
        (2021, "EPYC 7763 (Milan)", 64, 8),
        (2023, "EPYC 9654 (Genoa)", 96, 12),
        (2024, "EPYC 9754 (Bergamo)", 128, 12),
        (2026, "projected", 300, 12),
    ]
}

/// Print a standard bench header so every output records its config.
pub fn print_header(name: &str, args: &Args, topo: &Topology) {
    println!("### {name}");
    println!(
        "# topology={} scale={} cache-scale={} seed={} timer={}us quick={}",
        topo.summary(),
        args.str("scale"),
        args.str("cache-scale"),
        args.str("seed"),
        args.str("timer-us"),
        args.flag("quick"),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(extra: &[&str]) -> Args {
        bench_cli("t", "test")
            .parse_from(extra.iter().map(|s| s.to_string()))
            .unwrap()
    }

    #[test]
    fn defaults_resolve() {
        let args = parse(&[]);
        let topo = bench_topology(&args);
        assert_eq!(topo.name, "milan_2s");
        // cache-scale 0.05 applied.
        assert_eq!(topo.l3_per_chiplet, (32u64 << 20) / 20);
    }

    #[test]
    fn core_sweep_override_and_quick() {
        let args = parse(&["--cores", "1,2,4"]);
        assert_eq!(core_sweep(&args, &[8, 16]), vec![1, 2, 4]);
        let args = parse(&["--quick"]);
        assert_eq!(core_sweep(&args, &[1, 8, 16, 64]), vec![1, 8, 16]);
        let args = parse(&[]);
        assert_eq!(core_sweep(&args, &[1, 8]), vec![1, 8]);
    }

    #[test]
    fn policies_construct() {
        let args = parse(&[]);
        let topo = bench_topology(&args);
        assert_eq!(arcas(&topo, &args).name(), "ARCAS");
        assert_eq!(baseline("ring", &topo).name(), "RING");
    }

    fn parse_with_backend(extra: &[&str]) -> Args {
        with_backend_opt(bench_cli("t", "test"))
            .parse_from(extra.iter().map(|s| s.to_string()))
            .unwrap()
    }

    #[test]
    fn backend_resolves_from_args() {
        // Undeclared (bench without the opt) and declared-default both
        // mean the simulator.
        assert_eq!(backend(&parse(&[])), ExecBackend::Sim);
        assert_eq!(backend(&parse_with_backend(&[])), ExecBackend::Sim);
        assert_eq!(
            backend(&parse_with_backend(&["--backend", "host"])),
            ExecBackend::Host
        );
        // Benches that ignore the backend reject the flag outright.
        assert!(bench_cli("t", "test")
            .parse_from(["--backend".to_string(), "host".to_string()])
            .is_err());
    }

    #[test]
    #[should_panic(expected = "unknown backend")]
    fn backend_rejects_unknown_names() {
        let _ = backend(&parse_with_backend(&["--backend", "quantum"]));
    }

    #[test]
    fn fig4_trend_is_monotone_in_cores() {
        let rows = cores_vs_channels();
        assert!(rows.len() >= 8);
        for w in rows.windows(2) {
            assert!(w[1].2 >= w[0].2, "cores never regress");
        }
        // The gap grows: cores/channel at the end >> at the start.
        let first = rows[0].2 as f64 / rows[0].3 as f64;
        let last = rows.last().unwrap().2 as f64 / rows.last().unwrap().3 as f64;
        assert!(last > first * 5.0);
    }
}
