//! Public programming API (§4.6).
//!
//! Mirrors the paper's C++ surface:
//!
//! | paper               | here                                  |
//! |---------------------|---------------------------------------|
//! | `ARCAS_Init()`      | [`Arcas::init`] / [`Arcas::init_with`]|
//! | `ARCAS_Finalize()`  | [`Arcas::finalize`]                   |
//! | `run(lambda)`       | [`Arcas::run`]                        |
//! | `all_do(lambda)`    | [`Arcas::all_do`]                     |
//! | `call(core, f)`     | [`Arcas::call`] / [`Arcas::call_async`]|
//! | `barrier()`         | [`crate::task::BspTask`] barrier steps|
//!
//! ```no_run
//! use arcas::api::Arcas;
//! use arcas::mem::Placement;
//!
//! let mut rt = Arcas::init();
//! let data = rt.alloc("vector", 64 << 20, Placement::Interleave);
//! let report = rt.all_do(16, move |ctx, _rank| {
//!     ctx.seq_read(data, 4 << 20);
//!     ctx.compute_flops(1_000_000);
//! });
//! println!("took {} ms", report.makespan_ns as f64 / 1e6);
//! rt.finalize();
//! ```

use crate::controller::Approach;
use crate::engine::ExecBackend;
use crate::mem::{Placement, RegionId};
use crate::policy::{self, ArcasPolicy, Policy};
use crate::sched::RunReport;
use crate::sim::Machine;
use crate::task::{Coroutine, FnTask, IterTask, TaskCtx};
use crate::topology::Topology;
use crate::util::config::Config;

/// Runtime configuration.
#[derive(Clone, Debug)]
pub struct ArcasConfig {
    pub topology: Topology,
    pub policy: String,
    pub timer_ns: u64,
    pub threshold: f64,
    pub approach: Approach,
    /// Executor backend every [`Arcas::run`]/[`Arcas::all_do`] group runs
    /// on: the deterministic simulator (default) or real host threads.
    pub backend: ExecBackend,
}

impl Default for ArcasConfig {
    fn default() -> Self {
        Self {
            topology: Topology::milan_2s(),
            policy: "arcas".into(),
            timer_ns: crate::controller::DEFAULT_SCHEDULER_TIMER_NS,
            threshold: crate::controller::DEFAULT_RMT_CHIP_ACCESS_RATE,
            approach: Approach::Balanced,
            backend: ExecBackend::Sim,
        }
    }
}

impl ArcasConfig {
    /// Load from a config file (`[topology]` + `[scheduler]` sections).
    pub fn from_config(cfg: &Config) -> Self {
        let topology = Topology::from_config(cfg);
        Self {
            topology,
            policy: cfg.str_or("scheduler", "policy", "arcas"),
            timer_ns: cfg.u64_or(
                "scheduler",
                "timer_ns",
                crate::controller::DEFAULT_SCHEDULER_TIMER_NS,
            ),
            threshold: cfg.f64_or(
                "scheduler",
                "rmt_chip_access_rate",
                crate::controller::DEFAULT_RMT_CHIP_ACCESS_RATE,
            ),
            approach: match cfg.str_or("scheduler", "approach", "balanced").as_str() {
                "location" => Approach::LocationCentric,
                "cache_size" => Approach::CacheSizeCentric,
                _ => Approach::Balanced,
            },
            backend: cfg
                .str_or("scheduler", "backend", "sim")
                .parse()
                .unwrap_or_else(|e| panic!("[scheduler] backend: {e}")),
        }
    }
}

/// The ARCAS runtime handle.
pub struct Arcas {
    cfg: ArcasConfig,
    machine: Machine,
    finalized: bool,
}

impl Arcas {
    /// `ARCAS_Init()` with defaults (dual-socket Milan, adaptive policy).
    pub fn init() -> Self {
        Self::init_with(ArcasConfig::default())
    }

    pub fn init_with(cfg: ArcasConfig) -> Self {
        let machine = Machine::new(cfg.topology.clone());
        Self {
            cfg,
            machine,
            finalized: false,
        }
    }

    /// `ARCAS_Finalize()`.
    pub fn finalize(&mut self) {
        self.finalized = true;
    }

    pub fn topology(&self) -> &Topology {
        &self.cfg.topology
    }

    pub fn machine(&self) -> &Machine {
        &self.machine
    }

    pub fn machine_mut(&mut self) -> &mut Machine {
        &mut self.machine
    }

    /// Allocate a region visible to all tasks.
    pub fn alloc(&mut self, label: &str, size: u64, placement: Placement) -> RegionId {
        self.machine.alloc(label, size, placement)
    }

    fn build_policy(&self) -> Box<dyn Policy> {
        match self.cfg.policy.as_str() {
            "arcas" => Box::new(
                ArcasPolicy::new(&self.cfg.topology)
                    .with_timer(self.cfg.timer_ns)
                    .with_threshold(self.cfg.threshold)
                    .with_approach(self.cfg.approach),
            ),
            other => policy::by_name(other, &self.cfg.topology)
                .unwrap_or_else(|| panic!("unknown policy {other}")),
        }
    }

    /// Run a group of `n` coroutines (full control over yield points).
    /// Consumes the machine state for the run and restores it after,
    /// carrying cache residency forward. Execution goes through the
    /// engine's [`crate::engine::Run`] builder on the configured
    /// backend.
    pub fn run(
        &mut self,
        n: usize,
        make: impl FnMut(usize) -> Box<dyn Coroutine>,
    ) -> RunReport {
        assert!(!self.finalized, "runtime already finalized");
        let machine = std::mem::replace(&mut self.machine, Machine::new(self.cfg.topology.clone()));
        let (report, machine) = crate::engine::Run::on_machine(machine)
            .policy(self.build_policy())
            .backend(self.cfg.backend)
            .timer_ns(self.cfg.timer_ns)
            .tasks(n)
            .run_group(make);
        self.machine = machine;
        report
    }

    /// `all_do`: execute a closure once per task (one task per rank).
    pub fn all_do<F>(&mut self, n: usize, f: F) -> RunReport
    where
        F: Fn(&mut TaskCtx<'_>, usize) + Send + Sync + Clone + 'static,
    {
        self.run(n, move |rank| {
            let f = f.clone();
            Box::new(FnTask(move |ctx: &mut TaskCtx<'_>| f(ctx, rank)))
        })
    }

    /// `all_do` with `iters` chunks per task, yielding between chunks
    /// (the shape most paper workloads use).
    pub fn all_do_chunked<F>(&mut self, n: usize, iters: u64, f: F) -> RunReport
    where
        F: Fn(&mut TaskCtx<'_>, usize, u64) + Send + Sync + Clone + 'static,
    {
        self.run(n, move |rank| {
            let f = f.clone();
            Box::new(IterTask::new(iters, move |ctx, it| f(ctx, rank, it)))
        })
    }

    /// Synchronous RPC: run `f` on a specific core, charging the
    /// round-trip message cost from `from_core` (the `call()` API).
    pub fn call<R>(
        &mut self,
        from_core: usize,
        target_core: usize,
        f: impl FnOnce(&mut TaskCtx<'_>) -> R,
    ) -> R {
        // Request message.
        self.machine.message(from_core, target_core, 64);
        let mut ctx = TaskCtx {
            machine: &self.machine,
            core: target_core,
            task_id: usize::MAX,
            rank: 0,
            group_size: 1,
            now_ns: 0,
            step_outcome: Default::default(),
            probe_cache: Default::default(),
            book: Default::default(),
            peer_cores: None,
        };
        let r = f(&mut ctx);
        // Response message.
        self.machine.message(target_core, from_core, 64);
        r
    }

    /// Asynchronous RPC: fire-and-forget task pinned to a core; returns
    /// immediately after charging the send.
    pub fn call_async(&mut self, from_core: usize, target_core: usize, f: impl FnOnce(&mut TaskCtx<'_>) + Send) {
        self.machine.message(from_core, target_core, 64);
        let mut ctx = TaskCtx {
            machine: &self.machine,
            core: target_core,
            task_id: usize::MAX,
            rank: 0,
            group_size: 1,
            now_ns: 0,
            step_outcome: Default::default(),
            probe_cache: Default::default(),
            book: Default::default(),
            peer_cores: None,
        };
        f(&mut ctx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_run_finalize_lifecycle() {
        let mut rt = Arcas::init();
        let report = rt.all_do(4, |ctx, _| ctx.compute_ns(100));
        assert!(report.makespan_ns >= 100);
        rt.finalize();
    }

    #[test]
    #[should_panic(expected = "finalized")]
    fn run_after_finalize_panics() {
        let mut rt = Arcas::init();
        rt.finalize();
        let _ = rt.all_do(1, |_, _| {});
    }

    #[test]
    fn alloc_and_access_through_api() {
        let mut rt = Arcas::init();
        let r = rt.alloc("buf", 8 << 20, Placement::Bind(0));
        let report = rt.all_do(8, move |ctx, _| {
            ctx.seq_read(r, 1 << 20);
        });
        assert!(report.counts.total_ops() > 0.0);
    }

    #[test]
    fn chunked_run_dispatches_iters() {
        let mut rt = Arcas::init();
        let report = rt.all_do_chunked(2, 5, |ctx, _, _| ctx.compute_ns(10));
        assert_eq!(report.dispatches, 10);
    }

    #[test]
    fn call_charges_round_trip() {
        let mut rt = Arcas::init();
        let before = rt.machine().now(0);
        let v = rt.call(0, 9, |ctx| {
            ctx.compute_ns(100);
            42
        });
        assert_eq!(v, 42);
        assert!(rt.machine().now(0) > before);
        assert!(rt.machine().now(9) >= 100);
    }

    #[test]
    fn cache_state_carries_across_runs() {
        let mut rt = Arcas::init_with(ArcasConfig {
            policy: "local".into(),
            ..Default::default()
        });
        let r = rt.alloc("buf", 4 << 20, Placement::Bind(0));
        rt.all_do(1, move |ctx, _| {
            ctx.seq_read(r, 4 << 20);
        });
        // Second run: the region is warm in chiplet 0's L3.
        let resident = rt.machine().resident(0, r);
        assert!(resident > 0, "residency must persist across runs");
    }

    #[test]
    fn config_from_file_text() {
        let cfg = Config::parse(
            "[topology]\npreset = milan_1s\n[scheduler]\npolicy = ring\ntimer_ns = 5000000\n",
        )
        .unwrap();
        let ac = ArcasConfig::from_config(&cfg);
        assert_eq!(ac.topology.sockets, 1);
        assert_eq!(ac.policy, "ring");
        assert_eq!(ac.timer_ns, 5_000_000);
        assert_eq!(ac.backend, ExecBackend::Sim);
    }

    #[test]
    fn config_selects_the_host_backend() {
        let cfg = Config::parse("[scheduler]\nbackend = host\n").unwrap();
        assert_eq!(ArcasConfig::from_config(&cfg).backend, ExecBackend::Host);
    }

    #[test]
    fn all_do_runs_on_the_host_backend() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;
        let mut rt = Arcas::init_with(ArcasConfig {
            topology: Topology::milan_1s(),
            policy: "local".into(),
            backend: ExecBackend::Host,
            ..Default::default()
        });
        let hits = Arc::new(AtomicU64::new(0));
        let h = hits.clone();
        let report = rt.all_do(8, move |ctx, _| {
            h.fetch_add(1, Ordering::Relaxed);
            ctx.compute_ns(100);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 8);
        assert_eq!(report.dispatches, 8);
        assert!(report.wall_ns > 0);
        rt.finalize();
    }
}
