//! Generic timed event queue for the discrete-event engine.
//!
//! Used for scheduler timers (Algorithm 1 runs every `SCHEDULER_TIMER`),
//! delayed task wake-ups and experiment-level sampling (Fig. 11's
//! concurrency timeline).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A timed event carrying a payload tag.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Event<T> {
    pub at_ns: u64,
    pub seq: u64,
    pub payload: T,
}

impl<T: Eq> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at_ns, self.seq).cmp(&(other.at_ns, other.seq))
    }
}

impl<T: Eq> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap of events ordered by (time, insertion sequence).
#[derive(Clone, Debug, Default)]
pub struct EventQueue<T: Eq> {
    heap: BinaryHeap<Reverse<Event<T>>>,
    seq: u64,
}

impl<T: Eq> EventQueue<T> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn push(&mut self, at_ns: u64, payload: T) {
        self.seq += 1;
        self.heap.push(Reverse(Event {
            at_ns,
            seq: self.seq,
            payload,
        }));
    }

    /// Next event if it is due at or before `now_ns`.
    pub fn pop_due(&mut self, now_ns: u64) -> Option<Event<T>> {
        if let Some(Reverse(e)) = self.heap.peek() {
            if e.at_ns <= now_ns {
                return self.heap.pop().map(|Reverse(e)| e);
            }
        }
        None
    }

    /// Unconditional pop of the earliest event.
    pub fn pop(&mut self) -> Option<Event<T>> {
        self.heap.pop().map(|Reverse(e)| e)
    }

    pub fn peek_time(&self) -> Option<u64> {
        self.heap.peek().map(|Reverse(e)| e.at_ns)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn clear(&mut self) {
        self.heap.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        assert_eq!(q.pop().unwrap().payload, "a");
        assert_eq!(q.pop().unwrap().payload, "b");
        assert_eq!(q.pop().unwrap().payload, "c");
        assert!(q.pop().is_none());
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push(10, "first");
        q.push(10, "second");
        assert_eq!(q.pop().unwrap().payload, "first");
        assert_eq!(q.pop().unwrap().payload, "second");
    }

    #[test]
    fn pop_due_respects_now() {
        let mut q = EventQueue::new();
        q.push(100, 1u32);
        assert!(q.pop_due(50).is_none());
        assert_eq!(q.pop_due(100).unwrap().payload, 1);
    }

    #[test]
    fn peek_len_clear() {
        let mut q = EventQueue::new();
        assert!(q.is_empty());
        q.push(5, ());
        q.push(1, ());
        assert_eq!(q.peek_time(), Some(1));
        assert_eq!(q.len(), 2);
        q.clear();
        assert!(q.is_empty());
    }
}
