//! The simulated chiplet machine: discrete-event substrate.
//!
//! [`Machine`] composes the [`Topology`], the per-chiplet shard set from
//! [`crate::coordinator`] (L3 residency, access counters, IF-link and
//! DDR bandwidth trackers, virtual clocks) and the region registry. Task
//! execution charges virtual nanoseconds to the core a task currently
//! runs on; the simulator's executor (in [`crate::sched`]) always
//! advances the core with the smallest clock, which yields a
//! deterministic, causally-consistent interleaving — the discrete-event
//! replacement for running on real EPYC hardware.
//!
//! Every charging method takes `&self`: state is sharded per chiplet /
//! per socket behind leaf-level locks (never nested — see the
//! [`crate::coordinator`] docs), so the host backend shares one
//! `Machine` across worker threads with **no whole-machine lock**.
//! Steps on different chiplets charge concurrently and only contend
//! where the hardware would: sibling-L3 probes, coherence invalidations
//! and the shared DDR channels. Driven single-threaded, the arithmetic
//! is byte-for-byte the pre-shard monolith (pinned by
//! `rust/tests/shard_equivalence.rs` and the engine golden tests).

mod events;
pub use events::{Event, EventQueue};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::cachesim::{classify, Access, ClassCounts, Counters, Outcome};
use crate::coordinator::Shards;
use crate::mem::{MemoryManager, Placement, RegionId};
use crate::topology::Topology;

/// Per-step cache of **remote**-chiplet residency probes.
///
/// One coroutine step often issues several accesses against the same
/// region (read + write + log in an OLTP chunk, fill + frontier in a
/// graph sweep). Each access used to probe every remote chiplet's shard
/// lock for its residency; with this cache the step probes each
/// `(region, remote chiplet)` pair **once** and reuses the answer for
/// the rest of the step (ROADMAP follow-up from the sharding PR: batch
/// residency probes per step instead of per access).
///
/// Bit-identity on the Sim backend: within a single-threaded step the
/// only thing that can change a *remote* chiplet's residency is this
/// step's own writes (coherence invalidations) — and a write evicts the
/// written region from the cache ([`ProbeCache::forget`]), so the next
/// access re-probes. Local-chiplet residency is never cached (our own
/// fills change it on every access). `rust/tests/shard_equivalence.rs`
/// pins cached == uncached exactly. On the Host backend a cached probe
/// may miss a concurrent remote fill for the remainder of the step —
/// the same staleness a real core's snoop results have — while every
/// charge still lands exactly once.
///
/// Owned by `task::TaskCtx` (one per step), threaded through
/// [`Machine::access_cached`].
#[derive(Clone, Debug, Default)]
pub struct ProbeCache {
    /// (region, chiplet, resident bytes); linear scan — a step touches a
    /// handful of regions × at most 15 remote chiplets.
    entries: Vec<(RegionId, usize, u64)>,
}

impl ProbeCache {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn get(&self, region: RegionId, chiplet: usize) -> Option<u64> {
        self.entries
            .iter()
            .find(|e| e.0 == region && e.1 == chiplet)
            .map(|e| e.2)
    }

    #[inline]
    fn put(&mut self, region: RegionId, chiplet: usize, bytes: u64) {
        self.entries.push((region, chiplet, bytes));
    }

    /// Drop every cached probe for `region` (its remote residency just
    /// changed — e.g. this step wrote to it).
    pub fn forget(&mut self, region: RegionId) {
        self.entries.retain(|e| e.0 != region);
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Immutable `(size, placement)` snapshot of the region book, stamped
/// with the generation it was published at. Readers resolve size and
/// DRAM home from this table with no lock at all; the generation stamp
/// tells a [`RegionBookCache`] when the copy it holds went stale.
#[derive(Debug, Default)]
pub struct RegionTable {
    gen: u64,
    /// Indexed by raw region id (ids are allocated sequentially).
    entries: Vec<Option<(u64, Placement)>>,
}

impl RegionTable {
    /// Size + DRAM home of `id`, with the registry's own unknown-region
    /// defaults (size 1, `Interleave`) — mirrors `MemoryManager::size` +
    /// `MemoryManager::dram_home` exactly, so the snapshot path stays
    /// bit-identical to the locked path.
    #[inline]
    fn lookup(&self, id: RegionId, core_numa: usize, num_numa: usize) -> (u64, usize, f64) {
        let (size, placement) = self
            .entries
            .get(id.0 as usize)
            .and_then(|e| *e)
            .unwrap_or((1, Placement::Interleave));
        let (home, frac) = match placement {
            Placement::Bind(n) => (n, if n == core_numa { 1.0 } else { 0.0 }),
            Placement::Replicated => (core_numa, 1.0),
            Placement::Interleave => (core_numa, 1.0 / num_numa.max(1) as f64),
        };
        (size, home, frac)
    }
}

/// Per-task handle to the region-book snapshot — the lock-free fast
/// path. One relaxed-cost atomic load per access revalidates the cached
/// table; only a generation change (alloc/free/rebind/region move)
/// re-reads under the publication mutex. Lives in `task::TaskCtx` next
/// to the [`ProbeCache`] and is carried across a host batch the same way.
#[derive(Clone, Debug, Default)]
pub struct RegionBookCache {
    /// Generation of the held table; 0 is a never-published sentinel, so
    /// a fresh cache always pulls on first use.
    gen: u64,
    table: Arc<RegionTable>,
}

impl RegionBookCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Revalidate against the machine's current generation. Returns
    /// `true` when a fresh snapshot was pulled — callers must then drop
    /// stale residency probes, because a bumped generation may mean a
    /// free or a region move whose L3 eviction already hit the shards.
    #[inline]
    fn refresh(&mut self, machine: &Machine) -> bool {
        let gen = machine.book_gen.load(Ordering::Acquire);
        if self.gen == gen {
            return false;
        }
        let table = machine.book.lock().unwrap().clone();
        self.gen = table.gen;
        self.table = table;
        true
    }
}

/// The simulated machine.
#[derive(Debug)]
pub struct Machine {
    pub topo: Topology,
    /// Per-chiplet + per-socket accounting shards.
    shards: Shards,
    /// Region registry (sizes + NUMA placement), the write side of the
    /// book; mutated only by alloc/free/rebind/move_region. The access
    /// hot path reads the published snapshot below instead.
    regions: RwLock<MemoryManager>,
    /// Monotonic generation of the region book; bumped on every
    /// mutation. Access paths revalidate their snapshot against this
    /// with a single atomic load.
    book_gen: AtomicU64,
    /// Latest immutable snapshot; re-read by task caches only on a
    /// generation change.
    book: Mutex<Arc<RegionTable>>,
}

impl Machine {
    pub fn new(topo: Topology) -> Self {
        Self {
            shards: Shards::new(&topo),
            regions: RwLock::new(MemoryManager::new()),
            book_gen: AtomicU64::new(1),
            book: Mutex::new(Arc::new(RegionTable {
                gen: 1,
                entries: Vec::new(),
            })),
            topo,
        }
    }

    /// Publish a fresh snapshot of the (still write-locked) registry and
    /// bump the generation. Callers hold the `regions` write lock, which
    /// serializes publications; readers only touch `book` + `book_gen`,
    /// so the write lock never blocks the access fast path.
    fn publish_book(&self, mm: &MemoryManager) {
        let gen = self.book_gen.load(Ordering::Relaxed) + 1;
        let table = Arc::new(RegionTable {
            gen,
            entries: mm.snapshot_entries(),
        });
        *self.book.lock().unwrap() = table;
        self.book_gen.store(gen, Ordering::Release);
    }

    // --- memory management ---------------------------------------------

    /// Allocate a region and register it with the accounting model.
    pub fn alloc(&self, label: &str, size: u64, placement: Placement) -> RegionId {
        let mut mm = self.regions.write().unwrap();
        let id = mm.alloc(label, size, placement);
        self.publish_book(&mm);
        id
    }

    pub fn free(&self, id: RegionId) {
        let mut mm = self.regions.write().unwrap();
        mm.free(id);
        // The generation bump makes every live per-batch ProbeCache clear
        // on its next access, so probes of the freed region can never
        // resurface (they'd report residency the shards just dropped).
        self.publish_book(&mm);
        drop(mm);
        self.shards.drop_region(id);
    }

    /// Re-bind a region to a NUMA node (Algorithm 2's
    /// `set_mempolicy(MPOL_BIND, …)`). Setup-time API: the region must
    /// exist. For the adaptive path (which may race a free) see
    /// [`Machine::move_region`].
    pub fn rebind(&self, id: RegionId, numa: usize) {
        let mut mm = self.regions.write().unwrap();
        let known = mm.rebind(id, numa);
        debug_assert!(known, "rebind of unknown region {id:?}");
        if known {
            self.publish_book(&mm);
        }
    }

    /// Online region re-placement ("data follows tasks"): re-bind `id`
    /// to `to_numa`, evict its now-stale L3 residency everywhere, and
    /// charge the one-time DDR copy to `mover_core` — size-proportional,
    /// queued against the destination socket's channels like any other
    /// DRAM burst. Returns `false` (charging nothing) for unknown
    /// regions and moves to the current home, so adaptive ticks can race
    /// frees safely.
    pub fn move_region(&self, id: RegionId, to_numa: usize, mover_core: usize) -> bool {
        let size = {
            let mut mm = self.regions.write().unwrap();
            if mm.get(id).is_none() || mm.placement(id) == Placement::Bind(to_numa) {
                return false;
            }
            let known = mm.rebind(id, to_numa);
            debug_assert!(known, "rebind of unknown region {id:?}");
            self.publish_book(&mm);
            mm.size(id)
        };
        self.shards.drop_region(id);
        let now = self.now(mover_core) as f64;
        let socket = self.topo.socket_of_numa(to_numa);
        let copy_ns = self.shards.charge_ddr(socket, now, size as f64);
        self.advance(mover_core, copy_ns.round() as u64);
        true
    }

    /// Registered size of `id` (1 for unknown regions, matching the
    /// registry's own default).
    pub fn region_size(&self, id: RegionId) -> u64 {
        self.regions.read().unwrap().size(id)
    }

    /// NUMA placement of `id`.
    pub fn placement_of(&self, id: RegionId) -> Placement {
        self.regions.read().unwrap().placement(id)
    }

    // --- clocks ----------------------------------------------------------

    #[inline]
    pub fn now(&self, core: usize) -> u64 {
        self.shards.now(core)
    }

    /// Latest clock across all cores (= makespan when a run finishes).
    pub fn max_time(&self) -> u64 {
        self.shards.max_time()
    }

    /// Earliest-clock core among `candidates` (executor's pick rule).
    pub fn min_clock_core(&self, candidates: &[usize]) -> Option<usize> {
        candidates.iter().copied().min_by_key(|&c| self.now(c))
    }

    #[inline]
    pub fn advance(&self, core: usize, ns: u64) {
        self.shards.advance(core, ns);
    }

    /// Synchronize `core`'s clock forward to at least `t` (barrier wake-up,
    /// steal from a later core, timer alignment).
    #[inline]
    pub fn advance_to(&self, core: usize, t: u64) {
        self.shards.advance_to(core, t);
    }

    /// Reset clocks and dynamic state between experiment repetitions
    /// (allocations survive; caches and counters are cold again).
    pub fn reset_dynamic(&self) {
        self.shards.reset_dynamic();
    }

    // --- accounting snapshots --------------------------------------------

    /// Machine-wide class totals (hierarchy counters summed over chiplets).
    pub fn class_totals(&self) -> ClassCounts {
        self.shards.class_totals()
    }

    /// Per-chiplet counter snapshot (Tab. 1/2-style reporting).
    pub fn counters(&self) -> Counters {
        self.shards.counters()
    }

    /// Resident bytes of `region` in `chiplet`'s L3.
    pub fn resident(&self, chiplet: usize, region: RegionId) -> u64 {
        self.shards.resident(chiplet, region)
    }

    /// Total DRAM bytes served by `socket`.
    pub fn dram_bytes_of_socket(&self, socket: usize) -> f64 {
        self.shards.dram_bytes_of_socket(socket)
    }

    /// Total DRAM bytes across all sockets.
    pub fn dram_total_bytes(&self) -> f64 {
        self.shards.dram_total_bytes()
    }

    /// Per-region, per-chiplet access heat (cumulative classified ops;
    /// sorted by region id, chiplet order) — the profiler windows this
    /// into deltas for the policy's online region moves.
    pub fn region_heat(&self) -> Vec<(RegionId, Vec<f64>)> {
        self.shards.region_heat()
    }

    /// A charging handle bound to `core` (what each coroutine step works
    /// through — see [`MachineView`]).
    pub fn view(&self, core: usize) -> MachineView<'_> {
        MachineView {
            machine: self,
            core,
        }
    }

    // --- cost charging ---------------------------------------------------

    /// Pure compute on `core` for `ns` virtual nanoseconds.
    #[inline]
    pub fn compute(&self, core: usize, ns: u64) {
        self.advance(core, ns);
    }

    /// Model a memory access from `core`; charges the core's clock with
    /// cache latency + DRAM bandwidth terms and returns the outcome.
    ///
    /// Shard choreography (at most one lock held at any instant):
    /// 1. read the region book (size + DRAM home) under the read lock,
    /// 2. classify via lazy residency probes ([`classify`]) — one brief
    ///    shard lock per chiplet, none at all for remote chiplets when
    ///    the region is fully resident locally,
    /// 3. re-lock the *local* shard for the fill + counter record,
    /// 4. on writes, invalidate the other shards one by one,
    /// 5. charge the serving socket's DDR tracker and the local IF link.
    pub fn access(&self, core: usize, acc: Access) -> Outcome {
        self.access_with(core, acc, None)
    }

    /// [`Machine::access`] with a per-step [`ProbeCache`]: remote
    /// residency probes for a `(region, chiplet)` pair are answered from
    /// the cache after the first probe of the step. The task layer
    /// (`TaskCtx::access`) routes every coroutine-step access through
    /// this; bit-identical to the uncached path on the Sim backend
    /// (pinned by `rust/tests/shard_equivalence.rs`).
    pub fn access_cached(&self, core: usize, acc: Access, cache: &mut ProbeCache) -> Outcome {
        self.access_with(core, acc, Some(cache))
    }

    /// The zero-lock fast path: region size + DRAM home come from the
    /// caller's generation-validated snapshot ([`RegionBookCache`])
    /// instead of the book's read lock. In steady state (generation
    /// unchanged) an access touches no region-book lock at all; on a
    /// generation change the snapshot is re-read once and the probe
    /// cache is dropped (a bump may mean a free or a region move whose
    /// L3 eviction already hit the shards). Bit-identical to
    /// [`Machine::access`] — pinned by `rust/tests/shard_equivalence.rs`.
    pub fn access_task(
        &self,
        core: usize,
        acc: Access,
        cache: &mut ProbeCache,
        book: &mut RegionBookCache,
    ) -> Outcome {
        if book.refresh(self) {
            cache.clear();
        }
        let my_numa = self.topo.numa_of_core(core);
        let (size, home, local_frac) = book.table.lookup(acc.region, my_numa, self.topo.num_numa());
        self.access_classified(core, acc, size, home, local_frac, Some(cache))
    }

    fn access_with(&self, core: usize, acc: Access, cache: Option<&mut ProbeCache>) -> Outcome {
        let my_numa = self.topo.numa_of_core(core);
        let (size, home, local_frac) = {
            let book = self.regions.read().unwrap();
            let (home, frac) = book.dram_home(acc.region, my_numa, self.topo.num_numa());
            (book.size(acc.region), home, frac)
        };
        self.access_classified(core, acc, size, home, local_frac, cache)
    }

    /// Everything after the region-book read: classification, residency
    /// fill, coherence, bandwidth. Shared by the locked path
    /// ([`Machine::access`] / [`Machine::access_cached`]) and the
    /// snapshot path ([`Machine::access_task`]) so the arithmetic cannot
    /// diverge.
    fn access_classified(
        &self,
        core: usize,
        acc: Access,
        size: u64,
        home: usize,
        local_frac: f64,
        mut cache: Option<&mut ProbeCache>,
    ) -> Outcome {
        let now = self.now(core) as f64;
        let my_chiplet = self.topo.chiplet_of(core);
        let my_numa = self.topo.numa_of_core(core);

        if acc.pattern.ops() == 0 {
            return Outcome::default();
        }

        // Residency probing is lazy: `classify` asks for each chiplet's
        // resident bytes exactly once, and each answer takes one brief
        // shard lock (never nested). Local-hit fast path: when the
        // region is fully resident in the issuing chiplet's L3, the
        // near/far fractions clamp to exactly zero no matter what the
        // other shards hold — so remote probes are answered with 0
        // without touching their locks at all, and warm chiplet-local
        // traffic stays on its own shard (the shard-equivalence property
        // suite pins that this shortcut is bit-identical). With a step
        // cache, a remote probe already answered earlier in this step is
        // reused without touching the shard lock again.
        let local_res = self.shards.resident(my_chiplet, acc.region);
        let classified = classify(&self.topo, core, acc, size, |ch| {
            if ch == my_chiplet {
                local_res
            } else if local_res >= size {
                0
            } else {
                match cache.as_deref_mut() {
                    Some(c) => {
                        if let Some(v) = c.get(acc.region, ch) {
                            v
                        } else {
                            let v = self.shards.resident(ch, acc.region);
                            c.put(acc.region, ch, v);
                            v
                        }
                    }
                    None => self.shards.resident(ch, acc.region),
                }
            }
        });
        let mut out = classified.out;
        let p_local = classified.p_local;

        // Latency correction for remote-homed DRAM lines (the cache model
        // assumed local-NUMA DRAM latency).
        if local_frac < 1.0 {
            let remote_lines = out.dram_lines * (1.0 - local_frac);
            let extra = self.topo.lat.dram_remote_ns - self.topo.lat.dram_local_ns;
            out.latency_ns += remote_lines * extra / acc.mlp.max(1.0);
        }

        // Residency update: fills land in the local chiplet's L3.
        let unique = acc.pattern.unique_bytes().min(size);
        let fill_bytes = ((unique as f64) * (1.0 - p_local)) as u64;
        self.shards
            .fill_and_record(my_chiplet, acc.region, fill_bytes, size, &out);

        // Coherence: a write invalidates the written fraction elsewhere —
        // and stales any cached probes of this region, so the step cache
        // forgets them (next access re-probes; keeps cached == uncached).
        if acc.write {
            let written_frac = (unique as f64 / size.max(1) as f64).min(1.0);
            for ch in 0..self.topo.num_chiplets() {
                if ch != my_chiplet {
                    self.shards.invalidate(ch, acc.region, written_frac);
                }
            }
            if let Some(c) = cache.as_deref_mut() {
                c.forget(acc.region);
            }
        }

        // Bandwidth term, charged against the serving socket's DDR
        // channels and the issuing chiplet's IF link (the two stages
        // pipeline, so the slower one dominates).
        let bw_ns = if out.dram_bytes > 0.0 {
            let bw_numa = if local_frac >= 1.0 { my_numa } else { home };
            let socket = self.topo.socket_of_numa(bw_numa);
            let ddr = self.shards.charge_ddr(socket, now, out.dram_bytes);
            let link = self.shards.charge_if_link(my_chiplet, now, out.dram_bytes);
            ddr.max(link)
        } else {
            0.0
        };
        let total = out.latency_ns + bw_ns;
        out.latency_ns = total;
        self.advance(core, total.round() as u64);
        out
    }

    /// Point-to-point message cost between cores (RPC / steal / barrier
    /// traffic). Charges the *sender*; returns the latency.
    pub fn message(&self, from: usize, to: usize, bytes: u64) -> u64 {
        let lat = self.topo.core_to_core_ns(from, to);
        // Payload beyond a cache line streams at fabric bandwidth
        // (~32 B/ns on Infinity Fabric).
        let stream = (bytes.saturating_sub(64)) as f64 / 32.0;
        let ns = (lat + stream).round() as u64;
        self.advance(from, ns);
        ns
    }

    /// Cost of an OS context switch on `core` (std::async baseline).
    pub fn os_context_switch(&self, core: usize) {
        let ns = self.topo.lat.os_context_switch_ns.round() as u64;
        self.advance(core, ns);
    }

    /// Cost of a user-space coroutine switch on `core` (ARCAS tasks).
    pub fn coroutine_switch(&self, core: usize) {
        let ns = self.topo.lat.coroutine_switch_ns.round() as u64;
        self.advance(core, ns);
    }
}

impl Clone for Machine {
    fn clone(&self) -> Self {
        let table = self.book.lock().unwrap().clone();
        Self {
            topo: self.topo.clone(),
            shards: self.shards.clone(),
            regions: RwLock::new(self.regions.read().unwrap().clone()),
            book_gen: AtomicU64::new(table.gen),
            book: Mutex::new(table),
        }
    }
}

/// A per-core charging handle: the "view" a coroutine step gets of the
/// sharded machine. Charges land on the bound core's own chiplet shard
/// directly; remote shards are only touched for sibling/remote residency,
/// coherence and DRAM — mirroring what the hardware would do.
#[derive(Clone, Copy)]
pub struct MachineView<'m> {
    machine: &'m Machine,
    core: usize,
}

impl<'m> MachineView<'m> {
    pub fn machine(&self) -> &'m Machine {
        self.machine
    }

    pub fn core(&self) -> usize {
        self.core
    }

    #[inline]
    pub fn now(&self) -> u64 {
        self.machine.now(self.core)
    }

    #[inline]
    pub fn compute(&self, ns: u64) {
        self.machine.compute(self.core, ns);
    }

    #[inline]
    pub fn advance_to(&self, t: u64) {
        self.machine.advance_to(self.core, t);
    }

    pub fn access(&self, acc: Access) -> Outcome {
        self.machine.access(self.core, acc)
    }

    /// Message from this core to `to` (charges this core as sender).
    pub fn message_to(&self, to: usize, bytes: u64) -> u64 {
        self.machine.message(self.core, to, bytes)
    }

    pub fn coroutine_switch(&self) {
        self.machine.coroutine_switch(self.core);
    }

    pub fn os_context_switch(&self) {
        self.machine.os_context_switch(self.core);
    }

    pub fn chiplet(&self) -> usize {
        self.machine.topo.chiplet_of(self.core)
    }

    pub fn numa(&self) -> usize {
        self.machine.topo.numa_of_core(self.core)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine() -> Machine {
        Machine::new(Topology::milan_2s())
    }

    #[test]
    fn clocks_start_at_zero_and_advance() {
        let m = machine();
        assert_eq!(m.now(0), 0);
        m.compute(0, 100);
        assert_eq!(m.now(0), 100);
        assert_eq!(m.now(1), 0);
        assert_eq!(m.max_time(), 100);
    }

    #[test]
    fn advance_to_never_rewinds() {
        let m = machine();
        m.compute(0, 100);
        m.advance_to(0, 50);
        assert_eq!(m.now(0), 100);
        m.advance_to(0, 150);
        assert_eq!(m.now(0), 150);
    }

    #[test]
    fn min_clock_core_picks_earliest() {
        let m = machine();
        m.compute(0, 100);
        m.compute(1, 50);
        assert_eq!(m.min_clock_core(&[0, 1, 2]), Some(2));
        assert_eq!(m.min_clock_core(&[0, 1]), Some(1));
        assert_eq!(m.min_clock_core(&[]), None);
    }

    #[test]
    fn access_charges_time() {
        let m = machine();
        let r = m.alloc("data", 8 << 20, Placement::Bind(0));
        let out = m.access(0, Access::seq_read(r, 8 << 20));
        assert!(out.latency_ns > 0.0);
        assert!(m.now(0) > 0);
    }

    #[test]
    fn remote_numa_dram_costs_more() {
        let m1 = machine();
        let local = m1.alloc("l", 8 << 20, Placement::Bind(0));
        let a = m1.access(0, Access::seq_read(local, 8 << 20));

        let m2 = machine();
        let remote = m2.alloc("r", 8 << 20, Placement::Bind(1));
        let b = m2.access(0, Access::seq_read(remote, 8 << 20));
        assert!(
            b.latency_ns > a.latency_ns,
            "remote {} must exceed local {}",
            b.latency_ns,
            a.latency_ns
        );
    }

    #[test]
    fn message_cost_follows_topology() {
        let m = machine();
        let intra = m.message(0, 1, 64);
        let inter = m.message(0, 9, 64);
        let cross = m.message(0, 64, 64);
        assert!(intra < inter && inter < cross);
        // Sender clock advanced by all three.
        assert_eq!(m.now(0), intra + inter + cross);
    }

    #[test]
    fn large_message_pays_bandwidth() {
        let m = machine();
        let small = m.message(0, 8, 64);
        let big = m.message(1, 9, 1 << 20);
        assert!(big > small + 10_000, "big={big} small={small}");
    }

    #[test]
    fn switch_costs_differ_by_regime() {
        let m = machine();
        m.coroutine_switch(0);
        let coro = m.now(0);
        m.os_context_switch(1);
        let os = m.now(1);
        assert!(os > coro * 10);
    }

    #[test]
    fn reset_dynamic_clears_clocks_and_counters() {
        let m = machine();
        let r = m.alloc("d", 1 << 20, Placement::Bind(0));
        m.access(0, Access::seq_read(r, 1 << 20));
        m.reset_dynamic();
        assert_eq!(m.max_time(), 0);
        assert_eq!(m.class_totals().total_ops(), 0.0);
        // Region registration survives.
        assert_eq!(m.region_size(r), 1 << 20);
    }

    #[test]
    fn spreading_dram_traffic_across_chiplets_beats_one_if_link() {
        // The per-CCD IF link is the narrow stage for a single chiplet
        // (§2.3): the same DRAM bytes served through 8 chiplet shards
        // finish faster than funneled through one.
        let single = machine();
        let r1 = single.alloc("d", 64 << 20, Placement::Bind(0));
        let funneled = single.access(0, Access::seq_read(r1, 64 << 20));

        let spread = machine();
        let r2 = spread.alloc("d", 64 << 20, Placement::Bind(0));
        let mut spread_max = 0.0f64;
        for ch in 0..8 {
            let out = spread.access(ch * 8, Access::seq_read(r2, 8 << 20));
            spread_max = spread_max.max(out.latency_ns);
        }
        assert!(
            spread_max < funneled.latency_ns,
            "spread {spread_max} must beat single-link {}",
            funneled.latency_ns
        );
    }

    #[test]
    fn cached_access_equals_uncached_within_a_step() {
        // Warm chiplet 1 so chiplet 0 sees real remote residency, then
        // issue a step's worth of mixed accesses through both paths.
        let ops: Vec<(bool, bool, u64)> = vec![
            // (write, seq, amount)
            (false, false, 500),
            (false, true, 1 << 20),
            (true, false, 200),
            (false, false, 800),
            (true, true, 1 << 19),
            (false, false, 300),
        ];
        let run = |cached: bool| {
            let m = machine();
            let r = m.alloc("d", 16 << 20, Placement::Bind(0));
            m.access(8, Access::seq_read(r, 16 << 20)); // chiplet 1 warm
            let mut cache = ProbeCache::new();
            let mut outs = Vec::new();
            for &(write, seq, amount) in &ops {
                let acc = match (write, seq) {
                    (false, true) => Access::seq_read(r, amount),
                    (false, false) => Access::rand_read(r, amount, 16 << 20),
                    (true, true) => Access::seq_write(r, amount),
                    (true, false) => Access::rand_write(r, amount, 16 << 20),
                };
                let out = if cached {
                    m.access_cached(0, acc, &mut cache)
                } else {
                    m.access(0, acc)
                };
                outs.push((out.local_hits, out.near_hits, out.far_hits, out.latency_ns));
            }
            (outs, m.now(0), m.resident(0, r), m.resident(1, r))
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn probe_cache_reuses_remote_probes_and_forgets_on_write() {
        let m = machine();
        let r = m.alloc("d", 16 << 20, Placement::Bind(0));
        m.access(8, Access::seq_read(r, 16 << 20)); // remote residency on chiplet 1
        let mut cache = ProbeCache::new();
        assert!(cache.is_empty());
        m.access_cached(0, Access::rand_read(r, 100, 16 << 20), &mut cache);
        // Remote probes were recorded (one entry per probed remote chiplet).
        let probed = cache.len();
        assert!(probed > 0, "remote probes should have been cached");
        m.access_cached(0, Access::rand_read(r, 100, 16 << 20), &mut cache);
        assert_eq!(cache.len(), probed, "second access must reuse, not re-probe");
        // A write to the region stales the remote answers.
        m.access_cached(0, Access::rand_write(r, 10, 16 << 20), &mut cache);
        assert!(cache.is_empty(), "write must evict the region's probes");
        cache.put(r, 3, 42);
        assert_eq!(cache.get(r, 3), Some(42));
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn freed_region_probes_cannot_resurface() {
        let m = machine();
        let r = m.alloc("d", 16 << 20, Placement::Bind(0));
        m.access(8, Access::seq_read(r, 16 << 20)); // chiplet 1 warm
        let mut cache = ProbeCache::new();
        let mut book = RegionBookCache::new();
        m.access_task(0, Access::rand_read(r, 100, 16 << 20), &mut cache, &mut book);
        assert!(!cache.is_empty(), "remote probes should have been cached");
        m.free(r);
        // The free bumped the book generation, so the next access through
        // the same live caches must re-read and drop the stale probes —
        // without the bump, chiplet 1's dropped residency would resurface
        // from the cache. A fresh clone (cold caches) is the oracle.
        let oracle = m.clone();
        let expect = oracle.access(0, Access::rand_read(r, 100, 16 << 20));
        let got = m.access_task(0, Access::rand_read(r, 100, 16 << 20), &mut cache, &mut book);
        assert_eq!(got.near_hits, expect.near_hits);
        assert_eq!(got.latency_ns, expect.latency_ns);
        assert_eq!(got.dram_lines, expect.dram_lines);
    }

    #[test]
    fn move_region_rebinds_evicts_and_charges_mover() {
        let m = machine();
        let r = m.alloc("d", 8 << 20, Placement::Bind(0));
        m.access(0, Access::seq_read(r, 8 << 20));
        assert!(m.resident(0, r) > 0);
        let t0 = m.now(4);
        assert!(m.move_region(r, 1, 4));
        assert_eq!(m.placement_of(r), Placement::Bind(1));
        assert_eq!(m.resident(0, r), 0, "stale residency must be evicted");
        assert!(m.now(4) > t0, "mover pays the one-time copy");
        // Moves to the current home and unknown ids refuse, charging
        // nothing (an adaptive tick may race a free).
        let before = m.now(4);
        assert!(!m.move_region(r, 1, 4));
        assert!(!m.move_region(RegionId(9999), 0, 4));
        assert_eq!(m.now(4), before);
    }

    #[test]
    fn snapshot_path_matches_locked_path_across_rebinds() {
        // Same access stream through the locked read path and the
        // generation-stamped snapshot path, with a mid-stream rebind;
        // the two must stay bit-identical (the full property lives in
        // rust/tests/shard_equivalence.rs).
        let run = |snapshot: bool| {
            let m = machine();
            let r = m.alloc("d", 16 << 20, Placement::Bind(0));
            m.access(8, Access::seq_read(r, 16 << 20)); // chiplet 1 warm
            let mut cache = ProbeCache::new();
            let mut book = RegionBookCache::new();
            let mut outs = Vec::new();
            for i in 0..6 {
                if i == 3 {
                    m.rebind(r, 1);
                }
                let acc = Access::rand_read(r, 400, 16 << 20);
                let out = if snapshot {
                    m.access_task(0, acc, &mut cache, &mut book)
                } else {
                    m.access(0, acc)
                };
                outs.push((out.local_hits, out.near_hits, out.dram_lines, out.latency_ns));
            }
            (outs, m.now(0), m.resident(0, r), m.resident(1, r))
        };
        assert_eq!(run(true), run(false));
    }

    #[test]
    fn view_charges_the_bound_core() {
        let m = machine();
        let v = m.view(3);
        v.compute(100);
        let r = m.alloc("d", 1 << 20, Placement::Bind(0));
        let out = v.access(Access::seq_read(r, 1 << 20));
        assert!(out.total_ops() > 0.0);
        assert!(m.now(3) >= 100);
        assert_eq!(m.now(0), 0);
        assert_eq!(v.chiplet(), 0);
        assert_eq!(v.core(), 3);
    }
}
