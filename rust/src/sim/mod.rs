//! The simulated chiplet machine: discrete-event substrate.
//!
//! [`Machine`] composes the [`Topology`], the per-chiplet cache model, the
//! memory-bandwidth model and the region registry, and keeps one virtual
//! clock per core. Task execution charges virtual nanoseconds to the core
//! a task currently runs on; the executor (in [`crate::sched`]) always
//! advances the core with the smallest clock, which yields a
//! deterministic, causally-consistent interleaving — the discrete-event
//! replacement for running on real EPYC hardware.

mod events;
pub use events::{Event, EventQueue};

use crate::cachesim::{Access, CacheSim, Outcome};
use crate::mem::{MemoryManager, Placement, RegionId};
use crate::memsim::MemSim;
use crate::topology::Topology;

/// The simulated machine.
#[derive(Clone, Debug)]
pub struct Machine {
    pub topo: Topology,
    pub cache: CacheSim,
    pub membw: MemSim,
    pub mm: MemoryManager,
    clocks: Vec<u64>,
}

impl Machine {
    pub fn new(topo: Topology) -> Self {
        Self {
            cache: CacheSim::new(&topo),
            membw: MemSim::new(&topo),
            mm: MemoryManager::new(),
            clocks: vec![0; topo.num_cores()],
            topo,
        }
    }

    // --- memory management ---------------------------------------------

    /// Allocate a region and register it with the cache model.
    pub fn alloc(&mut self, label: &str, size: u64, placement: Placement) -> RegionId {
        let id = self.mm.alloc(label, size, placement);
        self.cache.register_region(id, size);
        id
    }

    pub fn free(&mut self, id: RegionId) {
        self.mm.free(id);
        self.cache.drop_region(id);
    }

    // --- clocks ----------------------------------------------------------

    #[inline]
    pub fn now(&self, core: usize) -> u64 {
        self.clocks[core]
    }

    /// Latest clock across all cores (= makespan when a run finishes).
    pub fn max_time(&self) -> u64 {
        *self.clocks.iter().max().unwrap_or(&0)
    }

    /// Earliest-clock core among `candidates` (executor's pick rule).
    pub fn min_clock_core(&self, candidates: &[usize]) -> Option<usize> {
        candidates
            .iter()
            .copied()
            .min_by_key(|&c| self.clocks[c])
    }

    #[inline]
    pub fn advance(&mut self, core: usize, ns: u64) {
        self.clocks[core] += ns;
    }

    /// Synchronize `core`'s clock forward to at least `t` (barrier wake-up,
    /// steal from a later core, timer alignment).
    #[inline]
    pub fn advance_to(&mut self, core: usize, t: u64) {
        if self.clocks[core] < t {
            self.clocks[core] = t;
        }
    }

    /// Reset clocks and dynamic state between experiment repetitions
    /// (allocations survive; caches and counters are cold again).
    pub fn reset_dynamic(&mut self) {
        self.clocks.iter_mut().for_each(|c| *c = 0);
        self.cache.flush_all();
        self.cache.counters.reset();
        self.membw.reset();
    }

    // --- cost charging ---------------------------------------------------

    /// Pure compute on `core` for `ns` virtual nanoseconds.
    #[inline]
    pub fn compute(&mut self, core: usize, ns: u64) {
        self.advance(core, ns);
    }

    /// Model a memory access from `core`; charges the core's clock with
    /// cache latency + DRAM bandwidth terms and returns the outcome.
    pub fn access(&mut self, core: usize, acc: Access) -> Outcome {
        let now = self.clocks[core] as f64;
        let mut out = self.cache.access(core, acc);

        // DRAM side: where is the region homed?
        let core_numa = self.topo.numa_of_core(core);
        let (home, local_frac) =
            self.mm
                .dram_home(acc.region, core_numa, self.topo.num_numa());
        // Latency correction for remote-homed DRAM lines (the cache model
        // assumed local-NUMA DRAM latency).
        if local_frac < 1.0 {
            let remote_lines = out.dram_lines * (1.0 - local_frac);
            let extra = self.topo.lat.dram_remote_ns - self.topo.lat.dram_local_ns;
            out.latency_ns += remote_lines * extra / acc.mlp.max(1.0);
        }
        // Bandwidth term, charged against the serving socket's channels
        // and the issuing chiplet's IF link.
        let bw_numa = if local_frac >= 1.0 { core_numa } else { home };
        let chiplet = self.topo.chiplet_of(core);
        let bw_ns = self.membw.charge(now, bw_numa, chiplet, out.dram_bytes);
        let total = out.latency_ns + bw_ns;
        out.latency_ns = total;
        self.advance(core, total.round() as u64);
        out
    }

    /// Point-to-point message cost between cores (RPC / steal / barrier
    /// traffic). Charges the *sender*; returns the latency.
    pub fn message(&mut self, from: usize, to: usize, bytes: u64) -> u64 {
        let lat = self.topo.core_to_core_ns(from, to);
        // Payload beyond a cache line streams at fabric bandwidth
        // (~32 B/ns on Infinity Fabric).
        let stream = (bytes.saturating_sub(64)) as f64 / 32.0;
        let ns = (lat + stream).round() as u64;
        self.advance(from, ns);
        ns
    }

    /// Cost of an OS context switch on `core` (std::async baseline).
    pub fn os_context_switch(&mut self, core: usize) {
        let ns = self.topo.lat.os_context_switch_ns.round() as u64;
        self.advance(core, ns);
    }

    /// Cost of a user-space coroutine switch on `core` (ARCAS tasks).
    pub fn coroutine_switch(&mut self, core: usize) {
        let ns = self.topo.lat.coroutine_switch_ns.round() as u64;
        self.advance(core, ns);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn machine() -> Machine {
        Machine::new(Topology::milan_2s())
    }

    #[test]
    fn clocks_start_at_zero_and_advance() {
        let mut m = machine();
        assert_eq!(m.now(0), 0);
        m.compute(0, 100);
        assert_eq!(m.now(0), 100);
        assert_eq!(m.now(1), 0);
        assert_eq!(m.max_time(), 100);
    }

    #[test]
    fn advance_to_never_rewinds() {
        let mut m = machine();
        m.compute(0, 100);
        m.advance_to(0, 50);
        assert_eq!(m.now(0), 100);
        m.advance_to(0, 150);
        assert_eq!(m.now(0), 150);
    }

    #[test]
    fn min_clock_core_picks_earliest() {
        let mut m = machine();
        m.compute(0, 100);
        m.compute(1, 50);
        assert_eq!(m.min_clock_core(&[0, 1, 2]), Some(2));
        assert_eq!(m.min_clock_core(&[0, 1]), Some(1));
        assert_eq!(m.min_clock_core(&[]), None);
    }

    #[test]
    fn access_charges_time() {
        let mut m = machine();
        let r = m.alloc("data", 8 << 20, Placement::Bind(0));
        let out = m.access(0, Access::seq_read(r, 8 << 20));
        assert!(out.latency_ns > 0.0);
        assert!(m.now(0) > 0);
    }

    #[test]
    fn remote_numa_dram_costs_more() {
        let mut m1 = machine();
        let local = m1.alloc("l", 8 << 20, Placement::Bind(0));
        let a = m1.access(0, Access::seq_read(local, 8 << 20));

        let mut m2 = machine();
        let remote = m2.alloc("r", 8 << 20, Placement::Bind(1));
        let b = m2.access(0, Access::seq_read(remote, 8 << 20));
        assert!(
            b.latency_ns > a.latency_ns,
            "remote {} must exceed local {}",
            b.latency_ns,
            a.latency_ns
        );
    }

    #[test]
    fn message_cost_follows_topology() {
        let mut m = machine();
        let intra = m.message(0, 1, 64);
        let inter = m.message(0, 9, 64);
        let cross = m.message(0, 64, 64);
        assert!(intra < inter && inter < cross);
        // Sender clock advanced by all three.
        assert_eq!(m.now(0), intra + inter + cross);
    }

    #[test]
    fn large_message_pays_bandwidth() {
        let mut m = machine();
        let small = m.message(0, 8, 64);
        let big = m.message(1, 9, 1 << 20);
        assert!(big > small + 10_000, "big={big} small={small}");
    }

    #[test]
    fn switch_costs_differ_by_regime() {
        let mut m = machine();
        m.coroutine_switch(0);
        let coro = m.now(0);
        m.os_context_switch(1);
        let os = m.now(1);
        assert!(os > coro * 10);
    }

    #[test]
    fn reset_dynamic_clears_clocks_and_counters() {
        let mut m = machine();
        let r = m.alloc("d", 1 << 20, Placement::Bind(0));
        m.access(0, Access::seq_read(r, 1 << 20));
        m.reset_dynamic();
        assert_eq!(m.max_time(), 0);
        assert_eq!(m.cache.counters.total().total_ops(), 0.0);
        // Region registration survives.
        assert_eq!(m.cache.region_size(r), 1 << 20);
    }
}
