//! Host executor: the same runtime running on real OS threads.
//!
//! The paper experiments run on the simulated machine (deterministic,
//! chiplet-parametric); [`HostExecutor`] proves the runtime is also a real
//! work-stealing pool: per-worker Chase–Lev deques, chiplet-aware steal
//! order derived from a [`Topology`] (worker *i* is treated as core *i*),
//! and optional `sched_setaffinity` pinning on Linux.
//!
//! ## Submission path
//!
//! Chase–Lev push/pop are *owner-only* operations, so external
//! submissions never touch a worker's deque directly. Instead every
//! worker has a mutex-protected **inbox**: [`HostExecutor::execute`] /
//! [`Submitter::execute`] push the job's slot id into an inbox (any
//! thread, any number of concurrent submitters), and the owning worker
//! drains its inbox into its own deque between jobs. Idle workers steal
//! from other deques first (lock-free, chiplet-aware order) and fall back
//! to raiding other inboxes, so targeted jobs cannot starve behind a
//! long-running victim.
//!
//! Job payloads live in a slot table with a free list: a slot is recycled
//! as soon as its job has been taken by a worker, so a long-lived pool's
//! memory is bounded by the *peak in-flight* job count, not by the total
//! number of jobs ever submitted.
//!
//! [`Submitter`] is a cheap clone-able handle onto the pool's shared
//! state. Jobs may capture one and submit follow-up work from inside the
//! pool (nested `execute`); [`HostExecutor::wait_all`] only returns once
//! such chains have fully drained. `wait_all` must be called from
//! *outside* the pool — calling it from a job would deadlock the worker.

use std::cell::Cell;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::deque::{Deque, Steal};
use crate::policy::chiplet_first_steal_order;
use crate::topology::Topology;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Job payload table: `jobs[slot]` holds the closure until a worker takes
/// it; freed slots are recycled through `free` (bounded growth).
#[derive(Default)]
struct Slots {
    jobs: Vec<Option<Job>>,
    free: Vec<usize>,
}

impl Slots {
    fn insert(&mut self, job: Job) -> usize {
        match self.free.pop() {
            Some(slot) => {
                debug_assert!(self.jobs[slot].is_none());
                self.jobs[slot] = Some(job);
                slot
            }
            None => {
                self.jobs.push(Some(job));
                self.jobs.len() - 1
            }
        }
    }

    fn take(&mut self, slot: usize) -> Option<Job> {
        let job = self.jobs[slot].take();
        if job.is_some() {
            self.free.push(slot);
        }
        job
    }
}

struct Shared {
    /// Per-worker deques (owner-only push/pop; thieves steal).
    queues: Vec<Deque>,
    /// Per-worker submission inboxes (any thread may push).
    inboxes: Vec<Mutex<VecDeque<usize>>>,
    slots: Mutex<Slots>,
    pending: AtomicUsize,
    stop: AtomicBool,
    idle: Mutex<()>,
    wake: Condvar,
    done: Condvar,
    steals: AtomicUsize,
    next_worker: AtomicUsize,
    /// Slots submitted but not yet picked up by any worker. Parking
    /// re-checks this under the `idle` mutex (and submissions notify
    /// under it), so a submission racing a worker's failed `find_slot`
    /// cannot be lost to a full park timeout.
    queued: AtomicUsize,
    /// First panic payload from a job; re-raised by `wait_all` on the
    /// caller so a panicking job fails the run instead of wedging it.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Shared {
    fn submit(&self, worker: usize, job: Job) {
        if self.stop.load(Ordering::SeqCst) {
            // The pool has shut down (a `Submitter` outlived it): the
            // job is discarded — there are no workers left to run it.
            return;
        }
        let slot = self.slots.lock().unwrap().insert(job);
        self.pending.fetch_add(1, Ordering::SeqCst);
        self.inboxes[worker % self.inboxes.len()]
            .lock()
            .unwrap()
            .push_back(slot);
        self.queued.fetch_add(1, Ordering::SeqCst);
        // Notify under the park mutex: a worker between its `queued`
        // re-check and `wait_timeout` holds the lock, so this notify
        // cannot slip into that window and be lost. One waker per job —
        // stealing and the park timeout cover any second waiter.
        let _guard = self.idle.lock().unwrap();
        self.wake.notify_one();
    }

    fn submit_round_robin(&self, job: Job) {
        let w = self.next_worker.fetch_add(1, Ordering::Relaxed);
        self.submit(w % self.inboxes.len(), job);
    }
}

/// A chiplet-aware work-stealing thread pool.
pub struct HostExecutor {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    n_workers: usize,
}

/// Clone-able submission handle onto a [`HostExecutor`]'s shared state.
///
/// Jobs may capture a `Submitter` and schedule follow-up work from inside
/// the pool; the handle keeps the queues alive but does **not** own the
/// worker threads, so dropping it inside a job never joins the pool.
/// A handle may outlive its pool, but submissions after the pool has
/// dropped are **discarded** — the workers are gone.
#[derive(Clone)]
pub struct Submitter {
    shared: Arc<Shared>,
}

impl Submitter {
    /// Submit a job (round-robin across worker inboxes).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.submit_round_robin(Box::new(job));
    }

    /// Submit a job to a specific worker's inbox (`worker` is taken
    /// modulo the pool size). Thieves may still move it elsewhere.
    pub fn execute_on(&self, worker: usize, job: impl FnOnce() + Send + 'static) {
        self.shared.submit(worker, Box::new(job));
    }

    pub fn workers(&self) -> usize {
        self.shared.inboxes.len()
    }
}

thread_local! {
    /// Index of the pool worker running on this thread, if any.
    static CURRENT_WORKER: Cell<Option<usize>> = const { Cell::new(None) };
}

/// The pool worker index of the calling thread (`None` off-pool).
///
/// Workers map 1:1 onto cores (worker *i* = core *i*), so this is also
/// the core a job should charge machine time to.
pub fn current_worker() -> Option<usize> {
    CURRENT_WORKER.with(|w| w.get())
}

/// The core a worker models (worker *i* = core *i*, wrapped for pools
/// larger than the topology).
#[inline]
pub fn worker_core(topo: &Topology, worker: usize) -> usize {
    worker % topo.num_cores()
}

/// The machine-accounting shard a worker charges by default: its core's
/// chiplet ([`crate::coordinator::ChipletShard`]). Workers on the same
/// chiplet share one shard (their cores share that L3 in hardware);
/// workers on different chiplets charge disjoint shards and therefore
/// run concurrently on the sharded machine.
#[inline]
pub fn worker_shard(topo: &Topology, worker: usize) -> usize {
    topo.chiplet_of(worker_core(topo, worker))
}

impl HostExecutor {
    /// Spawn `n_workers` threads; steal order follows `topo` with worker
    /// index interpreted as core id. `pin` attempts CPU affinity.
    pub fn new(n_workers: usize, topo: &Topology, pin: bool) -> Self {
        let n = n_workers.max(1);
        let shared = Arc::new(Shared {
            queues: (0..n).map(|_| Deque::new()).collect(),
            inboxes: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            slots: Mutex::new(Slots::default()),
            pending: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            idle: Mutex::new(()),
            wake: Condvar::new(),
            done: Condvar::new(),
            steals: AtomicUsize::new(0),
            next_worker: AtomicUsize::new(0),
            queued: AtomicUsize::new(0),
            panic: Mutex::new(None),
        });
        let cores: Vec<usize> = (0..n).collect();
        let mut workers = Vec::with_capacity(n);
        for w in 0..n {
            let shared = shared.clone();
            let order = chiplet_first_steal_order(topo, worker_core(topo, w), &cores);
            workers.push(std::thread::spawn(move || {
                if pin {
                    pin_to_core(w);
                }
                CURRENT_WORKER.with(|c| c.set(Some(w)));
                worker_loop(w, order, shared);
            }));
        }
        Self {
            shared,
            workers,
            n_workers: n,
        }
    }

    /// Submit a job (round-robin across worker inboxes).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.submit_round_robin(Box::new(job));
    }

    /// Submit a job to a specific worker's inbox.
    pub fn execute_on(&self, worker: usize, job: impl FnOnce() + Send + 'static) {
        self.shared.submit(worker, Box::new(job));
    }

    /// A clone-able submission handle (usable from inside jobs).
    pub fn submitter(&self) -> Submitter {
        Submitter {
            shared: self.shared.clone(),
        }
    }

    /// Block until every submitted job (including jobs submitted by other
    /// jobs) has run. Must not be called from inside a job. If a job
    /// panicked, the first panic is re-raised here on the caller.
    pub fn wait_all(&self) {
        self.wait_idle();
        let payload = self.shared.panic.lock().unwrap().take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }

    /// The draining half of [`Self::wait_all`], without re-raising job
    /// panics (used by `Drop`, which must not panic mid-unwind).
    fn wait_idle(&self) {
        let mut guard = self.shared.idle.lock().unwrap();
        while self.shared.pending.load(Ordering::SeqCst) > 0 {
            let (g, _timeout) = self
                .shared
                .done
                .wait_timeout(guard, std::time::Duration::from_millis(50))
                .unwrap();
            guard = g;
        }
    }

    /// Number of successful steals (diagnostics).
    pub fn steal_count(&self) -> usize {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// High-water mark of the job slot table. Bounded by the peak
    /// in-flight job count (slots are recycled), not by the total number
    /// of jobs ever submitted — pinned by a regression test.
    pub fn slot_capacity(&self) -> usize {
        self.shared.slots.lock().unwrap().jobs.len()
    }

    pub fn workers(&self) -> usize {
        self.n_workers
    }
}

impl Drop for HostExecutor {
    fn drop(&mut self) {
        self.wait_idle();
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.wake.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Surface an unobserved job panic — unless we are already
        // unwinding (double panic would abort).
        if !std::thread::panicking() {
            let payload = self.shared.panic.lock().unwrap().take();
            if let Some(p) = payload {
                std::panic::resume_unwind(p);
            }
        }
    }
}

/// Find the next slot for worker `me`: own deque, else drain own inbox,
/// else steal (deques first, then inboxes) in chiplet-aware order.
fn find_slot(me: usize, steal_order: &[usize], shared: &Shared) -> Option<usize> {
    if let Some(slot) = shared.queues[me].pop() {
        return Some(slot);
    }
    // Drain the inbox into the owned deque (owner-side push is safe),
    // keeping one to run now.
    {
        let mut inbox = shared.inboxes[me].lock().unwrap();
        if let Some(first) = inbox.pop_front() {
            while let Some(slot) = inbox.pop_front() {
                shared.queues[me].push(slot);
            }
            return Some(first);
        }
    }
    for &v in steal_order {
        loop {
            match shared.queues[v].steal() {
                Steal::Success(slot) => {
                    shared.steals.fetch_add(1, Ordering::Relaxed);
                    return Some(slot);
                }
                Steal::Retry => continue,
                Steal::Empty => break,
            }
        }
        // Raid the victim's inbox too: a targeted job must not starve
        // behind a long-running victim.
        if let Ok(mut inbox) = shared.inboxes[v].try_lock() {
            if let Some(slot) = inbox.pop_front() {
                shared.steals.fetch_add(1, Ordering::Relaxed);
                return Some(slot);
            }
        }
    }
    None
}

fn worker_loop(me: usize, steal_order: Vec<usize>, shared: Arc<Shared>) {
    loop {
        match find_slot(me, &steal_order, &shared) {
            Some(slot) => {
                shared.queued.fetch_sub(1, Ordering::SeqCst);
                let job = shared.slots.lock().unwrap().take(slot);
                if let Some(job) = job {
                    // Contain unwinds: a panicking job must still reach
                    // the `pending` decrement below, or `wait_all` (and
                    // `Drop`) would hang forever. The first payload is
                    // kept and re-raised on the `wait_all` caller.
                    if let Err(p) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)) {
                        let mut first = shared.panic.lock().unwrap();
                        if first.is_none() {
                            *first = Some(p);
                        }
                    }
                }
                // The job ran (and possibly submitted follow-up work,
                // bumping `pending`) before this decrement, so `wait_all`
                // cannot observe a spuriously drained pool mid-chain.
                if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                    // Under the `idle` mutex for the same lost-wakeup
                    // reason as `submit`: `wait_idle` re-checks `pending`
                    // while holding it, so this notify cannot land
                    // between its check and its wait.
                    let _guard = shared.idle.lock().unwrap();
                    shared.done.notify_all();
                }
            }
            None => {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                // Park, re-checking for queued work under the lock: a
                // submission completed before the check is retried
                // immediately; one still in flight notifies under this
                // same mutex, so its wake-up cannot be lost. The timeout
                // is a belt-and-braces bound, not the recovery path.
                let guard = shared.idle.lock().unwrap();
                if shared.queued.load(Ordering::SeqCst) == 0
                    && !shared.stop.load(Ordering::SeqCst)
                {
                    let _ = shared
                        .wake
                        .wait_timeout(guard, std::time::Duration::from_millis(1));
                }
            }
        }
    }
}

/// Pin the calling thread to `core` (best effort).
///
/// `sched_setaffinity` needs the `libc` crate, which is not in the
/// offline crate set, so pinning is a no-op reporting failure; the pool
/// still works — steal order just approximates locality instead of
/// enforcing it. Swap in a real implementation when `libc` is available.
pub fn pin_to_core(_core: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(4, &topo, false);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_speedup_on_cpu_bound_work() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(4, &topo, false);
        let t = std::time::Instant::now();
        let sink = Arc::new(AtomicU64::new(0));
        for i in 0..8 {
            let sink = sink.clone();
            pool.execute(move || {
                let mut s = i as u64;
                for k in 0..2_000_000u64 {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(k);
                }
                sink.fetch_xor(s, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        let _ = t.elapsed();
        assert_ne!(sink.load(Ordering::Relaxed), u64::MAX);
    }

    #[test]
    fn stealing_happens_under_imbalance() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(8, &topo, false);
        // All jobs land round-robin but some take much longer: thieves
        // should pick up the slack. (We only assert completion + nonzero
        // steals are *possible*, not required — timing dependent.)
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..64 {
            let c = counter.clone();
            pool.execute(move || {
                if i % 8 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn drop_joins_cleanly() {
        let topo = Topology::milan_1s();
        {
            let pool = HostExecutor::new(2, &topo, false);
            pool.execute(|| {});
        } // drop
    }

    #[test]
    fn reuse_after_wait() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(2, &topo, false);
        let c = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = c.clone();
                pool.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_all();
            assert_eq!(c.load(Ordering::Relaxed), (round + 1) * 10);
        }
    }

    #[test]
    fn slots_are_recycled_across_rounds() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(2, &topo, false);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            for _ in 0..64 {
                let c = c.clone();
                pool.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_all();
        }
        assert_eq!(c.load(Ordering::Relaxed), 50 * 64);
        // The old append-only table grew one slot per job ever submitted
        // (3200 here); the free list bounds it by the peak in-flight count.
        assert!(
            pool.slot_capacity() <= 64,
            "slot table leaked: {} slots after 3200 jobs in rounds of 64",
            pool.slot_capacity()
        );
    }

    #[test]
    #[should_panic(expected = "job exploded")]
    fn job_panic_propagates_to_wait_all_instead_of_hanging() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(2, &topo, false);
        let c = Arc::new(AtomicU64::new(0));
        for i in 0..16 {
            let c = c.clone();
            pool.execute(move || {
                if i == 7 {
                    panic!("job exploded");
                }
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_all();
    }

    #[test]
    fn pool_survives_a_job_panic() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(2, &topo, false);
        pool.execute(|| panic!("first round panics"));
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.wait_all()));
        assert!(res.is_err());
        // The pool is still usable afterwards.
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = c.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        assert_eq!(c.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn submitter_outliving_the_pool_discards_jobs() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(2, &topo, false);
        let sub = pool.submitter();
        let c = Arc::new(AtomicU64::new(0));
        {
            let c = c.clone();
            sub.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool);
        assert_eq!(c.load(Ordering::Relaxed), 1, "pre-drop job must run");
        // Post-drop submissions are discarded, not lost in a queue.
        let c2 = c.clone();
        sub.execute(move || {
            c2.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn worker_shard_follows_the_chiplet_map() {
        let topo = Topology::milan_1s(); // 8 chiplets x 8 cores
        assert_eq!(worker_shard(&topo, 0), 0);
        assert_eq!(worker_shard(&topo, 7), 0);
        assert_eq!(worker_shard(&topo, 8), 1);
        assert_eq!(worker_shard(&topo, 63), 7);
        // Oversized pools wrap onto the topology.
        assert_eq!(worker_core(&topo, 64), 0);
        assert_eq!(worker_shard(&topo, 64), 0);
    }

    #[test]
    fn targeted_execute_on_runs_and_reports_worker() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(4, &topo, false);
        let seen = Arc::new(AtomicU64::new(u64::MAX));
        for w in 0..4 {
            let seen = seen.clone();
            pool.execute_on(w, move || {
                // On-pool jobs always observe a worker id; which one is
                // timing dependent (an idle thief may raid the inbox).
                let id = current_worker().expect("job ran off-pool") as u64;
                seen.fetch_min(id, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        assert!(seen.load(Ordering::Relaxed) < 4);
        assert_eq!(current_worker(), None, "main thread is not a worker");
    }
}
