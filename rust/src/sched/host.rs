//! Host executor: the same runtime running on real OS threads.
//!
//! The paper experiments run on the simulated machine (deterministic,
//! chiplet-parametric); [`HostExecutor`] proves the runtime is also a real
//! work-stealing pool: per-worker Chase–Lev deques, chiplet-aware steal
//! order derived from a [`Topology`] (worker *i* is treated as core *i*),
//! and optional `sched_setaffinity` pinning on Linux.
//!
//! ## Submission path
//!
//! Chase–Lev push/pop are *owner-only* operations, so external
//! submissions never touch a worker's deque directly. Two front queues
//! feed the deques instead:
//!
//! - a global lock-free **MPMC injector** (bounded Vyukov ring,
//!   [`Injector`]) takes every *untargeted* submission
//!   ([`HostExecutor::execute`] / [`Submitter::execute`]): any thread
//!   pushes, any worker pops, so bulk load spreads to whichever worker
//!   is free instead of being guessed onto one inbox round-robin. When
//!   the ring is momentarily full the slot overflows into a round-robin
//!   inbox — delayed, never lost;
//! - per-worker mutex-protected **inboxes** carry *core-targeted*
//!   submissions ([`Submitter::execute_on`]) only. A worker drains its
//!   own inbox *before* touching the injector, so a job aimed at a
//!   specific worker cannot be buried under an injector flood. This is
//!   also the **migration re-target path**: when the host backend's
//!   adaptation tick moves a rank, its next batch is simply submitted
//!   to the new home worker's inbox — no thread teardown, no handoff
//!   protocol beyond the queue itself.
//!
//! An idle worker looks for work in the order: own deque → own inbox →
//! injector (draining a small batch into its own deque) → steal other
//! deques (lock-free, chiplet-aware order) → raid other inboxes.
//!
//! Wake-ups are lazy and batched: a submission touches the park mutex
//! only when some worker is actually parked (`parked` counter in a
//! Dekker-style handshake with the park path), and burst submissions
//! ([`Submitter::execute_on_many`] / [`Submitter::execute_many`])
//! notify once per burst instead of once per job — stealing and the
//! 1 ms park timeout cover stragglers. [`HostExecutor::wakeup_count`]
//! exposes how many notifies actually happened (regression-tested:
//! a flood against a busy pool must not thundering-herd).
//!
//! Job payloads live in a slot table with a free list: a slot is recycled
//! as soon as its job has been taken by a worker, so a long-lived pool's
//! memory is bounded by the *peak in-flight* job count, not by the total
//! number of jobs ever submitted.
//!
//! [`Submitter`] is a cheap clone-able handle onto the pool's shared
//! state. Jobs may capture one and submit follow-up work from inside the
//! pool (nested `execute`); [`HostExecutor::wait_all`] only returns once
//! such chains have fully drained. `wait_all` must be called from
//! *outside* the pool — calling it from a job would deadlock the worker.

use std::cell::{Cell, UnsafeCell};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::deque::{Deque, Steal};
use crate::policy::chiplet_first_steal_order;
use crate::topology::Topology;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Capacity of the global injector ring (power of two). Bulk submitters
/// that outrun the workers past this depth overflow into the inboxes,
/// so the bound is a fast-path size, not a correctness limit.
const INJECTOR_CAP: usize = 1024;

/// How many injector slots a worker moves into its own deque per visit:
/// one to run now plus up to this many buffered, amortizing the ring's
/// CAS traffic across several pops.
const INJECTOR_DRAIN: usize = 16;

/// Bounded lock-free MPMC queue (Vyukov ring): per-cell sequence
/// numbers arbitrate producers and consumers without locks.
///
/// Invariant: cell `i` has `seq == pos` when it is free for the
/// producer claiming ticket `pos` (`pos % cap == i`), `seq == pos + 1`
/// when it holds that ticket's value for the consumer, and
/// `seq == pos + cap` once consumed (free for the next lap). A producer
/// or consumer that claims a ticket via CAS on `tail`/`head` is the
/// only thread touching the cell's value until it bumps `seq`.
struct Injector {
    cells: Box<[InjectorCell]>,
    /// Next ticket to consume.
    head: AtomicUsize,
    /// Next ticket to produce.
    tail: AtomicUsize,
}

struct InjectorCell {
    seq: AtomicUsize,
    val: UnsafeCell<usize>,
}

// SAFETY: a cell's `val` is only written by the producer that claimed
// its ticket (exclusive via the `tail` CAS) and only read by the
// consumer that claimed it (exclusive via the `head` CAS); the
// Release/Acquire pair on `seq` orders the write before the read.
unsafe impl Sync for Injector {}

impl Injector {
    fn new(cap: usize) -> Self {
        assert!(cap.is_power_of_two(), "injector capacity must be 2^k");
        Self {
            cells: (0..cap)
                .map(|i| InjectorCell {
                    seq: AtomicUsize::new(i),
                    val: UnsafeCell::new(0),
                })
                .collect(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
        }
    }

    /// Push from any thread. `Err(v)` hands the value back when the
    /// ring is full (the caller overflows it into an inbox).
    fn push(&self, v: usize) -> Result<(), usize> {
        let mask = self.cells.len() - 1;
        let mut tail = self.tail.load(Ordering::Relaxed);
        loop {
            let cell = &self.cells[tail & mask];
            let seq = cell.seq.load(Ordering::Acquire);
            let dif = seq as isize - tail as isize;
            if dif == 0 {
                // Cell free for this ticket: claim it.
                match self.tail.compare_exchange_weak(
                    tail,
                    tail.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS gave this thread exclusive
                        // ownership of the cell until the seq store.
                        unsafe { *cell.val.get() = v };
                        cell.seq.store(tail.wrapping_add(1), Ordering::Release);
                        return Ok(());
                    }
                    Err(t) => tail = t,
                }
            } else if dif < 0 {
                // A full lap behind: the ring is full.
                return Err(v);
            } else {
                // Another producer claimed this ticket; reload.
                tail = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Pop from any thread (workers race on this). `None` = empty.
    fn pop(&self) -> Option<usize> {
        let mask = self.cells.len() - 1;
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            let cell = &self.cells[head & mask];
            let seq = cell.seq.load(Ordering::Acquire);
            let dif = seq as isize - head.wrapping_add(1) as isize;
            if dif == 0 {
                match self.head.compare_exchange_weak(
                    head,
                    head.wrapping_add(1),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS gave this thread exclusive
                        // ownership of the cell until the seq store.
                        let v = unsafe { *cell.val.get() };
                        cell.seq
                            .store(head.wrapping_add(mask).wrapping_add(1), Ordering::Release);
                        return Some(v);
                    }
                    Err(h) => head = h,
                }
            } else if dif < 0 {
                return None;
            } else {
                head = self.head.load(Ordering::Relaxed);
            }
        }
    }
}

/// Job payload table: `jobs[slot]` holds the closure until a worker takes
/// it; freed slots are recycled through `free` (bounded growth).
#[derive(Default)]
struct Slots {
    jobs: Vec<Option<Job>>,
    free: Vec<usize>,
}

impl Slots {
    fn insert(&mut self, job: Job) -> usize {
        match self.free.pop() {
            Some(slot) => {
                debug_assert!(self.jobs[slot].is_none());
                self.jobs[slot] = Some(job);
                slot
            }
            None => {
                self.jobs.push(Some(job));
                self.jobs.len() - 1
            }
        }
    }

    fn take(&mut self, slot: usize) -> Option<Job> {
        let job = self.jobs[slot].take();
        if job.is_some() {
            self.free.push(slot);
        }
        job
    }
}

struct Shared {
    /// Per-worker deques (owner-only push/pop; thieves steal).
    queues: Vec<Deque>,
    /// Global MPMC front queue for untargeted submissions.
    injector: Injector,
    /// Per-worker submission inboxes: core-*targeted* submissions only
    /// (plus injector overflow), so targeted jobs cannot starve behind
    /// an injector flood.
    inboxes: Vec<Mutex<VecDeque<usize>>>,
    slots: Mutex<Slots>,
    pending: AtomicUsize,
    stop: AtomicBool,
    idle: Mutex<()>,
    wake: Condvar,
    done: Condvar,
    steals: AtomicUsize,
    /// Round-robin cursor for injector-overflow inbox placement.
    next_worker: AtomicUsize,
    /// Slots submitted but not yet picked up by any worker. The park
    /// path re-checks this under the `idle` mutex after publishing
    /// itself in `parked`, so a submission racing a worker's failed
    /// `find_slot` cannot be lost to a full park timeout.
    queued: AtomicUsize,
    /// Workers currently inside the park path. Submissions skip the
    /// park mutex entirely while this is 0 (the common case on a busy
    /// pool); see [`Shared::notify`] for the Dekker handshake.
    parked: AtomicUsize,
    /// Condvar notifies actually issued (diagnostics + the
    /// thundering-herd regression test).
    wakeups: AtomicUsize,
    /// First panic payload from a job; re-raised by `wait_all` on the
    /// caller so a panicking job fails the run instead of wedging it.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl Shared {
    /// Insert the payload and enqueue its slot on the chosen queue
    /// (`Some(worker)` = that worker's inbox, `None` = the injector).
    /// Returns false when the pool has shut down (job discarded — there
    /// are no workers left to run it). Does **not** wake anybody: the
    /// caller batches wake-ups via [`Shared::notify`].
    fn enqueue(&self, target: Option<usize>, job: Job) -> bool {
        if self.stop.load(Ordering::SeqCst) {
            return false;
        }
        let slot = self.slots.lock().unwrap().insert(job);
        self.pending.fetch_add(1, Ordering::SeqCst);
        match target {
            Some(worker) => self.inboxes[worker % self.inboxes.len()]
                .lock()
                .unwrap()
                .push_back(slot),
            None => self.push_injector(slot),
        }
        self.queued.fetch_add(1, Ordering::SeqCst);
        true
    }

    fn push_injector(&self, slot: usize) {
        if let Err(slot) = self.injector.push(slot) {
            // Ring full: overflow into a round-robin inbox. The job is
            // delayed behind targeted work on that worker, never lost.
            let w = self.next_worker.fetch_add(1, Ordering::Relaxed) % self.inboxes.len();
            self.inboxes[w].lock().unwrap().push_back(slot);
        }
    }

    /// Wake workers for `burst` freshly enqueued jobs — lazily: skip
    /// the park mutex when nobody is parked.
    ///
    /// Lost-wakeup argument (Dekker): the park path publishes `parked`
    /// (SeqCst) *before* re-checking `queued`; `enqueue` bumps `queued`
    /// (SeqCst) before this reads `parked`. In any seqcst interleaving
    /// at least one side sees the other — either the parking worker
    /// sees the queued job and skips the wait, or this sees the parked
    /// worker and notifies under the mutex (where the notify cannot
    /// slip between the worker's re-check and its wait). One notify per
    /// *burst*, not per job: `notify_all` for multi-job bursts, and
    /// stealing + the park timeout cover any remaining sleeper.
    fn notify(&self, burst: usize) {
        if burst == 0 || self.parked.load(Ordering::SeqCst) == 0 {
            return;
        }
        let _guard = self.idle.lock().unwrap();
        self.wakeups.fetch_add(1, Ordering::Relaxed);
        if burst > 1 {
            self.wake.notify_all();
        } else {
            self.wake.notify_one();
        }
    }

    /// Targeted single submission: `worker`'s inbox + one wake.
    fn submit(&self, worker: usize, job: Job) {
        if self.enqueue(Some(worker), job) {
            self.notify(1);
        }
    }

    /// Untargeted single submission: injector + one wake.
    fn submit_injector(&self, job: Job) {
        if self.enqueue(None, job) {
            self.notify(1);
        }
    }
}

/// A chiplet-aware work-stealing thread pool.
pub struct HostExecutor {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    n_workers: usize,
}

/// Clone-able submission handle onto a [`HostExecutor`]'s shared state.
///
/// Jobs may capture a `Submitter` and schedule follow-up work from inside
/// the pool; the handle keeps the queues alive but does **not** own the
/// worker threads, so dropping it inside a job never joins the pool.
/// A handle may outlive its pool, but submissions after the pool has
/// dropped are **discarded** — the workers are gone.
#[derive(Clone)]
pub struct Submitter {
    shared: Arc<Shared>,
}

impl Submitter {
    /// Submit a job (global injector; any free worker picks it up).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.submit_injector(Box::new(job));
    }

    /// Submit a job to a specific worker's inbox (`worker` is taken
    /// modulo the pool size). Thieves may still move it elsewhere.
    pub fn execute_on(&self, worker: usize, job: impl FnOnce() + Send + 'static) {
        self.shared.submit(worker, Box::new(job));
    }

    /// Submit a burst of untargeted jobs with **one** wake-up for the
    /// whole burst (vs one per `execute` call).
    pub fn execute_many<F, I>(&self, jobs: I)
    where
        F: FnOnce() + Send + 'static,
        I: IntoIterator<Item = F>,
    {
        let mut n = 0;
        for job in jobs {
            if self.shared.enqueue(None, Box::new(job)) {
                n += 1;
            }
        }
        self.shared.notify(n);
    }

    /// Submit a burst of `(worker, job)` targeted pairs with **one**
    /// wake-up for the whole burst — the host backend's barrier-release
    /// path, where every parked rank resubmits at once.
    pub fn execute_on_many<F, I>(&self, jobs: I)
    where
        F: FnOnce() + Send + 'static,
        I: IntoIterator<Item = (usize, F)>,
    {
        let mut n = 0;
        for (worker, job) in jobs {
            if self.shared.enqueue(Some(worker), Box::new(job)) {
                n += 1;
            }
        }
        self.shared.notify(n);
    }

    pub fn workers(&self) -> usize {
        self.shared.inboxes.len()
    }
}

thread_local! {
    /// Index of the pool worker running on this thread, if any.
    static CURRENT_WORKER: Cell<Option<usize>> = const { Cell::new(None) };
}

/// The pool worker index of the calling thread (`None` off-pool).
///
/// Workers map 1:1 onto cores (worker *i* = core *i*), so this is also
/// the core a job should charge machine time to.
pub fn current_worker() -> Option<usize> {
    CURRENT_WORKER.with(|w| w.get())
}

/// The core a worker models (worker *i* = core *i*, wrapped for pools
/// larger than the topology).
#[inline]
pub fn worker_core(topo: &Topology, worker: usize) -> usize {
    worker % topo.num_cores()
}

/// The machine-accounting shard a worker charges by default: its core's
/// chiplet ([`crate::coordinator::ChipletShard`]). Workers on the same
/// chiplet share one shard (their cores share that L3 in hardware);
/// workers on different chiplets charge disjoint shards and therefore
/// run concurrently on the sharded machine.
#[inline]
pub fn worker_shard(topo: &Topology, worker: usize) -> usize {
    topo.chiplet_of(worker_core(topo, worker))
}

impl HostExecutor {
    /// Spawn `n_workers` threads; steal order follows `topo` with worker
    /// index interpreted as core id. `pin` attempts CPU affinity.
    pub fn new(n_workers: usize, topo: &Topology, pin: bool) -> Self {
        let n = n_workers.max(1);
        let shared = Arc::new(Shared {
            queues: (0..n).map(|_| Deque::new()).collect(),
            injector: Injector::new(INJECTOR_CAP),
            inboxes: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            slots: Mutex::new(Slots::default()),
            pending: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            idle: Mutex::new(()),
            wake: Condvar::new(),
            done: Condvar::new(),
            steals: AtomicUsize::new(0),
            next_worker: AtomicUsize::new(0),
            queued: AtomicUsize::new(0),
            parked: AtomicUsize::new(0),
            wakeups: AtomicUsize::new(0),
            panic: Mutex::new(None),
        });
        let cores: Vec<usize> = (0..n).collect();
        let mut workers = Vec::with_capacity(n);
        for w in 0..n {
            let shared = shared.clone();
            let order = chiplet_first_steal_order(topo, worker_core(topo, w), &cores);
            workers.push(std::thread::spawn(move || {
                if pin {
                    pin_to_core(w);
                }
                CURRENT_WORKER.with(|c| c.set(Some(w)));
                worker_loop(w, order, shared);
            }));
        }
        Self {
            shared,
            workers,
            n_workers: n,
        }
    }

    /// Submit a job (global injector; any free worker picks it up).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.submit_injector(Box::new(job));
    }

    /// Submit a job to a specific worker's inbox.
    pub fn execute_on(&self, worker: usize, job: impl FnOnce() + Send + 'static) {
        self.shared.submit(worker, Box::new(job));
    }

    /// A clone-able submission handle (usable from inside jobs).
    pub fn submitter(&self) -> Submitter {
        Submitter {
            shared: self.shared.clone(),
        }
    }

    /// Block until every submitted job (including jobs submitted by other
    /// jobs) has run. Must not be called from inside a job. If a job
    /// panicked, the first panic is re-raised here on the caller.
    pub fn wait_all(&self) {
        self.wait_idle();
        let payload = self.shared.panic.lock().unwrap().take();
        if let Some(p) = payload {
            std::panic::resume_unwind(p);
        }
    }

    /// The draining half of [`Self::wait_all`], without re-raising job
    /// panics (used by `Drop`, which must not panic mid-unwind).
    fn wait_idle(&self) {
        let mut guard = self.shared.idle.lock().unwrap();
        while self.shared.pending.load(Ordering::SeqCst) > 0 {
            let (g, _timeout) = self
                .shared
                .done
                .wait_timeout(guard, std::time::Duration::from_millis(50))
                .unwrap();
            guard = g;
        }
    }

    /// Number of successful steals (diagnostics).
    pub fn steal_count(&self) -> usize {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// Number of condvar notifies issued by submissions (diagnostics).
    /// Submitting against a busy pool (no parked workers) issues none —
    /// the thundering-herd regression test pins this.
    pub fn wakeup_count(&self) -> usize {
        self.shared.wakeups.load(Ordering::Relaxed)
    }

    /// High-water mark of the job slot table. Bounded by the peak
    /// in-flight job count (slots are recycled), not by the total number
    /// of jobs ever submitted — pinned by a regression test.
    pub fn slot_capacity(&self) -> usize {
        self.shared.slots.lock().unwrap().jobs.len()
    }

    pub fn workers(&self) -> usize {
        self.n_workers
    }
}

impl Drop for HostExecutor {
    fn drop(&mut self) {
        self.wait_idle();
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.wake.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Surface an unobserved job panic — unless we are already
        // unwinding (double panic would abort).
        if !std::thread::panicking() {
            let payload = self.shared.panic.lock().unwrap().take();
            if let Some(p) = payload {
                std::panic::resume_unwind(p);
            }
        }
    }
}

/// Find the next slot for worker `me`: own deque → own inbox (targeted
/// work drains ahead of injector floods) → global injector → steal
/// (deques first, then inboxes) in chiplet-aware order.
fn find_slot(me: usize, steal_order: &[usize], shared: &Shared) -> Option<usize> {
    if let Some(slot) = shared.queues[me].pop() {
        return Some(slot);
    }
    // Drain the inbox into the owned deque (owner-side push is safe),
    // keeping one to run now.
    {
        let mut inbox = shared.inboxes[me].lock().unwrap();
        if let Some(first) = inbox.pop_front() {
            while let Some(slot) = inbox.pop_front() {
                shared.queues[me].push(slot);
            }
            return Some(first);
        }
    }
    // Take a small batch from the injector: one to run now, the rest
    // buffered in the owned deque (where thieves can rebalance them).
    if let Some(first) = shared.injector.pop() {
        for _ in 0..INJECTOR_DRAIN {
            match shared.injector.pop() {
                Some(slot) => shared.queues[me].push(slot),
                None => break,
            }
        }
        return Some(first);
    }
    for &v in steal_order {
        loop {
            match shared.queues[v].steal() {
                Steal::Success(slot) => {
                    shared.steals.fetch_add(1, Ordering::Relaxed);
                    return Some(slot);
                }
                Steal::Retry => continue,
                Steal::Empty => break,
            }
        }
        // Raid the victim's inbox too: a targeted job must not starve
        // behind a long-running victim.
        if let Ok(mut inbox) = shared.inboxes[v].try_lock() {
            if let Some(slot) = inbox.pop_front() {
                shared.steals.fetch_add(1, Ordering::Relaxed);
                return Some(slot);
            }
        }
    }
    None
}

fn worker_loop(me: usize, steal_order: Vec<usize>, shared: Arc<Shared>) {
    loop {
        match find_slot(me, &steal_order, &shared) {
            Some(slot) => {
                shared.queued.fetch_sub(1, Ordering::SeqCst);
                let job = shared.slots.lock().unwrap().take(slot);
                if let Some(job) = job {
                    // Contain unwinds: a panicking job must still reach
                    // the `pending` decrement below, or `wait_all` (and
                    // `Drop`) would hang forever. The first payload is
                    // kept and re-raised on the `wait_all` caller.
                    if let Err(p) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job)) {
                        let mut first = shared.panic.lock().unwrap();
                        if first.is_none() {
                            *first = Some(p);
                        }
                    }
                }
                // The job ran (and possibly submitted follow-up work,
                // bumping `pending`) before this decrement, so `wait_all`
                // cannot observe a spuriously drained pool mid-chain.
                if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                    // Under the `idle` mutex for the same lost-wakeup
                    // reason as `notify`: `wait_idle` re-checks `pending`
                    // while holding it, so this notify cannot land
                    // between its check and its wait.
                    let _guard = shared.idle.lock().unwrap();
                    shared.done.notify_all();
                }
            }
            None => {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                // Park. Publish `parked` *before* re-checking `queued`
                // (the Dekker handshake with `Shared::notify`): a
                // submission completed before the re-check is retried
                // immediately; one still in flight is guaranteed to see
                // `parked > 0` and notify under this same mutex, so its
                // wake-up cannot be lost. The timeout is a
                // belt-and-braces bound, not the recovery path.
                let guard = shared.idle.lock().unwrap();
                shared.parked.fetch_add(1, Ordering::SeqCst);
                if shared.queued.load(Ordering::SeqCst) == 0
                    && !shared.stop.load(Ordering::SeqCst)
                {
                    let _ = shared
                        .wake
                        .wait_timeout(guard, std::time::Duration::from_millis(1));
                }
                shared.parked.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

/// Pin the calling thread to `core` (best effort).
///
/// `sched_setaffinity` needs the `libc` crate, which is not in the
/// offline crate set, so pinning is a no-op reporting failure; the pool
/// still works — steal order just approximates locality instead of
/// enforcing it. Swap in a real implementation when `libc` is available.
pub fn pin_to_core(_core: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(4, &topo, false);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_speedup_on_cpu_bound_work() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(4, &topo, false);
        let t = std::time::Instant::now();
        let sink = Arc::new(AtomicU64::new(0));
        for i in 0..8 {
            let sink = sink.clone();
            pool.execute(move || {
                let mut s = i as u64;
                for k in 0..2_000_000u64 {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(k);
                }
                sink.fetch_xor(s, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        let _ = t.elapsed();
        assert_ne!(sink.load(Ordering::Relaxed), u64::MAX);
    }

    #[test]
    fn stealing_happens_under_imbalance() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(8, &topo, false);
        // All jobs land in the injector but some take much longer: free
        // workers should pick up the slack. (We only assert completion —
        // which worker runs what is timing dependent.)
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..64 {
            let c = counter.clone();
            pool.execute(move || {
                if i % 8 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn drop_joins_cleanly() {
        let topo = Topology::milan_1s();
        {
            let pool = HostExecutor::new(2, &topo, false);
            pool.execute(|| {});
        } // drop
    }

    #[test]
    fn reuse_after_wait() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(2, &topo, false);
        let c = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = c.clone();
                pool.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_all();
            assert_eq!(c.load(Ordering::Relaxed), (round + 1) * 10);
        }
    }

    #[test]
    fn slots_are_recycled_across_rounds() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(2, &topo, false);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            for _ in 0..64 {
                let c = c.clone();
                pool.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_all();
        }
        assert_eq!(c.load(Ordering::Relaxed), 50 * 64);
        // The old append-only table grew one slot per job ever submitted
        // (3200 here); the free list bounds it by the peak in-flight count.
        assert!(
            pool.slot_capacity() <= 64,
            "slot table leaked: {} slots after 3200 jobs in rounds of 64",
            pool.slot_capacity()
        );
    }

    #[test]
    #[should_panic(expected = "job exploded")]
    fn job_panic_propagates_to_wait_all_instead_of_hanging() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(2, &topo, false);
        let c = Arc::new(AtomicU64::new(0));
        for i in 0..16 {
            let c = c.clone();
            pool.execute(move || {
                if i == 7 {
                    panic!("job exploded");
                }
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_all();
    }

    #[test]
    fn pool_survives_a_job_panic() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(2, &topo, false);
        pool.execute(|| panic!("first round panics"));
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| pool.wait_all()));
        assert!(res.is_err());
        // The pool is still usable afterwards.
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = c.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        assert_eq!(c.load(Ordering::Relaxed), 10);
    }

    #[test]
    fn submitter_outliving_the_pool_discards_jobs() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(2, &topo, false);
        let sub = pool.submitter();
        let c = Arc::new(AtomicU64::new(0));
        {
            let c = c.clone();
            sub.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        drop(pool);
        assert_eq!(c.load(Ordering::Relaxed), 1, "pre-drop job must run");
        // Post-drop submissions are discarded, not lost in a queue.
        let c2 = c.clone();
        sub.execute(move || {
            c2.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(c.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn worker_shard_follows_the_chiplet_map() {
        let topo = Topology::milan_1s(); // 8 chiplets x 8 cores
        assert_eq!(worker_shard(&topo, 0), 0);
        assert_eq!(worker_shard(&topo, 7), 0);
        assert_eq!(worker_shard(&topo, 8), 1);
        assert_eq!(worker_shard(&topo, 63), 7);
        // Oversized pools wrap onto the topology.
        assert_eq!(worker_core(&topo, 64), 0);
        assert_eq!(worker_shard(&topo, 64), 0);
    }

    #[test]
    fn targeted_execute_on_runs_and_reports_worker() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(4, &topo, false);
        let seen = Arc::new(AtomicU64::new(u64::MAX));
        for w in 0..4 {
            let seen = seen.clone();
            pool.execute_on(w, move || {
                // On-pool jobs always observe a worker id; which one is
                // timing dependent (an idle thief may raid the inbox).
                let id = current_worker().expect("job ran off-pool") as u64;
                seen.fetch_min(id, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        assert!(seen.load(Ordering::Relaxed) < 4);
        assert_eq!(current_worker(), None, "main thread is not a worker");
    }

    #[test]
    fn burst_submission_runs_everything() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(4, &topo, false);
        let sub = pool.submitter();
        let c = Arc::new(AtomicU64::new(0));
        sub.execute_many((0..100).map(|_| {
            let c = c.clone();
            move || {
                c.fetch_add(1, Ordering::Relaxed);
            }
        }));
        sub.execute_on_many((0..100).map(|i| {
            let c = c.clone();
            (i % 4, move || {
                c.fetch_add(1, Ordering::Relaxed);
            })
        }));
        pool.wait_all();
        assert_eq!(c.load(Ordering::Relaxed), 200);
    }

    // ---- Injector (Vyukov MPMC ring) unit tests ----

    #[test]
    fn injector_is_fifo_single_threaded() {
        let q = Injector::new(8);
        for v in 0..5 {
            q.push(v).unwrap();
        }
        for v in 0..5 {
            assert_eq!(q.pop(), Some(v));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn injector_reports_full_and_recovers() {
        let q = Injector::new(4);
        for v in 0..4 {
            q.push(v).unwrap();
        }
        assert_eq!(q.push(99), Err(99), "a full ring must hand the value back");
        assert_eq!(q.pop(), Some(0));
        q.push(99).unwrap();
        for want in [1, 2, 3, 99] {
            assert_eq!(q.pop(), Some(want));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn injector_wraps_around_many_laps() {
        // Capacity 4, 1000 values: the ticket counters lap the ring 250
        // times; per-cell sequence numbers must stay consistent.
        let q = Injector::new(4);
        for v in 0..1000 {
            q.push(v).unwrap();
            assert_eq!(q.pop(), Some(v));
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn injector_mpmc_no_loss_no_dup() {
        // 4 producers x 1024 values, 4 consumers, ring smaller than the
        // total (producers spin on full): every value must come out
        // exactly once.
        const PRODUCERS: usize = 4;
        const PER: usize = 1024;
        let q = Arc::new(Injector::new(256));
        let seen: Arc<Vec<AtomicUsize>> =
            Arc::new((0..PRODUCERS * PER).map(|_| AtomicUsize::new(0)).collect());
        let produced = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for p in 0..PRODUCERS {
            let q = q.clone();
            let produced = produced.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..PER {
                    let mut v = p * PER + i;
                    loop {
                        match q.push(v) {
                            Ok(()) => break,
                            Err(back) => {
                                v = back;
                                std::hint::spin_loop();
                            }
                        }
                    }
                }
                produced.fetch_add(PER, Ordering::SeqCst);
            }));
        }
        for _ in 0..4 {
            let q = q.clone();
            let seen = seen.clone();
            let produced = produced.clone();
            handles.push(std::thread::spawn(move || loop {
                match q.pop() {
                    Some(v) => {
                        seen[v].fetch_add(1, Ordering::SeqCst);
                    }
                    None => {
                        if produced.load(Ordering::SeqCst) == PRODUCERS * PER
                            && q.pop().is_none()
                        {
                            // Producers done and the ring drained; one
                            // more sweep happens via other consumers.
                            break;
                        }
                        std::hint::spin_loop();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Anything still in the ring after consumer exit is a loss.
        while let Some(v) = q.pop() {
            seen[v].fetch_add(1, Ordering::SeqCst);
        }
        for (v, c) in seen.iter().enumerate() {
            assert_eq!(
                c.load(Ordering::SeqCst),
                1,
                "value {v} came out {} times (must be exactly once)",
                c.load(Ordering::SeqCst)
            );
        }
    }
}
