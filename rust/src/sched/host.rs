//! Host executor: the same runtime running on real OS threads.
//!
//! The paper experiments run on the simulated machine (deterministic,
//! chiplet-parametric); [`HostExecutor`] proves the runtime is also a real
//! work-stealing pool: per-worker Chase–Lev deques, chiplet-aware steal
//! order derived from a [`Topology`] (worker *i* is treated as core *i*),
//! and optional `sched_setaffinity` pinning on Linux.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::deque::{Deque, Steal};
use crate::policy::chiplet_first_steal_order;
use crate::topology::Topology;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queues: Vec<Deque>,
    jobs: Mutex<Vec<Option<Job>>>,
    pending: AtomicUsize,
    stop: AtomicBool,
    idle: Mutex<()>,
    wake: Condvar,
    done: Condvar,
    steals: AtomicUsize,
}

/// A chiplet-aware work-stealing thread pool.
pub struct HostExecutor {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_worker: AtomicUsize,
    n_workers: usize,
}

impl HostExecutor {
    /// Spawn `n_workers` threads; steal order follows `topo` with worker
    /// index interpreted as core id. `pin` attempts CPU affinity.
    pub fn new(n_workers: usize, topo: &Topology, pin: bool) -> Self {
        let n = n_workers.max(1);
        let shared = Arc::new(Shared {
            queues: (0..n).map(|_| Deque::new()).collect(),
            jobs: Mutex::new(Vec::new()),
            pending: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            idle: Mutex::new(()),
            wake: Condvar::new(),
            done: Condvar::new(),
            steals: AtomicUsize::new(0),
        });
        let cores: Vec<usize> = (0..n).collect();
        let mut workers = Vec::with_capacity(n);
        for w in 0..n {
            let shared = shared.clone();
            let order = chiplet_first_steal_order(topo, w % topo.num_cores(), &cores);
            workers.push(std::thread::spawn(move || {
                if pin {
                    pin_to_core(w);
                }
                worker_loop(w, order, shared);
            }));
        }
        Self {
            shared,
            workers,
            next_worker: AtomicUsize::new(0),
            n_workers: n,
        }
    }

    /// Submit a job (round-robin across worker queues).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let slot = {
            let mut jobs = self.shared.jobs.lock().unwrap();
            jobs.push(Some(Box::new(job)));
            jobs.len() - 1
        };
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        let w = self.next_worker.fetch_add(1, Ordering::Relaxed) % self.n_workers;
        self.shared.queues[w].push(slot);
        self.shared.wake.notify_all();
    }

    /// Block until every submitted job has run.
    pub fn wait_all(&self) {
        let mut guard = self.shared.idle.lock().unwrap();
        while self.shared.pending.load(Ordering::SeqCst) > 0 {
            let (g, _timeout) = self
                .shared
                .done
                .wait_timeout(guard, std::time::Duration::from_millis(50))
                .unwrap();
            guard = g;
        }
    }

    /// Number of successful steals (diagnostics).
    pub fn steal_count(&self) -> usize {
        self.shared.steals.load(Ordering::Relaxed)
    }

    pub fn workers(&self) -> usize {
        self.n_workers
    }
}

impl Drop for HostExecutor {
    fn drop(&mut self) {
        self.wait_all();
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.wake.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(me: usize, steal_order: Vec<usize>, shared: Arc<Shared>) {
    loop {
        // 1. local queue, 2. steal in chiplet-aware order.
        let slot = shared.queues[me].pop().or_else(|| {
            for &v in &steal_order {
                loop {
                    match shared.queues[v].steal() {
                        Steal::Success(s) => {
                            shared.steals.fetch_add(1, Ordering::Relaxed);
                            return Some(s);
                        }
                        Steal::Retry => continue,
                        Steal::Empty => break,
                    }
                }
            }
            None
        });
        match slot {
            Some(s) => {
                let job = shared.jobs.lock().unwrap()[s].take();
                if let Some(job) = job {
                    job();
                }
                if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                    shared.done.notify_all();
                }
            }
            None => {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                let guard = shared.idle.lock().unwrap();
                if shared.pending.load(Ordering::SeqCst) == 0 && !shared.stop.load(Ordering::SeqCst)
                {
                    let _ = shared
                        .wake
                        .wait_timeout(guard, std::time::Duration::from_millis(10));
                } else {
                    drop(guard);
                    std::hint::spin_loop();
                }
            }
        }
    }
}

/// Pin the calling thread to `core` (best effort).
///
/// `sched_setaffinity` needs the `libc` crate, which is not in the
/// offline crate set, so pinning is a no-op reporting failure; the pool
/// still works — steal order just approximates locality instead of
/// enforcing it. Swap in a real implementation when `libc` is available.
pub fn pin_to_core(_core: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn executes_all_jobs() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(4, &topo, false);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
    }

    #[test]
    fn parallel_speedup_on_cpu_bound_work() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(4, &topo, false);
        let t = std::time::Instant::now();
        let sink = Arc::new(AtomicU64::new(0));
        for i in 0..8 {
            let sink = sink.clone();
            pool.execute(move || {
                let mut s = i as u64;
                for k in 0..2_000_000u64 {
                    s = s.wrapping_mul(6364136223846793005).wrapping_add(k);
                }
                sink.fetch_xor(s, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        let _ = t.elapsed();
        assert_ne!(sink.load(Ordering::Relaxed), u64::MAX);
    }

    #[test]
    fn stealing_happens_under_imbalance() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(8, &topo, false);
        // All jobs land round-robin but some take much longer: thieves
        // should pick up the slack. (We only assert completion + nonzero
        // steals are *possible*, not required — timing dependent.)
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..64 {
            let c = counter.clone();
            pool.execute(move || {
                if i % 8 == 0 {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                c.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_all();
        assert_eq!(counter.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn drop_joins_cleanly() {
        let topo = Topology::milan_1s();
        {
            let pool = HostExecutor::new(2, &topo, false);
            pool.execute(|| {});
        } // drop
    }

    #[test]
    fn reuse_after_wait() {
        let topo = Topology::milan_1s();
        let pool = HostExecutor::new(2, &topo, false);
        let c = Arc::new(AtomicU64::new(0));
        for round in 0..3 {
            for _ in 0..10 {
                let c = c.clone();
                pool.execute(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                });
            }
            pool.wait_all();
            assert_eq!(c.load(Ordering::Relaxed), (round + 1) * 10);
        }
    }
}
