//! The executor: per-core run queues, chiplet-aware work stealing, barrier
//! synchronization, policy timers and task migration (§4.1's global
//! scheduler + task manager).
//!
//! [`SimExecutor`] drives coroutine tasks over the simulated [`Machine`]
//! deterministically: it always dispatches on the core with the smallest
//! virtual clock, so the interleaving is causally consistent and
//! bit-reproducible. Real lock-free [`Deque`]s back the per-core queues —
//! the same structure the host executor uses with real threads.

mod host;
pub use host::{current_worker, worker_core, worker_shard, HostExecutor, Submitter};

use crate::cachesim::{ClassCounts, Outcome};
use crate::deque::Deque;
use crate::policy::{Policy, RegionHeat, SwitchModel};
use crate::profiler::Profiler;
use crate::sim::Machine;
use crate::task::{Coroutine, Step, Task, TaskCtx, TaskId, TaskState};

/// Scheduler bookkeeping knobs.
#[derive(Clone, Debug)]
pub struct ExecConfig {
    /// Profiler/controller window (Algorithm 1's SCHEDULER_TIMER).
    pub timer_ns: u64,
    /// Per-queue-operation overhead (lock-free push/pop), ns.
    pub queue_op_ns: u64,
    /// Extra "main + monitor" threads reported in concurrency samples
    /// (the paper counts 34 threads for 32 workers).
    pub aux_threads: usize,
}

impl Default for ExecConfig {
    fn default() -> Self {
        Self {
            timer_ns: crate::controller::DEFAULT_SCHEDULER_TIMER_NS,
            queue_op_ns: 20,
            aux_threads: 2,
        }
    }
}

/// Per-request latency aggregate of a serving run: sojourn
/// (queue wait + service) quantiles from a log-scaled histogram
/// (`util::stats::LogHistogram`, ≤3.2% relative error; min/max/mean
/// exact), plus the queue/service mean breakdown. Produced by
/// `engine::dispatch::LatencyRecorder`; attached to
/// [`RunReport::request_latency`] by the engine driver for scenarios
/// that implement the `Scenario::latency` hook.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyReport {
    /// Requests served.
    pub count: u64,
    /// Mean sojourn (exact).
    pub mean_ns: f64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    /// Worst sojourn (exact).
    pub max_ns: u64,
    /// Mean time between arrival and service start.
    pub mean_queue_ns: f64,
    /// Mean service time.
    pub mean_service_ns: f64,
}

/// One machine shard's contribution to a cluster run's merged report
/// (`RunReport::per_shard`): how much traffic it absorbed and what tail
/// it delivered.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardStat {
    /// Requests routed to (and served or shed by) this shard.
    pub requests: u64,
    /// Requests this shard shed past its SLO budget.
    pub shed: u64,
    /// The shard's own virtual-time makespan.
    pub makespan_ns: u64,
    /// The shard's own p99 sojourn (0 when it served nothing).
    pub p99_ns: u64,
}

/// Result of one executor run.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub policy: String,
    pub makespan_ns: u64,
    pub counts: ClassCounts,
    pub dispatches: u64,
    pub steals: u64,
    pub migrations: u64,
    pub barrier_epochs: u64,
    pub avg_concurrency: f64,
    pub peak_concurrency: usize,
    /// (t_ns, live threads) samples — Fig. 11.
    pub concurrency: Vec<(u64, usize)>,
    /// Controller decisions (t_ns, rate, spread) — ARCAS only.
    pub decisions: Vec<(u64, f64, usize)>,
    /// Online region re-placements applied during the run ("data follows
    /// tasks"); 0 unless an adaptive policy moved memory.
    pub region_moves: u64,
    /// Per-move decisions: (t_ns, raw region id, destination NUMA node).
    pub region_decisions: Vec<(u64, u32, usize)>,
    pub dram_bytes: f64,
    /// Final spread rate.
    pub spread_rate: usize,
    /// Wall-clock time the run took: the simulation itself on the sim
    /// backend (perf pass metric), real end-to-end execution on the host
    /// backend (throughput next to the simulated makespan).
    pub wall_ns: u64,
    /// Successful steals on the real [`HostExecutor`] pool (host backend
    /// only; 0 for simulated runs, which report virtual steals in
    /// `steals`).
    pub host_steals: u64,
    /// Per-request sojourn aggregate for request-serving scenarios
    /// (`serve-kv`, `serve-mixed`); `None` for batch workloads.
    pub request_latency: Option<LatencyReport>,
    /// Requests dropped by admission control / load shedding (serving
    /// scenarios under overload; always 0 for batch workloads).
    pub request_shed: u64,
    /// Per-priority-class latency aggregates, in dispatch order
    /// (critical first); empty unless the scenario serves a
    /// priority-tiered trace.
    pub class_latency: Vec<(&'static str, LatencyReport)>,
    /// Number of machine shards the run fanned out over (`Run::cluster`);
    /// 0 for the legacy single-machine path.
    pub machines: usize,
    /// Requests that crossed the inter-machine link tier (routed to a
    /// shard other than the front end's).
    pub cross_link_hops: u64,
    /// Bytes charged to inter-machine links: request payloads on every
    /// cross-shard hop plus key-range state shipped by rebalances.
    pub cross_link_bytes: u64,
    /// Key-range re-homings applied by [`crate::policy::Policy::plan_shard_moves`]
    /// — the cluster-level mirror of `region_moves`.
    pub shard_moves: u64,
    /// Per-move decisions: (t_ns, slot, destination shard) — the
    /// cluster-level mirror of `region_decisions`.
    pub shard_decisions: Vec<(u64, usize, usize)>,
    /// Per-shard traffic/tail breakdown; empty for single-machine runs.
    pub per_shard: Vec<ShardStat>,
}

impl RunReport {
    /// Virtual-time throughput for `items` processed.
    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.makespan_ns.max(1) as f64 / 1e9)
    }

    /// GB/s of DRAM traffic.
    pub fn dram_gbps(&self) -> f64 {
        self.dram_bytes / self.makespan_ns.max(1) as f64
    }
}

/// Deterministic simulator-backed executor.
pub struct SimExecutor {
    pub machine: Machine,
    policy: Box<dyn Policy>,
    cfg: ExecConfig,
    tasks: Vec<Task>,
    /// rank → core placement (updated on migration).
    placement: Vec<usize>,
    /// Atomic mirror of `placement` handed to every `TaskCtx` as
    /// `peer_cores`, so coroutines can message group peers at their
    /// *current* home (`TaskCtx::send_to_rank`). Atomics only because the
    /// field type is shared with the host backend, where migrations race
    /// in-flight steps; the sim updates it single-threaded.
    peer_cores: Vec<std::sync::atomic::AtomicUsize>,
    queues: Vec<Deque>,
    active_cores: Vec<usize>,
    profiler: Profiler,
    finished: usize,
    barrier_wait: Vec<TaskId>,
    barrier_epochs: u64,
    dispatches: u64,
    steals: u64,
    migrations: u64,
    region_moves: u64,
    region_decisions: Vec<(u64, u32, usize)>,
    next_timer_ns: u64,
    spawned: Vec<bool>,
    /// §Perf: steal orders are recomputed only when placement changes
    /// (they were a Vec allocation + sort per failed local pop).
    steal_cache: Vec<Option<Vec<usize>>>,
}

impl SimExecutor {
    pub fn new(machine: Machine, policy: Box<dyn Policy>) -> Self {
        let n_cores = machine.topo.num_cores();
        Self {
            machine,
            policy,
            cfg: ExecConfig::default(),
            tasks: Vec::new(),
            placement: Vec::new(),
            peer_cores: Vec::new(),
            queues: (0..n_cores).map(|_| Deque::new()).collect(),
            active_cores: Vec::new(),
            profiler: Profiler::new(),
            finished: 0,
            barrier_wait: Vec::new(),
            barrier_epochs: 0,
            dispatches: 0,
            steals: 0,
            migrations: 0,
            region_moves: 0,
            region_decisions: Vec::new(),
            next_timer_ns: 0,
            spawned: Vec::new(),
            steal_cache: vec![None; n_cores],
        }
    }

    pub fn with_config(mut self, cfg: ExecConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn with_timer(mut self, timer_ns: u64) -> Self {
        self.cfg.timer_ns = timer_ns;
        self
    }

    /// Spawn a group of `n` tasks; `make(rank)` builds each coroutine.
    /// Placement comes from the policy.
    pub fn spawn_group(&mut self, n: usize, mut make: impl FnMut(usize) -> Box<dyn Coroutine>) {
        assert!(self.tasks.is_empty(), "one group per run (paper model)");
        // Adopt the policy's preferred profiling window (Algorithm 1 and
        // the profiler must sample on the same cadence).
        if let Some(t) = self.policy.timer_ns() {
            self.cfg.timer_ns = t;
        }
        self.placement = self.policy.initial_placement(&self.machine.topo, n);
        assert_eq!(self.placement.len(), n);
        self.peer_cores = self
            .placement
            .iter()
            .map(|&c| std::sync::atomic::AtomicUsize::new(c))
            .collect();
        for rank in 0..n {
            let id = self.tasks.len();
            let mut t = Task::new(id, rank, n, make(rank));
            t.core = self.placement[rank];
            self.tasks.push(t);
            self.queues[self.placement[rank]].push(id);
        }
        self.spawned = vec![false; n];
        let mut cores: Vec<usize> = self.placement.clone();
        cores.sort_unstable();
        cores.dedup();
        self.active_cores = cores;
        // Re-anchor the profiler on the (possibly warm) machine: with
        // `--repeat`, rep N starts on rep N-1's counters and clocks, and
        // a zero baseline would attribute all of them to the first
        // window. Cold machines report 0/zeros, so this is a no-op there
        // and the goldens are unaffected.
        let t0 = self.machine.max_time();
        self.profiler.rebaseline(t0, self.machine.class_totals());
        self.profiler.seed_heat(&self.machine.region_heat());
        self.next_timer_ns = t0 + self.cfg.timer_ns;
    }

    fn live_threads(&self) -> usize {
        match self.policy.switch_model() {
            // OS model: every unfinished task is a kernel thread; runnable
            // ones fluctuate as tasks block/finish.
            SwitchModel::OsThread => self
                .tasks
                .iter()
                .filter(|t| t.state != TaskState::Finished && t.state != TaskState::Blocked)
                .count(),
            // Coroutine model: fixed worker pool + aux threads.
            SwitchModel::Coroutine => self.active_cores.len() + self.cfg.aux_threads,
        }
    }

    /// Fire the policy timer (profiling window + possible migration +
    /// possible region moves). `core` is the tick-triggering core: it
    /// plays the mover and is charged each move's one-time DDR copy.
    fn fire_timer(&mut self, now_ns: u64, core: usize) {
        let live = self.live_threads();
        let totals = self.machine.class_totals();
        let sample = self
            .profiler
            .sample_window(now_ns, totals, self.cfg.timer_ns, live);
        self.profiler.sample_concurrency(now_ns, live);
        let group = self.tasks.len();
        if let Some(new_map) = self
            .policy
            .on_timer(&self.machine.topo, now_ns, &sample, group)
        {
            self.apply_placement(new_map, now_ns);
        }
        // Memory half of the tick: window the per-region heat and let the
        // policy re-home regions toward their accessors.
        let deltas = self.profiler.heat_window(&self.machine.region_heat());
        if !deltas.is_empty() {
            let heat: Vec<RegionHeat> = deltas
                .into_iter()
                .map(|(region, per_chiplet)| RegionHeat {
                    region,
                    placement: self.machine.placement_of(region),
                    size: self.machine.region_size(region),
                    per_chiplet,
                })
                .collect();
            for mv in self
                .policy
                .plan_region_moves(&self.machine.topo, now_ns, &heat, group)
            {
                if self.machine.move_region(mv.region, mv.to_numa, core) {
                    self.region_moves += 1;
                    self.region_decisions.push((now_ns, mv.region.0, mv.to_numa));
                }
            }
        }
        self.next_timer_ns = now_ns + self.cfg.timer_ns;
    }

    /// Migrate tasks to a new rank→core map (Algorithm 2 application):
    /// re-bind placement, drain queues and re-push, charge migration
    /// messages.
    fn apply_placement(&mut self, new_map: Vec<usize>, _now_ns: u64) {
        assert_eq!(new_map.len(), self.placement.len());
        // Collect queued task ids.
        let mut queued: Vec<TaskId> = Vec::new();
        for q in &self.queues {
            while let Some(id) = q.pop() {
                queued.push(id);
            }
        }
        // rank → tid, built once: the old per-rank `iter().position()`
        // scan was O(tasks²) per timer fire and panicked on a rank with
        // no live task (e.g. a map wider than the group).
        let mut rank_to_tid: Vec<Option<TaskId>> = vec![None; new_map.len()];
        for (tid, t) in self.tasks.iter().enumerate() {
            if let Some(slot) = rank_to_tid.get_mut(t.rank) {
                *slot = Some(tid);
            }
        }
        for (rank, (&old, &new)) in self.placement.iter().zip(new_map.iter()).enumerate() {
            if old != new {
                // A rank without a live task is a no-op, not a panic.
                let Some(tid) = rank_to_tid[rank] else { continue };
                if self.tasks[tid].state != TaskState::Finished {
                    // Migration cost: task state moves across the fabric.
                    self.machine.message(old, new, 256);
                    self.tasks[tid].stats.migrations += 1;
                    self.migrations += 1;
                    self.tasks[tid].core = new;
                }
            }
        }
        self.placement = new_map;
        for (rank, &core) in self.placement.iter().enumerate() {
            self.peer_cores[rank].store(core, std::sync::atomic::Ordering::Relaxed);
        }
        // Re-push queued tasks at their (possibly new) placement.
        for id in queued {
            let core = self.placement[self.tasks[id].rank];
            self.queues[core].push(id);
        }
        let mut cores: Vec<usize> = self.placement.clone();
        cores.sort_unstable();
        cores.dedup();
        self.active_cores = cores;
        self.steal_cache.iter_mut().for_each(|c| *c = None);
    }

    /// Find work for `core`: local pop, else steal per policy order.
    fn find_work(&mut self, core: usize) -> Option<TaskId> {
        if let Some(id) = self.queues[core].pop() {
            self.machine.compute(core, self.cfg.queue_op_ns);
            return Some(id);
        }
        if self.steal_cache[core].is_none() {
            self.steal_cache[core] = Some(self.policy.steal_order(
                &self.machine.topo,
                core,
                &self.active_cores,
            ));
        }
        // Take the cached order out to sidestep the borrow (and avoid
        // cloning it on every failed local pop).
        let order = self.steal_cache[core].take().unwrap();
        let mut found = None;
        for &victim in &order {
            if let Some(id) = self.queues[victim].steal().success() {
                // Steal latency: one fabric round trip + queue op.
                self.machine.message(core, victim, 64);
                self.machine.compute(core, self.cfg.queue_op_ns);
                self.steals += 1;
                // The task now runs here.
                self.tasks[id].core = core;
                found = Some(id);
                break;
            }
        }
        self.steal_cache[core] = Some(order);
        found
    }

    /// Release a barrier: all unfinished tasks are waiting.
    fn release_barrier(&mut self) {
        self.barrier_epochs += 1;
        // Synchronization point: everyone resumes at the latest clock of
        // the participating cores.
        let t_max = self
            .barrier_wait
            .iter()
            .map(|&id| self.machine.now(self.tasks[id].core))
            .max()
            .unwrap_or(0);
        let waiting = std::mem::take(&mut self.barrier_wait);
        for id in waiting {
            let core = self.tasks[id].core;
            self.machine.advance_to(core, t_max);
            self.tasks[id].state = TaskState::Ready;
            self.queues[core].push(id);
        }
    }

    /// Run to completion; returns the report.
    pub fn run(&mut self) -> RunReport {
        let wall_start = std::time::Instant::now();
        let n = self.tasks.len();
        assert!(n > 0, "spawn_group first");
        self.profiler
            .sample_concurrency(0, self.live_threads());

        while self.finished < n {
            // Pick the runnable core with the smallest clock.
            let mut best: Option<(u64, usize)> = None;
            for &c in &self.active_cores {
                if !self.queues[c].is_empty() {
                    let t = self.machine.now(c);
                    if best.map_or(true, |(bt, _)| t < bt) {
                        best = Some((t, c));
                    }
                }
            }
            // Idle cores may steal: consider the min-clock active core even
            // with an empty queue if someone has surplus (> 1 queued).
            let surplus_exists = self
                .active_cores
                .iter()
                .any(|&c| self.queues[c].len() > 1);
            if surplus_exists {
                for &c in &self.active_cores {
                    if self.queues[c].is_empty() {
                        let t = self.machine.now(c);
                        if best.map_or(true, |(bt, _)| t < bt) {
                            best = Some((t, c));
                        }
                    }
                }
            }

            let (now, core) = match best {
                Some((t, c)) => (t, c),
                None => {
                    // No queued work anywhere: either a barrier is pending
                    // or we're done.
                    let blocked = self
                        .tasks
                        .iter()
                        .filter(|t| t.state == TaskState::Blocked)
                        .count();
                    if blocked > 0 && blocked + self.finished == n {
                        self.release_barrier();
                        continue;
                    }
                    break;
                }
            };

            // Fire the policy timer when virtual time crosses the window.
            if now >= self.next_timer_ns {
                self.fire_timer(now, core);
                continue;
            }

            let Some(tid) = self.find_work(core) else {
                // Lost the steal race / nothing stealable: skip this core
                // forward to the next busy core's time so it retries later.
                let next_busy = self
                    .active_cores
                    .iter()
                    .filter(|&&c| !self.queues[c].is_empty())
                    .map(|&c| self.machine.now(c))
                    .min()
                    .unwrap_or(now + self.cfg.timer_ns);
                self.machine.advance_to(core, next_busy.max(now + 1));
                continue;
            };

            // Context switch cost.
            match self.policy.switch_model() {
                SwitchModel::Coroutine => self.machine.coroutine_switch(core),
                SwitchModel::OsThread => {
                    if !self.spawned[self.tasks[tid].rank] {
                        self.spawned[self.tasks[tid].rank] = true;
                        let spawn = self.machine.topo.lat.os_thread_spawn_ns.round() as u64;
                        self.machine.compute(core, spawn);
                    }
                    self.machine.os_context_switch(core);
                }
            }

            // Dispatch one coroutine step.
            self.dispatches += 1;
            let t_before = self.machine.now(core);
            let task = &mut self.tasks[tid];
            task.state = TaskState::Running;
            let rank = task.rank;
            let group_size = task.group_size;
            let mut ctx = TaskCtx {
                machine: &self.machine,
                core,
                task_id: tid,
                rank,
                group_size,
                now_ns: t_before,
                step_outcome: Outcome::default(),
                probe_cache: Default::default(),
                book: Default::default(),
                peer_cores: Some(&self.peer_cores),
            };
            let step = task.coro.step(&mut ctx);
            let t_after = self.machine.now(core);
            let task = &mut self.tasks[tid];
            task.stats.steps += 1;
            task.stats.ns_run += t_after - t_before;

            match step {
                Step::Yield => {
                    task.stats.yields += 1;
                    task.state = TaskState::Ready;
                    let home = self.placement[task.rank];
                    task.core = home;
                    self.queues[home].push(tid);
                }
                Step::Barrier => {
                    task.stats.barriers += 1;
                    task.state = TaskState::Blocked;
                    self.barrier_wait.push(tid);
                    // If everyone alive reached the barrier, release now.
                    if self.barrier_wait.len() + self.finished == n {
                        self.release_barrier();
                    }
                }
                Step::Done => {
                    task.state = TaskState::Finished;
                    self.finished += 1;
                    // A finishing task may complete a pending barrier.
                    if !self.barrier_wait.is_empty()
                        && self.barrier_wait.len() + self.finished == n
                    {
                        self.release_barrier();
                    }
                }
            }
        }

        let makespan = self.machine.max_time();
        self.profiler
            .sample_concurrency(makespan, self.live_threads());
        RunReport {
            policy: self.policy.name().to_string(),
            makespan_ns: makespan,
            counts: self.machine.class_totals(),
            dispatches: self.dispatches,
            steals: self.steals,
            migrations: self.migrations,
            barrier_epochs: self.barrier_epochs,
            avg_concurrency: self.profiler.avg_concurrency(),
            peak_concurrency: self
                .profiler
                .concurrency
                .iter()
                .map(|&(_, l)| l)
                .max()
                .unwrap_or(0),
            concurrency: self.profiler.concurrency.clone(),
            decisions: Vec::new(),
            region_moves: self.region_moves,
            region_decisions: self.region_decisions.clone(),
            dram_bytes: self.machine.dram_total_bytes(),
            spread_rate: self.policy.spread_rate(),
            wall_ns: wall_start.elapsed().as_nanos() as u64,
            host_steals: 0,
            request_latency: None,
            request_shed: 0,
            class_latency: Vec::new(),
            machines: 0,
            cross_link_hops: 0,
            cross_link_bytes: 0,
            shard_moves: 0,
            shard_decisions: Vec::new(),
            per_shard: Vec::new(),
        }
    }

    pub fn task_stats(&self) -> Vec<crate::task::TaskStats> {
        self.tasks.iter().map(|t| t.stats).collect()
    }

    pub fn profiler(&self) -> &Profiler {
        &self.profiler
    }
}

/// Convenience: run `n` tasks of shape `make` under `policy` on `machine`,
/// returning the report. Routed through [`crate::engine::execute`] — the
/// single seam where the executor backend is chosen.
pub fn run_group(
    machine: Machine,
    policy: Box<dyn Policy>,
    n: usize,
    make: impl FnMut(usize) -> Box<dyn Coroutine>,
) -> RunReport {
    crate::engine::execute(machine, policy, None, n, make).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::Placement;
    use crate::policy::{ArcasPolicy, LocalCachePolicy, OsAsyncPolicy, ShoalPolicy};
    use crate::task::{BspTask, FnTask, IterTask};
    use crate::topology::Topology;

    fn machine() -> Machine {
        Machine::new(Topology::milan_1s())
    }

    #[test]
    fn single_task_completes() {
        let m = machine();
        let report = run_group(m, Box::new(LocalCachePolicy), 1, |_| {
            Box::new(FnTask(|ctx: &mut TaskCtx<'_>| ctx.compute_ns(1000)))
        });
        assert!(report.makespan_ns >= 1000);
        assert_eq!(report.dispatches, 1);
    }

    #[test]
    fn group_runs_in_parallel() {
        // 8 independent 1 ms tasks on 8 cores: makespan ~1 ms, not 8 ms.
        let m = machine();
        let report = run_group(m, Box::new(LocalCachePolicy), 8, |_| {
            Box::new(FnTask(|ctx: &mut TaskCtx<'_>| ctx.compute_ns(1_000_000)))
        });
        assert!(
            report.makespan_ns < 2_000_000,
            "makespan={} must be ~1ms (parallel), not 8ms",
            report.makespan_ns
        );
    }

    #[test]
    fn iter_tasks_yield_and_finish() {
        let m = machine();
        let report = run_group(m, Box::new(LocalCachePolicy), 4, |_| {
            Box::new(IterTask::new(10, |ctx, _| ctx.compute_ns(100)))
        });
        // 4 tasks x 10 steps.
        assert_eq!(report.dispatches, 40);
    }

    #[test]
    fn barrier_synchronizes_clocks() {
        // Rank 0 computes 10x longer; after the barrier both do a short
        // step. Total makespan must include the slow task's first phase
        // for BOTH (they wait).
        let m = machine();
        let report = run_group(m, Box::new(LocalCachePolicy), 2, |rank| {
            let slow = rank == 0;
            Box::new(BspTask::new(2, move |ctx, iter| {
                if iter == 0 && slow {
                    ctx.compute_ns(1_000_000);
                } else {
                    ctx.compute_ns(1_000);
                }
            }))
        });
        assert_eq!(report.barrier_epochs, 1);
        assert!(report.makespan_ns >= 1_001_000);
    }

    #[test]
    fn work_stealing_balances_load() {
        // 32 chunky tasks, all initially placed on 1 core group (spread=1
        // puts 8 tasks/core on chiplet 0 with 8 cores; local policy).
        // Steals must occur and makespan must beat serial.
        let m = machine();
        let report = run_group(m, Box::new(LocalCachePolicy), 32, |_| {
            Box::new(IterTask::new(4, |ctx, _| ctx.compute_ns(100_000)))
        });
        let serial = 32u64 * 4 * 100_000;
        assert!(
            report.makespan_ns < serial / 4,
            "makespan={} serial={}",
            report.makespan_ns,
            serial
        );
    }

    #[test]
    fn os_async_pays_switch_costs() {
        let mk = || {
            Box::new(IterTask::new(50, |ctx: &mut TaskCtx<'_>, _| {
                ctx.compute_ns(1_000)
            })) as Box<dyn Coroutine>
        };
        let coro = run_group(machine(), Box::new(LocalCachePolicy), 8, |_| mk());
        let os = run_group(machine(), Box::new(OsAsyncPolicy::new()), 8, |_| mk());
        assert!(
            os.makespan_ns > coro.makespan_ns * 2,
            "os={} coro={} (OS switching must dominate fine tasks)",
            os.makespan_ns,
            coro.makespan_ns
        );
    }

    #[test]
    fn arcas_controller_fires_and_reports_spread() {
        let m = machine();
        let r = m.alloc("shared", 64 << 20, Placement::Bind(0));
        let policy = ArcasPolicy::new(&m.topo).with_timer(100_000);
        let report = run_group(m, Box::new(policy), 8, |_| {
            Box::new(IterTask::new(200, move |ctx, _| {
                ctx.rand_read(r, 200, 64 << 20);
            }))
        });
        assert!(report.makespan_ns > 0);
        // Each fired timer records a concurrency sample on top of the
        // start/end samples the run always takes.
        assert!(
            report.concurrency.len() > 2,
            "timer must have fired (samples={})",
            report.concurrency.len()
        );
    }

    #[test]
    fn concurrency_profile_shapes_differ() {
        let mk = || {
            Box::new(IterTask::new(20, |ctx: &mut TaskCtx<'_>, _| {
                ctx.compute_ns(50_000)
            })) as Box<dyn Coroutine>
        };
        let coro = run_group(machine(), Box::new(LocalCachePolicy), 32, |_| mk());
        let os = run_group(machine(), Box::new(OsAsyncPolicy::new()), 32, |_| mk());
        // Coroutine model: worker pool size is stable; OS model: thread
        // count starts at group size and decays.
        assert!(coro.peak_concurrency <= 8 + 2 + 32); // workers + aux
        assert!(os.peak_concurrency >= 32);
    }

    #[test]
    fn shoal_uses_sequential_cores() {
        // Shoal's strict task→core order is the placement the executor
        // adopts verbatim at spawn time: observe the core each rank
        // actually runs on (equal-length tasks => no steals to blur it).
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;
        let ran_on: Arc<Vec<AtomicUsize>> =
            Arc::new((0..4).map(|_| AtomicUsize::new(usize::MAX)).collect());
        let report = run_group(machine(), Box::new(ShoalPolicy::new()), 4, |rank| {
            let ran_on = ran_on.clone();
            Box::new(FnTask(move |ctx: &mut TaskCtx<'_>| {
                ran_on[rank].store(ctx.core, Ordering::Relaxed);
                ctx.compute_ns(10);
            }))
        });
        assert_eq!(report.dispatches, 4);
        let cores: Vec<usize> = ran_on.iter().map(|c| c.load(Ordering::Relaxed)).collect();
        assert_eq!(cores, vec![0, 1, 2, 3]);
    }

    #[test]
    fn apply_placement_skips_ranks_without_a_live_task() {
        let m = machine();
        let mut ex = SimExecutor::new(m, Box::new(LocalCachePolicy));
        ex.spawn_group(4, |_| {
            Box::new(IterTask::new(2, |ctx: &mut TaskCtx<'_>, _| ctx.compute_ns(10)))
                as Box<dyn Coroutine>
        });
        // Detach rank 2: its task now answers for rank 3, so rank 2 has
        // no live task. The old code did `.position(..).unwrap()` per
        // rank and panicked here.
        ex.tasks[2].rank = 3;
        let mut map = ex.placement.clone();
        let n_cores = ex.machine.topo.num_cores();
        for c in &mut map {
            *c = (*c + 1) % n_cores;
        }
        let before = ex.migrations;
        ex.apply_placement(map.clone(), 0);
        assert_eq!(ex.placement, map);
        // Ranks 0, 1 and 3 migrated; the taskless rank 2 was a no-op.
        assert_eq!(ex.migrations - before, 3);
        // Every queued task was re-pushed somewhere.
        let queued: usize = (0..n_cores).map(|c| ex.queues[c].len()).sum();
        assert_eq!(queued, 4);
    }

    #[test]
    fn report_throughput_math() {
        let mut r = RunReport::default();
        r.makespan_ns = 1_000_000_000;
        assert!((r.throughput(500.0) - 500.0).abs() < 1e-9);
    }
}
