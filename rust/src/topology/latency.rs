//! Latency classes and the calibrated latency model.
//!
//! Numbers are calibrated to the paper's own measurements (Fig. 3: ≈25 ns
//! intra-chiplet, ≈80–90 ns inter-chiplet near group, ≥150 ns far group
//! within a NUMA domain, higher cross-NUMA/socket) plus public EPYC Milan
//! memory-latency data.

/// Communication path classification between two cores.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum LatencyClass {
    SameCore,
    /// Same CCD: via shared L3.
    IntraChiplet,
    /// Different CCD, same NUMA, same Infinity-Fabric quadrant.
    InterChipletNear,
    /// Different CCD, same NUMA, different quadrant.
    InterChipletFar,
    /// Different NUMA domain, same socket (NPS2/NPS4 only).
    CrossNuma,
    /// Different socket.
    CrossSocket,
}

impl LatencyClass {
    pub fn label(&self) -> &'static str {
        match self {
            LatencyClass::SameCore => "same-core",
            LatencyClass::IntraChiplet => "intra-chiplet",
            LatencyClass::InterChipletNear => "inter-chiplet-near",
            LatencyClass::InterChipletFar => "inter-chiplet-far",
            LatencyClass::CrossNuma => "cross-numa",
            LatencyClass::CrossSocket => "cross-socket",
        }
    }
}

/// Calibrated latencies (ns) for one machine generation.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencyModel {
    pub same_core_ns: f64,
    pub intra_chiplet_ns: f64,
    pub inter_chiplet_near_ns: f64,
    pub inter_chiplet_far_ns: f64,
    pub cross_numa_ns: f64,
    pub cross_socket_ns: f64,
    /// L1/L2/L3 hit latencies (load-to-use).
    pub l1_hit_ns: f64,
    pub l2_hit_ns: f64,
    pub l3_hit_ns: f64,
    /// DRAM latency, local NUMA / remote socket.
    pub dram_local_ns: f64,
    pub dram_remote_ns: f64,
    /// OS-thread costs for the std::async baseline cost model.
    pub os_context_switch_ns: f64,
    pub os_thread_spawn_ns: f64,
    /// ARCAS coroutine switch cost (user-space, ~a virtual dispatch).
    pub coroutine_switch_ns: f64,
}

impl LatencyModel {
    /// AMD EPYC Milan (Zen 3), calibrated to the paper's Fig. 3.
    pub fn milan() -> Self {
        Self {
            same_core_ns: 5.0,
            intra_chiplet_ns: 25.0,
            inter_chiplet_near_ns: 85.0,
            inter_chiplet_far_ns: 155.0,
            cross_numa_ns: 110.0,
            cross_socket_ns: 220.0,
            l1_hit_ns: 0.8,
            l2_hit_ns: 3.0,
            l3_hit_ns: 12.0,
            dram_local_ns: 96.0,
            dram_remote_ns: 195.0,
            os_context_switch_ns: 1_800.0,
            os_thread_spawn_ns: 12_000.0,
            coroutine_switch_ns: 22.0,
        }
    }

    /// EPYC Genoa (Zen 4): slightly faster fabric, DDR5.
    pub fn genoa() -> Self {
        Self {
            intra_chiplet_ns: 22.0,
            inter_chiplet_near_ns: 75.0,
            inter_chiplet_far_ns: 130.0,
            cross_socket_ns: 200.0,
            dram_local_ns: 92.0,
            dram_remote_ns: 185.0,
            ..Self::milan()
        }
    }

    /// Hypothetical monolithic die: uniform on-chip latency.
    pub fn monolithic() -> Self {
        Self {
            intra_chiplet_ns: 40.0,
            inter_chiplet_near_ns: 40.0,
            inter_chiplet_far_ns: 40.0,
            cross_numa_ns: 40.0,
            l3_hit_ns: 20.0,
            ..Self::milan()
        }
    }

    #[inline]
    pub fn class_ns(&self, class: LatencyClass) -> f64 {
        match class {
            LatencyClass::SameCore => self.same_core_ns,
            LatencyClass::IntraChiplet => self.intra_chiplet_ns,
            LatencyClass::InterChipletNear => self.inter_chiplet_near_ns,
            LatencyClass::InterChipletFar => self.inter_chiplet_far_ns,
            LatencyClass::CrossNuma => self.cross_numa_ns,
            LatencyClass::CrossSocket => self.cross_socket_ns,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn milan_classes_are_ordered() {
        let m = LatencyModel::milan();
        assert!(m.same_core_ns < m.intra_chiplet_ns);
        assert!(m.intra_chiplet_ns < m.inter_chiplet_near_ns);
        assert!(m.inter_chiplet_near_ns < m.inter_chiplet_far_ns);
        assert!(m.inter_chiplet_far_ns < m.cross_socket_ns);
    }

    #[test]
    fn cache_hierarchy_ordered() {
        let m = LatencyModel::milan();
        assert!(m.l1_hit_ns < m.l2_hit_ns);
        assert!(m.l2_hit_ns < m.l3_hit_ns);
        assert!(m.l3_hit_ns < m.dram_local_ns);
        assert!(m.dram_local_ns < m.dram_remote_ns);
    }

    #[test]
    fn coroutine_vs_os_switch_gap() {
        // §4.4 / Fig. 10-11's premise: user-space switching is orders of
        // magnitude cheaper than OS context switching.
        let m = LatencyModel::milan();
        assert!(m.os_context_switch_ns / m.coroutine_switch_ns > 50.0);
    }

    #[test]
    fn monolithic_is_uniform() {
        let m = LatencyModel::monolithic();
        assert_eq!(m.intra_chiplet_ns, m.inter_chiplet_far_ns);
    }

    #[test]
    fn labels() {
        assert_eq!(LatencyClass::IntraChiplet.label(), "intra-chiplet");
    }
}
