//! Chiplet machine topology model.
//!
//! This is the substitute for the paper's physical testbed (dual-socket
//! AMD EPYC Milan 7713). A [`Topology`] describes the core/chiplet/NUMA
//! hierarchy, the partitioned L3, the memory channels and the latency
//! classes measured in the paper's Fig. 3. Everything downstream (cache
//! model, scheduler, Algorithms 1+2) is parametric in this description, so
//! other machines (Genoa, single-socket, a hypothetical monolithic CPU)
//! are config presets, not code changes.

mod latency;
pub use latency::{LatencyClass, LatencyModel};

use crate::util::config::Config;

/// The inter-machine link tier above the on-package hierarchy: what a
/// request pays to hop between two machines of a cluster. Sits above
/// the IF-link/DDR tiers the same way cross-socket sits above
/// cross-NUMA — a per-link latency plus a shared-bandwidth pipe that
/// queues under load (the cluster router keeps a busy-until horizon per
/// link, exactly like the intra-socket `BwTracker`s charge transfers).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterLink {
    /// One-way propagation latency per hop, ns (NIC + ToR switch; an
    /// order of magnitude above the ~200 ns cross-socket tier).
    pub lat_ns: u64,
    /// Link bandwidth, bytes/ns (12.5 B/ns = 100 Gb/s Ethernet).
    pub bw: f64,
}

impl ClusterLink {
    /// Serialization delay for `bytes` on this link, ns (ceil'd so even
    /// a 1-byte transfer advances the busy horizon).
    pub fn xfer_ns(&self, bytes: u64) -> u64 {
        (bytes as f64 / self.bw).ceil() as u64
    }
}

/// A chiplet-based machine description.
///
/// Core numbering is hierarchical: cores `[0, cores_per_chiplet)` are
/// chiplet 0, and chiplets are numbered socket-major — matching how Linux
/// enumerates cores on EPYC (and what Algorithm 2's rank→core arithmetic
/// assumes).
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    pub name: String,
    pub sockets: usize,
    /// NUMA domains per socket (NPS1 ⇒ 1; the paper runs NPS1).
    pub numa_per_socket: usize,
    pub chiplets_per_numa: usize,
    pub cores_per_chiplet: usize,
    /// Per-chiplet (CCD) shared L3 in bytes.
    pub l3_per_chiplet: u64,
    /// Per-core private L2 in bytes.
    pub l2_per_core: u64,
    /// DDR channels per socket.
    pub mem_channels_per_socket: usize,
    /// Peak bandwidth per channel, bytes/ns (DDR4-3200 ≈ 25.6 GB/s).
    pub mem_bw_per_channel: f64,
    /// Per-CCD Infinity-Fabric link bandwidth to the IO die, bytes/ns.
    /// DRAM traffic of all cores on a chiplet shares this link — why
    /// DistributedCache keeps winning at huge working sets in Fig. 5
    /// (steady-state ratio = mem_bw_per_socket / if_bw ≈ 2.5x, the
    /// paper's measured peak). Calibrated to that ratio: GMI read+write
    /// combined is higher than the often-quoted 32 B/s read number.
    pub if_bw_per_chiplet: f64,
    pub lat: LatencyModel,
}

impl Topology {
    /// The paper's testbed: dual-socket AMD EPYC Milan 7713.
    /// 2 sockets × 8 CCDs × 8 cores, 32 MB L3 per CCD, 8 × DDR4-3200.
    pub fn milan_2s() -> Self {
        Self {
            name: "milan_2s".into(),
            sockets: 2,
            numa_per_socket: 1,
            chiplets_per_numa: 8,
            cores_per_chiplet: 8,
            l3_per_chiplet: 32 << 20,
            l2_per_core: 512 << 10,
            mem_channels_per_socket: 8,
            mem_bw_per_channel: 25.6,
            if_bw_per_chiplet: 80.0,
            lat: LatencyModel::milan(),
        }
    }

    /// Single-socket Milan (used for the §2.3 microbenchmark and Fig. 12's
    /// single-chiplet-count experiments).
    pub fn milan_1s() -> Self {
        Self {
            name: "milan_1s".into(),
            sockets: 1,
            ..Self::milan_2s()
        }
    }

    /// Single-socket Milan in an NPS4-style ruling: the same 8 CCDs × 8
    /// cores, carved into 4 NUMA domains of 2 chiplets each. This is the
    /// multi-node-but-small preset the memory-adaptation tests and the
    /// `--mem-follow-only` bench run on: a region bound to the wrong
    /// domain has three other domains to be stranded from, without
    /// paying dual-socket scale.
    pub fn milan_1s_nps4() -> Self {
        Self {
            name: "milan_1s_nps4".into(),
            numa_per_socket: 4,
            chiplets_per_numa: 2,
            ..Self::milan_1s()
        }
    }

    /// EPYC Genoa-like preset: 12 CCDs × 8 cores per socket, DDR5-4800.
    pub fn genoa_1s() -> Self {
        Self {
            name: "genoa_1s".into(),
            sockets: 1,
            numa_per_socket: 1,
            chiplets_per_numa: 12,
            cores_per_chiplet: 8,
            l3_per_chiplet: 32 << 20,
            l2_per_core: 1 << 20,
            mem_channels_per_socket: 12,
            mem_bw_per_channel: 38.4,
            if_bw_per_chiplet: 128.0,
            lat: LatencyModel::genoa(),
        }
    }

    /// A hypothetical monolithic 64-core CPU with one unified 256 MB LLC —
    /// the ablation baseline: chiplet-awareness should not matter here.
    pub fn monolithic_64() -> Self {
        Self {
            name: "monolithic_64".into(),
            sockets: 1,
            numa_per_socket: 1,
            chiplets_per_numa: 1,
            cores_per_chiplet: 64,
            l3_per_chiplet: 256 << 20,
            l2_per_core: 512 << 10,
            mem_channels_per_socket: 8,
            mem_bw_per_channel: 25.6,
            if_bw_per_chiplet: 1.0e9, // monolithic: no per-chiplet link
            lat: LatencyModel::monolithic(),
        }
    }

    /// Look up a preset by name.
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "milan_2s" => Some(Self::milan_2s()),
            "milan_1s" => Some(Self::milan_1s()),
            "milan_1s_nps4" => Some(Self::milan_1s_nps4()),
            "genoa_1s" => Some(Self::genoa_1s()),
            "monolithic_64" => Some(Self::monolithic_64()),
            _ => None,
        }
    }

    /// Build from a `[topology]` config section (preset plus overrides).
    pub fn from_config(cfg: &Config) -> Self {
        let base = cfg.str_or("topology", "preset", "milan_2s");
        let mut t = Self::preset(&base).unwrap_or_else(|| Self::milan_2s());
        t.sockets = cfg.usize_or("topology", "sockets", t.sockets);
        t.numa_per_socket = cfg.usize_or("topology", "numa_per_socket", t.numa_per_socket);
        t.chiplets_per_numa = cfg.usize_or("topology", "chiplets_per_numa", t.chiplets_per_numa);
        t.cores_per_chiplet = cfg.usize_or("topology", "cores_per_chiplet", t.cores_per_chiplet);
        t.l3_per_chiplet = cfg.u64_or("topology", "l3_per_chiplet", t.l3_per_chiplet);
        t.l2_per_core = cfg.u64_or("topology", "l2_per_core", t.l2_per_core);
        t.mem_channels_per_socket =
            cfg.usize_or("topology", "mem_channels_per_socket", t.mem_channels_per_socket);
        t.mem_bw_per_channel = cfg.f64_or("topology", "mem_bw_per_channel", t.mem_bw_per_channel);
        t
    }

    /// Scale cache capacities by `f` (scaled-down datasets keep crossovers
    /// at the same *relative* position — see DESIGN.md §1 scale note).
    pub fn scale_caches(mut self, f: f64) -> Self {
        self.l3_per_chiplet = ((self.l3_per_chiplet as f64) * f) as u64;
        self.l2_per_core = ((self.l2_per_core as f64) * f).max(1.0) as u64;
        self
    }

    /// The link this machine uses to reach its cluster peers. A method
    /// rather than a preset field: every preset models the same
    /// datacenter fabric, and keeping it out of the struct leaves the
    /// preset literals (and their goldens) untouched.
    pub fn cluster_link(&self) -> ClusterLink {
        ClusterLink {
            lat_ns: 2_000,
            bw: 12.5,
        }
    }

    // --- derived quantities -------------------------------------------

    pub fn num_numa(&self) -> usize {
        self.sockets * self.numa_per_socket
    }

    pub fn num_chiplets(&self) -> usize {
        self.num_numa() * self.chiplets_per_numa
    }

    pub fn num_cores(&self) -> usize {
        self.num_chiplets() * self.cores_per_chiplet
    }

    pub fn cores_per_numa(&self) -> usize {
        self.chiplets_per_numa * self.cores_per_chiplet
    }

    pub fn cores_per_socket(&self) -> usize {
        self.numa_per_socket * self.cores_per_numa()
    }

    pub fn total_l3(&self) -> u64 {
        self.l3_per_chiplet * self.num_chiplets() as u64
    }

    /// Peak DRAM bandwidth per socket, bytes/ns.
    pub fn mem_bw_per_socket(&self) -> f64 {
        self.mem_channels_per_socket as f64 * self.mem_bw_per_channel
    }

    // --- hierarchy mapping --------------------------------------------

    #[inline]
    pub fn chiplet_of(&self, core: usize) -> usize {
        debug_assert!(core < self.num_cores());
        core / self.cores_per_chiplet
    }

    #[inline]
    pub fn slot_of(&self, core: usize) -> usize {
        core % self.cores_per_chiplet
    }

    #[inline]
    pub fn numa_of_core(&self, core: usize) -> usize {
        core / self.cores_per_numa()
    }

    #[inline]
    pub fn numa_of_chiplet(&self, chiplet: usize) -> usize {
        chiplet / self.chiplets_per_numa
    }

    #[inline]
    pub fn socket_of_core(&self, core: usize) -> usize {
        core / self.cores_per_socket()
    }

    #[inline]
    pub fn socket_of_numa(&self, numa: usize) -> usize {
        numa / self.numa_per_socket
    }

    /// Core ids belonging to `chiplet`.
    pub fn cores_of_chiplet(&self, chiplet: usize) -> std::ops::Range<usize> {
        let base = chiplet * self.cores_per_chiplet;
        base..base + self.cores_per_chiplet
    }

    /// Chiplet ids belonging to `numa`.
    pub fn chiplets_of_numa(&self, numa: usize) -> std::ops::Range<usize> {
        let base = numa * self.chiplets_per_numa;
        base..base + self.chiplets_per_numa
    }

    /// Classify the communication path between two cores.
    pub fn latency_class(&self, a: usize, b: usize) -> LatencyClass {
        if a == b {
            return LatencyClass::SameCore;
        }
        if self.chiplet_of(a) == self.chiplet_of(b) {
            return LatencyClass::IntraChiplet;
        }
        if self.socket_of_core(a) != self.socket_of_core(b) {
            return LatencyClass::CrossSocket;
        }
        if self.numa_of_core(a) != self.numa_of_core(b) {
            return LatencyClass::CrossNuma;
        }
        // Within a NUMA domain chiplets come in "near groups" sharing an
        // Infinity-Fabric quadrant (half of the CCDs on Milan); the
        // paper's Fig. 3 shows two latency steps within a NUMA domain
        // (≈85 ns vs ≥150 ns).
        let group = (self.chiplets_per_numa / 2).max(1);
        let qa = self.chiplet_of(a) % self.chiplets_per_numa / group;
        let qb = self.chiplet_of(b) % self.chiplets_per_numa / group;
        if qa == qb {
            LatencyClass::InterChipletNear
        } else {
            LatencyClass::InterChipletFar
        }
    }

    /// Core-to-core communication latency in ns (cache-line transfer).
    #[inline]
    pub fn core_to_core_ns(&self, a: usize, b: usize) -> f64 {
        self.lat.class_ns(self.latency_class(a, b))
    }

    /// Latency of a core reading from another chiplet's L3, ns.
    pub fn l3_access_ns(&self, core: usize, owner_chiplet: usize) -> f64 {
        let class = if self.chiplet_of(core) == owner_chiplet {
            LatencyClass::IntraChiplet
        } else if self.socket_of_numa(self.numa_of_chiplet(owner_chiplet))
            != self.socket_of_core(core)
        {
            LatencyClass::CrossSocket
        } else if self.numa_of_chiplet(owner_chiplet) != self.numa_of_core(core) {
            LatencyClass::CrossNuma
        } else {
            let group = (self.chiplets_per_numa / 2).max(1);
            let qa = self.chiplet_of(core) % self.chiplets_per_numa / group;
            let qb = owner_chiplet % self.chiplets_per_numa / group;
            if qa == qb {
                LatencyClass::InterChipletNear
            } else {
                LatencyClass::InterChipletFar
            }
        };
        match class {
            LatencyClass::IntraChiplet => self.lat.l3_hit_ns,
            other => self.lat.l3_hit_ns + self.lat.class_ns(other),
        }
    }

    /// DRAM access latency from `core` to memory homed on `numa`, ns
    /// (un-contended; the memsim adds queueing).
    pub fn dram_access_ns(&self, core: usize, numa: usize) -> f64 {
        if self.numa_of_core(core) == numa {
            self.lat.dram_local_ns
        } else if self.socket_of_core(core) == self.socket_of_numa(numa) {
            self.lat.dram_local_ns + self.lat.cross_numa_ns
        } else {
            self.lat.dram_remote_ns
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{}: {} sockets x {} numa x {} chiplets x {} cores = {} cores; L3 {}/chiplet ({} total); {} ch x {:.1} B/ns",
            self.name,
            self.sockets,
            self.numa_per_socket,
            self.chiplets_per_numa,
            self.cores_per_chiplet,
            self.num_cores(),
            crate::util::fmt_bytes(self.l3_per_chiplet),
            crate::util::fmt_bytes(self.total_l3()),
            self.mem_channels_per_socket,
            self.mem_bw_per_channel,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn milan_shape() {
        let t = Topology::milan_2s();
        assert_eq!(t.num_cores(), 128);
        assert_eq!(t.num_chiplets(), 16);
        assert_eq!(t.num_numa(), 2);
        assert_eq!(t.total_l3(), 512 << 20);
        assert_eq!(t.cores_per_numa(), 64);
    }

    #[test]
    fn nps4_shape() {
        let t = Topology::milan_1s_nps4();
        assert_eq!(t.num_cores(), 64);
        assert_eq!(t.num_chiplets(), 8);
        assert_eq!(t.num_numa(), 4);
        assert_eq!(t.cores_per_numa(), 16);
        assert_eq!(t.socket_of_numa(3), 0);
        assert_eq!(Topology::preset("milan_1s_nps4").unwrap(), t);
    }

    #[test]
    fn hierarchy_mapping_roundtrips() {
        let t = Topology::milan_2s();
        for core in 0..t.num_cores() {
            let ch = t.chiplet_of(core);
            assert!(t.cores_of_chiplet(ch).contains(&core));
            let numa = t.numa_of_core(core);
            assert_eq!(t.numa_of_chiplet(ch), numa);
            assert!(t.chiplets_of_numa(numa).contains(&ch));
            assert_eq!(t.socket_of_core(core), t.socket_of_numa(numa));
        }
    }

    #[test]
    fn latency_hierarchy_matches_fig3() {
        let t = Topology::milan_2s();
        // core 0 & 1: same chiplet; 0 & 8: neighbour chiplet; 0 & 40: far
        // chiplet same NUMA; 0 & 64: cross socket.
        let intra = t.core_to_core_ns(0, 1);
        let near = t.core_to_core_ns(0, 8);
        let far = t.core_to_core_ns(0, 40);
        let cross = t.core_to_core_ns(0, 64);
        assert!(intra < near, "{intra} < {near}");
        assert!(near < far, "{near} < {far}");
        assert!(far < cross, "{far} < {cross}");
        // Calibration: the paper's Fig. 3 groups.
        assert!((20.0..35.0).contains(&intra), "intra={intra}");
        assert!((75.0..100.0).contains(&near), "near={near}");
        assert!((140.0..200.0).contains(&far), "far={far}");
        assert!(cross >= 200.0, "cross={cross}");
    }

    #[test]
    fn latency_class_symmetric() {
        let t = Topology::milan_2s();
        for &(a, b) in &[(0, 1), (0, 9), (3, 41), (2, 70), (127, 0)] {
            assert_eq!(t.latency_class(a, b), t.latency_class(b, a));
            assert_eq!(t.core_to_core_ns(a, b), t.core_to_core_ns(b, a));
        }
    }

    #[test]
    fn monolithic_has_flat_latency() {
        let t = Topology::monolithic_64();
        assert_eq!(t.num_chiplets(), 1);
        assert_eq!(
            t.latency_class(0, 63),
            LatencyClass::IntraChiplet
        );
    }

    #[test]
    fn dram_latency_orders() {
        let t = Topology::milan_2s();
        let local = t.dram_access_ns(0, 0);
        let remote = t.dram_access_ns(0, 1);
        assert!(local < remote);
    }

    #[test]
    fn l3_access_latency_orders() {
        let t = Topology::milan_2s();
        let own = t.l3_access_ns(0, 0);
        let near = t.l3_access_ns(0, 1);
        let far = t.l3_access_ns(0, 5);
        let cross = t.l3_access_ns(0, 8);
        assert!(own < near && near < far && far < cross);
    }

    #[test]
    fn config_overrides() {
        let cfg = Config::parse("[topology]\npreset = milan_1s\nchiplets_per_numa = 4\n").unwrap();
        let t = Topology::from_config(&cfg);
        assert_eq!(t.sockets, 1);
        assert_eq!(t.chiplets_per_numa, 4);
        assert_eq!(t.num_cores(), 32);
    }

    #[test]
    fn cache_scaling() {
        let t = Topology::milan_1s().scale_caches(0.125);
        assert_eq!(t.l3_per_chiplet, 4 << 20);
    }

    #[test]
    fn cluster_link_sits_above_the_cross_socket_tier() {
        let t = Topology::milan_2s();
        let link = t.cluster_link();
        // The network hop must dominate every on-package latency class.
        assert!((link.lat_ns as f64) > t.core_to_core_ns(0, 64));
        // Serialization: 128 B at 12.5 B/ns rounds up to 11 ns, and a
        // 1-byte transfer still advances the busy horizon.
        assert_eq!(link.xfer_ns(128), 11);
        assert!(link.xfer_ns(1) >= 1);
        // The wire is far slower than one socket's DRAM complex.
        assert!(link.bw < t.mem_bw_per_socket());
    }
}
