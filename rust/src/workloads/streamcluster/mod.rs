//! StreamCluster (PARSEC) — streaming k-median clustering (§5.3, Fig. 8,
//! Tab. 2).
//!
//! Points arrive in batches; each batch runs a few local-search
//! iterations: assign every point to its nearest center, then open new
//! centers at high-cost points when that reduces total cost. The hot
//! memory behaviour is the one the paper exploits: each worker *re-reads
//! its slice of the current batch* every local-search iteration — so a
//! policy that spreads 16 workers across 8 chiplets caches the whole
//! batch in 8×32 MB of L3, while Shoal's sequential placement squeezes it
//! through 2×32 MB and spills to DRAM (Tab. 2's 7× main-memory gap).
//!
//! Per-slice regions make that locality visible to the cache model.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::engine::{Driver, Scenario, ScenarioMetrics};
use crate::mem::{Placement, RegionId};
use crate::policy::Policy;
use crate::sched::RunReport;
use crate::sim::Machine;
use crate::task::{Coroutine, StateTask, Step};
use crate::topology::Topology;
use crate::util::prng::Rng;

/// StreamCluster configuration (paper defaults scaled by the caller).
#[derive(Clone, Debug)]
pub struct ScConfig {
    pub n_points: usize,
    pub dims: usize,
    pub batch_size: usize,
    /// Target center range (paper: 10–20).
    pub k_min: usize,
    pub k_max: usize,
    /// Cap on intermediate centers (paper: 5000).
    pub max_centers: usize,
    /// Local-search iterations per batch.
    pub local_iters: usize,
    pub seed: u64,
}

impl ScConfig {
    /// Small config for tests.
    pub fn tiny() -> Self {
        Self {
            n_points: 2_000,
            dims: 16,
            batch_size: 1_000,
            k_min: 5,
            k_max: 10,
            max_centers: 100,
            local_iters: 3,
            seed: 42,
        }
    }

    /// Scaled-down PARSEC `native`-shaped input for benches.
    pub fn bench(scale: f64) -> Self {
        Self {
            n_points: (200_000.0 * scale) as usize,
            dims: 64,
            batch_size: (40_000.0 * scale) as usize,
            k_min: 10,
            k_max: 20,
            max_centers: 5_000,
            local_iters: 4,
            seed: 7,
        }
    }

    pub fn point_bytes(&self) -> u64 {
        (self.dims * 4) as u64
    }

    pub fn batch_bytes(&self) -> u64 {
        self.batch_size as u64 * self.point_bytes()
    }
}

/// Generate clustered points: Gaussian blobs in `[0,1]^dims`.
pub fn generate_points(cfg: &ScConfig) -> Vec<f32> {
    let mut rng = Rng::new(cfg.seed);
    let k_true = (cfg.k_min + cfg.k_max) / 2;
    let centers: Vec<f32> = (0..k_true * cfg.dims).map(|_| rng.gen_f32()).collect();
    let mut pts = Vec::with_capacity(cfg.n_points * cfg.dims);
    for _ in 0..cfg.n_points {
        let c = rng.gen_index(k_true);
        for d in 0..cfg.dims {
            pts.push(centers[c * cfg.dims + d] + 0.05 * rng.gen_normal() as f32);
        }
    }
    pts
}

#[inline]
fn dist2(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// Result of a streamcluster run.
#[derive(Clone, Debug)]
pub struct ScResult {
    pub report: RunReport,
    pub final_cost: f64,
    pub n_centers: usize,
    /// Cost after each (batch, iter) assignment phase.
    pub cost_trace: Vec<f64>,
}

/// Serial reference: same algorithm, single-threaded (cost oracle).
pub fn serial_cost(cfg: &ScConfig, points: &[f32]) -> (f64, usize) {
    let mut centers: Vec<f32> = points[..cfg.dims].to_vec(); // first point
    let n = cfg.n_points.min(points.len() / cfg.dims);
    let mut rng = Rng::new(cfg.seed ^ 0xC0FFEE);
    for _ in 0..cfg.local_iters {
        let mut worst: (f32, usize) = (-1.0, 0);
        let k = centers.len() / cfg.dims;
        for p in 0..n {
            let pt = &points[p * cfg.dims..(p + 1) * cfg.dims];
            let best = (0..k)
                .map(|c| dist2(pt, &centers[c * cfg.dims..(c + 1) * cfg.dims]))
                .fold(f32::INFINITY, f32::min);
            if best > worst.0 {
                worst = (best, p);
            }
        }
        if centers.len() / cfg.dims < cfg.k_max && rng.gen_bool(0.9) {
            centers.extend_from_slice(&points[worst.1 * cfg.dims..(worst.1 + 1) * cfg.dims]);
        }
    }
    let k = centers.len() / cfg.dims;
    let mut cost = 0.0f64;
    for p in 0..n {
        let pt = &points[p * cfg.dims..(p + 1) * cfg.dims];
        let best = (0..k)
            .map(|c| dist2(pt, &centers[c * cfg.dims..(c + 1) * cfg.dims]))
            .fold(f32::INFINITY, f32::min);
        cost += best as f64;
    }
    (cost, k)
}

/// Streaming k-median clustering as a [`Scenario`].
pub struct ScScenario {
    cfg: ScConfig,
    points: Arc<Vec<f32>>,
    st: Option<ScState>,
}

/// Post-`setup` shared state.
struct ScState {
    slice_regions: Vec<RegionId>,
    centers_region: RegionId,
    centers: Arc<RwLock<Arc<Vec<f32>>>>,
    costs: Arc<Vec<AtomicU64>>,
    proposals: Arc<Mutex<Vec<(f32, usize)>>>,
    iters_total: usize,
}

impl ScScenario {
    pub fn new(cfg: ScConfig, points: Arc<Vec<f32>>) -> Self {
        Self {
            cfg,
            points,
            st: None,
        }
    }

    /// Number of centers opened; valid after the run.
    pub fn n_centers(&self) -> usize {
        self.st
            .as_ref()
            .map_or(0, |st| st.centers.read().unwrap().len() / self.cfg.dims)
    }

    /// Cost after each (batch, iter) assignment phase; valid after the run.
    pub fn cost_trace(&self) -> Vec<f64> {
        self.st
            .as_ref()
            .map(|st| {
                st.costs
                    .iter()
                    .map(|c| f64::from_bits(c.load(Ordering::Relaxed)))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Assemble the legacy result type from a finished run.
    pub fn into_result(self, report: RunReport) -> ScResult {
        let cost_trace = self.cost_trace();
        let final_cost = *cost_trace.last().unwrap_or(&0.0);
        ScResult {
            report,
            final_cost,
            n_centers: self.n_centers(),
            cost_trace,
        }
    }
}

impl Scenario for ScScenario {
    fn name(&self) -> &'static str {
        "streamcluster"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        let cfg = &self.cfg;
        let dims = cfg.dims;
        let n_batches = cfg.n_points.div_ceil(cfg.batch_size).max(1);

        // Per-worker slice regions: slice locality is the experiment.
        let slice_bytes = cfg.batch_bytes() / tasks as u64;
        let slice_regions: Vec<_> = (0..tasks)
            .map(|r| {
                machine.alloc(
                    &format!("sc-slice-{r}"),
                    slice_bytes.max(64),
                    Placement::Interleave,
                )
            })
            .collect();
        let centers_region = machine.alloc(
            "sc-centers",
            (cfg.max_centers * dims * 4) as u64,
            Placement::Interleave,
        );

        // Shared center set (snapshot-swapped between phases).
        let centers: Arc<RwLock<Arc<Vec<f32>>>> =
            Arc::new(RwLock::new(Arc::new(self.points[..dims].to_vec())));
        // Per-iteration aggregated cost (f64 bits) and worst-point proposals.
        let iters_total = n_batches * cfg.local_iters;
        let costs: Arc<Vec<AtomicU64>> =
            Arc::new((0..iters_total).map(|_| AtomicU64::new(0)).collect());
        self.st = Some(ScState {
            slice_regions,
            centers_region,
            centers,
            costs,
            proposals: Arc::new(Mutex::new(Vec::new())),
            iters_total,
        });
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        let st = self.st.as_ref().expect("setup() before spawn()");
        let dims = self.cfg.dims;
        let k_max = self.cfg.k_max;
        let max_centers = self.cfg.max_centers;
        let local_iters = self.cfg.local_iters;
        let batch_size = self.cfg.batch_size;
        let n_points = self.cfg.n_points;
        let iters_total = st.iters_total;
        let centers_region = st.centers_region;
        let points = self.points.clone();
        let centers = st.centers.clone();
        let costs = st.costs.clone();
        let proposals = st.proposals.clone();
        let slice_region = st.slice_regions[rank];
        Box::new(StateTask::new(move |ctx, step| {
            // Two phases per local iteration: 0 = assign, 1 = reconcile.
            let global_iter = (step / 2) as usize;
            let phase = step % 2;
            if global_iter >= iters_total {
                return Step::Done;
            }
            let batch = global_iter / local_iters;
            let b_lo = batch * batch_size;
            let b_hi = ((batch + 1) * batch_size).min(n_points);
            let b_n = b_hi - b_lo;
            // This worker's slice of the batch.
            let per = b_n.div_ceil(ctx.group_size);
            let lo = b_lo + (rank * per).min(b_n);
            let hi = b_lo + ((rank + 1) * per).min(b_n);

            if phase == 0 {
                // --- assignment: re-read my slice + the centers.
                let snap = centers.read().unwrap().clone();
                let k = snap.len() / dims;
                let mut cost = 0.0f64;
                let mut worst: (f32, usize) = (-1.0, lo);
                for p in lo..hi {
                    let pt = &points[p * dims..(p + 1) * dims];
                    let mut best = f32::INFINITY;
                    for c in 0..k {
                        let d = dist2(pt, &snap[c * dims..(c + 1) * dims]);
                        if d < best {
                            best = d;
                        }
                    }
                    cost += best as f64;
                    if best > worst.0 {
                        worst = (best, p);
                    }
                }
                // Aggregate (atomic f64 add) + propose my worst point.
                let slot = &costs[global_iter];
                let mut cur = slot.load(Ordering::Relaxed);
                loop {
                    let new = (f64::from_bits(cur) + cost).to_bits();
                    match slot.compare_exchange_weak(
                        cur,
                        new,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => break,
                        Err(c) => cur = c,
                    }
                }
                if worst.0 >= 0.0 {
                    proposals.lock().unwrap().push(worst);
                }
                // --- model: slice re-read (the cacheable working set),
                // centers random-read, distance flops.
                let slice_read = ((hi - lo) * dims * 4) as u64;
                ctx.seq_read(slice_region, slice_read);
                ctx.rand_read(
                    centers_region,
                    (((hi - lo) * k.max(1)) as u64 / 8).max(1),
                    (k.max(1) * dims * 4) as u64,
                );
                ctx.compute_flops((3 * (hi - lo) * k.max(1) * dims) as u64);
            } else if rank == 0 {
                // --- reconcile (rank 0): open a center at the globally
                // worst point if there is headroom.
                let mut props = proposals.lock().unwrap();
                if let Some(&(_, p)) = props
                    .iter()
                    .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
                {
                    let mut guard = centers.write().unwrap();
                    let k = guard.len() / dims;
                    if k < k_max.min(max_centers) {
                        let mut next = guard.as_ref().clone();
                        next.extend_from_slice(&points[p * dims..(p + 1) * dims]);
                        *guard = Arc::new(next);
                    }
                }
                props.clear();
                ctx.seq_write(centers_region, (dims * 4) as u64);
                ctx.compute_ns(500);
            } else {
                ctx.compute_ns(50);
            }
            Step::Barrier
        }))
    }

    fn verify(&self) {
        let k = self.n_centers();
        assert!(k >= 1 && k <= self.cfg.k_max.max(1), "center count {k} out of range");
        let trace = self.cost_trace();
        let final_cost = trace.last().copied().unwrap_or(0.0);
        assert!(
            final_cost.is_finite() && final_cost >= 0.0,
            "clustering cost must be finite, got {final_cost}"
        );
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        // Every point is re-assigned once per local-search iteration of
        // its batch.
        let assigned = (self.cfg.n_points * self.cfg.local_iters) as f64;
        ScenarioMetrics::new(assigned, "assignments")
            .with("final_cost", self.cost_trace().last().copied().unwrap_or(0.0))
            .with("centers", self.n_centers() as f64)
            .with("points_per_s", report.throughput(self.cfg.n_points as f64))
    }
}

/// Run parallel StreamCluster under `policy` on `cores` workers.
pub fn run_streamcluster(
    topo: &Topology,
    policy: Box<dyn Policy>,
    cores: usize,
    cfg: &ScConfig,
    points: Arc<Vec<f32>>,
) -> ScResult {
    let mut s = ScScenario::new(cfg.clone(), points);
    let run = Driver::new(topo, policy, cores).run(&mut s);
    s.into_result(run.report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{ArcasPolicy, LocalCachePolicy, ShoalPolicy};

    fn topo() -> Topology {
        Topology::milan_1s()
    }

    #[test]
    fn points_generation_is_deterministic_and_bounded() {
        let cfg = ScConfig::tiny();
        let a = generate_points(&cfg);
        let b = generate_points(&cfg);
        assert_eq!(a.len(), cfg.n_points * cfg.dims);
        assert_eq!(a, b);
        assert!(a.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn centers_open_and_stay_bounded() {
        let cfg = ScConfig::tiny();
        let pts = Arc::new(generate_points(&cfg));
        let res = run_streamcluster(&topo(), Box::new(LocalCachePolicy), 4, &cfg, pts);
        assert!(res.n_centers > 1, "centers must open");
        assert!(res.n_centers <= cfg.k_max);
        assert!(res.final_cost.is_finite() && res.final_cost > 0.0);
        assert_eq!(res.cost_trace.len(), 2 * cfg.local_iters); // 2 batches
    }

    #[test]
    fn cost_improves_within_first_batch() {
        let cfg = ScConfig::tiny();
        let pts = Arc::new(generate_points(&cfg));
        let res = run_streamcluster(&topo(), Box::new(LocalCachePolicy), 4, &cfg, pts);
        let first = res.cost_trace[0];
        let last = res.cost_trace[cfg.local_iters - 1];
        assert!(last <= first * 1.001, "first={first} last={last}");
    }

    #[test]
    fn parallel_cost_matches_serial_order_of_magnitude() {
        let cfg = ScConfig::tiny();
        let pts = generate_points(&cfg);
        let (ser_cost, _) = serial_cost(&cfg, &pts);
        let res = run_streamcluster(
            &topo(),
            Box::new(LocalCachePolicy),
            4,
            &cfg,
            Arc::new(pts),
        );
        let ratio = res.final_cost / ser_cost.max(1e-9);
        assert!(
            (0.05..20.0).contains(&ratio),
            "par={} ser={ser_cost}",
            res.final_cost
        );
    }

    #[test]
    fn arcas_beats_shoal_at_16_cores() {
        // Fig. 8's biggest gap: 16 cores. Batch sized so it fits 8 chiplets'
        // L3 (8×256 KiB) but not the 2 chiplets Shoal fills (scaled caches
        // keep the test fast): batch = 1 MiB.
        let t = Topology::milan_1s().scale_caches(1.0 / 128.0); // 256 KiB/chiplet
        let mut cfg = ScConfig::tiny();
        cfg.n_points = 8_000;
        cfg.batch_size = 4_000;
        cfg.dims = 64; // batch = 1 MiB
        cfg.local_iters = 6;
        let pts = Arc::new(generate_points(&cfg));
        let shoal = run_streamcluster(&t, Box::new(ShoalPolicy::new()), 16, &cfg, pts.clone());
        let arcas = run_streamcluster(
            &t,
            Box::new(ArcasPolicy::new(&t).with_timer(20_000)),
            16,
            &cfg,
            pts,
        );
        assert!(
            arcas.report.makespan_ns < shoal.report.makespan_ns,
            "arcas={} shoal={}",
            arcas.report.makespan_ns,
            shoal.report.makespan_ns
        );
    }

    #[test]
    fn dist2_is_squared_euclidean() {
        assert_eq!(dist2(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(dist2(&[1.0], &[1.0]), 0.0);
    }
}
