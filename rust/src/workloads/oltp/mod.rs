//! OLTP engine (ERMIA-style, memory-optimized) with YCSB and TPC-C-lite
//! drivers (§5.6, Fig. 13).
//!
//! Short transactions with optimistic version checks, a shared commit
//! counter and a sequential log. The paper's (null) result — LocalCache ≈
//! DistributedCache for OLTP — emerges from the cost structure: per-txn
//! data footprints are a few cache lines, while every commit pays the
//! shared commit-counter ping-pong and log append, which no cache
//! placement policy can hide.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::engine::{Driver, Scenario, ScenarioMetrics};
use crate::mem::{Placement, RegionId};
use crate::policy::Policy;
use crate::sched::RunReport;
use crate::sim::Machine;
use crate::task::{Coroutine, StateTask, Step};
use crate::topology::Topology;
use crate::util::prng::Rng;

/// Which benchmark drives the engine.
#[derive(Clone, Debug)]
pub enum OltpWorkload {
    /// YCSB: single table, `read_frac` reads vs read-modify-writes
    /// (paper: 45% read / 55% RMW on 50 M records — scaled).
    Ycsb { records: usize, read_frac: f64 },
    /// TPC-C-lite: `warehouses` warehouses, standard transaction mix
    /// (45% NewOrder, 43% Payment, 12% others), home-warehouse access.
    TpcC { warehouses: usize },
}

impl OltpWorkload {
    pub fn ycsb_scaled(scale: f64) -> Self {
        OltpWorkload::Ycsb {
            records: ((50_000_000.0 * scale) as usize).max(1024),
            read_frac: 0.45,
        }
    }

    pub fn tpcc_scaled(scale: f64) -> Self {
        OltpWorkload::TpcC {
            warehouses: ((50.0 * scale).ceil() as usize).max(2),
        }
    }
}

/// Result of an OLTP run.
#[derive(Clone, Debug)]
pub struct OltpRun {
    pub report: RunReport,
    pub commits: u64,
    pub aborts: u64,
}

impl OltpRun {
    pub fn commits_per_sec(&self) -> f64 {
        self.commits as f64 / (self.report.makespan_ns.max(1) as f64 / 1e9)
    }
}

/// In-memory record store: one versioned word per record. Shared with
/// the mixed multi-tenant scenario (`workloads::mixed`), whose OLTP
/// tenant runs the same YCSB mix over it.
pub(crate) struct Store {
    pub(crate) records: Vec<AtomicU64>,
    pub(crate) region: RegionId,
    pub(crate) bytes: u64,
}

impl Store {
    pub(crate) fn new(machine: &mut Machine, label: &str, n: usize, rec_bytes: u64) -> Self {
        let bytes = (n as u64 * rec_bytes).max(64);
        let region = machine.alloc(label, bytes, Placement::Interleave);
        Self {
            records: (0..n).map(|i| AtomicU64::new(i as u64)).collect(),
            region,
            bytes,
        }
    }

    #[inline]
    pub(crate) fn read(&self, i: usize) -> u64 {
        self.records[i % self.records.len()].load(Ordering::Relaxed)
    }

    /// Optimistic RMW: returns false on version conflict (abort).
    #[inline]
    pub(crate) fn rmw(&self, i: usize, delta: u64) -> bool {
        let slot = &self.records[i % self.records.len()];
        let cur = slot.load(Ordering::Relaxed);
        slot.compare_exchange(
            cur,
            cur.wrapping_add(delta),
            Ordering::Relaxed,
            Ordering::Relaxed,
        )
        .is_ok()
    }
}

const TXNS_PER_STEP: u64 = 64;

/// The ERMIA-style OLTP engine (YCSB / TPC-C-lite) as a [`Scenario`].
pub struct OltpScenario {
    workload: OltpWorkload,
    txns_per_core: u64,
    seed: u64,
    tasks: usize,
    st: Option<OltpState>,
}

/// Post-`setup` shared state.
struct OltpState {
    main: Arc<Store>,
    stock: Option<Arc<Store>>,
    orders_store: Option<Arc<Store>>,
    commit_region: RegionId,
    log_region: RegionId,
    commit_counter: Arc<AtomicU64>,
    commits: Arc<AtomicU64>,
    aborts: Arc<AtomicU64>,
    steps: u64,
}

impl OltpScenario {
    pub fn new(workload: OltpWorkload, txns_per_core: u64, seed: u64) -> Self {
        Self {
            workload,
            txns_per_core,
            seed,
            tasks: 0,
            st: None,
        }
    }

    /// Committed transactions; valid after the run.
    pub fn commits(&self) -> u64 {
        self.st
            .as_ref()
            .map_or(0, |st| st.commits.load(Ordering::Relaxed))
    }

    /// Aborted transactions; valid after the run.
    pub fn aborts(&self) -> u64 {
        self.st
            .as_ref()
            .map_or(0, |st| st.aborts.load(Ordering::Relaxed))
    }
}

impl Scenario for OltpScenario {
    fn name(&self) -> &'static str {
        "oltp"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        self.tasks = tasks;
        let txns_per_core = self.txns_per_core;
        // Stores per workload.
        let (main, stock, orders_store) = match &self.workload {
            OltpWorkload::Ycsb { records, .. } => (
                Arc::new(Store::new(machine, "ycsb-table", *records, 100)),
                None,
                None,
            ),
            OltpWorkload::TpcC { warehouses } => {
                // warehouse+district+customer rolled into `main`;
                // stock separate (largest table); orders append-only.
                let cust = warehouses * 3_000;
                (
                    Arc::new(Store::new(machine, "tpcc-wh-dist-cust", cust, 64)),
                    Some(Arc::new(Store::new(
                        machine,
                        "tpcc-stock",
                        warehouses * 10_000,
                        32,
                    ))),
                    Some(Arc::new(Store::new(
                        machine,
                        "tpcc-orders",
                        (txns_per_core as usize * tasks).max(1024),
                        48,
                    ))),
                )
            }
        };
        // Shared commit infrastructure: counter line + log.
        let commit_region = machine.alloc("commit-counter", 64, Placement::Bind(0));
        let log_region = machine.alloc("txn-log", 64 << 20, Placement::Bind(0));
        self.st = Some(OltpState {
            main,
            stock,
            orders_store,
            commit_region,
            log_region,
            commit_counter: Arc::new(AtomicU64::new(0)),
            commits: Arc::new(AtomicU64::new(0)),
            aborts: Arc::new(AtomicU64::new(0)),
            steps: txns_per_core.div_ceil(TXNS_PER_STEP),
        });
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        let st = self.st.as_ref().expect("setup() before spawn()");
        let txns_per_core = self.txns_per_core;
        let steps = st.steps;
        let commit_region = st.commit_region;
        let log_region = st.log_region;
        let main = st.main.clone();
        let stock = st.stock.clone();
        let orders_store = st.orders_store.clone();
        let commit_counter = st.commit_counter.clone();
        let commits = st.commits.clone();
        let aborts = st.aborts.clone();
        let workload = self.workload.clone();
        let mut rng = Rng::new(self.seed ^ ((rank as u64) << 40));
        Box::new(StateTask::new(move |ctx, step| {
            if step >= steps {
                return Step::Done;
            }
            let todo = TXNS_PER_STEP.min(txns_per_core - step * TXNS_PER_STEP);
            let mut ok = 0u64;
            let mut failed = 0u64;
            let mut reads = 0u64;
            let mut writes = 0u64;
            for _ in 0..todo {
                let committed = match &workload {
                    OltpWorkload::Ycsb { records, read_frac } => {
                        let key = rng.gen_zipf(*records as u64, 0.99) as usize;
                        if rng.gen_bool(*read_frac) {
                            let _ = main.read(key);
                            reads += 1;
                            true
                        } else {
                            reads += 1;
                            writes += 1;
                            main.rmw(key, 1)
                        }
                    }
                    OltpWorkload::TpcC { warehouses } => {
                        let wh = rank % warehouses; // home warehouse
                        let kind = rng.gen_f64();
                        if kind < 0.45 {
                            // NewOrder: district seq + 5-15 stock updates
                            // + order insert.
                            let items = 5 + rng.gen_range(11);
                            let mut all = main.rmw(wh * 3_000, 1);
                            for _ in 0..items {
                                let s = wh * 10_000 + rng.gen_index(10_000);
                                all &= stock.as_ref().unwrap().rmw(s, 1);
                                reads += 1;
                                writes += 1;
                            }
                            let o = commit_counter.load(Ordering::Relaxed) as usize;
                            let _ = orders_store.as_ref().unwrap().rmw(o, 1);
                            writes += 2;
                            all
                        } else if kind < 0.88 {
                            // Payment: wh + district + customer updates.
                            let c = wh * 3_000 + rng.gen_index(3_000);
                            let a = main.rmw(wh * 3_000, 1);
                            let b = main.rmw(c, 1);
                            reads += 3;
                            writes += 3;
                            a && b
                        } else if kind < 0.92 {
                            // OrderStatus: reads only.
                            let c = wh * 3_000 + rng.gen_index(3_000);
                            let _ = main.read(c);
                            reads += 4;
                            true
                        } else if kind < 0.97 {
                            // Delivery: update 10 orders.
                            for _ in 0..10 {
                                let o = rng.gen_index(
                                    orders_store.as_ref().unwrap().records.len(),
                                );
                                let _ = orders_store.as_ref().unwrap().rmw(o, 1);
                            }
                            reads += 10;
                            writes += 10;
                            true
                        } else {
                            // StockLevel: scan 200 stock records.
                            for _ in 0..200 {
                                let s = wh * 10_000 + rng.gen_index(10_000);
                                let _ = stock.as_ref().unwrap().read(s);
                            }
                            reads += 200;
                            true
                        }
                    }
                };
                if committed {
                    commit_counter.fetch_add(1, Ordering::Relaxed);
                    ok += 1;
                } else {
                    failed += 1;
                }
            }
            commits.fetch_add(ok, Ordering::Relaxed);
            aborts.fetch_add(failed, Ordering::Relaxed);

            // --- cost model for this chunk.
            if reads > 0 {
                ctx.access(
                    crate::cachesim::Access::rand_read(main.region, reads, main.bytes)
                        .with_mlp(1.5),
                );
            }
            if writes > 0 {
                let (wr, wb) = match &stock {
                    Some(s) => (s.region, s.bytes),
                    None => (main.region, main.bytes),
                };
                ctx.access(
                    crate::cachesim::Access::rand_write(wr, writes, wb).with_mlp(1.5),
                );
            }
            // Commit path: counter ping-pong + log append + latch wait.
            if ok > 0 {
                ctx.rand_write(commit_region, ok, 64);
                ctx.seq_write(log_region, ok * 128);
                // Serialization: ~600 ns latch + fsync-amortized delay.
                ctx.compute_ns(ok * 600);
            }
            ctx.compute_flops(todo * 300);
            if step + 1 >= steps {
                Step::Done
            } else {
                Step::Yield
            }
        }))
    }

    fn verify(&self) {
        let total = self.commits() + self.aborts();
        let expect = self.tasks as u64 * self.txns_per_core;
        assert_eq!(
            total, expect,
            "every transaction must commit or abort ({total} of {expect})"
        );
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        ScenarioMetrics::new(self.commits() as f64, "commits")
            .with("commits_per_s", report.throughput(self.commits() as f64))
            .with("aborts", self.aborts() as f64)
    }
}

/// Run an OLTP benchmark: `cores` clients, `txns_per_core` transactions
/// each.
pub fn run_oltp(
    topo: &Topology,
    policy: Box<dyn Policy>,
    cores: usize,
    workload: &OltpWorkload,
    txns_per_core: u64,
    seed: u64,
) -> OltpRun {
    let mut s = OltpScenario::new(workload.clone(), txns_per_core, seed);
    let run = Driver::new(topo, policy, cores).run(&mut s);
    OltpRun {
        report: run.report,
        commits: s.commits(),
        aborts: s.aborts(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{DistributedCachePolicy, LocalCachePolicy};

    fn topo() -> Topology {
        Topology::milan_1s()
    }

    #[test]
    fn ycsb_commits_all_reads() {
        let wl = OltpWorkload::Ycsb {
            records: 10_000,
            read_frac: 1.0,
        };
        let run = run_oltp(&topo(), Box::new(LocalCachePolicy), 4, &wl, 1_000, 1);
        assert_eq!(run.commits, 4_000);
        assert_eq!(run.aborts, 0);
        assert!(run.commits_per_sec() > 0.0);
    }

    #[test]
    fn ycsb_rmw_mix_mostly_commits() {
        let wl = OltpWorkload::Ycsb {
            records: 10_000,
            read_frac: 0.45,
        };
        let run = run_oltp(&topo(), Box::new(LocalCachePolicy), 8, &wl, 2_000, 2);
        let total = run.commits + run.aborts;
        assert_eq!(total, 16_000);
        assert!(
            run.commits as f64 > total as f64 * 0.95,
            "commits={} aborts={}",
            run.commits,
            run.aborts
        );
    }

    #[test]
    fn tpcc_executes_standard_mix() {
        let wl = OltpWorkload::TpcC { warehouses: 4 };
        let run = run_oltp(&topo(), Box::new(LocalCachePolicy), 4, &wl, 1_000, 3);
        assert!(run.commits > 3_500, "commits={}", run.commits);
    }

    #[test]
    fn local_vs_distributed_is_a_null_result() {
        // Fig. 13: OLTP throughput is commit-bound; the two static cache
        // policies must land within ~20% of each other.
        let wl = OltpWorkload::Ycsb {
            records: 100_000,
            read_frac: 0.45,
        };
        let local = run_oltp(&topo(), Box::new(LocalCachePolicy), 8, &wl, 4_000, 4);
        let dist = run_oltp(&topo(), Box::new(DistributedCachePolicy), 8, &wl, 4_000, 4);
        let ratio = local.commits_per_sec() / dist.commits_per_sec();
        assert!(
            (0.8..1.25).contains(&ratio),
            "local={:.0} dist={:.0} ratio={ratio:.3}",
            local.commits_per_sec(),
            dist.commits_per_sec()
        );
    }

    #[test]
    fn throughput_scales_with_cores_some() {
        let wl = OltpWorkload::Ycsb {
            records: 100_000,
            read_frac: 0.45,
        };
        let c1 = run_oltp(&topo(), Box::new(LocalCachePolicy), 1, &wl, 4_000, 5);
        let c8 = run_oltp(&topo(), Box::new(LocalCachePolicy), 8, &wl, 4_000, 5);
        assert!(c8.commits_per_sec() > c1.commits_per_sec() * 2.0);
    }
}
