//! Morsel-parallel query execution over ARCAS tasks (§5.5, Fig. 12).
//!
//! A query runs as build phases (one per hash join) followed by a probe
//! phase over the fact table and a merge phase — all data-parallel BSP
//! steps over the coroutine executor. Hash tables and aggregates are real
//! (sharded hash sets / per-task maps); filters use deterministic
//! hash-based selectivities from the [`super::queries::QuerySpec`].
//!
//! The working-set story the paper tells is explicit here: build-side
//! hash tables live in region(s) sized by the filtered build cardinality —
//! join-heavy queries (large orders-side tables) want the aggregate L3 of
//! many chiplets, while small scans want compaction.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::data::{Db, Table};
use super::queries::{KeyCol, QuerySpec};
use crate::engine::{Driver, Scenario, ScenarioMetrics};
use crate::mem::{Placement, RegionId};
use crate::policy::Policy;
use crate::sched::RunReport;
use crate::sim::Machine;
use crate::task::{Coroutine, StateTask, Step};
use crate::topology::Topology;

const HASH_SHARDS: usize = 64;

/// Deterministic selectivity filter: keep `row` with probability `sel`.
/// Shared with the mixed multi-tenant scenario's OLAP tenant so both
/// verify against the same serial oracle.
#[inline]
pub(crate) fn keep(row: u64, salt: u64, sel: f64) -> bool {
    if sel >= 1.0 {
        return true;
    }
    let h = (row ^ salt).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64) < sel
}

/// Query execution result.
#[derive(Clone, Debug)]
pub struct QueryResult {
    pub id: usize,
    pub rows_out: u64,
    pub agg_sum: f64,
    pub groups_touched: usize,
    pub report: RunReport,
}

struct JoinState {
    shards: Vec<Mutex<HashSet<u64>>>,
}

impl JoinState {
    fn new() -> Self {
        Self {
            shards: (0..HASH_SHARDS).map(|_| Mutex::new(HashSet::new())).collect(),
        }
    }

    fn insert(&self, k: u64) {
        self.shards[(k as usize) % HASH_SHARDS]
            .lock()
            .unwrap()
            .insert(k);
    }

    fn contains(&self, k: u64) -> bool {
        self.shards[(k as usize) % HASH_SHARDS]
            .lock()
            .unwrap()
            .contains(&k)
    }
}

/// Build-side key iterator for a table.
fn build_key(db: &Db, t: Table, row: usize) -> u64 {
    match t {
        Table::Orders => db.orders.orderkey[row],
        Table::Part => db.part.partkey[row] as u64,
        Table::Supplier => db.supplier.suppkey[row] as u64,
        Table::Customer => db.customer.custkey[row] as u64,
        Table::Lineitem => db.lineitem.orderkey[row],
    }
}

/// Probe-side key for `col` at probe row `row` (chased through orders for
/// customer joins when probing lineitem).
fn probe_key(db: &Db, probe: Table, col: KeyCol, row: usize) -> u64 {
    match (probe, col) {
        (Table::Lineitem, KeyCol::Orderkey) => db.lineitem.orderkey[row],
        (Table::Lineitem, KeyCol::Partkey) => db.lineitem.partkey[row] as u64,
        (Table::Lineitem, KeyCol::Suppkey) => db.lineitem.suppkey[row] as u64,
        (Table::Lineitem, KeyCol::Custkey) => {
            let ok = db.lineitem.orderkey[row] as usize;
            db.orders.custkey[ok] as u64
        }
        (Table::Orders, KeyCol::Custkey) => db.orders.custkey[row] as u64,
        (Table::Orders, KeyCol::Orderkey) => db.orders.orderkey[row],
        _ => 0,
    }
}

/// Aggregation value for a passing probe row.
pub(crate) fn agg_value(db: &Db, probe: Table, row: usize) -> f64 {
    match probe {
        Table::Lineitem => {
            (db.lineitem.extendedprice[row] * (1.0 - db.lineitem.discount[row])) as f64
        }
        Table::Orders => db.orders.totalprice[row] as f64,
        _ => 1.0,
    }
}

/// Effective group count for the scaled database.
pub fn scaled_groups(spec: &QuerySpec, db: &Db) -> usize {
    if spec.groups <= 1024 {
        spec.groups
    } else {
        ((spec.groups as f64 * db.sf).ceil() as usize).clamp(1024, spec.groups)
    }
}

/// One TPC-H-shaped query on the morsel-parallel engine as a
/// [`Scenario`].
pub struct OlapScenario {
    db: Arc<Db>,
    spec: QuerySpec,
    st: Option<OlapState>,
}

/// Post-`setup` shared state.
struct OlapState {
    probe_region: RegionId,
    join_regions: Vec<(RegionId, RegionId, u64)>,
    group_region: RegionId,
    group_bytes: u64,
    joins: Arc<Vec<JoinState>>,
    global_agg: Arc<Mutex<HashMap<u64, f64>>>,
    rows_out: Arc<AtomicU64>,
}

impl OlapScenario {
    pub fn new(db: Arc<Db>, spec: QuerySpec) -> Self {
        Self { db, spec, st: None }
    }

    /// Rows passing all predicates; valid after the run.
    pub fn rows_out(&self) -> u64 {
        self.st
            .as_ref()
            .map_or(0, |st| st.rows_out.load(Ordering::Relaxed))
    }

    /// Assemble the legacy result type from a finished run.
    pub fn into_result(self, report: RunReport) -> QueryResult {
        let (agg_sum, groups_touched) = self
            .st
            .as_ref()
            .map(|st| {
                let agg = st.global_agg.lock().unwrap();
                (agg.values().sum(), agg.len())
            })
            .unwrap_or((0.0, 0));
        QueryResult {
            id: self.spec.id,
            rows_out: self.rows_out(),
            agg_sum,
            groups_touched,
            report,
        }
    }
}

impl Scenario for OlapScenario {
    fn name(&self) -> &'static str {
        "olap"
    }

    fn setup(&mut self, machine: &mut Machine, _tasks: usize) {
        let (db, spec) = (&self.db, &self.spec);
        // Regions: one per scanned table + per-join hash + group state.
        let probe_region = machine.alloc(
            "probe-table",
            db.table_bytes(spec.probe),
            Placement::Interleave,
        );
        let join_regions: Vec<_> = spec
            .joins
            .iter()
            .enumerate()
            .map(|(i, jn)| {
                let build_rows = (db.rows(jn.build) as f64 * jn.selectivity).ceil() as u64;
                (
                    machine.alloc(
                        &format!("build-scan-{i}"),
                        db.table_bytes(jn.build),
                        Placement::Interleave,
                    ),
                    machine.alloc(
                        &format!("join-hash-{i}"),
                        (build_rows * 16).max(64),
                        Placement::Interleave,
                    ),
                    (build_rows * 16).max(64),
                )
            })
            .collect();
        let groups = scaled_groups(spec, db);
        let group_bytes = (groups as u64 * 16).max(64);
        let group_region = machine.alloc("group-state", group_bytes, Placement::Interleave);

        self.st = Some(OlapState {
            probe_region,
            join_regions,
            group_region,
            group_bytes,
            joins: Arc::new(spec.joins.iter().map(|_| JoinState::new()).collect()),
            global_agg: Arc::new(Mutex::new(HashMap::new())),
            rows_out: Arc::new(AtomicU64::new(0)),
        });
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        let st = self.st.as_ref().expect("setup() before spawn()");
        let n_joins = self.spec.joins.len();
        // Phases: n_joins build steps, 1 probe step, 1 merge step.
        let total_steps = (n_joins + 2) as u64;
        let salt = self.spec.id as u64 * 0x1234_5678;
        let probe_region = st.probe_region;
        let group_region = st.group_region;
        let group_bytes = st.group_bytes;
        let db = self.db.clone();
        let joins = st.joins.clone();
        let global_agg = st.global_agg.clone();
        let rows_out = st.rows_out.clone();
        let spec = self.spec.clone();
        let join_regions = st.join_regions.clone();
        // Per-task aggregation state, merged in the final phase.
        let mut local_agg: HashMap<u64, f64> = HashMap::new();
        let mut local_rows = 0u64;
        Box::new(StateTask::new(move |ctx, step| {
            if step >= total_steps {
                return Step::Done;
            }
            let phase = step as usize;
            if phase < n_joins {
                // --- build phase for join `phase`.
                let jn = &spec.joins[phase];
                let rows = db.rows(jn.build);
                let per = rows.div_ceil(ctx.group_size);
                let lo = (rank * per).min(rows);
                let hi = ((rank + 1) * per).min(rows);
                let mut inserted = 0u64;
                for r in lo..hi {
                    if keep(r as u64, salt ^ (phase as u64) << 8, jn.selectivity) {
                        joins[phase].insert(build_key(&db, jn.build, r));
                        inserted += 1;
                    }
                }
                let (scan_r, hash_r, hash_bytes) = join_regions[phase];
                ctx.seq_read(scan_r, ((hi - lo) as u64) * db.row_bytes(jn.build));
                if inserted > 0 {
                    ctx.rand_write(hash_r, inserted, hash_bytes);
                }
                ctx.compute_flops(2 * (hi - lo) as u64);
                Step::Barrier
            } else if phase == n_joins {
                // --- probe phase over the fact table.
                let rows = db.rows(spec.probe);
                let per = rows.div_ceil(ctx.group_size);
                let lo = (rank * per).min(rows);
                let hi = ((rank + 1) * per).min(rows);
                let mut probes = 0u64;
                for r in lo..hi {
                    if !keep(r as u64, salt, spec.probe_selectivity) {
                        continue;
                    }
                    let mut pass = true;
                    for (ji, jn) in spec.joins.iter().enumerate() {
                        probes += 1;
                        let k = probe_key(&db, spec.probe, jn.key, r);
                        if !joins[ji].contains(k) {
                            pass = false;
                            break;
                        }
                    }
                    if pass {
                        local_rows += 1;
                        let groups = scaled_groups(&spec, &db) as u64;
                        let g = (r as u64).wrapping_mul(0x9E37_79B9) % groups;
                        *local_agg.entry(g).or_insert(0.0) += agg_value(&db, spec.probe, r);
                    }
                }
                ctx.seq_read(probe_region, ((hi - lo) as u64) * db.row_bytes(spec.probe));
                for (ji, _) in spec.joins.iter().enumerate() {
                    let (_, hash_r, hash_bytes) = join_regions[ji];
                    let ops = (probes / n_joins.max(1) as u64).max(1);
                    ctx.rand_read(hash_r, ops, hash_bytes);
                }
                if local_rows > 0 {
                    ctx.rand_write(group_region, local_rows.min(1 << 20), group_bytes);
                }
                ctx.compute_flops(spec.flops_per_row * (hi - lo) as u64);
                Step::Barrier
            } else {
                // --- merge phase.
                let mut g = global_agg.lock().unwrap();
                for (k, v) in local_agg.drain() {
                    *g.entry(k).or_insert(0.0) += v;
                }
                rows_out.fetch_add(local_rows, Ordering::Relaxed);
                ctx.seq_write(group_region, group_bytes / ctx.group_size as u64);
                Step::Done
            }
        }))
    }

    fn verify(&self) {
        let (rows_ref, sum_ref) = run_query_serial(&self.db, &self.spec);
        let st = self.st.as_ref().expect("run first");
        let agg_sum: f64 = st.global_agg.lock().unwrap().values().sum();
        assert_eq!(
            self.rows_out(),
            rows_ref,
            "Q{}: parallel row count diverges from the serial oracle",
            self.spec.id
        );
        assert!(
            (agg_sum - sum_ref).abs() <= sum_ref.abs() * 1e-9 + 1e-6,
            "Q{}: aggregate {} vs serial {}",
            self.spec.id,
            agg_sum,
            sum_ref
        );
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        let scanned = self.db.rows(self.spec.probe) as f64;
        ScenarioMetrics::new(scanned, "rows")
            .with("rows_out", self.rows_out() as f64)
            .with("rows_per_s", report.throughput(scanned))
    }
}

/// Execute one query under `policy` with `cores` workers.
pub fn run_query(
    topo: &Topology,
    policy: Box<dyn Policy>,
    cores: usize,
    db: Arc<Db>,
    spec: &QuerySpec,
) -> QueryResult {
    let mut s = OlapScenario::new(db, spec.clone());
    let run = Driver::new(topo, policy, cores).run(&mut s);
    s.into_result(run.report)
}

/// Serial reference: same semantics, single-threaded (correctness oracle
/// for the parallel engine).
pub fn run_query_serial(db: &Db, spec: &QuerySpec) -> (u64, f64) {
    let salt = spec.id as u64 * 0x1234_5678;
    let mut sets: Vec<HashSet<u64>> = Vec::new();
    for (ji, jn) in spec.joins.iter().enumerate() {
        let mut s = HashSet::new();
        for r in 0..db.rows(jn.build) {
            if keep(r as u64, salt ^ (ji as u64) << 8, jn.selectivity) {
                s.insert(build_key(db, jn.build, r));
            }
        }
        sets.push(s);
    }
    let mut rows_out = 0u64;
    let mut sum = 0.0f64;
    for r in 0..db.rows(spec.probe) {
        if !keep(r as u64, salt, spec.probe_selectivity) {
            continue;
        }
        let mut pass = true;
        for (ji, jn) in spec.joins.iter().enumerate() {
            let k = probe_key(db, spec.probe, jn.key, r);
            if !sets[ji].contains(&k) {
                pass = false;
                break;
            }
        }
        if pass {
            rows_out += 1;
            sum += agg_value(db, spec.probe, r);
        }
    }
    (rows_out, sum)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{DistributedCachePolicy, LocalCachePolicy};
    use crate::workloads::olap::queries::all_queries;

    fn small_db() -> Arc<Db> {
        Arc::new(Db::generate(0.002, 99))
    }

    fn topo() -> Topology {
        Topology::milan_1s()
    }

    #[test]
    fn q6_parallel_matches_serial() {
        let db = small_db();
        let q6 = &all_queries()[5];
        let (rows, sum) = run_query_serial(&db, q6);
        let res = run_query(&topo(), Box::new(LocalCachePolicy), 8, db.clone(), q6);
        assert_eq!(res.rows_out, rows);
        assert!((res.agg_sum - sum).abs() < sum.abs() * 1e-9 + 1e-6);
    }

    #[test]
    fn q3_parallel_matches_serial() {
        let db = small_db();
        let q3 = &all_queries()[2];
        let (rows, sum) = run_query_serial(&db, q3);
        let res = run_query(&topo(), Box::new(LocalCachePolicy), 8, db.clone(), q3);
        assert_eq!(res.rows_out, rows);
        assert!((res.agg_sum - sum).abs() < sum.abs() * 1e-9 + 1e-6);
    }

    #[test]
    fn selectivities_hold_roughly() {
        let db = small_db();
        let q6 = &all_queries()[5];
        let (rows, _) = run_query_serial(&db, q6);
        let expect = db.rows(Table::Lineitem) as f64 * q6.probe_selectivity;
        assert!(
            (rows as f64) < expect * 2.0 + 50.0 && (rows as f64) > expect * 0.5 - 50.0,
            "rows={rows} expect={expect}"
        );
    }

    #[test]
    fn all_22_execute_without_panic() {
        let db = Arc::new(Db::generate(0.0005, 5));
        for q in all_queries() {
            let res = run_query(&topo(), Box::new(LocalCachePolicy), 4, db.clone(), &q);
            assert!(res.report.makespan_ns > 0, "Q{}", q.id);
        }
    }

    #[test]
    fn join_heavy_query_benefits_from_spread() {
        // Q9-style: big hash tables => distributed beats local when the
        // hash state exceeds one chiplet's L3 (scaled caches).
        let t = Topology::milan_1s().scale_caches(1.0 / 256.0); // 128 KiB/chiplet
        let db = Arc::new(Db::generate(0.01, 7));
        let q9 = &all_queries()[8];
        let local = run_query(&t, Box::new(LocalCachePolicy), 8, db.clone(), q9);
        let dist = run_query(&t, Box::new(DistributedCachePolicy), 8, db.clone(), q9);
        assert!(
            dist.report.makespan_ns < local.report.makespan_ns,
            "dist={} local={}",
            dist.report.makespan_ns,
            local.report.makespan_ns
        );
    }

    #[test]
    fn keep_is_deterministic_and_calibrated() {
        let n = 100_000u64;
        let hits = (0..n).filter(|&r| keep(r, 42, 0.25)).count() as f64;
        assert!((hits / n as f64 - 0.25).abs() < 0.01);
        assert!(keep(7, 1, 1.0));
    }
}
