//! TPC-H-shaped columnar data generator.
//!
//! Column layouts, key relationships (orderkey/partkey/suppkey FKs), value
//! distributions and date ranges follow the TPC-H spec; text columns are
//! replaced by small integer dictionaries (the engine never touches
//! strings on the hot path, matching columnar execution). `sf = 1.0`
//! means 6 M lineitem rows; the reproduction defaults to `sf = 0.05–0.1`.

use crate::util::prng::Rng;

/// Days since 1992-01-01; the TPC-H date domain spans 7 years.
pub const DATE_MAX: u16 = 2556;

#[derive(Clone, Debug, Default)]
pub struct Lineitem {
    pub orderkey: Vec<u64>,
    pub partkey: Vec<u32>,
    pub suppkey: Vec<u32>,
    pub quantity: Vec<f32>,
    pub extendedprice: Vec<f32>,
    pub discount: Vec<f32>,
    pub tax: Vec<f32>,
    pub returnflag: Vec<u8>,
    pub linestatus: Vec<u8>,
    pub shipdate: Vec<u16>,
    pub commitdate: Vec<u16>,
    pub receiptdate: Vec<u16>,
    pub shipmode: Vec<u8>,
}

#[derive(Clone, Debug, Default)]
pub struct Orders {
    pub orderkey: Vec<u64>,
    pub custkey: Vec<u32>,
    pub orderdate: Vec<u16>,
    pub orderpriority: Vec<u8>,
    pub totalprice: Vec<f32>,
}

#[derive(Clone, Debug, Default)]
pub struct Customer {
    pub custkey: Vec<u32>,
    pub nationkey: Vec<u8>,
    pub mktsegment: Vec<u8>,
    pub acctbal: Vec<f32>,
}

#[derive(Clone, Debug, Default)]
pub struct Part {
    pub partkey: Vec<u32>,
    pub brand: Vec<u8>,
    pub container: Vec<u8>,
    pub size: Vec<u8>,
}

#[derive(Clone, Debug, Default)]
pub struct Supplier {
    pub suppkey: Vec<u32>,
    pub nationkey: Vec<u8>,
}

/// The database.
#[derive(Clone, Debug)]
pub struct Db {
    pub sf: f64,
    pub lineitem: Lineitem,
    pub orders: Orders,
    pub customer: Customer,
    pub part: Part,
    pub supplier: Supplier,
}

/// Table identifiers for region/cost bookkeeping.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Table {
    Lineitem,
    Orders,
    Customer,
    Part,
    Supplier,
}

impl Db {
    /// Generate a scaled TPC-H database (deterministic from `seed`).
    pub fn generate(sf: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let n_orders = ((1_500_000.0 * sf) as usize).max(64);
        let n_li = n_orders * 4; // avg 4 lineitems per order
        let n_cust = ((150_000.0 * sf) as usize).max(16);
        let n_part = ((200_000.0 * sf) as usize).max(16);
        let n_supp = ((10_000.0 * sf) as usize).max(8);

        let mut orders = Orders::default();
        for ok in 0..n_orders as u64 {
            orders.orderkey.push(ok);
            orders.custkey.push(rng.gen_range(n_cust as u64) as u32);
            orders.orderdate.push(rng.gen_range(DATE_MAX as u64) as u16);
            orders.orderpriority.push(rng.gen_range(5) as u8);
            orders.totalprice.push(1000.0 + 100_000.0 * rng.gen_f32());
        }

        let mut li = Lineitem::default();
        for _ in 0..n_li {
            let o = rng.gen_range(n_orders as u64);
            li.orderkey.push(o);
            li.partkey.push(rng.gen_range(n_part as u64) as u32);
            li.suppkey.push(rng.gen_range(n_supp as u64) as u32);
            li.quantity.push(1.0 + (rng.gen_range(50)) as f32);
            li.extendedprice.push(900.0 + 104_000.0 * rng.gen_f32());
            li.discount.push((rng.gen_range(11)) as f32 / 100.0);
            li.tax.push((rng.gen_range(9)) as f32 / 100.0);
            let od = orders.orderdate[o as usize];
            let ship = od.saturating_add(1 + rng.gen_range(121) as u16).min(DATE_MAX);
            li.shipdate.push(ship);
            li.commitdate
                .push(ship.saturating_add(rng.gen_range(60) as u16).min(DATE_MAX));
            li.receiptdate
                .push(ship.saturating_add(1 + rng.gen_range(30) as u16).min(DATE_MAX));
            li.returnflag.push(rng.gen_range(3) as u8);
            li.linestatus.push(rng.gen_range(2) as u8);
            li.shipmode.push(rng.gen_range(7) as u8);
        }

        let mut customer = Customer::default();
        for ck in 0..n_cust as u32 {
            customer.custkey.push(ck);
            customer.nationkey.push(rng.gen_range(25) as u8);
            customer.mktsegment.push(rng.gen_range(5) as u8);
            customer.acctbal.push(-999.0 + 10_999.0 * rng.gen_f32());
        }

        let mut part = Part::default();
        for pk in 0..n_part as u32 {
            part.partkey.push(pk);
            part.brand.push(rng.gen_range(25) as u8);
            part.container.push(rng.gen_range(40) as u8);
            part.size.push(1 + rng.gen_range(50) as u8);
        }

        let mut supplier = Supplier::default();
        for sk in 0..n_supp as u32 {
            supplier.suppkey.push(sk);
            supplier.nationkey.push(rng.gen_range(25) as u8);
        }

        Self {
            sf,
            lineitem: li,
            orders,
            customer,
            part,
            supplier,
        }
    }

    pub fn rows(&self, t: Table) -> usize {
        match t {
            Table::Lineitem => self.lineitem.orderkey.len(),
            Table::Orders => self.orders.orderkey.len(),
            Table::Customer => self.customer.custkey.len(),
            Table::Part => self.part.partkey.len(),
            Table::Supplier => self.supplier.suppkey.len(),
        }
    }

    /// Approximate bytes per row touched by a typical query on `t`
    /// (the columnar scan footprint).
    pub fn row_bytes(&self, t: Table) -> u64 {
        match t {
            Table::Lineitem => 40,
            Table::Orders => 20,
            Table::Customer => 12,
            Table::Part => 8,
            Table::Supplier => 5,
        }
    }

    pub fn table_bytes(&self, t: Table) -> u64 {
        self.rows(t) as u64 * self.row_bytes(t)
    }

    pub fn total_bytes(&self) -> u64 {
        [
            Table::Lineitem,
            Table::Orders,
            Table::Customer,
            Table::Part,
            Table::Supplier,
        ]
        .iter()
        .map(|&t| self.table_bytes(t))
        .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let a = Db::generate(0.001, 1);
        let b = Db::generate(0.001, 1);
        assert_eq!(a.lineitem.orderkey, b.lineitem.orderkey);
        assert_eq!(a.orders.custkey, b.orders.custkey);
    }

    #[test]
    fn row_ratios_follow_tpch() {
        let db = Db::generate(0.01, 2);
        let li = db.rows(Table::Lineitem);
        let ord = db.rows(Table::Orders);
        assert_eq!(li, 4 * ord);
        assert!(db.rows(Table::Customer) < ord);
    }

    #[test]
    fn fk_integrity() {
        let db = Db::generate(0.002, 3);
        let n_ord = db.rows(Table::Orders) as u64;
        let n_part = db.rows(Table::Part) as u32;
        let n_supp = db.rows(Table::Supplier) as u32;
        let n_cust = db.rows(Table::Customer) as u32;
        assert!(db.lineitem.orderkey.iter().all(|&k| k < n_ord));
        assert!(db.lineitem.partkey.iter().all(|&k| k < n_part));
        assert!(db.lineitem.suppkey.iter().all(|&k| k < n_supp));
        assert!(db.orders.custkey.iter().all(|&k| k < n_cust));
    }

    #[test]
    fn value_domains() {
        let db = Db::generate(0.002, 4);
        assert!(db.lineitem.discount.iter().all(|&d| (0.0..=0.10).contains(&d)));
        assert!(db.lineitem.quantity.iter().all(|&q| (1.0..=50.0).contains(&q)));
        assert!(db.lineitem.shipdate.iter().all(|&d| d <= DATE_MAX));
        // Shipdate after orderdate.
        for i in 0..db.rows(Table::Lineitem) {
            let od = db.orders.orderdate[db.lineitem.orderkey[i] as usize];
            assert!(db.lineitem.shipdate[i] >= od);
        }
    }

    #[test]
    fn bytes_scale_with_sf() {
        let small = Db::generate(0.001, 5).total_bytes();
        let big = Db::generate(0.004, 5).total_bytes();
        assert!(big > small * 3);
    }
}
