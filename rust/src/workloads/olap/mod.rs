//! OLAP mini-engine: TPC-H-shaped columnar analytics on ARCAS tasks
//! (§5.5, Fig. 12). The DuckDB substitute.
//!
//! - [`data`] — scaled TPC-H data generator (columnar, FK-consistent),
//! - [`queries`] — all 22 query shapes as operator specs,
//! - [`exec`] — morsel-parallel build/probe/merge execution with real
//!   hash joins and aggregation, plus a serial oracle.
pub mod data;
pub mod queries;
pub mod exec;

pub use data::{Db, Table};
pub use exec::{run_query, run_query_serial, OlapScenario, QueryResult};
pub use queries::{all_queries, QuerySpec};
