//! The 22 TPC-H query shapes as operator specs.
//!
//! Each query is reduced to the operator mix that drives its memory
//! behaviour (the property Fig. 12 exercises): which tables are scanned,
//! how selective the filters are, which hash joins feed the probe
//! pipeline over the fact table, and how large the group-by state is.
//! Selectivities come from the TPC-H spec's predicate definitions.
//! Aggregates are computed for real over the generated columns — the
//! simplification is in predicate shape, not in execution.

use super::data::Table;

/// One hash join feeding the probe pipeline.
#[derive(Clone, Copy, Debug)]
pub struct JoinSpec {
    /// Build side.
    pub build: Table,
    /// Which probe-side key column to match on.
    pub key: KeyCol,
    /// Fraction of the build side that passes its filters.
    pub selectivity: f64,
}

/// Probe-side key columns (lineitem FKs + orders custkey).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KeyCol {
    Orderkey,
    Partkey,
    Suppkey,
    Custkey,
}

/// A TPC-H-shaped query plan.
#[derive(Clone, Debug)]
pub struct QuerySpec {
    pub id: usize,
    pub name: &'static str,
    /// Table streamed through the probe pipeline.
    pub probe: Table,
    /// Selectivity of the probe-side filters (e.g. shipdate ranges).
    pub probe_selectivity: f64,
    /// Hash joins (build order = vector order).
    pub joins: Vec<JoinSpec>,
    /// Number of distinct groups in the final aggregation.
    pub groups: usize,
    /// Extra per-row arithmetic weight (expressions, case-when chains).
    pub flops_per_row: u64,
}

fn j(build: Table, key: KeyCol, selectivity: f64) -> JoinSpec {
    JoinSpec {
        build,
        key,
        selectivity,
    }
}

/// All 22 query shapes. Group counts are for SF≈1 and are scaled by the
/// engine with the database's actual row counts.
pub fn all_queries() -> Vec<QuerySpec> {
    use KeyCol::*;
    use Table::*;
    vec![
        QuerySpec { id: 1, name: "Q1 pricing summary", probe: Lineitem, probe_selectivity: 0.986, joins: vec![], groups: 4, flops_per_row: 8 },
        QuerySpec { id: 2, name: "Q2 min cost supplier", probe: Lineitem, probe_selectivity: 0.02, joins: vec![j(Part, Partkey, 0.004), j(Supplier, Suppkey, 1.0)], groups: 100, flops_per_row: 2 },
        QuerySpec { id: 3, name: "Q3 shipping priority", probe: Lineitem, probe_selectivity: 0.54, joins: vec![j(Orders, Orderkey, 0.24)], groups: 1_150_000, flops_per_row: 3 },
        QuerySpec { id: 4, name: "Q4 order priority", probe: Lineitem, probe_selectivity: 0.63, joins: vec![j(Orders, Orderkey, 0.038)], groups: 5, flops_per_row: 1 },
        QuerySpec { id: 5, name: "Q5 local supplier volume", probe: Lineitem, probe_selectivity: 1.0, joins: vec![j(Orders, Orderkey, 0.15), j(Supplier, Suppkey, 0.2), j(Customer, Custkey, 0.2)], groups: 5, flops_per_row: 3 },
        QuerySpec { id: 6, name: "Q6 forecast revenue", probe: Lineitem, probe_selectivity: 0.019, joins: vec![], groups: 1, flops_per_row: 2 },
        QuerySpec { id: 7, name: "Q7 volume shipping", probe: Lineitem, probe_selectivity: 0.29, joins: vec![j(Orders, Orderkey, 1.0), j(Supplier, Suppkey, 0.04), j(Customer, Custkey, 0.04)], groups: 4, flops_per_row: 3 },
        QuerySpec { id: 8, name: "Q8 market share", probe: Lineitem, probe_selectivity: 1.0, joins: vec![j(Part, Partkey, 0.007), j(Orders, Orderkey, 0.29), j(Customer, Custkey, 0.2)], groups: 2, flops_per_row: 4 },
        QuerySpec { id: 9, name: "Q9 product profit", probe: Lineitem, probe_selectivity: 1.0, joins: vec![j(Part, Partkey, 0.055), j(Orders, Orderkey, 1.0), j(Supplier, Suppkey, 1.0)], groups: 175, flops_per_row: 4 },
        QuerySpec { id: 10, name: "Q10 returned items", probe: Lineitem, probe_selectivity: 0.33, joins: vec![j(Orders, Orderkey, 0.031), j(Customer, Custkey, 1.0)], groups: 38_000, flops_per_row: 3 },
        QuerySpec { id: 11, name: "Q11 important stock", probe: Lineitem, probe_selectivity: 0.3, joins: vec![j(Supplier, Suppkey, 0.04)], groups: 30_000, flops_per_row: 2 },
        QuerySpec { id: 12, name: "Q12 shipping modes", probe: Lineitem, probe_selectivity: 0.0086, joins: vec![j(Orders, Orderkey, 1.0)], groups: 2, flops_per_row: 3 },
        QuerySpec { id: 13, name: "Q13 customer distribution", probe: Orders, probe_selectivity: 0.98, joins: vec![j(Customer, Custkey, 1.0)], groups: 42, flops_per_row: 1 },
        QuerySpec { id: 14, name: "Q14 promotion effect", probe: Lineitem, probe_selectivity: 0.0125, joins: vec![j(Part, Partkey, 1.0)], groups: 1, flops_per_row: 4 },
        QuerySpec { id: 15, name: "Q15 top supplier", probe: Lineitem, probe_selectivity: 0.0375, joins: vec![j(Supplier, Suppkey, 1.0)], groups: 10_000, flops_per_row: 2 },
        QuerySpec { id: 16, name: "Q16 part/supplier rel", probe: Lineitem, probe_selectivity: 0.2, joins: vec![j(Part, Partkey, 0.14), j(Supplier, Suppkey, 0.99)], groups: 18_000, flops_per_row: 1 },
        QuerySpec { id: 17, name: "Q17 small-qty revenue", probe: Lineitem, probe_selectivity: 1.0, joins: vec![j(Part, Partkey, 0.001)], groups: 200, flops_per_row: 3 },
        QuerySpec { id: 18, name: "Q18 large volume customer", probe: Lineitem, probe_selectivity: 1.0, joins: vec![j(Orders, Orderkey, 1.0), j(Customer, Custkey, 1.0)], groups: 1_500_000, flops_per_row: 2 },
        QuerySpec { id: 19, name: "Q19 discounted revenue", probe: Lineitem, probe_selectivity: 0.02, joins: vec![j(Part, Partkey, 0.002)], groups: 1, flops_per_row: 6 },
        QuerySpec { id: 20, name: "Q20 potential promotion", probe: Lineitem, probe_selectivity: 0.0375, joins: vec![j(Part, Partkey, 0.011), j(Supplier, Suppkey, 1.0)], groups: 400, flops_per_row: 2 },
        QuerySpec { id: 21, name: "Q21 late suppliers", probe: Lineitem, probe_selectivity: 0.5, joins: vec![j(Orders, Orderkey, 0.49), j(Supplier, Suppkey, 0.04), j(Orders, Orderkey, 0.5)], groups: 10_000, flops_per_row: 4 },
        QuerySpec { id: 22, name: "Q22 global sales opp", probe: Orders, probe_selectivity: 1.0, joins: vec![j(Customer, Custkey, 0.25)], groups: 7, flops_per_row: 2 },
    ]
}

impl QuerySpec {
    /// Is this a join-heavy query (the class the paper says benefits most
    /// from spreading — Q3, Q4, Q5, Q7, Q9, Q10, Q21)?
    pub fn join_heavy(&self) -> bool {
        self.joins
            .iter()
            .any(|jn| matches!(jn.build, Table::Orders) && jn.selectivity > 0.1)
            || self.joins.len() >= 3
    }

    /// Small-working-set query (Q1, Q2, Q6, Q11 class)?
    pub fn small_working_set(&self, li_rows: usize) -> bool {
        let probe_rows = self.probe_selectivity * li_rows as f64;
        self.joins.iter().map(|jn| jn.selectivity).sum::<f64>() < 0.05
            || probe_rows < li_rows as f64 * 0.05
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twenty_two_queries() {
        let qs = all_queries();
        assert_eq!(qs.len(), 22);
        for (i, q) in qs.iter().enumerate() {
            assert_eq!(q.id, i + 1);
            assert!(q.groups >= 1);
            assert!((0.0..=1.0).contains(&q.probe_selectivity));
            for jn in &q.joins {
                assert!((0.0..=1.0).contains(&jn.selectivity));
            }
        }
    }

    #[test]
    fn classification_matches_paper_examples() {
        let qs = all_queries();
        // Paper: Q3, Q5, Q7, Q9, Q21 are join-heavy winners.
        for id in [3, 5, 7, 9, 21] {
            assert!(qs[id - 1].join_heavy(), "Q{id} should be join-heavy");
        }
        // Paper: Q1, Q6 have small working sets / no joins.
        assert!(qs[0].small_working_set(6_000_000) || qs[0].joins.is_empty());
        assert!(qs[5].small_working_set(6_000_000));
    }

    #[test]
    fn key_columns_match_tables() {
        // Sanity: orderkey joins build Orders, partkey builds Part, etc.
        for q in all_queries() {
            for jn in &q.joins {
                match jn.key {
                    KeyCol::Orderkey => assert_eq!(jn.build, Table::Orders),
                    KeyCol::Partkey => assert_eq!(jn.build, Table::Part),
                    KeyCol::Suppkey => assert_eq!(jn.build, Table::Supplier),
                    KeyCol::Custkey => assert_eq!(jn.build, Table::Customer),
                }
            }
        }
    }
}
