//! Evaluation workloads (paper §5), plus multi-tenant mixes beyond it.
pub mod graph;
pub mod streamcluster;
pub mod sgd;
pub mod olap;
pub mod oltp;
pub mod mixed;
