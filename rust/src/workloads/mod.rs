//! Evaluation workloads (paper §5).
pub mod graph;
pub mod streamcluster;
pub mod sgd;
pub mod olap;
pub mod oltp;
