//! Evaluation workloads (paper §5), plus multi-tenant mixes and the
//! trace-replay request-serving story beyond it.
pub mod graph;
pub mod streamcluster;
pub mod sgd;
pub mod olap;
pub mod oltp;
pub mod mixed;
pub mod phaseshift;
pub mod serve;
