//! Statistical analytics: SGD for logistic regression on a
//! DimmWitted-style engine (§5.4.2, Fig. 10, Fig. 11).
//!
//! The engine supports DimmWitted's three native model-replication
//! strategies (per-core, per-NUMA-node, per-machine) plus the
//! ARCAS-managed variant; the std::async baseline is the same sharding
//! run under [`crate::policy::OsAsyncPolicy`] with task-per-shard
//! explosion (the paper counts 641 threads on 32 cores).
//!
//! The numeric hot spot — minibatch logistic loss + gradient — is
//! abstracted behind [`GradEngine`]: [`RustGrad`] is the portable
//! implementation, and `runtime::PjrtGrad` (L2/L1 path) runs the AOT
//! JAX/Pallas artifact through PJRT with identical semantics.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::engine::{Driver, Scenario, ScenarioMetrics};
use crate::mem::{Placement, RegionId};
use crate::policy::Policy;
use crate::sched::RunReport;
use crate::sim::Machine;
use crate::task::{Coroutine, StateTask, Step};
use crate::topology::Topology;
use crate::util::prng::Rng;

/// SGD configuration.
#[derive(Clone, Debug)]
pub struct SgdConfig {
    pub n_samples: usize,
    pub n_features: usize,
    pub minibatch: usize,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
}

impl SgdConfig {
    pub fn tiny() -> Self {
        Self {
            n_samples: 512,
            n_features: 64,
            minibatch: 64,
            epochs: 8,
            lr: 4.0,
            seed: 13,
        }
    }

    /// Paper-shaped (10,000 × 8,192 ≈ 320 MB f32) scaled by `scale`.
    pub fn bench(scale: f64) -> Self {
        Self {
            n_samples: (10_000.0 * scale).max(64.0) as usize,
            n_features: (8_192.0 * scale.sqrt()).max(64.0) as usize,
            minibatch: 128,
            epochs: 3,
            lr: 0.2,
            seed: 77,
        }
    }

    pub fn data_bytes(&self) -> u64 {
        (self.n_samples * self.n_features * 4) as u64
    }
}

/// Synthetic linearly-separable-ish dataset.
pub struct SgdData {
    pub x: Arc<Vec<f32>>,
    pub y: Arc<Vec<f32>>,
    pub w_true: Vec<f32>,
}

pub fn generate_data(cfg: &SgdConfig) -> SgdData {
    let mut rng = Rng::new(cfg.seed);
    let nf = cfg.n_features;
    let w_true: Vec<f32> = (0..nf).map(|_| rng.gen_normal() as f32).collect();
    let mut x = Vec::with_capacity(cfg.n_samples * nf);
    let mut y = Vec::with_capacity(cfg.n_samples);
    for _ in 0..cfg.n_samples {
        let mut dot = 0.0f32;
        for f in 0..nf {
            let v = rng.gen_normal() as f32 / (nf as f32).sqrt();
            dot += v * w_true[f];
            x.push(v);
        }
        y.push(if dot > 0.0 { 1.0 } else { 0.0 });
    }
    SgdData {
        x: Arc::new(x),
        y: Arc::new(y),
        w_true,
    }
}

/// The numeric hot spot: minibatch logistic loss + gradient.
pub trait GradEngine: Send + Sync {
    /// `x`: `batch × nf` row-major; returns (mean loss, gradient[nf]).
    fn loss_grad(&self, x: &[f32], y: &[f32], w: &[f32], nf: usize) -> (f64, Vec<f32>);

    fn name(&self) -> &'static str {
        "rust"
    }
}

/// Portable pure-Rust engine (and the oracle the PJRT path is checked
/// against).
pub struct RustGrad;

impl GradEngine for RustGrad {
    fn loss_grad(&self, x: &[f32], y: &[f32], w: &[f32], nf: usize) -> (f64, Vec<f32>) {
        let batch = y.len();
        let mut grad = vec![0.0f32; nf];
        let mut loss = 0.0f64;
        for i in 0..batch {
            let row = &x[i * nf..(i + 1) * nf];
            let mut z = 0.0f32;
            for f in 0..nf {
                z += row[f] * w[f];
            }
            let p = 1.0 / (1.0 + (-z).exp());
            let eps = 1e-7f32;
            let pc = p.clamp(eps, 1.0 - eps);
            loss -= (y[i] * pc.ln() + (1.0 - y[i]) * (1.0 - pc).ln()) as f64;
            let err = p - y[i];
            for f in 0..nf {
                grad[f] += err * row[f];
            }
        }
        let inv = 1.0 / batch as f32;
        grad.iter_mut().for_each(|g| *g *= inv);
        (loss / batch as f64, grad)
    }
}

/// DimmWitted model-replication strategies (§5.4.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DwStrategy {
    /// One model replica per core/task; averaged per epoch.
    PerCore,
    /// One replica per NUMA node (shared within the node).
    PerNode,
    /// A single machine-wide model (maximal sharing/contention).
    PerMachine,
}

/// What Fig. 10 measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SgdMode {
    /// Forward pass only (Fig. 10a, "logistic loss").
    Loss,
    /// Forward + gradient + model update (Fig. 10b).
    Grad,
}

/// Result of an SGD run.
#[derive(Clone, Debug)]
pub struct SgdRun {
    pub report: RunReport,
    pub loss_trace: Vec<f64>,
    pub final_loss: f64,
    pub bytes_processed: u64,
}

impl SgdRun {
    /// The paper's throughput metric: GB/s of training data streamed.
    pub fn gbps(&self) -> f64 {
        self.bytes_processed as f64 / self.report.makespan_ns.max(1) as f64
    }
}

struct ModelStore {
    /// One weight vector per replica.
    replicas: Vec<Mutex<Vec<f32>>>,
    /// Task rank → replica index.
    assign: Vec<usize>,
    regions: Vec<RegionId>,
}

/// DimmWitted-style SGD as a [`Scenario`].
pub struct SgdScenario {
    cfg: SgdConfig,
    x: Arc<Vec<f32>>,
    y: Arc<Vec<f32>>,
    strategy: DwStrategy,
    mode: SgdMode,
    engine: Arc<dyn GradEngine>,
    st: Option<SgdState>,
}

/// Post-`setup` shared state and derived schedule constants.
struct SgdState {
    shard_regions: Vec<RegionId>,
    model: Arc<ModelStore>,
    epoch_loss: Arc<Vec<AtomicU64>>,
    per_task: usize,
    mb: usize,
    batches_per_epoch: usize,
    steps_per_epoch: u64,
    total_steps: u64,
    model_bytes: u64,
}

impl SgdScenario {
    pub fn new(
        cfg: SgdConfig,
        data: &SgdData,
        strategy: DwStrategy,
        mode: SgdMode,
        engine: Arc<dyn GradEngine>,
    ) -> Self {
        Self {
            cfg,
            x: data.x.clone(),
            y: data.y.clone(),
            strategy,
            mode,
            engine,
            st: None,
        }
    }

    /// Per-epoch aggregated minibatch loss; valid after the run.
    pub fn loss_trace(&self) -> Vec<f64> {
        self.st
            .as_ref()
            .map(|st| {
                st.epoch_loss
                    .iter()
                    .map(|l| f64::from_bits(l.load(Ordering::Relaxed)))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Training bytes streamed (the paper's throughput numerator).
    pub fn bytes_processed(&self) -> u64 {
        self.cfg.data_bytes()
            * self.cfg.epochs as u64
            * if self.mode == SgdMode::Grad { 2 } else { 1 }
    }

    /// Assemble the legacy result type from a finished run.
    pub fn into_run(self, report: RunReport) -> SgdRun {
        let loss_trace = self.loss_trace();
        let final_loss = *loss_trace.last().unwrap_or(&0.0);
        SgdRun {
            bytes_processed: self.bytes_processed(),
            report,
            loss_trace,
            final_loss,
        }
    }
}

impl Scenario for SgdScenario {
    fn name(&self) -> &'static str {
        "sgd"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        let cfg = &self.cfg;
        let nf = cfg.n_features;
        let n = cfg.n_samples;
        let topo = machine.topo.clone();

        // Per-task shard regions (shards stream through L3 repeatedly
        // across epochs — the cacheable working set).
        let shard_bytes = cfg.data_bytes() / tasks as u64;
        let shard_regions: Vec<_> = (0..tasks)
            .map(|r| {
                let numa = topo.numa_of_core(r % topo.num_cores());
                machine.alloc(
                    &format!("sgd-shard-{r}"),
                    shard_bytes.max(64),
                    Placement::Bind(numa),
                )
            })
            .collect();

        // Model replicas per strategy.
        let strategy = self.strategy;
        let n_replicas = match strategy {
            DwStrategy::PerCore => tasks,
            DwStrategy::PerNode => topo.num_numa(),
            DwStrategy::PerMachine => 1,
        };
        let model_bytes = (nf * 4) as u64;
        let model = Arc::new(ModelStore {
            replicas: (0..n_replicas)
                .map(|_| Mutex::new(vec![0.0f32; nf]))
                .collect(),
            assign: (0..tasks)
                .map(|r| match strategy {
                    DwStrategy::PerCore => r,
                    DwStrategy::PerNode => topo.numa_of_core(r % topo.num_cores()),
                    DwStrategy::PerMachine => 0,
                })
                .collect(),
            regions: (0..n_replicas)
                .map(|i| {
                    let numa = match strategy {
                        DwStrategy::PerNode => i,
                        _ => 0,
                    };
                    machine.alloc(
                        &format!("sgd-model-{i}"),
                        model_bytes,
                        Placement::Bind(numa.min(topo.num_numa() - 1)),
                    )
                })
                .collect(),
        });

        let epoch_loss: Arc<Vec<AtomicU64>> =
            Arc::new((0..cfg.epochs).map(|_| AtomicU64::new(0)).collect());

        let per_task = n.div_ceil(tasks);
        let mb = cfg.minibatch.min(per_task.max(1));
        let batches_per_epoch = per_task.div_ceil(mb).max(1);
        // Steps: epochs × (batches + 1 sync step).
        let steps_per_epoch = batches_per_epoch as u64 + 1;
        let total_steps = cfg.epochs as u64 * steps_per_epoch;

        self.st = Some(SgdState {
            shard_regions,
            model,
            epoch_loss,
            per_task,
            mb,
            batches_per_epoch,
            steps_per_epoch,
            total_steps,
            model_bytes,
        });
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        let st = self.st.as_ref().expect("setup() before spawn()");
        let nf = self.cfg.n_features;
        let n = self.cfg.n_samples;
        let lr = self.cfg.lr;
        let epochs = self.cfg.epochs;
        let mode = self.mode;
        let per_task = st.per_task;
        let mb = st.mb;
        let batches_per_epoch = st.batches_per_epoch;
        let steps_per_epoch = st.steps_per_epoch;
        let total_steps = st.total_steps;
        let model_bytes = st.model_bytes;
        let x = self.x.clone();
        let y = self.y.clone();
        let model = st.model.clone();
        let engine = self.engine.clone();
        let epoch_loss = st.epoch_loss.clone();
        let shard_region = st.shard_regions[rank];
        Box::new(StateTask::new(move |ctx, step| {
            if step >= total_steps {
                return Step::Done;
            }
            let epoch = (step / steps_per_epoch) as usize;
            let sub = step % steps_per_epoch;
            let lo = (rank * per_task).min(n);
            let hi = ((rank + 1) * per_task).min(n);
            if sub < batches_per_epoch as u64 {
                // --- one minibatch.
                let b_lo = lo + (sub as usize) * mb;
                if b_lo >= hi {
                    return Step::Yield; // shard shorter than schedule
                }
                let b_hi = (b_lo + mb).min(hi);
                let bx = &x[b_lo * nf..b_hi * nf];
                let by = &y[b_lo..b_hi];
                let replica = model.assign[rank];
                let (loss, grad) = {
                    let w = model.replicas[replica].lock().unwrap();
                    engine.loss_grad(bx, by, &w, nf)
                };
                // Accumulate epoch loss.
                let slot = &epoch_loss[epoch.min(epochs - 1)];
                let mut cur = slot.load(Ordering::Relaxed);
                loop {
                    let new = (f64::from_bits(cur) + loss).to_bits();
                    match slot.compare_exchange_weak(
                        cur,
                        new,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    ) {
                        Ok(_) => break,
                        Err(c) => cur = c,
                    }
                }
                // --- model costs.
                let batch_bytes = ((b_hi - b_lo) * nf * 4) as u64;
                ctx.seq_read(shard_region, batch_bytes);
                let m_region = model.regions[replica];
                ctx.seq_read(m_region, model_bytes);
                ctx.compute_flops((2 * (b_hi - b_lo) * nf) as u64);
                if mode == SgdMode::Grad {
                    // Apply the update.
                    {
                        let mut w = model.replicas[replica].lock().unwrap();
                        for f in 0..nf {
                            w[f] -= lr * grad[f];
                        }
                    }
                    ctx.seq_write(m_region, model_bytes);
                    ctx.compute_flops((2 * (b_hi - b_lo) * nf) as u64);
                    // Shared replicas serialize their updates: every writer
                    // must pull the model's cache lines to exclusive state
                    // (one inter-chiplet transfer per line), and expected
                    // queue wait grows with the number of co-writers — the
                    // convoy that stops per-machine/per-node scaling in the
                    // paper's Fig. 10.
                    let sharers = ctx.group_size / model.replicas.len().max(1);
                    if sharers > 1 {
                        let lines = model_bytes / 64;
                        let xfer =
                            ctx.machine.topo.lat.inter_chiplet_near_ns as u64;
                        ctx.compute_ns(lines * xfer * (sharers as u64 - 1) / 4);
                    }
                }
                Step::Yield
            } else {
                // --- epoch sync: average per-core replicas (rank 0).
                if rank == 0 && mode == SgdMode::Grad && model.replicas.len() > 1 {
                    let k = model.replicas.len();
                    let mut avg = vec![0.0f32; nf];
                    for r in model.replicas.iter() {
                        let w = r.lock().unwrap();
                        for f in 0..nf {
                            avg[f] += w[f];
                        }
                    }
                    avg.iter_mut().for_each(|v| *v /= k as f32);
                    for r in model.replicas.iter() {
                        *r.lock().unwrap() = avg.clone();
                    }
                    // Reads every replica region + broadcast write.
                    for &reg in &model.regions {
                        ctx.seq_read(reg, model_bytes);
                        ctx.seq_write(reg, model_bytes);
                    }
                    ctx.compute_flops((k * nf) as u64);
                }
                if step + 1 >= total_steps {
                    Step::Done
                } else {
                    Step::Barrier
                }
            }
        }))
    }

    fn verify(&self) {
        let trace = self.loss_trace();
        assert!(!trace.is_empty(), "no epochs recorded");
        assert!(
            trace.iter().all(|l| l.is_finite()),
            "loss diverged: {trace:?}"
        );
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        let bytes = self.bytes_processed() as f64;
        ScenarioMetrics::new(bytes, "bytes")
            .with("gbps", bytes / report.makespan_ns.max(1) as f64)
            .with(
                "final_loss",
                self.loss_trace().last().copied().unwrap_or(0.0),
            )
    }
}

/// Run SGD with `tasks` workers under `policy`.
///
/// `tasks` may exceed the core count (the std::async configuration
/// explodes shards into OS threads); `engine` computes the actual math.
#[allow(clippy::too_many_arguments)]
pub fn run_sgd(
    topo: &Topology,
    policy: Box<dyn Policy>,
    tasks: usize,
    cfg: &SgdConfig,
    data: &SgdData,
    strategy: DwStrategy,
    mode: SgdMode,
    engine: Arc<dyn GradEngine>,
) -> SgdRun {
    let mut s = SgdScenario::new(cfg.clone(), data, strategy, mode, engine);
    let run = Driver::new(topo, policy, tasks).run(&mut s);
    s.into_run(run.report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{ArcasPolicy, OsAsyncPolicy, ShoalPolicy};

    fn topo() -> Topology {
        Topology::milan_1s()
    }

    #[test]
    fn data_is_deterministic_and_labeled() {
        let cfg = SgdConfig::tiny();
        let d = generate_data(&cfg);
        assert_eq!(d.x.len(), cfg.n_samples * cfg.n_features);
        assert_eq!(d.y.len(), cfg.n_samples);
        let pos = d.y.iter().filter(|&&v| v == 1.0).count();
        // Roughly balanced labels.
        assert!(pos > cfg.n_samples / 5 && pos < cfg.n_samples * 4 / 5);
    }

    #[test]
    fn rust_grad_matches_finite_differences() {
        let cfg = SgdConfig {
            n_samples: 8,
            n_features: 5,
            ..SgdConfig::tiny()
        };
        let d = generate_data(&cfg);
        let w: Vec<f32> = (0..5).map(|i| 0.1 * i as f32).collect();
        let eng = RustGrad;
        let (l0, g) = eng.loss_grad(&d.x[..8 * 5], &d.y[..8], &w, 5);
        let eps = 1e-3f32;
        for f in 0..5 {
            let mut wp = w.clone();
            wp[f] += eps;
            let (lp, _) = eng.loss_grad(&d.x[..8 * 5], &d.y[..8], &wp, 5);
            let fd = (lp - l0) / eps as f64;
            assert!(
                (fd - g[f] as f64).abs() < 2e-2,
                "f={f} fd={fd} g={}",
                g[f]
            );
        }
    }

    #[test]
    fn loss_decreases_over_epochs() {
        let cfg = SgdConfig::tiny();
        let d = generate_data(&cfg);
        let run = run_sgd(
            &topo(),
            Box::new(ShoalPolicy::new()),
            4,
            &cfg,
            &d,
            DwStrategy::PerCore,
            SgdMode::Grad,
            Arc::new(RustGrad),
        );
        assert!(
            run.loss_trace.last().unwrap() < &(run.loss_trace[0] * 0.9),
            "trace={:?}",
            run.loss_trace
        );
    }

    #[test]
    fn strategies_produce_different_contention() {
        // Tasks must sit on *different chiplets* for the shared-model
        // invalidation ping-pong to show; the model must also be large
        // enough to dominate the traffic.
        let cfg = SgdConfig {
            n_samples: 128,
            n_features: 16_384,
            minibatch: 4,
            epochs: 4,
            lr: 0.1,
            seed: 13,
        };
        let d = generate_data(&cfg);
        let per_core = run_sgd(
            &topo(),
            Box::new(crate::policy::DistributedCachePolicy),
            8,
            &cfg,
            &d,
            DwStrategy::PerCore,
            SgdMode::Grad,
            Arc::new(RustGrad),
        );
        let per_machine = run_sgd(
            &topo(),
            Box::new(crate::policy::DistributedCachePolicy),
            8,
            &cfg,
            &d,
            DwStrategy::PerMachine,
            SgdMode::Grad,
            Arc::new(RustGrad),
        );
        // Shared model => coherence invalidations => more remote traffic.
        let pc_remote = per_core.report.counts.fill_events() + per_core.report.counts.dram;
        let pm_remote =
            per_machine.report.counts.fill_events() + per_machine.report.counts.dram;
        assert!(
            pm_remote > pc_remote,
            "per-machine {pm_remote} vs per-core {pc_remote}"
        );
    }

    #[test]
    fn os_async_slower_than_coroutines() {
        let cfg = SgdConfig::tiny();
        let d = generate_data(&cfg);
        let coro = run_sgd(
            &topo(),
            Box::new(ArcasPolicy::new(&topo()).with_timer(50_000)),
            8,
            &cfg,
            &d,
            DwStrategy::PerCore,
            SgdMode::Grad,
            Arc::new(RustGrad),
        );
        // std::async: shard explosion into OS threads.
        let os = run_sgd(
            &topo(),
            Box::new(OsAsyncPolicy::new()),
            64,
            &cfg,
            &d,
            DwStrategy::PerCore,
            SgdMode::Grad,
            Arc::new(RustGrad),
        );
        assert!(
            os.report.makespan_ns > coro.report.makespan_ns,
            "os={} coro={}",
            os.report.makespan_ns,
            coro.report.makespan_ns
        );
        assert!(os.peak_threads() >= 64);
        assert!(coro.report.peak_concurrency <= 8 + 2);
    }

    impl SgdRun {
        fn peak_threads(&self) -> usize {
            self.report.peak_concurrency
        }
    }

    #[test]
    fn gbps_is_positive() {
        let cfg = SgdConfig::tiny();
        let d = generate_data(&cfg);
        let run = run_sgd(
            &topo(),
            Box::new(ShoalPolicy::new()),
            4,
            &cfg,
            &d,
            DwStrategy::PerNode,
            SgdMode::Loss,
            Arc::new(RustGrad),
        );
        assert!(run.gbps() > 0.0);
    }
}
