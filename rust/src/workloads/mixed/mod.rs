//! Mixed multi-tenant workload: YCSB (OLTP) + TPC-H-shaped scan (OLAP)
//! co-resident on one machine.
//!
//! The "millions of users" serving story is never one workload at a
//! time: a production box runs latency-sensitive transactions *next to*
//! scan-heavy analytics, and the interesting systems question is what
//! the tenants do to each other's caches and memory channels. This
//! scenario makes that contention first-class:
//!
//! - **OLTP tenant** (ranks `0..n_oltp`): the ERMIA-style YCSB mix from
//!   [`crate::workloads::oltp`] — zipfian point reads/RMWs over a shared
//!   record store, commit-counter ping-pong and log appends.
//! - **OLAP tenant** (ranks `n_oltp..n`): a TPC-H Q1-shaped pricing
//!   summary — a full scan of the `lineitem` fact table with the same
//!   deterministic selectivity filter and aggregate the OLAP engine
//!   uses, verified against [`crate::workloads::olap::run_query_serial`].
//!
//! Both tenants' regions are interleaved across NUMA nodes and their
//! coroutines yield every chunk, so the scheduler genuinely co-schedules
//! them: OLAP scan fills evict OLTP residency, both sides queue on the
//! same DDR trackers, and on partitioned-L3 machines the per-chiplet
//! shards ([`crate::coordinator`]) make the cross-tenant interference
//! visible per chiplet instead of as one blurred global number. The
//! tenants are deliberately barrier-free (the scan is embarrassingly
//! parallel; transactions are independent), so neither tenant's progress
//! gates the other's — contention is the only coupling.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::engine::{Scenario, ScenarioMetrics};
use crate::mem::{Placement, RegionId};
use crate::sched::RunReport;
use crate::sim::Machine;
use crate::task::{Coroutine, StateTask, Step};
use crate::util::prng::Rng;
use crate::workloads::olap::exec::{agg_value, keep};
use crate::workloads::olap::{run_query_serial, Db, QuerySpec};
use crate::workloads::oltp::Store;

/// Transactions per OLTP coroutine step (same chunking as the pure OLTP
/// scenario: every chunk is a yield/co-scheduling point).
const TXNS_PER_STEP: u64 = 64;

/// Probe rows per OLAP coroutine step.
const ROWS_PER_STEP: usize = 2048;

/// A co-resident TPC-H-shaped scan tenant: the OLAP half of the mixed
/// scenario, factored out so other multi-tenant scenarios (the serving
/// mix, `workloads::serve`) can co-schedule the same scan pressure
/// against their own foreground traffic. Owns the scan's regions and
/// the merged (rows, aggregate) result; `coroutine(rank, n)` builds one
/// rank's chunked, yielding scan over its slice of the fact table.
pub(crate) struct ScanTenant {
    pub(crate) db: Arc<Db>,
    pub(crate) spec: QuerySpec,
    probe_region: RegionId,
    group_region: RegionId,
    /// Per-rank partials merged at each rank's final chunk.
    olap: Arc<Mutex<(u64, f64)>>,
}

impl ScanTenant {
    /// Allocate the scan tenant's regions on `machine` (probe table
    /// interleaved across NUMA nodes, like the standalone OLAP engine).
    /// `spec` must be join-free — the tenant is a scan.
    pub(crate) fn new(
        machine: &mut Machine,
        label_prefix: &str,
        db: Arc<Db>,
        spec: QuerySpec,
    ) -> Self {
        assert!(
            spec.joins.is_empty(),
            "scan tenant requires a join-free query: Q{} has joins",
            spec.id
        );
        let probe_region = machine.alloc(
            &format!("{label_prefix}-probe-table"),
            db.table_bytes(spec.probe),
            Placement::Interleave,
        );
        let group_region = machine.alloc(
            &format!("{label_prefix}-group-state"),
            4 << 10,
            Placement::Interleave,
        );
        Self {
            db,
            spec,
            probe_region,
            group_region,
            olap: Arc::new(Mutex::new((0, 0.0))),
        }
    }

    /// (rows, aggregate) produced by the tenant; valid after the run.
    pub(crate) fn result(&self) -> (u64, f64) {
        *self.olap.lock().unwrap()
    }

    /// Assert the co-resident scan matches the OLAP engine's serial
    /// oracle (float tolerance covers rank-order-dependent summation on
    /// the host backend).
    pub(crate) fn verify_against_serial(&self) {
        let (rows, sum) = self.result();
        let (rows_ref, sum_ref) = run_query_serial(&self.db, &self.spec);
        assert_eq!(
            rows, rows_ref,
            "Q{}: co-resident scan row count diverges from the serial oracle",
            self.spec.id
        );
        assert!(
            (sum - sum_ref).abs() <= sum_ref.abs() * 1e-9 + 1e-6,
            "Q{}: aggregate {} vs serial {}",
            self.spec.id,
            sum,
            sum_ref
        );
    }

    /// Build scan rank `olap_rank` of `n_olap`: its slice of the fact
    /// table, scanned in yielding [`ROWS_PER_STEP`] chunks.
    pub(crate) fn coroutine(&self, olap_rank: usize, n_olap: usize) -> Box<dyn Coroutine> {
        let db = self.db.clone();
        let spec = self.spec.clone();
        let salt = spec.id as u64 * 0x1234_5678;
        let probe_region = self.probe_region;
        let group_region = self.group_region;
        let olap = self.olap.clone();
        let rows = db.rows(spec.probe);
        let per = rows.div_ceil(n_olap);
        let lo = (olap_rank * per).min(rows);
        let hi = ((olap_rank + 1) * per).min(rows);
        let chunks = (hi - lo).div_ceil(ROWS_PER_STEP).max(1) as u64;
        let mut local_rows = 0u64;
        let mut local_sum = 0.0f64;
        Box::new(StateTask::new(move |ctx, step| {
            if step >= chunks {
                return Step::Done;
            }
            let c_lo = lo + step as usize * ROWS_PER_STEP;
            let c_hi = (c_lo + ROWS_PER_STEP).min(hi);
            for r in c_lo..c_hi {
                if keep(r as u64, salt, spec.probe_selectivity) {
                    local_rows += 1;
                    local_sum += agg_value(&db, spec.probe, r);
                }
            }
            ctx.seq_read(
                probe_region,
                ((c_hi - c_lo) as u64) * db.row_bytes(spec.probe),
            );
            ctx.compute_flops(spec.flops_per_row * (c_hi - c_lo) as u64);
            if step + 1 >= chunks {
                // Final chunk: publish this rank's partials.
                let mut agg = olap.lock().unwrap();
                agg.0 += local_rows;
                agg.1 += local_sum;
                ctx.seq_write(group_region, 64);
                Step::Done
            } else {
                Step::Yield
            }
        }))
    }
}

/// YCSB + TPC-H scan co-residency as a [`Scenario`].
pub struct MixedScenario {
    /// YCSB table size (records).
    records: usize,
    /// YCSB read fraction (reads vs RMWs).
    read_frac: f64,
    /// Transactions per OLTP rank.
    txns_per_core: u64,
    seed: u64,
    /// The analytics database (scan side).
    db: Arc<Db>,
    /// The scan query shape (must be join-free; Q1 by default).
    spec: QuerySpec,
    tasks: usize,
    n_oltp: usize,
    st: Option<MixedState>,
}

/// Post-`setup` shared state.
struct MixedState {
    store: Arc<Store>,
    commit_region: RegionId,
    log_region: RegionId,
    scan: ScanTenant,
    commits: Arc<AtomicU64>,
    aborts: Arc<AtomicU64>,
}

impl MixedScenario {
    /// `records`/`read_frac` shape the YCSB tenant; `txns_per_core` is
    /// per OLTP rank; `spec` must be a join-free scan query.
    pub fn new(
        records: usize,
        read_frac: f64,
        txns_per_core: u64,
        seed: u64,
        db: Arc<Db>,
        spec: QuerySpec,
    ) -> Self {
        assert!(
            spec.joins.is_empty(),
            "mixed scenario's OLAP tenant is a scan: Q{} has joins",
            spec.id
        );
        Self {
            records,
            read_frac,
            txns_per_core,
            seed,
            db,
            spec,
            tasks: 0,
            n_oltp: 0,
            st: None,
        }
    }

    /// Committed transactions; valid after the run.
    pub fn commits(&self) -> u64 {
        self.st
            .as_ref()
            .map_or(0, |st| st.commits.load(Ordering::Relaxed))
    }

    /// Aborted transactions; valid after the run.
    pub fn aborts(&self) -> u64 {
        self.st
            .as_ref()
            .map_or(0, |st| st.aborts.load(Ordering::Relaxed))
    }

    /// (rows, aggregate) produced by the OLAP tenant; valid after the run.
    pub fn olap_result(&self) -> (u64, f64) {
        self.st.as_ref().map_or((0, 0.0), |st| st.scan.result())
    }

    /// How many ranks each tenant got (OLTP first).
    pub fn split(&self) -> (usize, usize) {
        (self.n_oltp, self.tasks - self.n_oltp)
    }

    fn olap_rank_coroutine(&self, olap_rank: usize, n_olap: usize) -> Box<dyn Coroutine> {
        let st = self.st.as_ref().expect("setup() before spawn()");
        st.scan.coroutine(olap_rank, n_olap)
    }

    fn oltp_rank_coroutine(&self, rank: usize) -> Box<dyn Coroutine> {
        let st = self.st.as_ref().expect("setup() before spawn()");
        let txns_per_core = self.txns_per_core;
        let steps = txns_per_core.div_ceil(TXNS_PER_STEP);
        let records = self.records;
        let read_frac = self.read_frac;
        let store = st.store.clone();
        let commit_region = st.commit_region;
        let log_region = st.log_region;
        let commits = st.commits.clone();
        let aborts = st.aborts.clone();
        let mut rng = Rng::new(self.seed ^ ((rank as u64) << 40));
        Box::new(StateTask::new(move |ctx, step| {
            if step >= steps {
                return Step::Done;
            }
            let todo = TXNS_PER_STEP.min(txns_per_core - step * TXNS_PER_STEP);
            let mut ok = 0u64;
            let mut failed = 0u64;
            let mut reads = 0u64;
            let mut writes = 0u64;
            for _ in 0..todo {
                let key = rng.gen_zipf(records as u64, 0.99) as usize;
                let committed = if rng.gen_bool(read_frac) {
                    let _ = store.read(key);
                    reads += 1;
                    true
                } else {
                    reads += 1;
                    writes += 1;
                    store.rmw(key, 1)
                };
                if committed {
                    ok += 1;
                } else {
                    failed += 1;
                }
            }
            commits.fetch_add(ok, Ordering::Relaxed);
            aborts.fetch_add(failed, Ordering::Relaxed);

            // --- cost model for this chunk (same shape as OltpScenario).
            if reads > 0 {
                ctx.access(
                    crate::cachesim::Access::rand_read(store.region, reads, store.bytes)
                        .with_mlp(1.5),
                );
            }
            if writes > 0 {
                ctx.access(
                    crate::cachesim::Access::rand_write(store.region, writes, store.bytes)
                        .with_mlp(1.5),
                );
            }
            if ok > 0 {
                ctx.rand_write(commit_region, ok, 64);
                ctx.seq_write(log_region, ok * 128);
                ctx.compute_ns(ok * 600);
            }
            ctx.compute_flops(todo * 300);
            if step + 1 >= steps {
                Step::Done
            } else {
                Step::Yield
            }
        }))
    }
}

impl Scenario for MixedScenario {
    fn name(&self) -> &'static str {
        "mixed-oltp-olap"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        self.tasks = tasks;
        // Split ranks between tenants: OLTP gets the ceiling half, so a
        // single-rank group degenerates to pure OLTP (never to nothing).
        self.n_oltp = tasks.div_ceil(2);
        let store = Arc::new(Store::new(machine, "mixed-ycsb-table", self.records, 100));
        let commit_region = machine.alloc("mixed-commit-counter", 64, Placement::Bind(0));
        let log_region = machine.alloc("mixed-txn-log", 64 << 20, Placement::Bind(0));
        // Same allocation order and labels as pre-refactor (probe table,
        // then group state), so the golden sim reports are unchanged.
        let scan = ScanTenant::new(machine, "mixed", self.db.clone(), self.spec.clone());
        self.st = Some(MixedState {
            store,
            commit_region,
            log_region,
            scan,
            commits: Arc::new(AtomicU64::new(0)),
            aborts: Arc::new(AtomicU64::new(0)),
        });
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        if rank < self.n_oltp {
            self.oltp_rank_coroutine(rank)
        } else {
            let n_olap = self.tasks - self.n_oltp;
            self.olap_rank_coroutine(rank - self.n_oltp, n_olap)
        }
    }

    fn verify(&self) {
        // OLTP tenant: every transaction committed or aborted.
        let total = self.commits() + self.aborts();
        let expect = self.n_oltp as u64 * self.txns_per_core;
        assert_eq!(
            total, expect,
            "every transaction must commit or abort ({total} of {expect})"
        );
        // OLAP tenant: scan matches the OLAP engine's serial oracle.
        if self.tasks > self.n_oltp {
            let st = self.st.as_ref().expect("setup() before verify()");
            st.scan.verify_against_serial();
        }
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        let (rows, _) = self.olap_result();
        let scanned = if self.tasks > self.n_oltp {
            self.db.rows(self.spec.probe) as f64
        } else {
            0.0
        };
        // Primary work-item count: both tenants' completed units.
        let items = self.commits() as f64 + scanned;
        ScenarioMetrics::new(items, "ops")
            .with("commits", self.commits() as f64)
            .with("aborts", self.aborts() as f64)
            .with("commits_per_s", report.throughput(self.commits() as f64))
            .with("olap_rows_out", rows as f64)
            .with("olap_rows_per_s", report.throughput(scanned))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Driver;
    use crate::policy::LocalCachePolicy;
    use crate::topology::Topology;
    use crate::workloads::olap::all_queries;

    fn scenario(scale: f64, txns: u64) -> MixedScenario {
        let db = Arc::new(Db::generate(scale, 7));
        MixedScenario::new(10_000, 0.45, txns, 3, db, all_queries()[0].clone())
    }

    fn topo() -> Topology {
        Topology::milan_1s()
    }

    #[test]
    fn tenants_split_the_group_and_both_make_progress() {
        let mut s = scenario(0.002, 512);
        let run = Driver::new(&topo(), Box::new(LocalCachePolicy), 8)
            .with_verify(true)
            .run(&mut s);
        assert_eq!(s.split(), (4, 4));
        assert_eq!(s.commits() + s.aborts(), 4 * 512);
        let (rows, sum) = s.olap_result();
        assert!(rows > 0, "scan produced nothing");
        assert!(sum > 0.0);
        assert!(run.report.makespan_ns > 0);
        assert!(run.metrics.get("commits").unwrap() > 0.0);
    }

    #[test]
    fn single_rank_degenerates_to_pure_oltp() {
        let mut s = scenario(0.002, 128);
        let _ = Driver::new(&topo(), Box::new(LocalCachePolicy), 1)
            .with_verify(true)
            .run(&mut s);
        assert_eq!(s.split(), (1, 0));
        assert_eq!(s.commits() + s.aborts(), 128);
        assert_eq!(s.olap_result().0, 0);
    }

    #[test]
    fn runs_are_deterministic_on_the_sim_backend() {
        let run_once = || {
            let mut s = scenario(0.002, 256);
            let run = Driver::new(&topo(), Box::new(LocalCachePolicy), 8).run(&mut s);
            (
                run.report.makespan_ns,
                run.report.dispatches,
                s.commits(),
                s.olap_result().0,
            )
        };
        assert_eq!(run_once(), run_once());
    }

    #[test]
    fn co_residency_contends_vs_isolated_oltp() {
        // The same OLTP work with a co-resident scan tenant must consume
        // more DRAM bandwidth machine-wide than alone (the scan's
        // traffic), i.e. the tenants actually share the accounting.
        let mut mixed = scenario(0.01, 512);
        let with_scan = Driver::new(&topo(), Box::new(LocalCachePolicy), 8).run(&mut mixed);
        let wl = crate::workloads::oltp::OltpWorkload::Ycsb {
            records: 10_000,
            read_frac: 0.45,
        };
        let alone =
            crate::workloads::oltp::run_oltp(&topo(), Box::new(LocalCachePolicy), 4, &wl, 512, 3);
        assert!(
            with_scan.report.dram_bytes > alone.report.dram_bytes,
            "mixed {} must out-traffic isolated {}",
            with_scan.report.dram_bytes,
            alone.report.dram_bytes
        );
    }

    #[test]
    #[should_panic(expected = "joins")]
    fn join_queries_are_rejected() {
        let db = Arc::new(Db::generate(0.002, 7));
        // Q3 has a join: the scan tenant cannot run it.
        let _ = MixedScenario::new(1024, 0.5, 10, 1, db, all_queries()[2].clone());
    }
}
