//! Graph-processing workloads (§5.2, §5.4): Kronecker generator, CSR
//! storage, five graph algorithms + GUPS, each with a serial reference and
//! a parallel ARCAS runner whose memory behaviour feeds the cache model.
pub mod csr;
pub mod kronecker;
pub mod algos;
pub mod runner;

pub use csr::Csr;
pub use runner::{
    run_bfs, run_cc, run_gups, run_pagerank, run_sssp, BfsRandomRootsScenario, BfsScenario,
    CcScenario, GraphRun, GupsScenario, PagerankScenario, SsspScenario,
};
