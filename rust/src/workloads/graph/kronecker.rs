//! Graph generators: Graph500 Kronecker (R-MAT) and uniform random.
//!
//! The paper's graph benchmarks use "a Kronecker graph model with 2^24
//! vertices and 16×2^24 edges" — the Graph500 spec with edge factor 16 and
//! initiator (A, B, C) = (0.57, 0.19, 0.19). The generator is
//! deterministic from a seed.

use super::csr::Csr;
use crate::util::prng::Rng;

/// Graph500 initiator parameters.
pub const A: f64 = 0.57;
pub const B: f64 = 0.19;
pub const C: f64 = 0.19;

/// Generate a Kronecker (R-MAT) edge list: `2^scale` vertices,
/// `edge_factor * 2^scale` directed edges, weights in `[1, 255]`.
pub fn kronecker_edges(scale: u32, edge_factor: usize, seed: u64) -> (Vec<(u32, u32)>, Vec<u32>) {
    let n = 1u64 << scale;
    let m = edge_factor as u64 * n;
    let mut rng = Rng::new(seed);
    let mut edges = Vec::with_capacity(m as usize);
    let mut weights = Vec::with_capacity(m as usize);
    let ab = A + B;
    let c_norm = C / (1.0 - ab);
    let a_norm = A / ab;
    for _ in 0..m {
        let (mut u, mut v) = (0u64, 0u64);
        for bit in 0..scale {
            let ii = rng.gen_f64() > ab;
            let jj = rng.gen_f64()
                > (c_norm * (ii as u64 as f64) + a_norm * (!ii as u64 as f64));
            u |= (ii as u64) << bit;
            v |= (jj as u64) << bit;
        }
        edges.push((u as u32, v as u32));
        weights.push(1 + (rng.next_u64() % 255) as u32);
    }
    // Graph500 permutes vertex labels to break locality of the recursion.
    let mut perm: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut perm);
    for e in &mut edges {
        e.0 = perm[e.0 as usize];
        e.1 = perm[e.1 as usize];
    }
    (edges, weights)
}

/// Build a symmetrized Kronecker CSR (each edge inserted both ways, as the
/// Graph500 benchmark does before BFS).
pub fn kronecker(scale: u32, edge_factor: usize, seed: u64) -> Csr {
    let (edges, weights) = kronecker_edges(scale, edge_factor, seed);
    let n = 1usize << scale;
    let mut sym = Vec::with_capacity(edges.len() * 2);
    let mut wsym = Vec::with_capacity(edges.len() * 2);
    for (i, &(u, v)) in edges.iter().enumerate() {
        sym.push((u, v));
        wsym.push(weights[i]);
        sym.push((v, u));
        wsym.push(weights[i]);
    }
    Csr::from_edges(n, &sym, Some(&wsym))
}

/// Uniform Erdős–Rényi-style random graph (degree-regular expectation).
pub fn uniform(n: usize, edges_per_vertex: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let m = n * edges_per_vertex;
    let mut edges = Vec::with_capacity(m * 2);
    let mut weights = Vec::with_capacity(m * 2);
    for u in 0..n as u32 {
        for _ in 0..edges_per_vertex {
            let v = rng.gen_range(n as u64) as u32;
            let w = 1 + (rng.next_u64() % 255) as u32;
            edges.push((u, v));
            weights.push(w);
            edges.push((v, u));
            weights.push(w);
        }
    }
    Csr::from_edges(n, &edges, Some(&weights))
}

/// Dataset size in bytes for a given scale/edge-factor, matching the
/// paper's Fig. 9 sweep (19 MB at 2^16 ... 5,300 MB at 2^24).
pub fn dataset_bytes(scale: u32, edge_factor: usize) -> u64 {
    let n = 1u64 << scale;
    let m = 2 * edge_factor as u64 * n; // symmetrized
    (n + 1) * 8 + m * 8 // offsets + targets/weights
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let a = kronecker(10, 4, 42);
        let b = kronecker(10, 4, 42);
        assert_eq!(a.targets, b.targets);
        let c = kronecker(10, 4, 43);
        assert_ne!(a.targets, c.targets);
    }

    #[test]
    fn size_matches_spec() {
        let g = kronecker(10, 8, 1);
        assert_eq!(g.num_vertices(), 1024);
        assert_eq!(g.num_edges(), 2 * 8 * 1024); // symmetrized
    }

    #[test]
    fn degree_distribution_is_skewed() {
        // R-MAT graphs are heavy-tailed: the max degree should far exceed
        // the mean.
        let g = kronecker(12, 8, 7);
        let n = g.num_vertices();
        let mean = g.num_edges() as f64 / n as f64;
        let max = (0..n as u32).map(|v| g.degree(v)).max().unwrap() as f64;
        assert!(
            max > mean * 8.0,
            "max degree {max} should dwarf mean {mean}"
        );
    }

    #[test]
    fn uniform_is_flat() {
        let g = uniform(1024, 8, 3);
        let n = g.num_vertices();
        let mean = g.num_edges() as f64 / n as f64;
        let max = (0..n as u32).map(|v| g.degree(v)).max().unwrap() as f64;
        assert!(max < mean * 4.0, "uniform max {max} vs mean {mean}");
    }

    #[test]
    fn weights_in_range() {
        let g = kronecker(8, 4, 5);
        assert!(g.weights.iter().all(|&w| (1..=255).contains(&w)));
        assert_eq!(g.weights.len(), g.num_edges());
    }

    #[test]
    fn dataset_bytes_monotone() {
        assert!(dataset_bytes(16, 16) < dataset_bytes(20, 16));
        // Scale 24, ef 16 ~ 4.5 GB (paper: ~4 GB symmetric-ish).
        let gb = dataset_bytes(24, 16) as f64 / 1e9;
        assert!(gb > 2.0 && gb < 8.0, "gb={gb}");
    }
}
