//! Parallel graph algorithms as ARCAS task groups.
//!
//! Each runner executes the *real* algorithm on the real graph (atomics,
//! level-synchronous BSP) while mirroring its memory behaviour into the
//! cache model: edge scans are random reads over the graph region, label
//! updates are random writes over the state region. The algorithm result
//! is checked against the serial references in [`super::algos`]; the
//! virtual-time [`RunReport`] provides the paper's performance numbers.
//!
//! Every algorithm is a [`Scenario`] driven by [`crate::engine::Driver`];
//! the `run_*` functions are thin wrappers that preserve the original
//! entry-point signatures (and their deterministic reports).

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

use super::algos;
use super::csr::Csr;
use crate::engine::{Driver, Scenario, ScenarioMetrics};
use crate::mem::{Placement, RegionId};
use crate::policy::Policy;
use crate::sched::RunReport;
use crate::sim::Machine;
use crate::task::{Coroutine, StateTask, Step, TaskCtx};
use crate::topology::Topology;

const MAX_ROUNDS: usize = 4096;

/// Vertex range owned by `rank` of `group`.
#[inline]
pub fn vertex_range(rank: usize, group: usize, n: usize) -> (usize, usize) {
    let per = n.div_ceil(group);
    let lo = (rank * per).min(n);
    let hi = ((rank + 1) * per).min(n);
    (lo, hi)
}

/// Regions shared by all graph runners.
struct GraphRegions {
    /// Whole-graph region (kept for residency inspection / future shared
    /// accesses; the hot path charges the per-task slices instead).
    #[allow(dead_code)]
    graph: RegionId,
    state: RegionId,
    /// Per-task slice of the state array (each task's own vertex range):
    /// the sequential-scan working set whose chiplet residency policies
    /// fight over. Shared `state` remains the target of random
    /// neighbour-label accesses.
    slices: Vec<RegionId>,
    /// Per-task slice of the CSR adjacency rows (each task re-scans its
    /// own rows every round — the dominant cacheable stream).
    graph_slices: Vec<RegionId>,
    #[allow(dead_code)]
    graph_bytes: u64,
    state_bytes: u64,
}

fn alloc_regions(
    machine: &mut Machine,
    g: &Csr,
    state_bytes: u64,
    tasks: usize,
) -> GraphRegions {
    let graph_bytes = g.bytes();
    let graph = machine.alloc("graph", graph_bytes, Placement::Interleave);
    let state = machine.alloc("graph-state", state_bytes, Placement::Interleave);
    let slice_bytes = (state_bytes / tasks as u64).max(64);
    let slices = (0..tasks)
        .map(|r| machine.alloc(&format!("state-slice-{r}"), slice_bytes, Placement::Interleave))
        .collect();
    let gslice_bytes = (graph_bytes / tasks as u64).max(64);
    let graph_slices = (0..tasks)
        .map(|r| machine.alloc(&format!("graph-slice-{r}"), gslice_bytes, Placement::Interleave))
        .collect();
    GraphRegions {
        graph,
        state,
        slices,
        graph_slices,
        graph_bytes,
        state_bytes,
    }
}

/// Charge the cache model for one BSP step of a graph task.
#[allow(clippy::too_many_arguments)]
fn charge_step(
    ctx: &mut TaskCtx<'_>,
    r: &ChargePlan,
    slice: RegionId,
    gslice: RegionId,
    range_len: usize,
    scanned: u64,
    updates: u64,
) {
    // Scan own state slice sequentially (slice-local working set).
    ctx.seq_read(slice, (range_len as u64) * r.state_stride);
    if scanned > 0 {
        // Own adjacency rows: a re-scanned sequential stream (~8 B/edge:
        // 4 B target + amortized offsets/weights).
        ctx.seq_read(gslice, scanned * 8);
        // Neighbour labels: random over the whole (shared) state array.
        ctx.rand_read(r.state, scanned, r.state_bytes);
    }
    if updates > 0 {
        ctx.rand_write(r.state, updates, r.state_bytes);
    }
    ctx.compute_flops(4 * scanned + range_len as u64);
}

#[derive(Clone, Copy)]
struct ChargePlan {
    state: RegionId,
    state_bytes: u64,
    state_stride: u64,
}

impl ChargePlan {
    fn from(r: &GraphRegions, state_stride: u64) -> Self {
        Self {
            state: r.state,
            state_bytes: r.state_bytes,
            state_stride,
        }
    }
}

/// Result of one parallel graph run.
pub struct GraphRun {
    pub report: RunReport,
    /// Total edges processed (TEPS numerator).
    pub edges_processed: u64,
}

impl GraphRun {
    /// Traversed edges per second (virtual time).
    pub fn teps(&self) -> f64 {
        self.report.throughput(self.edges_processed as f64)
    }
}

/// Post-`setup` state shared by the BSP graph scenarios.
struct GraphState {
    plan: ChargePlan,
    slices: Vec<RegionId>,
    gslices: Vec<RegionId>,
    edges_scanned: Arc<AtomicU64>,
}

impl GraphState {
    fn new(machine: &mut Machine, g: &Csr, state_bytes: u64, tasks: usize, stride: u64) -> Self {
        let regs = alloc_regions(machine, g, state_bytes, tasks);
        Self {
            plan: ChargePlan::from(&regs, stride),
            slices: regs.slices,
            gslices: regs.graph_slices,
            edges_scanned: Arc::new(AtomicU64::new(0)),
        }
    }

    fn edges(&self) -> u64 {
        self.edges_scanned.load(Ordering::Relaxed)
    }
}

fn graph_metrics(edges: u64, report: &RunReport) -> ScenarioMetrics {
    ScenarioMetrics::new(edges as f64, "edges").with("teps", report.throughput(edges as f64))
}

// ====================================================================
// BFS
// ====================================================================

/// Level-synchronous parallel BFS as a [`Scenario`].
pub struct BfsScenario {
    graph: Arc<Csr>,
    src: u32,
    st: Option<GraphState>,
    dist: Option<Arc<Vec<AtomicU32>>>,
    level_updates: Option<Arc<Vec<AtomicU64>>>,
}

impl BfsScenario {
    pub fn new(graph: Arc<Csr>, src: u32) -> Self {
        Self {
            graph,
            src,
            st: None,
            dist: None,
            level_updates: None,
        }
    }

    /// Total edges scanned (TEPS numerator); valid after the run.
    pub fn edges_processed(&self) -> u64 {
        self.st.as_ref().map_or(0, GraphState::edges)
    }

    /// Final distances (`u32::MAX` = unreached); valid after the run.
    pub fn distances(&self) -> Vec<u32> {
        self.dist
            .as_ref()
            .map(|d| d.iter().map(|x| x.load(Ordering::Relaxed)).collect())
            .unwrap_or_default()
    }
}

impl Scenario for BfsScenario {
    fn name(&self) -> &'static str {
        "bfs"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        let n = self.graph.num_vertices();
        self.st = Some(GraphState::new(machine, &self.graph, (n * 4) as u64, tasks, 4));
        let dist: Arc<Vec<AtomicU32>> =
            Arc::new((0..n).map(|_| AtomicU32::new(u32::MAX)).collect());
        dist[self.src as usize].store(0, Ordering::Relaxed);
        self.dist = Some(dist);
        self.level_updates =
            Some(Arc::new((0..MAX_ROUNDS).map(|_| AtomicU64::new(0)).collect()));
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        let st = self.st.as_ref().expect("setup() before spawn()");
        let graph = self.graph.clone();
        let n = graph.num_vertices();
        let dist = self.dist.as_ref().unwrap().clone();
        let level_updates = self.level_updates.as_ref().unwrap().clone();
        let edges_scanned = st.edges_scanned.clone();
        let slice = st.slices[rank];
        let gslice = st.gslices[rank];
        let plan = st.plan;
        Box::new(StateTask::new(move |ctx, step| {
            let level = step as usize;
            if level >= MAX_ROUNDS - 1 {
                return Step::Done;
            }
            if level > 0 && level_updates[level - 1].load(Ordering::Relaxed) == 0 {
                return Step::Done;
            }
            let (lo, hi) = vertex_range(rank, ctx.group_size, n);
            let (mut scanned, mut upd) = (0u64, 0u64);
            for v in lo..hi {
                if dist[v].load(Ordering::Relaxed) == level as u32 {
                    for &u in graph.neighbors(v as u32) {
                        scanned += 1;
                        if dist[u as usize]
                            .compare_exchange(
                                u32::MAX,
                                level as u32 + 1,
                                Ordering::Relaxed,
                                Ordering::Relaxed,
                            )
                            .is_ok()
                        {
                            upd += 1;
                        }
                    }
                }
            }
            level_updates[level].fetch_add(upd, Ordering::Relaxed);
            edges_scanned.fetch_add(scanned, Ordering::Relaxed);
            charge_step(ctx, &plan, slice, gslice, hi - lo, scanned, upd);
            Step::Barrier
        }))
    }

    fn verify(&self) {
        assert_eq!(
            self.distances(),
            algos::bfs_ref(&self.graph, self.src),
            "BFS distances diverge from the serial reference"
        );
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        graph_metrics(self.edges_processed(), report)
    }
}

/// Level-synchronous parallel BFS; returns distances + run info.
pub fn run_bfs(
    topo: &Topology,
    policy: Box<dyn Policy>,
    cores: usize,
    graph: Arc<Csr>,
    src: u32,
) -> (GraphRun, Vec<u32>) {
    let mut s = BfsScenario::new(graph, src);
    let run = Driver::new(topo, policy, cores).run(&mut s);
    (
        GraphRun {
            report: run.report,
            edges_processed: s.edges_processed(),
        },
        s.distances(),
    )
}

// ====================================================================
// BFS from random roots (Graph500-style)
// ====================================================================

/// Graph500-style multi-root BFS as a [`Scenario`]: the same
/// level-synchronous kernel as [`BfsScenario`], run back to back from a
/// seeded sample of random roots (the benchmark's "64 search keys"
/// shape). Every rank walks the *same* root/level schedule — each
/// transition is read from shared per-root frontier counters after a
/// barrier, so the schedule is identical across ranks on both backends.
pub struct BfsRandomRootsScenario {
    graph: Arc<Csr>,
    roots: Vec<u32>,
    st: Option<GraphState>,
    /// One distance array per root.
    dists: Option<Vec<Arc<Vec<AtomicU32>>>>,
    /// Per-root, per-level frontier-update counters.
    level_updates: Option<Vec<Arc<Vec<AtomicU64>>>>,
}

impl BfsRandomRootsScenario {
    /// Sample `n_roots` random roots with at least one outgoing edge
    /// (Graph500 discards isolated keys; a zero-degree root would make
    /// its whole traversal a no-op). Sampling is seeded and may repeat a
    /// root — repeats are valid search keys, as in the benchmark.
    pub fn new(graph: Arc<Csr>, n_roots: usize, seed: u64) -> Self {
        let n = graph.num_vertices();
        let mut rng = crate::util::Rng::new(seed);
        let mut roots = Vec::with_capacity(n_roots.max(1));
        while roots.len() < n_roots.max(1) {
            let v = rng.gen_index(n) as u32;
            if graph.degree(v) > 0 {
                roots.push(v);
            }
        }
        Self {
            graph,
            roots,
            st: None,
            dists: None,
            level_updates: None,
        }
    }

    pub fn roots(&self) -> &[u32] {
        &self.roots
    }

    /// Total edges scanned across every traversal; valid after the run.
    pub fn edges_processed(&self) -> u64 {
        self.st.as_ref().map_or(0, GraphState::edges)
    }

    /// Distances of traversal `i`; valid after the run.
    fn distances(&self, i: usize) -> Vec<u32> {
        self.dists
            .as_ref()
            .map(|d| d[i].iter().map(|x| x.load(Ordering::Relaxed)).collect())
            .unwrap_or_default()
    }
}

impl Scenario for BfsRandomRootsScenario {
    fn name(&self) -> &'static str {
        "bfs-random-roots"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        let n = self.graph.num_vertices();
        self.st = Some(GraphState::new(machine, &self.graph, (n * 4) as u64, tasks, 4));
        let dists: Vec<Arc<Vec<AtomicU32>>> = self
            .roots
            .iter()
            .map(|&root| {
                let d: Arc<Vec<AtomicU32>> =
                    Arc::new((0..n).map(|_| AtomicU32::new(u32::MAX)).collect());
                d[root as usize].store(0, Ordering::Relaxed);
                d
            })
            .collect();
        self.dists = Some(dists);
        self.level_updates = Some(
            self.roots
                .iter()
                .map(|_| Arc::new((0..MAX_ROUNDS).map(|_| AtomicU64::new(0)).collect()))
                .collect(),
        );
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        let st = self.st.as_ref().expect("setup() before spawn()");
        let graph = self.graph.clone();
        let n = graph.num_vertices();
        let dists = self.dists.as_ref().unwrap().clone();
        let level_updates = self.level_updates.as_ref().unwrap().clone();
        let edges_scanned = st.edges_scanned.clone();
        let slice = st.slices[rank];
        let gslice = st.gslices[rank];
        let plan = st.plan;
        // Per-rank traversal cursor. Every rank advances it by the same
        // rule from the same shared counters, so the (root, level)
        // schedule stays in lockstep across the barrier-synchronized
        // group.
        let (mut root_idx, mut level) = (0usize, 0usize);
        Box::new(StateTask::new(move |ctx, _step| {
            loop {
                if root_idx >= dists.len() {
                    return Step::Done;
                }
                let done_level = level >= MAX_ROUNDS - 1
                    || (level > 0
                        && level_updates[root_idx][level - 1].load(Ordering::Relaxed) == 0);
                if done_level {
                    root_idx += 1;
                    level = 0;
                    continue;
                }
                break;
            }
            let dist = &dists[root_idx];
            let (lo, hi) = vertex_range(rank, ctx.group_size, n);
            let (mut scanned, mut upd) = (0u64, 0u64);
            for v in lo..hi {
                if dist[v].load(Ordering::Relaxed) == level as u32 {
                    for &u in graph.neighbors(v as u32) {
                        scanned += 1;
                        if dist[u as usize]
                            .compare_exchange(
                                u32::MAX,
                                level as u32 + 1,
                                Ordering::Relaxed,
                                Ordering::Relaxed,
                            )
                            .is_ok()
                        {
                            upd += 1;
                        }
                    }
                }
            }
            level_updates[root_idx][level].fetch_add(upd, Ordering::Relaxed);
            edges_scanned.fetch_add(scanned, Ordering::Relaxed);
            charge_step(ctx, &plan, slice, gslice, hi - lo, scanned, upd);
            level += 1;
            Step::Barrier
        }))
    }

    fn verify(&self) {
        for (i, &root) in self.roots.iter().enumerate() {
            assert_eq!(
                self.distances(i),
                algos::bfs_ref(&self.graph, root),
                "BFS from root {root} (traversal {i}) diverges from the serial reference"
            );
        }
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        graph_metrics(self.edges_processed(), report)
            .with("roots", self.roots.len() as f64)
    }
}

// ====================================================================
// Connected components (label propagation)
// ====================================================================

/// Label-propagation connected components as a [`Scenario`].
pub struct CcScenario {
    graph: Arc<Csr>,
    st: Option<GraphState>,
    label: Option<Arc<Vec<AtomicU32>>>,
    round_updates: Option<Arc<Vec<AtomicU64>>>,
}

impl CcScenario {
    pub fn new(graph: Arc<Csr>) -> Self {
        Self {
            graph,
            st: None,
            label: None,
            round_updates: None,
        }
    }

    pub fn edges_processed(&self) -> u64 {
        self.st.as_ref().map_or(0, GraphState::edges)
    }

    /// Final component labels; valid after the run.
    pub fn labels(&self) -> Vec<u32> {
        self.label
            .as_ref()
            .map(|l| l.iter().map(|x| x.load(Ordering::Relaxed)).collect())
            .unwrap_or_default()
    }
}

impl Scenario for CcScenario {
    fn name(&self) -> &'static str {
        "cc"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        let n = self.graph.num_vertices();
        self.st = Some(GraphState::new(machine, &self.graph, (n * 4) as u64, tasks, 4));
        self.label = Some(Arc::new(
            (0..n).map(|v| AtomicU32::new(v as u32)).collect(),
        ));
        self.round_updates =
            Some(Arc::new((0..MAX_ROUNDS).map(|_| AtomicU64::new(0)).collect()));
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        let st = self.st.as_ref().expect("setup() before spawn()");
        let graph = self.graph.clone();
        let n = graph.num_vertices();
        let label = self.label.as_ref().unwrap().clone();
        let round_updates = self.round_updates.as_ref().unwrap().clone();
        let edges_scanned = st.edges_scanned.clone();
        let slice = st.slices[rank];
        let gslice = st.gslices[rank];
        let plan = st.plan;
        Box::new(StateTask::new(move |ctx, step| {
            let round = step as usize;
            if round >= MAX_ROUNDS - 1 {
                return Step::Done;
            }
            if round > 0 && round_updates[round - 1].load(Ordering::Relaxed) == 0 {
                return Step::Done;
            }
            let (lo, hi) = vertex_range(rank, ctx.group_size, n);
            let (mut scanned, mut upd) = (0u64, 0u64);
            for v in lo..hi {
                let lv = label[v].load(Ordering::Relaxed);
                let mut best = lv;
                for &u in graph.neighbors(v as u32) {
                    scanned += 1;
                    let lu = label[u as usize].load(Ordering::Relaxed);
                    if lu < best {
                        best = lu;
                    }
                }
                if best < lv {
                    atomic_min_u32(&label[v], best);
                    upd += 1;
                    // Push the improvement to neighbours too (speeds up
                    // convergence like the serial reference).
                    for &u in graph.neighbors(v as u32) {
                        if atomic_min_u32(&label[u as usize], best) {
                            upd += 1;
                        }
                    }
                }
            }
            round_updates[round].fetch_add(upd, Ordering::Relaxed);
            edges_scanned.fetch_add(scanned, Ordering::Relaxed);
            charge_step(ctx, &plan, slice, gslice, hi - lo, scanned, upd);
            Step::Barrier
        }))
    }

    fn verify(&self) {
        // Labels may differ from the reference; component *partitions*
        // must match.
        let par = self.labels();
        let ser = algos::cc_ref(&self.graph);
        let mut map = std::collections::HashMap::new();
        for v in 0..self.graph.num_vertices() {
            let e = map.entry(par[v]).or_insert(ser[v]);
            assert_eq!(*e, ser[v], "vertex {v} crosses components");
        }
        assert_eq!(
            algos::component_count(&par),
            algos::component_count(&ser),
            "component count diverges from the serial reference"
        );
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        graph_metrics(self.edges_processed(), report)
            .with("components", algos::component_count(&self.labels()) as f64)
    }
}

pub fn run_cc(
    topo: &Topology,
    policy: Box<dyn Policy>,
    cores: usize,
    graph: Arc<Csr>,
) -> (GraphRun, Vec<u32>) {
    let mut s = CcScenario::new(graph);
    let run = Driver::new(topo, policy, cores).run(&mut s);
    (
        GraphRun {
            report: run.report,
            edges_processed: s.edges_processed(),
        },
        s.labels(),
    )
}

/// CAS-min; returns true if it lowered the value.
fn atomic_min_u32(a: &AtomicU32, v: u32) -> bool {
    let mut cur = a.load(Ordering::Relaxed);
    while v < cur {
        match a.compare_exchange_weak(cur, v, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(c) => cur = c,
        }
    }
    false
}

fn atomic_min_u64(a: &AtomicU64, v: u64) -> bool {
    let mut cur = a.load(Ordering::Relaxed);
    while v < cur {
        match a.compare_exchange_weak(cur, v, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return true,
            Err(c) => cur = c,
        }
    }
    false
}

fn atomic_f64_add(a: &AtomicU64, v: f64) {
    let mut cur = a.load(Ordering::Relaxed);
    loop {
        let new = (f64::from_bits(cur) + v).to_bits();
        match a.compare_exchange_weak(cur, new, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(c) => cur = c,
        }
    }
}

// ====================================================================
// PageRank (push-based, 3 BSP phases per iteration)
// ====================================================================

/// Push-based PageRank as a [`Scenario`].
pub struct PagerankScenario {
    graph: Arc<Csr>,
    iters: usize,
    st: Option<GraphState>,
    rank_v: Option<Arc<Vec<AtomicU64>>>,
    next_v: Option<Arc<Vec<AtomicU64>>>,
    dangling: Option<Arc<Vec<AtomicU64>>>,
}

impl PagerankScenario {
    pub fn new(graph: Arc<Csr>, iters: usize) -> Self {
        Self {
            graph,
            iters,
            st: None,
            rank_v: None,
            next_v: None,
            dangling: None,
        }
    }

    pub fn edges_processed(&self) -> u64 {
        self.st.as_ref().map_or(0, GraphState::edges)
    }

    /// Final PageRank vector; valid after the run.
    pub fn ranks(&self) -> Vec<f64> {
        self.rank_v
            .as_ref()
            .map(|r| {
                r.iter()
                    .map(|x| f64::from_bits(x.load(Ordering::Relaxed)))
                    .collect()
            })
            .unwrap_or_default()
    }
}

impl Scenario for PagerankScenario {
    fn name(&self) -> &'static str {
        "pagerank"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        let n = self.graph.num_vertices();
        // two f64 arrays
        self.st = Some(GraphState::new(machine, &self.graph, (n * 16) as u64, tasks, 16));
        self.rank_v = Some(Arc::new(
            (0..n)
                .map(|_| AtomicU64::new((1.0 / n as f64).to_bits()))
                .collect(),
        ));
        self.next_v = Some(Arc::new((0..n).map(|_| AtomicU64::new(0)).collect()));
        self.dangling = Some(Arc::new(
            (0..self.iters).map(|_| AtomicU64::new(0)).collect(),
        ));
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        let st = self.st.as_ref().expect("setup() before spawn()");
        let graph = self.graph.clone();
        let n = graph.num_vertices();
        let iters = self.iters;
        let rank_v = self.rank_v.as_ref().unwrap().clone();
        let next_v = self.next_v.as_ref().unwrap().clone();
        let dangling = self.dangling.as_ref().unwrap().clone();
        let edges_scanned = st.edges_scanned.clone();
        let slice = st.slices[rank];
        let gslice = st.gslices[rank];
        let plan = st.plan;
        Box::new(StateTask::new(move |ctx, step| {
            let iter = (step / 3) as usize;
            let phase = step % 3;
            if iter >= iters {
                return Step::Done;
            }
            let (lo, hi) = vertex_range(rank, ctx.group_size, n);
            match phase {
                0 => {
                    // Zero the accumulator slice.
                    for v in lo..hi {
                        next_v[v].store(0, Ordering::Relaxed);
                    }
                    ctx.seq_write(slice, ((hi - lo) * 8) as u64);
                }
                1 => {
                    // Scatter contributions.
                    let mut scanned = 0u64;
                    let mut local_dangling = 0.0f64;
                    for v in lo..hi {
                        let rv = f64::from_bits(rank_v[v].load(Ordering::Relaxed));
                        let deg = graph.degree(v as u32);
                        if deg == 0 {
                            local_dangling += rv;
                            continue;
                        }
                        let share = rv / deg as f64;
                        for &u in graph.neighbors(v as u32) {
                            scanned += 1;
                            atomic_f64_add(&next_v[u as usize], share);
                        }
                    }
                    if local_dangling != 0.0 {
                        atomic_f64_add(&dangling[iter], local_dangling);
                    }
                    edges_scanned.fetch_add(scanned, Ordering::Relaxed);
                    charge_step(ctx, &plan, slice, gslice, hi - lo, scanned, scanned);
                }
                _ => {
                    // Apply damping + dangling mass; swap via copy-back.
                    let d = f64::from_bits(dangling[iter].load(Ordering::Relaxed));
                    let base = 0.15 / n as f64 + 0.85 * d / n as f64;
                    for v in lo..hi {
                        let nv = f64::from_bits(next_v[v].load(Ordering::Relaxed));
                        rank_v[v].store((base + 0.85 * nv).to_bits(), Ordering::Relaxed);
                    }
                    ctx.seq_read(slice, ((hi - lo) * 8) as u64);
                    ctx.seq_write(slice, ((hi - lo) * 8) as u64);
                    ctx.compute_flops(2 * (hi - lo) as u64);
                }
            }
            Step::Barrier
        }))
    }

    fn verify(&self) {
        let par = self.ranks();
        let ser = algos::pagerank_ref(&self.graph, self.iters);
        for v in 0..self.graph.num_vertices() {
            assert!(
                (par[v] - ser[v]).abs() < 1e-9,
                "pagerank diverges at v={v}: par={} ser={}",
                par[v],
                ser[v]
            );
        }
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        graph_metrics(self.edges_processed(), report)
    }
}

pub fn run_pagerank(
    topo: &Topology,
    policy: Box<dyn Policy>,
    cores: usize,
    graph: Arc<Csr>,
    iters: usize,
) -> (GraphRun, Vec<f64>) {
    let mut s = PagerankScenario::new(graph, iters);
    let run = Driver::new(topo, policy, cores).run(&mut s);
    (
        GraphRun {
            report: run.report,
            edges_processed: s.edges_processed(),
        },
        s.ranks(),
    )
}

// ====================================================================
// SSSP (chunked Bellman-Ford)
// ====================================================================

/// Chunked Bellman-Ford SSSP as a [`Scenario`].
pub struct SsspScenario {
    graph: Arc<Csr>,
    src: u32,
    st: Option<GraphState>,
    dist: Option<Arc<Vec<AtomicU64>>>,
    round_updates: Option<Arc<Vec<AtomicU64>>>,
}

impl SsspScenario {
    pub fn new(graph: Arc<Csr>, src: u32) -> Self {
        Self {
            graph,
            src,
            st: None,
            dist: None,
            round_updates: None,
        }
    }

    pub fn edges_processed(&self) -> u64 {
        self.st.as_ref().map_or(0, GraphState::edges)
    }

    /// Final distances (`u64::MAX` = unreached); valid after the run.
    pub fn distances(&self) -> Vec<u64> {
        self.dist
            .as_ref()
            .map(|d| d.iter().map(|x| x.load(Ordering::Relaxed)).collect())
            .unwrap_or_default()
    }
}

impl Scenario for SsspScenario {
    fn name(&self) -> &'static str {
        "sssp"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        let n = self.graph.num_vertices();
        self.st = Some(GraphState::new(machine, &self.graph, (n * 8) as u64, tasks, 8));
        let dist: Arc<Vec<AtomicU64>> =
            Arc::new((0..n).map(|_| AtomicU64::new(u64::MAX)).collect());
        dist[self.src as usize].store(0, Ordering::Relaxed);
        self.dist = Some(dist);
        self.round_updates =
            Some(Arc::new((0..MAX_ROUNDS).map(|_| AtomicU64::new(0)).collect()));
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        let st = self.st.as_ref().expect("setup() before spawn()");
        let graph = self.graph.clone();
        let n = graph.num_vertices();
        let dist = self.dist.as_ref().unwrap().clone();
        let round_updates = self.round_updates.as_ref().unwrap().clone();
        let edges_scanned = st.edges_scanned.clone();
        let slice = st.slices[rank];
        let gslice = st.gslices[rank];
        let plan = st.plan;
        Box::new(StateTask::new(move |ctx, step| {
            let round = step as usize;
            if round >= MAX_ROUNDS - 1 {
                return Step::Done;
            }
            if round > 0 && round_updates[round - 1].load(Ordering::Relaxed) == 0 {
                return Step::Done;
            }
            let (lo, hi) = vertex_range(rank, ctx.group_size, n);
            let (mut scanned, mut upd) = (0u64, 0u64);
            for v in lo..hi {
                let dv = dist[v].load(Ordering::Relaxed);
                if dv == u64::MAX {
                    continue;
                }
                let (nbrs, ws) = graph.neighbors_weighted(v as u32);
                for (&u, &w) in nbrs.iter().zip(ws) {
                    scanned += 1;
                    if atomic_min_u64(&dist[u as usize], dv + w as u64) {
                        upd += 1;
                    }
                }
            }
            round_updates[round].fetch_add(upd, Ordering::Relaxed);
            edges_scanned.fetch_add(scanned, Ordering::Relaxed);
            charge_step(ctx, &plan, slice, gslice, hi - lo, scanned, upd);
            Step::Barrier
        }))
    }

    fn verify(&self) {
        assert_eq!(
            self.distances(),
            algos::sssp_ref(&self.graph, self.src),
            "SSSP distances diverge from the serial reference"
        );
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        graph_metrics(self.edges_processed(), report)
    }
}

pub fn run_sssp(
    topo: &Topology,
    policy: Box<dyn Policy>,
    cores: usize,
    graph: Arc<Csr>,
    src: u32,
) -> (GraphRun, Vec<u64>) {
    let mut s = SsspScenario::new(graph, src);
    let run = Driver::new(topo, policy, cores).run(&mut s);
    (
        GraphRun {
            report: run.report,
            edges_processed: s.edges_processed(),
        },
        s.distances(),
    )
}

// ====================================================================
// GUPS (RandomAccess)
// ====================================================================

/// HPCC RandomAccess (XOR-updates at random table locations) as a
/// [`Scenario`].
pub struct GupsScenario {
    table_words: usize,
    updates_per_core: u64,
    seed: u64,
    tasks: usize,
    table: Option<Arc<Vec<AtomicU64>>>,
    region: Option<(RegionId, u64)>,
}

impl GupsScenario {
    pub fn new(table_words: usize, updates_per_core: u64, seed: u64) -> Self {
        Self {
            table_words,
            updates_per_core,
            seed,
            tasks: 0,
            table: None,
            region: None,
        }
    }

    /// Total updates performed (GUPS numerator); valid after the run.
    pub fn updates(&self) -> u64 {
        self.tasks as u64 * self.updates_per_core
    }

    /// The updated table; valid after the run.
    pub fn table(&self) -> Arc<Vec<AtomicU64>> {
        self.table.as_ref().expect("run first").clone()
    }
}

impl Scenario for GupsScenario {
    fn name(&self) -> &'static str {
        "gups"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        self.tasks = tasks;
        let bytes = (self.table_words * 8) as u64;
        let table_r = machine.alloc("gups-table", bytes, Placement::Interleave);
        self.region = Some((table_r, bytes));
        self.table = Some(Arc::new(
            (0..self.table_words).map(|i| AtomicU64::new(i as u64)).collect(),
        ));
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        const CHUNK: u64 = 4096;
        let (table_r, bytes) = self.region.expect("setup() before spawn()");
        let table = self.table.as_ref().unwrap().clone();
        let updates_per_core = self.updates_per_core;
        let chunks = updates_per_core.div_ceil(CHUNK);
        let mut rng = crate::util::Rng::new(self.seed ^ (rank as u64) << 32);
        Box::new(StateTask::new(move |ctx, step| {
            if step >= chunks {
                return Step::Done;
            }
            let todo = CHUNK.min(updates_per_core - step * CHUNK);
            for _ in 0..todo {
                let idx = rng.gen_index(table.len());
                let v = rng.next_u64();
                table[idx].fetch_xor(v, Ordering::Relaxed);
            }
            ctx.access(
                crate::cachesim::Access::rand_write(table_r, todo, bytes).with_mlp(4.0),
            );
            ctx.compute_flops(todo);
            if step + 1 >= chunks {
                Step::Done
            } else {
                Step::Yield
            }
        }))
    }

    fn verify(&self) {
        if self.updates() == 0 {
            return;
        }
        // XOR updates must have actually landed in the table.
        let table = self.table.as_ref().expect("run first");
        let changed = table
            .iter()
            .enumerate()
            .filter(|(i, v)| v.load(Ordering::Relaxed) != *i as u64)
            .count();
        assert!(changed > 0, "GUPS table untouched after {} updates", self.updates());
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        ScenarioMetrics::new(self.updates() as f64, "updates")
            .with("gups", report.throughput(self.updates() as f64) / 1e9)
    }
}

/// HPCC RandomAccess: XOR-updates at random table locations. Returns the
/// run and the updated table (GUPS numerator in `edges_processed`).
pub fn run_gups(
    topo: &Topology,
    policy: Box<dyn Policy>,
    cores: usize,
    table_words: usize,
    updates_per_core: u64,
    seed: u64,
) -> (GraphRun, Arc<Vec<AtomicU64>>) {
    let mut s = GupsScenario::new(table_words, updates_per_core, seed);
    let run = Driver::new(topo, policy, cores).run(&mut s);
    (
        GraphRun {
            report: run.report,
            edges_processed: s.updates(),
        },
        s.table(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::{ArcasPolicy, LocalCachePolicy, RingPolicy};
    use crate::workloads::graph::algos;
    use crate::workloads::graph::kronecker::kronecker;

    fn topo() -> Topology {
        Topology::milan_1s()
    }

    fn test_graph() -> Arc<Csr> {
        Arc::new(kronecker(10, 8, 42))
    }

    #[test]
    fn parallel_bfs_matches_reference() {
        let g = test_graph();
        let (_, par) = run_bfs(&topo(), Box::new(LocalCachePolicy), 8, g.clone(), 0);
        let ser = algos::bfs_ref(&g, 0);
        assert_eq!(par, ser);
    }

    #[test]
    fn bfs_random_roots_matches_reference_per_root() {
        let g = test_graph();
        let mut s = BfsRandomRootsScenario::new(g.clone(), 4, 11);
        assert_eq!(s.roots().len(), 4);
        for &r in s.roots() {
            assert!(g.degree(r) > 0, "sampled root {r} is isolated");
        }
        let run = Driver::new(&topo(), Box::new(LocalCachePolicy), 8)
            .with_verify(true)
            .run(&mut s);
        assert!(s.edges_processed() > 0);
        assert!(run.metrics.get("roots").unwrap() == 4.0);
    }

    #[test]
    fn bfs_random_roots_sampling_is_seeded() {
        let g = test_graph();
        let a = BfsRandomRootsScenario::new(g.clone(), 8, 3);
        let b = BfsRandomRootsScenario::new(g.clone(), 8, 3);
        let c = BfsRandomRootsScenario::new(g, 8, 4);
        assert_eq!(a.roots(), b.roots());
        assert_ne!(a.roots(), c.roots(), "different seeds must move the sample");
    }

    #[test]
    fn parallel_cc_matches_reference_components() {
        let g = test_graph();
        let (_, par) = run_cc(&topo(), Box::new(LocalCachePolicy), 8, g.clone());
        let ser = algos::cc_ref(&g);
        // Labels may differ; component *partitions* must match.
        let n = g.num_vertices();
        let mut map = std::collections::HashMap::new();
        for v in 0..n {
            let e = map.entry(par[v]).or_insert(ser[v]);
            assert_eq!(*e, ser[v], "vertex {v} crosses components");
        }
        assert_eq!(
            algos::component_count(&par),
            algos::component_count(&ser)
        );
    }

    #[test]
    fn parallel_pagerank_close_to_reference() {
        let g = test_graph();
        let (_, par) = run_pagerank(&topo(), Box::new(LocalCachePolicy), 8, g.clone(), 10);
        let ser = algos::pagerank_ref(&g, 10);
        for v in 0..g.num_vertices() {
            assert!(
                (par[v] - ser[v]).abs() < 1e-9,
                "v={v} par={} ser={}",
                par[v],
                ser[v]
            );
        }
    }

    #[test]
    fn parallel_sssp_matches_dijkstra() {
        let g = test_graph();
        let (_, par) = run_sssp(&topo(), Box::new(LocalCachePolicy), 8, g.clone(), 0);
        let ser = algos::sssp_ref(&g, 0);
        assert_eq!(par, ser);
    }

    #[test]
    fn gups_preserves_xor_invariant_shape() {
        let (run, table) = run_gups(&topo(), Box::new(LocalCachePolicy), 4, 1 << 12, 10_000, 9);
        assert_eq!(run.edges_processed, 40_000);
        assert!(run.report.makespan_ns > 0);
        // Table was actually modified.
        let changed = table
            .iter()
            .enumerate()
            .filter(|(i, v)| v.load(Ordering::Relaxed) != *i as u64)
            .count();
        assert!(changed > table.len() / 2);
    }

    #[test]
    fn arcas_beats_ring_on_bfs() {
        // The headline claim (Fig. 7): chiplet-aware placement outperforms
        // NUMA-aware RING at higher core counts on the 2-socket machine.
        let g = Arc::new(kronecker(11, 8, 7));
        let t = Topology::milan_2s();
        let arcas_policy = ArcasPolicy::new(&t).with_timer(20_000);
        let (arcas, _) = run_bfs(&t, Box::new(arcas_policy), 32, g.clone(), 0);
        let (ring, _) = run_bfs(&t, Box::new(RingPolicy::new()), 32, g.clone(), 0);
        assert!(
            arcas.report.makespan_ns < ring.report.makespan_ns,
            "arcas={} ring={}",
            arcas.report.makespan_ns,
            ring.report.makespan_ns
        );
    }

    #[test]
    fn bfs_scales_with_cores() {
        let g = test_graph();
        let (c1, _) = run_bfs(&topo(), Box::new(LocalCachePolicy), 1, g.clone(), 0);
        let (c8, _) = run_bfs(&topo(), Box::new(LocalCachePolicy), 8, g.clone(), 0);
        assert!(
            c8.report.makespan_ns < c1.report.makespan_ns,
            "8 cores {} must beat 1 core {}",
            c8.report.makespan_ns,
            c1.report.makespan_ns
        );
    }

    #[test]
    fn scenario_verify_accepts_correct_runs() {
        let g = test_graph();
        let mut s = BfsScenario::new(g.clone(), 0);
        let run = Driver::new(&topo(), Box::new(LocalCachePolicy), 8)
            .with_verify(true)
            .run(&mut s);
        assert!(run.report.makespan_ns > 0);
        assert!(run.metrics.get("teps").unwrap() > 0.0);
    }

    #[test]
    fn vertex_ranges_partition() {
        let n = 1000;
        let g = 7;
        let mut covered = 0;
        for r in 0..g {
            let (lo, hi) = vertex_range(r, g, n);
            covered += hi - lo;
        }
        assert_eq!(covered, n);
        assert_eq!(vertex_range(g - 1, g, n).1, n);
    }
}
