//! Serial reference implementations of the five graph algorithms.
//!
//! These are the correctness oracles for the parallel ARCAS runners in
//! [`super::runner`], and the `*_ref` functions double as the
//! single-threaded baselines for scalability normalization.

use super::csr::Csr;

/// BFS distances (hops) from `src`; unreachable = `u32::MAX`.
pub fn bfs_ref(g: &Csr, src: u32) -> Vec<u32> {
    let n = g.num_vertices();
    let mut dist = vec![u32::MAX; n];
    let mut frontier = vec![src];
    dist[src as usize] = 0;
    let mut level = 0u32;
    while !frontier.is_empty() {
        let mut next = Vec::new();
        for &v in &frontier {
            for &u in g.neighbors(v) {
                if dist[u as usize] == u32::MAX {
                    dist[u as usize] = level + 1;
                    next.push(u);
                }
            }
        }
        frontier = next;
        level += 1;
    }
    dist
}

/// PageRank with damping 0.85, `iters` power iterations.
pub fn pagerank_ref(g: &Csr, iters: usize) -> Vec<f64> {
    let n = g.num_vertices();
    let mut rank = vec![1.0 / n as f64; n];
    let mut next = vec![0.0f64; n];
    for _ in 0..iters {
        next.iter_mut().for_each(|x| *x = 0.0);
        let mut dangling = 0.0;
        for v in 0..n as u32 {
            let deg = g.degree(v);
            if deg == 0 {
                dangling += rank[v as usize];
                continue;
            }
            let share = rank[v as usize] / deg as f64;
            for &u in g.neighbors(v) {
                next[u as usize] += share;
            }
        }
        let base = 0.15 / n as f64 + 0.85 * dangling / n as f64;
        for x in next.iter_mut() {
            *x = base + 0.85 * *x;
        }
        std::mem::swap(&mut rank, &mut next);
    }
    rank
}

/// Connected components by label propagation (undirected semantics:
/// assumes the CSR is symmetrized). Returns per-vertex component label.
pub fn cc_ref(g: &Csr) -> Vec<u32> {
    let n = g.num_vertices();
    let mut label: Vec<u32> = (0..n as u32).collect();
    let mut changed = true;
    while changed {
        changed = false;
        for v in 0..n as u32 {
            for &u in g.neighbors(v) {
                let (lv, lu) = (label[v as usize], label[u as usize]);
                if lu < lv {
                    label[v as usize] = lu;
                    changed = true;
                } else if lv < lu {
                    label[u as usize] = lv;
                    changed = true;
                }
            }
        }
    }
    label
}

/// Single-source shortest paths (Dijkstra with a binary heap); weighted.
pub fn sssp_ref(g: &Csr, src: u32) -> Vec<u64> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let n = g.num_vertices();
    let mut dist = vec![u64::MAX; n];
    let mut heap = BinaryHeap::new();
    dist[src as usize] = 0;
    heap.push(Reverse((0u64, src)));
    while let Some(Reverse((d, v))) = heap.pop() {
        if d > dist[v as usize] {
            continue;
        }
        let (nbrs, ws) = g.neighbors_weighted(v);
        for (&u, &w) in nbrs.iter().zip(ws) {
            let nd = d + w as u64;
            if nd < dist[u as usize] {
                dist[u as usize] = nd;
                heap.push(Reverse((nd, u)));
            }
        }
    }
    dist
}

/// Count distinct components from a label array.
pub fn component_count(labels: &[u32]) -> usize {
    let mut set: Vec<u32> = labels.to_vec();
    set.sort_unstable();
    set.dedup();
    set.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::graph::kronecker::{kronecker, uniform};

    fn path_graph() -> Csr {
        // 0 - 1 - 2 - 3 (symmetric), weights 1,2,3
        Csr::from_edges(
            4,
            &[(0, 1), (1, 0), (1, 2), (2, 1), (2, 3), (3, 2)],
            Some(&[1, 1, 2, 2, 3, 3]),
        )
    }

    #[test]
    fn bfs_on_path() {
        let d = bfs_ref(&path_graph(), 0);
        assert_eq!(d, vec![0, 1, 2, 3]);
    }

    #[test]
    fn bfs_unreachable() {
        let g = Csr::from_edges(3, &[(0, 1)], None);
        let d = bfs_ref(&g, 0);
        assert_eq!(d[2], u32::MAX);
    }

    #[test]
    fn pagerank_sums_to_one() {
        let g = kronecker(8, 4, 11);
        let pr = pagerank_ref(&g, 20);
        let sum: f64 = pr.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "sum={sum}");
    }

    #[test]
    fn pagerank_hub_ranks_higher() {
        // Star: everyone points to 0 (and back).
        let mut edges = Vec::new();
        for v in 1..10u32 {
            edges.push((v, 0));
            edges.push((0, v));
        }
        let g = Csr::from_edges(10, &edges, None);
        let pr = pagerank_ref(&g, 30);
        assert!(pr[0] > pr[1] * 3.0);
    }

    #[test]
    fn cc_on_two_components() {
        let g = Csr::from_edges(5, &[(0, 1), (1, 0), (2, 3), (3, 2)], None);
        let labels = cc_ref(&g);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[2], labels[3]);
        assert_ne!(labels[0], labels[2]);
        assert_eq!(component_count(&labels), 3); // {0,1}, {2,3}, {4}
    }

    #[test]
    fn sssp_on_weighted_path() {
        let d = sssp_ref(&path_graph(), 0);
        assert_eq!(d, vec![0, 1, 3, 6]);
    }

    #[test]
    fn sssp_distances_lower_bound_bfs() {
        // With weights >= 1, sssp dist >= bfs hops.
        let g = uniform(256, 4, 5);
        let b = bfs_ref(&g, 0);
        let s = sssp_ref(&g, 0);
        for v in 0..256 {
            if b[v] != u32::MAX {
                assert!(s[v] >= b[v] as u64);
                assert!(s[v] != u64::MAX);
            }
        }
    }

    #[test]
    fn kronecker_is_mostly_connected() {
        let g = kronecker(10, 8, 3);
        let labels = cc_ref(&g);
        // The giant component should cover most vertices with ef=8.
        let mut counts = std::collections::HashMap::new();
        for &l in &labels {
            *counts.entry(l).or_insert(0usize) += 1;
        }
        let giant = *counts.values().max().unwrap();
        assert!(giant > g.num_vertices() / 2);
    }
}
