//! Compressed-sparse-row graph storage.

/// A directed graph in CSR form (out-edges). Weights are optional and used
//  by SSSP only.
#[derive(Clone, Debug)]
pub struct Csr {
    /// `offsets[v]..offsets[v+1]` indexes `targets` for vertex `v`.
    pub offsets: Vec<u64>,
    pub targets: Vec<u32>,
    /// Edge weights parallel to `targets` (empty = unweighted).
    pub weights: Vec<u32>,
}

impl Csr {
    /// Build from an edge list (u, v[, w]); self-loops kept, duplicates
    /// kept (Graph500 semantics).
    pub fn from_edges(n: usize, edges: &[(u32, u32)], weights: Option<&[u32]>) -> Self {
        let mut deg = vec![0u64; n + 1];
        for &(u, _) in edges {
            deg[u as usize + 1] += 1;
        }
        for i in 0..n {
            deg[i + 1] += deg[i];
        }
        let offsets = deg.clone();
        let mut cursor = deg;
        let mut targets = vec![0u32; edges.len()];
        let mut w_out = if weights.is_some() {
            vec![0u32; edges.len()]
        } else {
            Vec::new()
        };
        for (i, &(u, v)) in edges.iter().enumerate() {
            let pos = cursor[u as usize] as usize;
            targets[pos] = v;
            if let Some(ws) = weights {
                w_out[pos] = ws[i];
            }
            cursor[u as usize] += 1;
        }
        Self {
            offsets,
            targets,
            weights: w_out,
        }
    }

    #[inline]
    pub fn num_vertices(&self) -> usize {
        self.offsets.len() - 1
    }

    #[inline]
    pub fn num_edges(&self) -> usize {
        self.targets.len()
    }

    #[inline]
    pub fn neighbors(&self, v: u32) -> &[u32] {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        &self.targets[s..e]
    }

    #[inline]
    pub fn neighbors_weighted(&self, v: u32) -> (&[u32], &[u32]) {
        let s = self.offsets[v as usize] as usize;
        let e = self.offsets[v as usize + 1] as usize;
        (&self.targets[s..e], &self.weights[s..e])
    }

    #[inline]
    pub fn degree(&self, v: u32) -> usize {
        (self.offsets[v as usize + 1] - self.offsets[v as usize]) as usize
    }

    /// Memory footprint in bytes (what the cache model sees).
    pub fn bytes(&self) -> u64 {
        (self.offsets.len() * 8 + self.targets.len() * 4 + self.weights.len() * 4) as u64
    }

    /// Highest-degree vertex — the canonical BFS/SSSP source (Graph500
    /// requires sampling sources with nonzero degree; Kronecker graphs
    /// leave many isolated vertices after permutation).
    pub fn max_degree_vertex(&self) -> u32 {
        (0..self.num_vertices() as u32)
            .max_by_key(|&v| self.degree(v))
            .unwrap_or(0)
    }

    /// Reverse (transpose) graph — used by pull-style PageRank.
    pub fn transpose(&self) -> Csr {
        let n = self.num_vertices();
        let edges: Vec<(u32, u32)> = (0..n as u32)
            .flat_map(|u| self.neighbors(u).iter().map(move |&v| (v, u)))
            .collect();
        Csr::from_edges(n, &edges, None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> Csr {
        // 0 -> 1, 0 -> 2, 1 -> 3, 2 -> 3
        Csr::from_edges(4, &[(0, 1), (0, 2), (1, 3), (2, 3)], None)
    }

    #[test]
    fn basic_shape() {
        let g = diamond();
        assert_eq!(g.num_vertices(), 4);
        assert_eq!(g.num_edges(), 4);
        assert_eq!(g.neighbors(0), &[1, 2]);
        assert_eq!(g.neighbors(3), &[] as &[u32]);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(3), 0);
    }

    #[test]
    fn weighted_edges_align() {
        let g = Csr::from_edges(3, &[(0, 1), (0, 2), (1, 2)], Some(&[5, 7, 9]));
        let (nbrs, ws) = g.neighbors_weighted(0);
        assert_eq!(nbrs, &[1, 2]);
        assert_eq!(ws, &[5, 7]);
    }

    #[test]
    fn transpose_reverses() {
        let g = diamond();
        let t = g.transpose();
        assert_eq!(t.neighbors(3), &[1, 2]);
        assert_eq!(t.neighbors(0), &[] as &[u32]);
        assert_eq!(t.num_edges(), g.num_edges());
    }

    #[test]
    fn bytes_accounting() {
        let g = diamond();
        assert_eq!(g.bytes(), (5 * 8 + 4 * 4) as u64);
    }

    #[test]
    fn empty_graph() {
        let g = Csr::from_edges(2, &[], None);
        assert_eq!(g.num_vertices(), 2);
        assert_eq!(g.num_edges(), 0);
        assert_eq!(g.neighbors(1), &[] as &[u32]);
    }
}
