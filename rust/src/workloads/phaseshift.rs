//! Phase-shifting adaptive-scheduling workload: the adversarial proof
//! for online migration (ISSUE 8 / ROADMAP item 2).
//!
//! Two phases with opposite optimal placements, run back to back by the
//! same task group:
//!
//! - **Phase A — communication-bound.** Every step each rank sends a
//!   burst of small fabric messages to its ring neighbor
//!   (`TaskCtx::send_to_rank`). Messages pay core-to-core latency on the
//!   sender's clock (intra-chiplet ≈ 12 ns vs cross-chiplet ≈ 97 ns on
//!   `milan_1s`) but generate **zero cache-fill events**, so the
//!   profiler's remote-fill rate sits at ~0 and Algorithm 1 *compacts*
//!   the group — which is exactly right: a compact group turns neighbor
//!   messages intra-chiplet.
//! - **Phase B — bandwidth-bound.** Every step each rank random-reads a
//!   shared streaming region sized well past twice a chiplet's L3, so no
//!   compact placement can cache it. Fills (and DRAM pressure) spike the
//!   profiler rate past the spread threshold and the controller *spreads*
//!   the group back out, buying aggregate L3 and DDR channels.
//!
//! A static policy is wrong in one of the two phases by construction;
//! only the adaptive policy can win both. The `BENCH_adaptive.json`
//! bench gate (`micro_runtime --adaptive-only`) pins that
//! adaptive ≥ best-static on this scenario, and `backend_conformance`
//! pins `migrations > 0` on both backends.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::cachesim::Access;
use crate::engine::{Scenario, ScenarioMetrics};
use crate::mem::{Placement, RegionId};
use crate::sched::RunReport;
use crate::sim::Machine;
use crate::task::{Coroutine, StateTask, Step};

/// Small-message burst per rank per phase-A step.
const MSGS_PER_STEP: u64 = 24;
/// One cache line: the message payload stays latency- (not
/// bandwidth-) dominated.
const MSG_BYTES: u64 = 64;
/// Random reads per rank per phase-B step.
const READS_PER_STEP: u64 = 2048;

/// The phase-shifting scenario (`--scenario phase-shift`).
pub struct PhaseShiftScenario {
    /// Shared streaming-region size for phase B.
    bytes: u64,
    /// Steps in the communication-bound phase (per rank).
    steps_a: u64,
    /// Steps in the bandwidth-bound phase (per rank).
    steps_b: u64,
    tasks: usize,
    region: Option<RegionId>,
    /// Steps actually executed across all ranks (verify counter).
    steps_done: Arc<AtomicU64>,
}

impl PhaseShiftScenario {
    pub fn new(bytes: u64, steps_a: u64, steps_b: u64) -> Self {
        Self {
            bytes: bytes.max(1),
            steps_a: steps_a.max(1),
            steps_b: steps_b.max(1),
            tasks: 0,
            region: None,
            steps_done: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Total steps the group runs (metrics numerator).
    pub fn total_steps(&self) -> u64 {
        self.tasks as u64 * (self.steps_a + self.steps_b)
    }
}

impl Scenario for PhaseShiftScenario {
    fn name(&self) -> &'static str {
        "phase-shift"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        self.tasks = tasks;
        self.region = Some(machine.alloc("phase-b-stream", self.bytes, Placement::Interleave));
        self.steps_done.store(0, Ordering::Relaxed);
    }

    fn spawn(&mut self, _rank: usize) -> Box<dyn Coroutine> {
        let region = self.region.expect("setup() before spawn()");
        let bytes = self.bytes;
        let (steps_a, total) = (self.steps_a, self.steps_a + self.steps_b);
        let counter = self.steps_done.clone();
        Box::new(StateTask::new(move |ctx, step| {
            if step >= total {
                return Step::Done;
            }
            if step < steps_a {
                // Communication-bound: a burst of small messages to the
                // ring neighbor. Charged to the sender's clock at the
                // live core-to-core distance (peer placement is read per
                // message, so migrations change the cost mid-run) —
                // invisible to the fill-event counters.
                let next = (ctx.rank + 1) % ctx.group_size;
                for _ in 0..MSGS_PER_STEP {
                    ctx.send_to_rank(next, MSG_BYTES);
                }
                ctx.compute_ns(100);
            } else {
                // Bandwidth-bound: stream random reads over the shared
                // region; it overflows any compact placement's L3, so
                // fills/DRAM pressure push the profiler rate up.
                ctx.access(Access::rand_read(region, READS_PER_STEP, bytes).with_mlp(4.0));
                ctx.compute_ns(100);
            }
            counter.fetch_add(1, Ordering::Relaxed);
            if step + 1 >= total {
                Step::Done
            } else {
                Step::Yield
            }
        }))
    }

    fn verify(&self) {
        let done = self.steps_done.load(Ordering::Relaxed);
        assert_eq!(
            done,
            self.total_steps(),
            "every rank must run both phases to completion"
        );
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        ScenarioMetrics::new(self.total_steps() as f64, "steps")
            .with("phase_a_steps", (self.tasks as u64 * self.steps_a) as f64)
            .with("phase_b_steps", (self.tasks as u64 * self.steps_b) as f64)
            .with("migrations", report.migrations as f64)
    }
}

/// Random reads per rank per mem-follow stream step.
const MF_READS_PER_STEP: u64 = 4096;

/// The memory-stranding scenario (`--scenario mem-follow`): the
/// adversarial proof for online *region* moves, the data half of ISSUE 9.
///
/// - **Phase A — communication-bound.** Identical to
///   [`PhaseShiftScenario`]'s phase A: ring-neighbor message bursts with
///   zero fill events, so the adaptive controller *compacts* the group
///   (onto chiplet 0, i.e. NUMA node 0).
/// - **Phase B — DRAM-bound on stranded data.** Every rank random-reads
///   a shared region bound to the *last* NUMA node, sized far past any
///   L3 so nearly every access is a DRAM line. Because DRAM lines are
///   not remote-chiplet *fill* events, the profiler rate stays low and
///   the group stays compact on NUMA 0 — while every line pays the
///   cross-NUMA DDR path to the region's stranded home.
///
/// Task migration alone cannot fix phase B (compact-vs-spread never
/// relocates the *data*); only a policy that closes the memory loop can,
/// by rebinding the region to its accessors' node for a one-time copy
/// charge. The `BENCH_mem_follow.json` gate (`micro_runtime
/// --mem-follow-only`) pins that adaptive-with-region-moves beats
/// task-move-only on this scenario.
pub struct MemFollowScenario {
    /// Stranded-region size for phase B.
    bytes: u64,
    steps_a: u64,
    steps_b: u64,
    tasks: usize,
    region: Option<RegionId>,
    /// Steps actually executed across all ranks (verify counter).
    steps_done: Arc<AtomicU64>,
}

impl MemFollowScenario {
    pub fn new(bytes: u64, steps_a: u64, steps_b: u64) -> Self {
        Self {
            bytes: bytes.max(1),
            steps_a: steps_a.max(1),
            steps_b: steps_b.max(1),
            tasks: 0,
            region: None,
            steps_done: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Total steps the group runs (metrics numerator).
    pub fn total_steps(&self) -> u64 {
        self.tasks as u64 * (self.steps_a + self.steps_b)
    }
}

impl Scenario for MemFollowScenario {
    fn name(&self) -> &'static str {
        "mem-follow"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        self.tasks = tasks;
        // Strand the stream on the highest NUMA node: phase A compacts
        // the group onto node 0, so on any multi-node topology the home
        // is maximally wrong by the time phase B starts. (On one-node
        // topologies the scenario still runs; there is just nothing to
        // move.)
        let home = machine.topo.num_numa() - 1;
        self.region = Some(machine.alloc("mem-follow-stream", self.bytes, Placement::Bind(home)));
        self.steps_done.store(0, Ordering::Relaxed);
    }

    fn spawn(&mut self, _rank: usize) -> Box<dyn Coroutine> {
        let region = self.region.expect("setup() before spawn()");
        let bytes = self.bytes;
        let (steps_a, total) = (self.steps_a, self.steps_a + self.steps_b);
        let counter = self.steps_done.clone();
        Box::new(StateTask::new(move |ctx, step| {
            if step >= total {
                return Step::Done;
            }
            if step < steps_a {
                // Communication-bound: compacts the group (see
                // PhaseShiftScenario's phase A).
                let next = (ctx.rank + 1) % ctx.group_size;
                for _ in 0..MSGS_PER_STEP {
                    ctx.send_to_rank(next, MSG_BYTES);
                }
                ctx.compute_ns(100);
            } else {
                // DRAM-bound: the region dwarfs every L3, so the lines
                // stream from the region's home DDR — cross-NUMA until a
                // region move follows the data to the accessors.
                ctx.access(Access::rand_read(region, MF_READS_PER_STEP, bytes).with_mlp(2.0));
                ctx.compute_ns(200);
            }
            counter.fetch_add(1, Ordering::Relaxed);
            if step + 1 >= total {
                Step::Done
            } else {
                Step::Yield
            }
        }))
    }

    fn verify(&self) {
        let done = self.steps_done.load(Ordering::Relaxed);
        assert_eq!(
            done,
            self.total_steps(),
            "every rank must run both phases to completion"
        );
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        ScenarioMetrics::new(self.total_steps() as f64, "steps")
            .with("migrations", report.migrations as f64)
            .with("region_moves", report.region_moves as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Driver;
    use crate::policy::ArcasPolicy;
    use crate::topology::Topology;

    #[test]
    fn both_phases_run_and_verify() {
        let topo = Topology::milan_1s();
        let mut s = PhaseShiftScenario::new(96 << 20, 8, 8);
        let run = Driver::new(&topo, Box::new(ArcasPolicy::new(&topo)), 16)
            .with_verify(true)
            .run(&mut s);
        assert_eq!(run.metrics.items, 16.0 * 16.0);
        assert_eq!(run.report.dispatches, 16 * 16);
    }

    #[test]
    fn mem_follow_runs_and_verifies_without_moves() {
        let topo = Topology::milan_1s();
        let mut s = MemFollowScenario::new(2 << 30, 4, 4);
        let run = Driver::new(&topo, Box::new(ArcasPolicy::new(&topo)), 8)
            .with_verify(true)
            .run(&mut s);
        assert_eq!(run.metrics.items, 8.0 * 8.0);
        // One NUMA node: nothing to move, and the policy must know it.
        assert_eq!(run.report.region_moves, 0);
    }

    #[test]
    fn adaptive_moves_the_stranded_region_to_its_accessors() {
        // Phase A compacts the group onto NUMA 0 (long enough to cover
        // the controller's warmup plus the spread ramp-down); phase B
        // streams the region stranded on NUMA 3. The mostly-DRAM stream
        // keeps the fill rate low (DRAM lines are not fill events), so
        // the group stays compact and the heat majority sits on NUMA 0 —
        // the policy must rebind the region there, away from its home.
        let topo = crate::topology::Topology::milan_1s_nps4();
        let home = topo.num_numa() - 1;
        let mut s = MemFollowScenario::new(2 << 30, 120, 60);
        let policy = Box::new(ArcasPolicy::new(&topo).with_timer(10_000));
        let run = Driver::new(&topo, policy, 16).with_verify(true).run(&mut s);
        assert!(
            run.report.region_moves > 0,
            "the stranded region must follow its accessors: {:?}",
            run.report.decisions
        );
        for (_, _, to) in &run.report.region_decisions {
            assert_ne!(*to, home, "a move must leave the stranded home");
            assert!(*to < topo.num_numa());
        }
    }

    #[test]
    fn region_moves_can_be_disabled() {
        let topo = crate::topology::Topology::milan_1s_nps4();
        let mut s = MemFollowScenario::new(2 << 30, 120, 60);
        let policy =
            Box::new(ArcasPolicy::new(&topo).with_timer(10_000).with_region_moves(false));
        let run = Driver::new(&topo, policy, 16).with_verify(true).run(&mut s);
        assert_eq!(run.report.region_moves, 0);
        assert!(run.report.region_decisions.is_empty());
    }

    #[test]
    fn adaptive_migrates_on_the_shift_in_virtual_time() {
        // Sim backend, policy timer in virtual ns: phase A's ~zero fill
        // rate compacts the initially spread group, phase B's fill storm
        // spreads it back out — both transitions are migrations.
        let topo = Topology::milan_1s();
        let mut s = PhaseShiftScenario::new(96 << 20, 60, 60);
        let policy = Box::new(ArcasPolicy::new(&topo).with_timer(20_000));
        let run = Driver::new(&topo, policy, 16).with_verify(true).run(&mut s);
        assert!(
            run.report.migrations > 0,
            "the phase shift must trigger adaptive migrations: {:?}",
            run.report.decisions
        );
    }
}
