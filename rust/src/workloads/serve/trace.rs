//! Deterministic request traces for the serving subsystem.
//!
//! A trace is a time-ordered list of point-op requests — the open-loop
//! arrival process the dispatcher replays regardless of how fast the
//! servers drain it (arrivals never wait on completions; that is what
//! makes tail latency honest). Two sources:
//!
//! - **Synthetic generators** ([`Trace::synth`]): Zipfian key popularity
//!   over a configurable keyspace, and a choice of arrival processes —
//!   evenly spaced, Poisson, diurnally modulated Poisson (a slow
//!   sinusoidal load swing, the "day/night" shape of user traffic) and
//!   on/off bursts. All draws come from the repo's seeded PRNG, so a
//!   `(config, seed)` pair is a reproducible workload.
//! - **Text traces** ([`Trace::parse`] / [`Trace::load`]): a tiny
//!   line-oriented format for replaying recorded or hand-written traffic:
//!
//!   ```text
//!   # arcas request trace: "<arrival_ns> <op> <key> [priority]" per line
//!   0 r 17
//!   250 u 3 critical
//!   900 r 17 bg
//!   ```
//!
//!   `#` starts a comment, blank lines are skipped, ops are `r`/`read`
//!   and `u`/`update` (alias `w`/`write`), arrivals are non-decreasing
//!   nanoseconds. The optional fourth column is a priority class
//!   (`critical`/`normal`/`background`, defaulting to normal — see
//!   [`Priority`]). [`Trace::to_text`] writes the same format back, so
//!   traces round-trip.
//!
//! Synthetic traces assign priorities per *key* (a key models a tenant):
//! a [`PriorityMix`] carves the keyspace into critical / background
//! tenants by hashing the key, so the class assignment adds no PRNG
//! draws and the arrival/op/key stream is byte-identical with or
//! without a mix.

use std::path::Path;

use crate::engine::dispatch::{Prioritized, Priority};
use crate::util::prng::Rng;

/// A request's operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReqOp {
    /// Point read of a key.
    Read,
    /// Read-modify-write of a key.
    Update,
}

impl ReqOp {
    pub fn as_str(self) -> &'static str {
        match self {
            ReqOp::Read => "r",
            ReqOp::Update => "u",
        }
    }
}

/// One request: when it arrives (virtual ns since trace start), what it
/// asks for, and its priority class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Request {
    pub arrival_ns: u64,
    pub op: ReqOp,
    pub key: u64,
    pub priority: Priority,
}

impl Prioritized for Request {
    fn arrival_ns(&self) -> u64 {
        self.arrival_ns
    }

    fn priority(&self) -> Priority {
        self.priority
    }
}

/// Fractions of tenants (keys) assigned to the non-default priority
/// classes; the remainder is Normal. Assignment is by key hash, so a
/// key's class is stable across the whole trace — a tenant is critical,
/// not an individual request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PriorityMix {
    /// Fraction of the keyspace that is [`Priority::Critical`].
    pub critical: f64,
    /// Fraction of the keyspace that is [`Priority::Background`].
    pub background: f64,
}

impl PriorityMix {
    /// Parse the CLI form `"<critical>,<background>"` (two fractions,
    /// e.g. `0.2,0.3`), validating each lies in `[0, 1]` and the pair
    /// sums to at most 1.
    pub fn parse(s: &str) -> Result<PriorityMix, String> {
        let err = || {
            format!(
                "bad --priority-mix {s:?}: expected \"<critical>,<background>\" \
                 fractions, e.g. 0.2,0.3"
            )
        };
        let (c, b) = s.split_once(',').ok_or_else(err)?;
        let critical: f64 = c.trim().parse().map_err(|_| err())?;
        let background: f64 = b.trim().parse().map_err(|_| err())?;
        if !(0.0..=1.0).contains(&critical)
            || !(0.0..=1.0).contains(&background)
            || critical + background > 1.0
        {
            return Err(format!(
                "bad --priority-mix {s:?}: fractions must lie in [0, 1] and sum to <= 1"
            ));
        }
        Ok(PriorityMix {
            critical,
            background,
        })
    }

    /// The class of a key (tenant): a hash of the key is mapped to
    /// `[0, 1)` and compared against the critical/background bands.
    /// Deterministic, PRNG-free — mixing priorities into a trace never
    /// perturbs its arrival/op/key stream.
    pub fn class_for_key(&self, key: u64) -> Priority {
        // splitmix64 finalizer: cheap, well-mixed 64-bit avalanche.
        let mut x = key.wrapping_add(0x9E37_79B9_7F4A_7C15);
        x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        let u = (x >> 11) as f64 / (1u64 << 53) as f64;
        if u < self.critical {
            Priority::Critical
        } else if u < self.critical + self.background {
            Priority::Background
        } else {
            Priority::Normal
        }
    }
}

/// The arrival process of a synthetic trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalModel {
    /// Evenly spaced at the mean rate (deterministic spacing).
    Uniform,
    /// Poisson process: exponential interarrivals at the mean rate.
    Poisson,
    /// Poisson with a sinusoidally modulated rate:
    /// `rate(t) = mean * (1 + depth * sin(2πt/period))`, the diurnal
    /// load swing compressed to simulation timescales.
    Diurnal { period_ns: u64, depth: f64 },
    /// On/off bursts: `burst` requests arrive back-to-back at 10× the
    /// mean rate, then the gap stretches so the long-run rate stays at
    /// the configured mean.
    Bursty { burst: usize },
}

/// Knobs of a synthetic trace.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub requests: usize,
    /// Mean offered load, requests per second (of virtual time).
    pub rate_rps: f64,
    /// Keys are drawn from `[0, keyspace)`.
    pub keyspace: u64,
    /// Zipfian skew of key popularity (YCSB default 0.99; 0 = uniform).
    pub zipf_theta: f64,
    /// Fraction of reads (the rest are updates).
    pub read_frac: f64,
    pub arrivals: ArrivalModel,
    pub seed: u64,
    /// Optional per-tenant priority assignment; `None` = all Normal.
    pub priority_mix: Option<PriorityMix>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        Self {
            requests: 20_000,
            rate_rps: 2.0e6,
            keyspace: 1 << 20,
            zipf_theta: 0.99,
            read_frac: 0.45,
            arrivals: ArrivalModel::Poisson,
            seed: 42,
            priority_mix: None,
        }
    }
}

/// A time-ordered request trace.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Trace {
    pub requests: Vec<Request>,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Arrival time of the last request (0 for empty traces).
    pub fn last_arrival_ns(&self) -> u64 {
        self.requests.last().map_or(0, |r| r.arrival_ns)
    }

    /// Long-run offered rate implied by the trace (requests per second
    /// of virtual time).
    pub fn offered_rate_rps(&self) -> f64 {
        if self.requests.len() < 2 {
            return 0.0;
        }
        self.requests.len() as f64 / (self.last_arrival_ns().max(1) as f64 / 1e9)
    }

    /// Generate a synthetic trace — deterministic in `cfg` (seed
    /// included).
    pub fn synth(cfg: &TraceConfig) -> Trace {
        assert!(cfg.rate_rps > 0.0, "trace rate must be positive");
        assert!(cfg.keyspace > 0, "trace keyspace must be non-empty");
        let mut rng = Rng::new(cfg.seed ^ 0x5E2F_7ACE);
        let mean_gap_ns = 1e9 / cfg.rate_rps;
        let mut t = 0.0f64;
        let mut requests = Vec::with_capacity(cfg.requests);
        for i in 0..cfg.requests {
            let gap = match cfg.arrivals {
                ArrivalModel::Uniform => mean_gap_ns,
                ArrivalModel::Poisson => rng.gen_exp(1.0 / mean_gap_ns),
                ArrivalModel::Diurnal { period_ns, depth } => {
                    let depth = depth.clamp(0.0, 0.95);
                    let phase = 2.0 * std::f64::consts::PI * t / period_ns.max(1) as f64;
                    let rate = (1.0 + depth * phase.sin()).max(0.05) / mean_gap_ns;
                    rng.gen_exp(rate)
                }
                ArrivalModel::Bursty { burst } => {
                    let burst = burst.max(1);
                    if i % burst == 0 && i > 0 {
                        // The off period repays the burst's 10x-rate
                        // spacing so the long-run mean holds.
                        mean_gap_ns * (burst as f64 - (burst - 1) as f64 / 10.0)
                    } else {
                        mean_gap_ns / 10.0
                    }
                }
            };
            t += gap;
            let op = if rng.gen_bool(cfg.read_frac) {
                ReqOp::Read
            } else {
                ReqOp::Update
            };
            let key = rng.gen_zipf(cfg.keyspace, cfg.zipf_theta);
            let priority = cfg
                .priority_mix
                .map_or(Priority::Normal, |m| m.class_for_key(key));
            requests.push(Request {
                arrival_ns: t as u64,
                op,
                key,
                priority,
            });
        }
        Trace { requests }
    }

    /// Rotate the keyspace by `stride` every `period_ns` of virtual
    /// time: request `r`'s key becomes
    /// `(r.key + stride * (r.arrival_ns / period_ns)) % keyspace`.
    ///
    /// This turns a static Zipf head into a *moving* hotspot — the hot
    /// key range walks across the keyspace as the trace plays out, so a
    /// static key→shard table goes stale and cluster rebalancing
    /// ([`crate::policy::Policy::plan_shard_moves`]) has something to
    /// chase. Arrival times, ops, and priorities are untouched, the
    /// pass is PRNG-free, and `stride = 0` returns the trace
    /// byte-identical.
    pub fn with_hotspot_drift(mut self, period_ns: u64, stride: u64, keyspace: u64) -> Trace {
        assert!(keyspace > 0, "hotspot drift needs a non-empty keyspace");
        let period = period_ns.max(1);
        for r in &mut self.requests {
            let epoch = r.arrival_ns / period;
            let shift = (stride as u128 * epoch as u128 % keyspace as u128) as u64;
            r.key = ((r.key % keyspace) as u128 + shift as u128) as u64 % keyspace;
        }
        self
    }

    /// Parse the text trace format. Strict: malformed lines and
    /// out-of-order arrivals are errors (a silently reordered trace
    /// would corrupt every latency number derived from it).
    pub fn parse(text: &str) -> Result<Trace, String> {
        let mut requests = Vec::new();
        let mut last_arrival = 0u64;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut fields = line.split_whitespace();
            let (a, o, k, p) = match (
                fields.next(),
                fields.next(),
                fields.next(),
                fields.next(),
                fields.next(),
            ) {
                (Some(a), Some(o), Some(k), p, None) => (a, o, k, p),
                _ => {
                    return Err(format!(
                        "trace line {}: expected \"<arrival_ns> <op> <key> [priority]\", got {raw:?}",
                        lineno + 1
                    ))
                }
            };
            let arrival_ns: u64 = a.parse().map_err(|_| {
                format!("trace line {}: bad arrival {a:?}", lineno + 1)
            })?;
            let op = match o {
                "r" | "read" => ReqOp::Read,
                "u" | "update" | "w" | "write" => ReqOp::Update,
                other => {
                    return Err(format!(
                        "trace line {}: unknown op {other:?} (r|read|u|update)",
                        lineno + 1
                    ))
                }
            };
            let key: u64 = k
                .parse()
                .map_err(|_| format!("trace line {}: bad key {k:?}", lineno + 1))?;
            let priority = match p {
                None => Priority::Normal,
                Some(s) => s
                    .parse()
                    .map_err(|e| format!("trace line {}: {e}", lineno + 1))?,
            };
            if arrival_ns < last_arrival {
                return Err(format!(
                    "trace line {}: arrivals must be non-decreasing ({arrival_ns} after {last_arrival})",
                    lineno + 1
                ));
            }
            last_arrival = arrival_ns;
            requests.push(Request {
                arrival_ns,
                op,
                key,
                priority,
            });
        }
        if requests.is_empty() {
            return Err("trace contains no requests".into());
        }
        Ok(Trace { requests })
    }

    /// Load a text trace from a file.
    pub fn load(path: &Path) -> Result<Trace, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read trace {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// Serialize back to the text format (round-trips through
    /// [`Trace::parse`]).
    pub fn to_text(&self) -> String {
        let mut out = String::with_capacity(16 * self.requests.len() + 64);
        out.push_str("# arcas request trace: \"<arrival_ns> <op> <key> [priority]\" per line\n");
        for r in &self.requests {
            match r.priority {
                Priority::Normal => {
                    out.push_str(&format!("{} {} {}\n", r.arrival_ns, r.op.as_str(), r.key))
                }
                p => out.push_str(&format!(
                    "{} {} {} {}\n",
                    r.arrival_ns,
                    r.op.as_str(),
                    r.key,
                    p.as_str()
                )),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(model: ArrivalModel) -> TraceConfig {
        TraceConfig {
            requests: 4_000,
            rate_rps: 1.0e6,
            keyspace: 10_000,
            arrivals: model,
            ..Default::default()
        }
    }

    #[test]
    fn synth_is_deterministic_and_ordered() {
        for model in [
            ArrivalModel::Uniform,
            ArrivalModel::Poisson,
            ArrivalModel::Diurnal {
                period_ns: 500_000,
                depth: 0.8,
            },
            ArrivalModel::Bursty { burst: 32 },
        ] {
            let a = Trace::synth(&cfg(model));
            let b = Trace::synth(&cfg(model));
            assert_eq!(a, b, "{model:?} must be reproducible");
            assert_eq!(a.len(), 4_000);
            for w in a.requests.windows(2) {
                assert!(w[0].arrival_ns <= w[1].arrival_ns, "{model:?} out of order");
            }
            assert!(a.requests.iter().all(|r| r.key < 10_000));
        }
    }

    #[test]
    fn synth_hits_the_mean_rate() {
        for model in [
            ArrivalModel::Uniform,
            ArrivalModel::Poisson,
            ArrivalModel::Bursty { burst: 64 },
        ] {
            let t = Trace::synth(&cfg(model));
            let rate = t.offered_rate_rps();
            assert!(
                (0.8..1.25).contains(&(rate / 1.0e6)),
                "{model:?}: offered {rate:.0} rps vs 1M configured"
            );
        }
    }

    #[test]
    fn zipf_keys_are_skewed() {
        let t = Trace::synth(&cfg(ArrivalModel::Poisson));
        let hot = t.requests.iter().filter(|r| r.key == 0).count();
        // Uniform share would be 4000/10000 < 1; the Zipf head gets far more.
        assert!(hot > 100, "hottest key drew {hot} of 4000");
    }

    #[test]
    fn bursty_gaps_alternate() {
        let t = Trace::synth(&TraceConfig {
            requests: 300,
            rate_rps: 1.0e6,
            arrivals: ArrivalModel::Bursty { burst: 100 },
            ..Default::default()
        });
        let gap = |i: usize| t.requests[i].arrival_ns - t.requests[i - 1].arrival_ns;
        // Within a burst: ~mean/10; at the burst boundary: a long gap.
        assert!(gap(50) < 500);
        assert!(gap(100) > 50_000);
    }

    #[test]
    fn hotspot_drift_rotates_keys_per_epoch() {
        let base = Trace::synth(&cfg(ArrivalModel::Uniform));
        let ks = 10_000u64;
        let drifted = base.clone().with_hotspot_drift(1_000_000, 2_500, ks);
        assert_eq!(drifted.len(), base.len());
        for (b, d) in base.requests.iter().zip(&drifted.requests) {
            // Only the key moves; timing/op/priority are untouched.
            assert_eq!(b.arrival_ns, d.arrival_ns);
            assert_eq!(b.op, d.op);
            assert_eq!(b.priority, d.priority);
            let epoch = b.arrival_ns / 1_000_000;
            let want = (b.key + 2_500 * (epoch % 4)) % ks;
            assert_eq!(d.key, want, "key rotation wrong at t={}", b.arrival_ns);
            assert!(d.key < ks);
        }
        // The 4ms trace spans ≥2 epochs, so some keys actually moved.
        assert_ne!(base, drifted);
        // Deterministic and stride-0 is the identity.
        let again = base.clone().with_hotspot_drift(1_000_000, 2_500, ks);
        assert_eq!(drifted, again);
        assert_eq!(base.clone().with_hotspot_drift(1_000_000, 0, ks), base);
    }

    #[test]
    fn text_format_round_trips() {
        let t = Trace::synth(&TraceConfig {
            requests: 200,
            ..Default::default()
        });
        let parsed = Trace::parse(&t.to_text()).unwrap();
        assert_eq!(parsed, t);
    }

    #[test]
    fn parse_accepts_comments_and_aliases() {
        let t = Trace::parse(
            "# header\n\n10 r 5\n20 read 6\n20 u 7\n30 update 8\n40 w 9\n",
        )
        .unwrap();
        assert_eq!(t.len(), 5);
        assert_eq!(t.requests[0].op, ReqOp::Read);
        assert_eq!(t.requests[2].op, ReqOp::Update);
        assert_eq!(t.requests[4].op, ReqOp::Update);
        assert_eq!(t.last_arrival_ns(), 40);
    }

    #[test]
    fn parse_rejects_malformed_traces() {
        for (bad, why) in [
            ("", "empty"),
            ("# only comments\n", "no requests"),
            ("10 r\n", "missing key"),
            ("10 r 5 urgent\n", "unknown priority"),
            ("10 r 5 critical extra\n", "extra field"),
            ("x r 5\n", "bad arrival"),
            ("10 q 5\n", "unknown op"),
            ("10 r x\n", "bad key"),
            ("20 r 1\n10 r 2\n", "out of order"),
        ] {
            assert!(Trace::parse(bad).is_err(), "{why}: {bad:?} must not parse");
        }
    }

    #[test]
    fn parse_accepts_a_priority_column_defaulting_to_normal() {
        let t = Trace::parse("10 r 5\n20 u 6 critical\n30 r 7 bg\n40 r 8 n\n").unwrap();
        let classes: Vec<Priority> = t.requests.iter().map(|r| r.priority).collect();
        assert_eq!(
            classes,
            vec![
                Priority::Normal,
                Priority::Critical,
                Priority::Background,
                Priority::Normal
            ]
        );
    }

    #[test]
    fn priorities_round_trip_through_the_text_format() {
        let t = Trace::synth(&TraceConfig {
            requests: 500,
            keyspace: 64, // few tenants: every class is populated
            priority_mix: Some(PriorityMix {
                critical: 0.25,
                background: 0.25,
            }),
            ..Default::default()
        });
        for p in Priority::ALL {
            assert!(
                t.requests.iter().any(|r| r.priority == p),
                "mix produced no {p} requests"
            );
        }
        let parsed = Trace::parse(&t.to_text()).unwrap();
        assert_eq!(parsed, t);
    }

    /// The priority mix must not perturb the arrival/op/key stream: a
    /// mixed trace is the all-Normal trace plus a class column.
    #[test]
    fn priority_mix_leaves_the_request_stream_byte_identical() {
        let base = cfg(ArrivalModel::Poisson);
        let plain = Trace::synth(&base);
        let mixed = Trace::synth(&TraceConfig {
            priority_mix: Some(PriorityMix {
                critical: 0.2,
                background: 0.3,
            }),
            ..base
        });
        assert!(plain.requests.iter().all(|r| r.priority == Priority::Normal));
        for (a, b) in plain.requests.iter().zip(&mixed.requests) {
            assert_eq!((a.arrival_ns, a.op, a.key), (b.arrival_ns, b.op, b.key));
        }
        // Same key -> same class, everywhere in the trace.
        let mix = PriorityMix {
            critical: 0.2,
            background: 0.3,
        };
        for r in &mixed.requests {
            assert_eq!(r.priority, mix.class_for_key(r.key));
        }
    }

    #[test]
    fn priority_mix_hits_the_configured_shares() {
        let mix = PriorityMix {
            critical: 0.2,
            background: 0.3,
        };
        let n = 100_000u64;
        let crit = (0..n)
            .filter(|&k| mix.class_for_key(k) == Priority::Critical)
            .count() as f64
            / n as f64;
        let bg = (0..n)
            .filter(|&k| mix.class_for_key(k) == Priority::Background)
            .count() as f64
            / n as f64;
        assert!((crit - 0.2).abs() < 0.01, "critical share {crit}");
        assert!((bg - 0.3).abs() < 0.01, "background share {bg}");
    }

    #[test]
    fn priority_mix_parses_and_validates() {
        assert_eq!(
            PriorityMix::parse("0.2,0.3").unwrap(),
            PriorityMix {
                critical: 0.2,
                background: 0.3,
            }
        );
        assert_eq!(
            PriorityMix::parse(" 0 , 1 ").unwrap(),
            PriorityMix {
                critical: 0.0,
                background: 1.0,
            }
        );
        for bad in ["", "0.2", "0.2,0.3,0.4", "x,0.3", "0.8,0.8", "-0.1,0.2"] {
            let err = PriorityMix::parse(bad).unwrap_err();
            assert!(err.contains("--priority-mix"), "{bad:?}: {err}");
        }
    }
}
