//! Trace-replay request serving: the "millions of users" story.
//!
//! Every other workload in the registry is a batch job — build data,
//! burn through it, report a makespan. A serving system answers a
//! different question: requests arrive on *their* schedule (the trace),
//! and what matters is how long each one waited. This module turns the
//! engine seam into that experiment:
//!
//! - [`trace`] — deterministic request traces: seeded synthetic
//!   generators (Zipfian keys; uniform/Poisson/diurnal/bursty open-loop
//!   arrivals) and a tiny text format for replaying recorded traffic.
//! - **Server workers** — each rank is a server coroutine that claims
//!   requests FCFS from an [`OpenLoopQueue`] (engine-side dispatcher).
//!   An idle server *waits for the next arrival* (advances its virtual
//!   clock to the request's timestamp); a backlogged one starts service
//!   immediately — so sojourn = queue wait + service, measured per
//!   request in virtual time and folded into a log-scaled histogram
//!   ([`LatencyRecorder`]) that the driver attaches to
//!   [`RunReport::request_latency`].
//! - [`ServeKvScenario`] (`serve-kv`) — YCSB-style point reads/updates
//!   over the shared [`Store`] from the OLTP engine: zipfian key
//!   contention, a shared commit line and log appends on the update
//!   path.
//! - [`ServeMixedScenario`] (`serve-mixed`) — the same KV traffic
//!   co-resident with the TPC-H scan tenant from [`mixed`]: the scan
//!   evicts the KV working set and queues on the same DDR trackers, so
//!   the serving tail directly measures cross-tenant interference.
//!
//! Both scenarios run on the Sim backend (deterministic latency
//! distributions — the paper-figure path, see `fig_serving`) and the
//! Host backend (real threads racing on the same admission queue; every
//! request still served exactly once).

pub mod trace;

pub use trace::{ArrivalModel, ReqOp, Request, Trace, TraceConfig};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cachesim::Access;
use crate::engine::{LatencyRecorder, OpenLoopQueue, Scenario, ScenarioMetrics};
use crate::mem::{Placement, RegionId};
use crate::sched::{LatencyReport, RunReport};
use crate::sim::Machine;
use crate::task::{Coroutine, StateTask, Step};
use crate::util::stats::LogHistogram;
use crate::workloads::mixed::ScanTenant;
use crate::workloads::olap::{Db, QuerySpec};
use crate::workloads::oltp::Store;

/// The KV serving tenant: store + commit/log regions + the admission
/// queue and latency accounting, shared by `serve-kv` and `serve-mixed`.
struct KvTenant {
    store: Arc<Store>,
    commit_region: RegionId,
    log_region: RegionId,
    queue: Arc<OpenLoopQueue<Request>>,
    served: Arc<AtomicU64>,
    conflicts: Arc<AtomicU64>,
    lat: Arc<Mutex<LatencyRecorder>>,
}

impl KvTenant {
    fn new(machine: &mut Machine, label_prefix: &str, records: usize, trace: &Trace) -> Self {
        let store = Arc::new(Store::new(
            machine,
            &format!("{label_prefix}-kv-table"),
            records,
            100,
        ));
        let commit_region =
            machine.alloc(&format!("{label_prefix}-commit-counter"), 64, Placement::Bind(0));
        let log_region =
            machine.alloc(&format!("{label_prefix}-log"), 64 << 20, Placement::Bind(0));
        Self {
            store,
            commit_region,
            log_region,
            queue: OpenLoopQueue::new(trace.requests.clone()),
            served: Arc::new(AtomicU64::new(0)),
            conflicts: Arc::new(AtomicU64::new(0)),
            lat: Arc::new(Mutex::new(LatencyRecorder::new())),
        }
    }

    fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    fn conflicts(&self) -> u64 {
        self.conflicts.load(Ordering::Relaxed)
    }

    fn report(&self) -> Option<LatencyReport> {
        self.lat.lock().unwrap().report()
    }

    fn histogram(&self) -> LogHistogram {
        self.lat.lock().unwrap().histogram().clone()
    }

    /// One server worker: a coroutine serving one request per step
    /// (every request is a scheduling/profiling/migration point), with
    /// per-request sojourn recorded locally and merged once at drain.
    fn worker(&self) -> Box<dyn Coroutine> {
        let store = self.store.clone();
        let commit_region = self.commit_region;
        let log_region = self.log_region;
        let queue = self.queue.clone();
        let served = self.served.clone();
        let conflicts = self.conflicts.clone();
        let lat = self.lat.clone();
        let mut local = LatencyRecorder::new();
        Box::new(StateTask::new(move |ctx, _step| {
            let Some(req) = queue.pop() else {
                // Trace drained: publish this worker's latency samples.
                lat.lock().unwrap().merge(&local);
                local = LatencyRecorder::new();
                return Step::Done;
            };
            // Open loop: an idle server waits for the arrival; a
            // backlogged one starts immediately (the request was
            // queueing while every server was busy).
            let v = ctx.view();
            if v.now() < req.arrival_ns {
                v.advance_to(req.arrival_ns);
            }
            let start = v.now();
            let key = req.key as usize;
            match req.op {
                ReqOp::Read => {
                    let _ = store.read(key);
                    ctx.access(Access::rand_read(store.region, 1, store.bytes).with_mlp(1.0));
                }
                ReqOp::Update => {
                    if !store.rmw(key, 1) {
                        conflicts.fetch_add(1, Ordering::Relaxed);
                    }
                    // Read-modify-write: point read + write back, then
                    // the commit path (shared counter line ping-pong,
                    // log append, ~600 ns latch) — the same cost shape
                    // as the OLTP engine's commit.
                    ctx.access(Access::rand_read(store.region, 1, store.bytes).with_mlp(1.0));
                    ctx.access(Access::rand_write(store.region, 1, store.bytes).with_mlp(1.0));
                    ctx.rand_write(commit_region, 1, 64);
                    ctx.seq_write(log_region, 128);
                    ctx.compute_ns(600);
                }
            }
            // Request parse/dispatch CPU.
            ctx.compute_flops(300);
            let end = ctx.view().now();
            local.record(start - req.arrival_ns, end - start);
            served.fetch_add(1, Ordering::Relaxed);
            Step::Yield
        }))
    }
}

/// `serve-kv`: open-loop trace replay of YCSB-style point ops over the
/// OLTP engine's record store, with per-request latency accounting.
pub struct ServeKvScenario {
    records: usize,
    trace: Arc<Trace>,
    kv: Option<KvTenant>,
}

impl ServeKvScenario {
    /// `records` sizes the KV table; `trace` is the arrival schedule
    /// (keys are taken modulo the table size).
    pub fn new(records: usize, trace: Arc<Trace>) -> Self {
        Self {
            records,
            trace,
            kv: None,
        }
    }

    /// Requests served; valid after the run.
    pub fn served(&self) -> u64 {
        self.kv.as_ref().map_or(0, KvTenant::served)
    }

    /// Update RMWs that lost their version race; valid after the run.
    pub fn conflicts(&self) -> u64 {
        self.kv.as_ref().map_or(0, KvTenant::conflicts)
    }

    /// The sojourn histogram (CDF source for `fig_serving`).
    pub fn latency_histogram(&self) -> Option<LogHistogram> {
        self.kv.as_ref().map(KvTenant::histogram)
    }
}

impl Scenario for ServeKvScenario {
    fn name(&self) -> &'static str {
        "serve-kv"
    }

    fn setup(&mut self, machine: &mut Machine, _tasks: usize) {
        self.kv = Some(KvTenant::new(machine, "serve", self.records, &self.trace));
    }

    fn spawn(&mut self, _rank: usize) -> Box<dyn Coroutine> {
        self.kv.as_ref().expect("setup() before spawn()").worker()
    }

    fn verify(&self) {
        let served = self.served();
        assert_eq!(
            served,
            self.trace.len() as u64,
            "every request must be served exactly once"
        );
        let recorded = self.kv.as_ref().map_or(0, |kv| kv.lat.lock().unwrap().count());
        assert_eq!(
            recorded, served,
            "every served request must have a latency sample"
        );
    }

    fn latency(&self) -> Option<LatencyReport> {
        self.kv.as_ref().and_then(KvTenant::report)
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        let p99 = self.latency().map_or(0.0, |l| l.p99_ns as f64);
        ScenarioMetrics::new(self.served() as f64, "reqs")
            .with("reqs_per_s", report.throughput(self.served() as f64))
            .with("update_conflicts", self.conflicts() as f64)
            .with("p99_sojourn_ns", p99)
    }
}

/// `serve-mixed`: the `serve-kv` traffic co-resident with a TPC-H-shaped
/// scan tenant — serving tail latency under analytics interference.
pub struct ServeMixedScenario {
    records: usize,
    trace: Arc<Trace>,
    db: Arc<Db>,
    spec: QuerySpec,
    tasks: usize,
    n_serve: usize,
    st: Option<(KvTenant, ScanTenant)>,
}

impl ServeMixedScenario {
    /// `spec` must be a join-free scan query (Q1 by default in the
    /// registry).
    pub fn new(records: usize, trace: Arc<Trace>, db: Arc<Db>, spec: QuerySpec) -> Self {
        assert!(
            spec.joins.is_empty(),
            "serve-mixed's scan tenant requires a join-free query: Q{} has joins",
            spec.id
        );
        Self {
            records,
            trace,
            db,
            spec,
            tasks: 0,
            n_serve: 0,
            st: None,
        }
    }

    /// Requests served; valid after the run.
    pub fn served(&self) -> u64 {
        self.st.as_ref().map_or(0, |(kv, _)| kv.served())
    }

    /// (rows, aggregate) produced by the scan tenant; valid after the run.
    pub fn olap_result(&self) -> (u64, f64) {
        self.st.as_ref().map_or((0, 0.0), |(_, scan)| scan.result())
    }

    /// How many ranks each tenant got (serving first).
    pub fn split(&self) -> (usize, usize) {
        (self.n_serve, self.tasks - self.n_serve)
    }

    /// The sojourn histogram (CDF source for benches).
    pub fn latency_histogram(&self) -> Option<LogHistogram> {
        self.st.as_ref().map(|(kv, _)| kv.histogram())
    }
}

impl Scenario for ServeMixedScenario {
    fn name(&self) -> &'static str {
        "serve-mixed"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        self.tasks = tasks;
        // Serving gets the ceiling half (a single-rank group degenerates
        // to pure serving, never to nothing), like the mixed scenario.
        self.n_serve = tasks.div_ceil(2);
        let kv = KvTenant::new(machine, "serve-mixed", self.records, &self.trace);
        let scan = ScanTenant::new(machine, "serve-mixed", self.db.clone(), self.spec.clone());
        self.st = Some((kv, scan));
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        let (kv, scan) = self.st.as_ref().expect("setup() before spawn()");
        if rank < self.n_serve {
            kv.worker()
        } else {
            scan.coroutine(rank - self.n_serve, self.tasks - self.n_serve)
        }
    }

    fn verify(&self) {
        let (kv, scan) = self.st.as_ref().expect("setup() before verify()");
        assert_eq!(
            kv.served(),
            self.trace.len() as u64,
            "every request must be served exactly once"
        );
        if self.tasks > self.n_serve {
            scan.verify_against_serial();
        }
    }

    fn latency(&self) -> Option<LatencyReport> {
        self.st.as_ref().and_then(|(kv, _)| kv.report())
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        let scanned = if self.tasks > self.n_serve {
            self.db.rows(self.spec.probe) as f64
        } else {
            0.0
        };
        let p99 = self.latency().map_or(0.0, |l| l.p99_ns as f64);
        ScenarioMetrics::new(self.served() as f64 + scanned, "ops")
            .with("reqs_per_s", report.throughput(self.served() as f64))
            .with("p99_sojourn_ns", p99)
            .with("olap_rows_out", self.olap_result().0 as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Driver;
    use crate::policy::LocalCachePolicy;
    use crate::topology::Topology;
    use crate::workloads::olap::all_queries;

    fn topo() -> Topology {
        Topology::milan_1s()
    }

    fn kv_trace(requests: usize, rate_rps: f64) -> Arc<Trace> {
        Arc::new(Trace::synth(&TraceConfig {
            requests,
            rate_rps,
            keyspace: 10_000,
            seed: 3,
            ..Default::default()
        }))
    }

    fn run_kv(requests: usize, rate_rps: f64, workers: usize) -> (ServeKvScenario, RunReport) {
        let mut s = ServeKvScenario::new(10_000, kv_trace(requests, rate_rps));
        let run = Driver::new(&topo(), Box::new(LocalCachePolicy), workers)
            .with_verify(true)
            .run(&mut s);
        (s, run.report)
    }

    #[test]
    fn serves_every_request_and_reports_latency() {
        let (s, report) = run_kv(2_000, 2.0e6, 8);
        assert_eq!(s.served(), 2_000);
        let l = report.request_latency.expect("serving must report latency");
        assert_eq!(l.count, 2_000);
        assert!(l.p50_ns <= l.p95_ns && l.p95_ns <= l.p99_ns && l.p99_ns <= l.max_ns);
        assert!(l.mean_ns > 0.0);
        assert!(l.mean_service_ns > 0.0);
        // The open loop ran at least as long as the arrival horizon.
        assert!(report.makespan_ns >= s.trace.last_arrival_ns());
        assert_eq!(s.latency_histogram().unwrap().count(), 2_000);
    }

    #[test]
    fn sim_runs_are_deterministic_including_latency() {
        let once = || {
            let (s, report) = run_kv(1_000, 2.0e6, 8);
            (
                report.makespan_ns,
                report.dispatches,
                report.request_latency,
                s.served(),
                s.conflicts(),
            )
        };
        assert_eq!(once(), once());
    }

    #[test]
    fn underload_has_idle_queue_and_overload_queues() {
        // 0.2M rps on 8 servers: arrivals are far apart, queue wait ~0.
        let (_, light) = run_kv(600, 0.2e6, 8);
        let light = light.request_latency.unwrap();
        assert!(
            light.mean_queue_ns < light.mean_service_ns,
            "underload queue {} should be below service {}",
            light.mean_queue_ns,
            light.mean_service_ns
        );
        // 200M rps: everything arrives at once; sojourn is queue-bound
        // and the tail dwarfs the service time.
        let (_, heavy) = run_kv(600, 200.0e6, 8);
        let heavy = heavy.request_latency.unwrap();
        assert!(
            heavy.mean_queue_ns > 10.0 * heavy.mean_service_ns,
            "overload queue {} should dominate service {}",
            heavy.mean_queue_ns,
            heavy.mean_service_ns
        );
        assert!(heavy.p99_ns > light.p99_ns);
    }

    #[test]
    fn fewer_requests_than_workers_is_fine() {
        let (s, report) = run_kv(3, 1.0e6, 8);
        assert_eq!(s.served(), 3);
        assert_eq!(report.request_latency.unwrap().count, 3);
    }

    #[test]
    fn update_traffic_mutates_the_store() {
        let trace = Arc::new(
            Trace::parse("0 u 5\n100 u 5\n200 r 5\n300 u 6\n").unwrap(),
        );
        let mut s = ServeKvScenario::new(100, trace);
        let _ = Driver::new(&topo(), Box::new(LocalCachePolicy), 2)
            .with_verify(true)
            .run(&mut s);
        assert_eq!(s.served(), 4);
        let kv = s.kv.as_ref().unwrap();
        // Key 5 started at 5 and took two increments; key 6 one.
        assert_eq!(kv.store.read(5), 7);
        assert_eq!(kv.store.read(6), 7);
    }

    #[test]
    fn serve_mixed_splits_ranks_and_both_tenants_finish() {
        let db = Arc::new(Db::generate(0.002, 7));
        let mut s = ServeMixedScenario::new(
            10_000,
            kv_trace(1_000, 2.0e6),
            db,
            all_queries()[0].clone(),
        );
        let run = Driver::new(&topo(), Box::new(LocalCachePolicy), 8)
            .with_verify(true)
            .run(&mut s);
        assert_eq!(s.split(), (4, 4));
        assert_eq!(s.served(), 1_000);
        let (rows, sum) = s.olap_result();
        assert!(rows > 0 && sum > 0.0);
        let l = run.report.request_latency.unwrap();
        assert_eq!(l.count, 1_000);
        assert!(run.metrics.get("olap_rows_out").unwrap() > 0.0);
    }

    #[test]
    fn serve_mixed_scan_interference_raises_the_tail() {
        // Same serving traffic with and without the co-resident scan:
        // the scan tenant's cache/bandwidth pressure must not *lower*
        // the p99 (and DRAM traffic must be strictly higher).
        let db = Arc::new(Db::generate(0.01, 7));
        let trace = kv_trace(2_000, 2.0e6);
        let mut alone = ServeKvScenario::new(10_000, trace.clone());
        let alone_run = Driver::new(&topo(), Box::new(LocalCachePolicy), 4).run(&mut alone);
        let mut mixed =
            ServeMixedScenario::new(10_000, trace, db, all_queries()[0].clone());
        let mixed_run = Driver::new(&topo(), Box::new(LocalCachePolicy), 8).run(&mut mixed);
        assert!(
            mixed_run.report.dram_bytes > alone_run.report.dram_bytes,
            "the scan tenant must add DRAM traffic"
        );
        let (a, m) = (
            alone_run.report.request_latency.unwrap(),
            mixed_run.report.request_latency.unwrap(),
        );
        assert!(
            m.p99_ns * 10 >= a.p99_ns,
            "co-residency cannot make the tail 10x better: alone {} mixed {}",
            a.p99_ns,
            m.p99_ns
        );
    }

    #[test]
    #[should_panic(expected = "join-free")]
    fn serve_mixed_rejects_join_queries() {
        let db = Arc::new(Db::generate(0.002, 7));
        let _ = ServeMixedScenario::new(100, kv_trace(10, 1e6), db, all_queries()[2].clone());
    }

    #[test]
    fn single_rank_serve_mixed_degenerates_to_pure_serving() {
        let db = Arc::new(Db::generate(0.002, 7));
        let mut s = ServeMixedScenario::new(
            1_000,
            kv_trace(128, 1.0e6),
            db,
            all_queries()[0].clone(),
        );
        let _ = Driver::new(&topo(), Box::new(LocalCachePolicy), 1)
            .with_verify(true)
            .run(&mut s);
        assert_eq!(s.split(), (1, 0));
        assert_eq!(s.served(), 128);
        assert_eq!(s.olap_result().0, 0);
    }
}
