//! Trace-replay request serving: the "millions of users" story.
//!
//! Every other workload in the registry is a batch job — build data,
//! burn through it, report a makespan. A serving system answers a
//! different question: requests arrive on *their* schedule (the trace),
//! and what matters is how long each one waited. This module turns the
//! engine seam into that experiment:
//!
//! - [`trace`] — deterministic request traces: seeded synthetic
//!   generators (Zipfian keys; uniform/Poisson/diurnal/bursty open-loop
//!   arrivals; optional per-tenant [`PriorityMix`]) and a tiny text
//!   format for replaying recorded traffic.
//! - **Server workers** — each rank is a server coroutine that claims
//!   requests from a [`TieredQueue`] (engine-side dispatcher): per-class
//!   FCFS with Critical-first dispatch among arrived requests, streak
//!   promotion so Background never starves, and (opt-in) Background
//!   shedding once queue wait blows the SLO budget. An idle server
//!   *waits for the next arrival* (advances its virtual clock to the
//!   request's timestamp); a backlogged one starts service immediately —
//!   so sojourn = queue wait + service, measured per request in virtual
//!   time and folded into per-class log-scaled histograms
//!   ([`ClassLatencyRecorder`]) that the driver attaches to
//!   [`RunReport::request_latency`] / `class_latency`. Workers also
//!   publish per-chiplet queue/service windows to an [`SloSignal`] for
//!   p99-driven placement (`policy::SloPolicy`).
//! - **Open vs closed loop** ([`ServeOpts`]) — the default open loop
//!   replays trace arrivals regardless of server progress (honest tails
//!   under overload). `closed_loop_think_ns` turns each worker into a
//!   fixed-concurrency client: issue → serve → think → issue, the
//!   load-generator shape whose latency *cannot* diverge (queue wait is
//!   structurally 0) — the control experiment for overload plots.
//! - [`ServeKvScenario`] (`serve-kv`) — YCSB-style point reads/updates
//!   over the shared [`Store`] from the OLTP engine: zipfian key
//!   contention, a shared commit line and log appends on the update
//!   path.
//! - [`ServeMixedScenario`] (`serve-mixed`) — the same KV traffic
//!   co-resident with the TPC-H scan tenant from [`mixed`]: the scan
//!   evicts the KV working set and queues on the same DDR trackers, so
//!   the serving tail directly measures cross-tenant interference.
//!
//! Both scenarios run on the Sim backend (deterministic latency
//! distributions — the paper-figure path, see `fig_serving`) and the
//! Host backend (real threads racing on the same admission queue; every
//! request still served exactly once).

pub mod trace;

pub use trace::{ArrivalModel, PriorityMix, ReqOp, Request, Trace, TraceConfig};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::cachesim::Access;
use crate::engine::{
    ClassLatencyRecorder, Priority, Scenario, ScenarioMetrics, SloSignal, TieredQueue,
};
use crate::mem::{Placement, RegionId};
use crate::sched::{LatencyReport, RunReport};
use crate::sim::Machine;
use crate::task::{Coroutine, StateTask, Step};
use crate::util::stats::LogHistogram;
use crate::workloads::mixed::ScanTenant;
use crate::workloads::olap::{Db, QuerySpec};
use crate::workloads::oltp::Store;

/// SLO / load-generation knobs of the serving scenarios. The default
/// (`None` everywhere) is the plain open loop with no shedding — the
/// byte-identical golden path.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeOpts {
    /// Queue-wait budget after which Background requests are shed
    /// instead of served (`arcas run --slo-p99`). Ignored under
    /// `closed_loop_think_ns` (a closed loop has no arrival queue).
    pub slo_shed_ns: Option<u64>,
    /// Run closed-loop clients instead of open-loop trace replay: each
    /// worker issues its next request after this much think time
    /// (`arcas run --closed-loop`). Trace arrival times are ignored.
    pub closed_loop_think_ns: Option<u64>,
}

/// The KV serving tenant: store + commit/log regions + the tiered
/// admission queue and per-class latency accounting, shared by
/// `serve-kv` and `serve-mixed`.
struct KvTenant {
    store: Arc<Store>,
    commit_region: RegionId,
    log_region: RegionId,
    queue: Arc<TieredQueue<Request>>,
    served: Arc<AtomicU64>,
    conflicts: Arc<AtomicU64>,
    lat: Arc<Mutex<ClassLatencyRecorder>>,
    slo: Arc<SloSignal>,
    /// Machine clock at setup: trace arrivals are relative to *this
    /// run's* start, so warm `--repeat` runs replay the arrival process
    /// instead of treating past timestamps as an instant backlog.
    base_ns: u64,
    closed_loop_think_ns: Option<u64>,
}

impl KvTenant {
    fn new(
        machine: &mut Machine,
        label_prefix: &str,
        records: usize,
        trace: &Trace,
        opts: ServeOpts,
    ) -> Self {
        let store = Arc::new(Store::new(
            machine,
            &format!("{label_prefix}-kv-table"),
            records,
            100,
        ));
        let commit_region =
            machine.alloc(&format!("{label_prefix}-commit-counter"), 64, Placement::Bind(0));
        let log_region =
            machine.alloc(&format!("{label_prefix}-log"), 64 << 20, Placement::Bind(0));
        // A closed loop has no arrival queue, so a queue-wait budget is
        // meaningless there (and `pop(u64::MAX)` would shed everything).
        let shed = opts
            .slo_shed_ns
            .filter(|_| opts.closed_loop_think_ns.is_none());
        Self {
            store,
            commit_region,
            log_region,
            queue: TieredQueue::new(trace.requests.clone(), shed),
            served: Arc::new(AtomicU64::new(0)),
            conflicts: Arc::new(AtomicU64::new(0)),
            lat: Arc::new(Mutex::new(ClassLatencyRecorder::new())),
            slo: SloSignal::new(machine.topo.num_chiplets()),
            base_ns: machine.max_time(),
            closed_loop_think_ns: opts.closed_loop_think_ns,
        }
    }

    fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    fn conflicts(&self) -> u64 {
        self.conflicts.load(Ordering::Relaxed)
    }

    fn shed(&self) -> u64 {
        self.queue.shed_total()
    }

    fn report(&self) -> Option<LatencyReport> {
        self.lat.lock().unwrap().report()
    }

    fn class_reports(&self) -> Vec<(&'static str, LatencyReport)> {
        self.lat.lock().unwrap().class_reports()
    }

    fn histogram(&self) -> LogHistogram {
        self.lat.lock().unwrap().histogram().clone()
    }

    /// One server worker: a coroutine serving one request per step
    /// (every request is a scheduling/profiling/migration point), with
    /// per-request sojourn recorded locally and merged once at drain.
    fn worker(&self) -> Box<dyn Coroutine> {
        let store = self.store.clone();
        let commit_region = self.commit_region;
        let log_region = self.log_region;
        let queue = self.queue.clone();
        let served = self.served.clone();
        let conflicts = self.conflicts.clone();
        let lat = self.lat.clone();
        let slo = self.slo.clone();
        let base_ns = self.base_ns;
        let closed_loop = self.closed_loop_think_ns;
        let mut local = ClassLatencyRecorder::new();
        Box::new(StateTask::new(move |ctx, _step| {
            // The queue clock: trace-relative virtual time (re-based so
            // warm repeats replay arrivals against this run's start).
            // Closed-loop clients ignore arrivals — every queued request
            // is "due", so pops are pure priority order.
            let pop_now = if closed_loop.is_some() {
                u64::MAX
            } else {
                ctx.view().now().saturating_sub(base_ns)
            };
            let Some(req) = queue.pop(pop_now) else {
                // Trace drained: publish this worker's latency samples.
                lat.lock().unwrap().merge(&local);
                local = ClassLatencyRecorder::new();
                return Step::Done;
            };
            let (start, queue_wait) = if let Some(think_ns) = closed_loop {
                // Closed loop: think, then issue. The request never
                // waits in an arrival queue, so queue wait is 0 by
                // construction — the saturating counterpart to the
                // open loop's unbounded backlog.
                if think_ns > 0 {
                    ctx.compute_ns(think_ns);
                }
                (ctx.view().now(), 0)
            } else {
                // Open loop: an idle server waits for the arrival; a
                // backlogged one starts immediately (the request was
                // queueing while every server was busy).
                let arrival = base_ns + req.arrival_ns;
                let v = ctx.view();
                if v.now() < arrival {
                    v.advance_to(arrival);
                }
                let start = v.now();
                (start, start - arrival)
            };
            let key = req.key as usize;
            match req.op {
                ReqOp::Read => {
                    let _ = store.read(key);
                    ctx.access(Access::rand_read(store.region, 1, store.bytes).with_mlp(1.0));
                }
                ReqOp::Update => {
                    if !store.rmw(key, 1) {
                        conflicts.fetch_add(1, Ordering::Relaxed);
                    }
                    // Read-modify-write: point read + write back, then
                    // the commit path (shared counter line ping-pong,
                    // log append, ~600 ns latch) — the same cost shape
                    // as the OLTP engine's commit.
                    ctx.access(Access::rand_read(store.region, 1, store.bytes).with_mlp(1.0));
                    ctx.access(Access::rand_write(store.region, 1, store.bytes).with_mlp(1.0));
                    ctx.rand_write(commit_region, 1, 64);
                    ctx.seq_write(log_region, 128);
                    ctx.compute_ns(600);
                }
            }
            // Request parse/dispatch CPU.
            ctx.compute_flops(300);
            let end = ctx.view().now();
            let service = end - start;
            local.record(req.priority, queue_wait, service);
            slo.record(ctx.chiplet(), queue_wait, service);
            served.fetch_add(1, Ordering::Relaxed);
            Step::Yield
        }))
    }
}

/// Admission-control invariant shared by both serving scenarios: every
/// request is either served (with a latency sample) or shed, exactly
/// once — and only Background is ever shed.
fn verify_kv(kv: &KvTenant, trace: &Trace) {
    let served = kv.served();
    let shed = kv.shed();
    assert_eq!(
        served + shed,
        trace.len() as u64,
        "every request must be served or shed exactly once ({served} + {shed})"
    );
    let counts = kv.queue.shed_counts();
    assert_eq!(
        counts[Priority::Critical.idx()] + counts[Priority::Normal.idx()],
        0,
        "only Background requests may be shed"
    );
    let recorded = kv.lat.lock().unwrap().count();
    assert_eq!(
        recorded, served,
        "every served request must have a latency sample"
    );
}

/// `serve-kv`: open-loop trace replay of YCSB-style point ops over the
/// OLTP engine's record store, with per-request latency accounting.
pub struct ServeKvScenario {
    records: usize,
    trace: Arc<Trace>,
    opts: ServeOpts,
    kv: Option<KvTenant>,
}

impl ServeKvScenario {
    /// `records` sizes the KV table; `trace` is the arrival schedule
    /// (keys are taken modulo the table size).
    pub fn new(records: usize, trace: Arc<Trace>) -> Self {
        Self {
            records,
            trace,
            opts: ServeOpts::default(),
            kv: None,
        }
    }

    /// SLO / load-generation knobs (default: plain open loop).
    pub fn with_opts(mut self, opts: ServeOpts) -> Self {
        self.opts = opts;
        self
    }

    /// Requests served; valid after the run.
    pub fn served(&self) -> u64 {
        self.kv.as_ref().map_or(0, KvTenant::served)
    }

    /// Update RMWs that lost their version race; valid after the run.
    pub fn conflicts(&self) -> u64 {
        self.kv.as_ref().map_or(0, KvTenant::conflicts)
    }

    /// Requests shed per priority class (indexed by [`Priority::idx`]);
    /// valid after the run. Only the Background slot can be non-zero.
    pub fn shed_counts(&self) -> [u64; 3] {
        self.kv.as_ref().map_or([0; 3], |kv| kv.queue.shed_counts())
    }

    /// The sojourn histogram (CDF source for `fig_serving`).
    pub fn latency_histogram(&self) -> Option<LogHistogram> {
        self.kv.as_ref().map(KvTenant::histogram)
    }
}

impl Scenario for ServeKvScenario {
    fn name(&self) -> &'static str {
        "serve-kv"
    }

    fn setup(&mut self, machine: &mut Machine, _tasks: usize) {
        self.kv = Some(KvTenant::new(
            machine,
            "serve",
            self.records,
            &self.trace,
            self.opts,
        ));
    }

    fn spawn(&mut self, _rank: usize) -> Box<dyn Coroutine> {
        self.kv.as_ref().expect("setup() before spawn()").worker()
    }

    fn verify(&self) {
        verify_kv(self.kv.as_ref().expect("setup() before verify()"), &self.trace);
    }

    fn latency(&self) -> Option<LatencyReport> {
        self.kv.as_ref().and_then(KvTenant::report)
    }

    fn shed(&self) -> u64 {
        self.kv.as_ref().map_or(0, KvTenant::shed)
    }

    fn class_latency(&self) -> Vec<(&'static str, LatencyReport)> {
        self.kv.as_ref().map_or_else(Vec::new, KvTenant::class_reports)
    }

    fn slo_signal(&self) -> Option<Arc<SloSignal>> {
        self.kv.as_ref().map(|kv| kv.slo.clone())
    }

    fn cluster_parts(&self) -> Option<crate::cluster::ClusterParts> {
        Some(crate::cluster::ClusterParts {
            records: self.records,
            trace: self.trace.clone(),
            opts: self.opts,
        })
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        let p99 = self.latency().map_or(0.0, |l| l.p99_ns as f64);
        ScenarioMetrics::new(self.served() as f64, "reqs")
            .with("reqs_per_s", report.throughput(self.served() as f64))
            .with("update_conflicts", self.conflicts() as f64)
            .with("p99_sojourn_ns", p99)
            .with("shed", self.shed() as f64)
    }
}

/// `serve-mixed`: the `serve-kv` traffic co-resident with a TPC-H-shaped
/// scan tenant — serving tail latency under analytics interference.
pub struct ServeMixedScenario {
    records: usize,
    trace: Arc<Trace>,
    db: Arc<Db>,
    spec: QuerySpec,
    opts: ServeOpts,
    tasks: usize,
    n_serve: usize,
    st: Option<(KvTenant, ScanTenant)>,
}

impl ServeMixedScenario {
    /// `spec` must be a join-free scan query (Q1 by default in the
    /// registry).
    pub fn new(records: usize, trace: Arc<Trace>, db: Arc<Db>, spec: QuerySpec) -> Self {
        assert!(
            spec.joins.is_empty(),
            "serve-mixed's scan tenant requires a join-free query: Q{} has joins",
            spec.id
        );
        Self {
            records,
            trace,
            db,
            spec,
            opts: ServeOpts::default(),
            tasks: 0,
            n_serve: 0,
            st: None,
        }
    }

    /// SLO / load-generation knobs (default: plain open loop).
    pub fn with_opts(mut self, opts: ServeOpts) -> Self {
        self.opts = opts;
        self
    }

    /// Requests served; valid after the run.
    pub fn served(&self) -> u64 {
        self.st.as_ref().map_or(0, |(kv, _)| kv.served())
    }

    /// (rows, aggregate) produced by the scan tenant; valid after the run.
    pub fn olap_result(&self) -> (u64, f64) {
        self.st.as_ref().map_or((0, 0.0), |(_, scan)| scan.result())
    }

    /// How many ranks each tenant got (serving first).
    pub fn split(&self) -> (usize, usize) {
        (self.n_serve, self.tasks - self.n_serve)
    }

    /// The sojourn histogram (CDF source for benches).
    pub fn latency_histogram(&self) -> Option<LogHistogram> {
        self.st.as_ref().map(|(kv, _)| kv.histogram())
    }
}

impl Scenario for ServeMixedScenario {
    fn name(&self) -> &'static str {
        "serve-mixed"
    }

    fn setup(&mut self, machine: &mut Machine, tasks: usize) {
        self.tasks = tasks;
        // Serving gets the ceiling half (a single-rank group degenerates
        // to pure serving, never to nothing), like the mixed scenario.
        self.n_serve = tasks.div_ceil(2);
        let kv = KvTenant::new(machine, "serve-mixed", self.records, &self.trace, self.opts);
        let scan = ScanTenant::new(machine, "serve-mixed", self.db.clone(), self.spec.clone());
        self.st = Some((kv, scan));
    }

    fn spawn(&mut self, rank: usize) -> Box<dyn Coroutine> {
        let (kv, scan) = self.st.as_ref().expect("setup() before spawn()");
        if rank < self.n_serve {
            kv.worker()
        } else {
            scan.coroutine(rank - self.n_serve, self.tasks - self.n_serve)
        }
    }

    fn verify(&self) {
        let (kv, scan) = self.st.as_ref().expect("setup() before verify()");
        verify_kv(kv, &self.trace);
        if self.tasks > self.n_serve {
            scan.verify_against_serial();
        }
    }

    fn latency(&self) -> Option<LatencyReport> {
        self.st.as_ref().and_then(|(kv, _)| kv.report())
    }

    fn shed(&self) -> u64 {
        self.st.as_ref().map_or(0, |(kv, _)| kv.shed())
    }

    fn class_latency(&self) -> Vec<(&'static str, LatencyReport)> {
        self.st
            .as_ref()
            .map_or_else(Vec::new, |(kv, _)| kv.class_reports())
    }

    fn slo_signal(&self) -> Option<Arc<SloSignal>> {
        self.st.as_ref().map(|(kv, _)| kv.slo.clone())
    }

    fn metrics(&self, report: &RunReport) -> ScenarioMetrics {
        let scanned = if self.tasks > self.n_serve {
            self.db.rows(self.spec.probe) as f64
        } else {
            0.0
        };
        let p99 = self.latency().map_or(0.0, |l| l.p99_ns as f64);
        ScenarioMetrics::new(self.served() as f64 + scanned, "ops")
            .with("reqs_per_s", report.throughput(self.served() as f64))
            .with("p99_sojourn_ns", p99)
            .with("olap_rows_out", self.olap_result().0 as f64)
            .with("shed", self.shed() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Driver;
    use crate::policy::LocalCachePolicy;
    use crate::topology::Topology;
    use crate::workloads::olap::all_queries;

    fn topo() -> Topology {
        Topology::milan_1s()
    }

    fn kv_trace(requests: usize, rate_rps: f64) -> Arc<Trace> {
        Arc::new(Trace::synth(&TraceConfig {
            requests,
            rate_rps,
            keyspace: 10_000,
            seed: 3,
            ..Default::default()
        }))
    }

    fn run_kv(requests: usize, rate_rps: f64, workers: usize) -> (ServeKvScenario, RunReport) {
        let mut s = ServeKvScenario::new(10_000, kv_trace(requests, rate_rps));
        let run = Driver::new(&topo(), Box::new(LocalCachePolicy), workers)
            .with_verify(true)
            .run(&mut s);
        (s, run.report)
    }

    #[test]
    fn serves_every_request_and_reports_latency() {
        let (s, report) = run_kv(2_000, 2.0e6, 8);
        assert_eq!(s.served(), 2_000);
        let l = report.request_latency.expect("serving must report latency");
        assert_eq!(l.count, 2_000);
        assert!(l.p50_ns <= l.p95_ns && l.p95_ns <= l.p99_ns && l.p99_ns <= l.max_ns);
        assert!(l.mean_ns > 0.0);
        assert!(l.mean_service_ns > 0.0);
        // The open loop ran at least as long as the arrival horizon.
        assert!(report.makespan_ns >= s.trace.last_arrival_ns());
        assert_eq!(s.latency_histogram().unwrap().count(), 2_000);
    }

    #[test]
    fn sim_runs_are_deterministic_including_latency() {
        let once = || {
            let (s, report) = run_kv(1_000, 2.0e6, 8);
            (
                report.makespan_ns,
                report.dispatches,
                report.request_latency,
                s.served(),
                s.conflicts(),
            )
        };
        assert_eq!(once(), once());
    }

    #[test]
    fn underload_has_idle_queue_and_overload_queues() {
        // 0.2M rps on 8 servers: arrivals are far apart, queue wait ~0.
        let (_, light) = run_kv(600, 0.2e6, 8);
        let light = light.request_latency.unwrap();
        assert!(
            light.mean_queue_ns < light.mean_service_ns,
            "underload queue {} should be below service {}",
            light.mean_queue_ns,
            light.mean_service_ns
        );
        // 200M rps: everything arrives at once; sojourn is queue-bound
        // and the tail dwarfs the service time.
        let (_, heavy) = run_kv(600, 200.0e6, 8);
        let heavy = heavy.request_latency.unwrap();
        assert!(
            heavy.mean_queue_ns > 10.0 * heavy.mean_service_ns,
            "overload queue {} should dominate service {}",
            heavy.mean_queue_ns,
            heavy.mean_service_ns
        );
        assert!(heavy.p99_ns > light.p99_ns);
    }

    #[test]
    fn fewer_requests_than_workers_is_fine() {
        let (s, report) = run_kv(3, 1.0e6, 8);
        assert_eq!(s.served(), 3);
        assert_eq!(report.request_latency.unwrap().count, 3);
    }

    #[test]
    fn update_traffic_mutates_the_store() {
        let trace = Arc::new(
            Trace::parse("0 u 5\n100 u 5\n200 r 5\n300 u 6\n").unwrap(),
        );
        let mut s = ServeKvScenario::new(100, trace);
        let _ = Driver::new(&topo(), Box::new(LocalCachePolicy), 2)
            .with_verify(true)
            .run(&mut s);
        assert_eq!(s.served(), 4);
        let kv = s.kv.as_ref().unwrap();
        // Key 5 started at 5 and took two increments; key 6 one.
        assert_eq!(kv.store.read(5), 7);
        assert_eq!(kv.store.read(6), 7);
    }

    #[test]
    fn serve_mixed_splits_ranks_and_both_tenants_finish() {
        let db = Arc::new(Db::generate(0.002, 7));
        let mut s = ServeMixedScenario::new(
            10_000,
            kv_trace(1_000, 2.0e6),
            db,
            all_queries()[0].clone(),
        );
        let run = Driver::new(&topo(), Box::new(LocalCachePolicy), 8)
            .with_verify(true)
            .run(&mut s);
        assert_eq!(s.split(), (4, 4));
        assert_eq!(s.served(), 1_000);
        let (rows, sum) = s.olap_result();
        assert!(rows > 0 && sum > 0.0);
        let l = run.report.request_latency.unwrap();
        assert_eq!(l.count, 1_000);
        assert!(run.metrics.get("olap_rows_out").unwrap() > 0.0);
    }

    #[test]
    fn serve_mixed_scan_interference_raises_the_tail() {
        // Same serving traffic with and without the co-resident scan:
        // the scan tenant's cache/bandwidth pressure must not *lower*
        // the p99 (and DRAM traffic must be strictly higher).
        let db = Arc::new(Db::generate(0.01, 7));
        let trace = kv_trace(2_000, 2.0e6);
        let mut alone = ServeKvScenario::new(10_000, trace.clone());
        let alone_run = Driver::new(&topo(), Box::new(LocalCachePolicy), 4).run(&mut alone);
        let mut mixed =
            ServeMixedScenario::new(10_000, trace, db, all_queries()[0].clone());
        let mixed_run = Driver::new(&topo(), Box::new(LocalCachePolicy), 8).run(&mut mixed);
        assert!(
            mixed_run.report.dram_bytes > alone_run.report.dram_bytes,
            "the scan tenant must add DRAM traffic"
        );
        let (a, m) = (
            alone_run.report.request_latency.unwrap(),
            mixed_run.report.request_latency.unwrap(),
        );
        assert!(
            m.p99_ns * 10 >= a.p99_ns,
            "co-residency cannot make the tail 10x better: alone {} mixed {}",
            a.p99_ns,
            m.p99_ns
        );
    }

    #[test]
    #[should_panic(expected = "join-free")]
    fn serve_mixed_rejects_join_queries() {
        let db = Arc::new(Db::generate(0.002, 7));
        let _ = ServeMixedScenario::new(100, kv_trace(10, 1e6), db, all_queries()[2].clone());
    }

    #[test]
    fn single_rank_serve_mixed_degenerates_to_pure_serving() {
        let db = Arc::new(Db::generate(0.002, 7));
        let mut s = ServeMixedScenario::new(
            1_000,
            kv_trace(128, 1.0e6),
            db,
            all_queries()[0].clone(),
        );
        let _ = Driver::new(&topo(), Box::new(LocalCachePolicy), 1)
            .with_verify(true)
            .run(&mut s);
        assert_eq!(s.split(), (1, 0));
        assert_eq!(s.served(), 128);
        assert_eq!(s.olap_result().0, 0);
    }

    /// Regression for the `--repeat` re-base bug: a warm machine's clock
    /// is far past the trace's arrival timestamps, and before arrivals
    /// were re-based every warm repetition treated the whole trace as an
    /// instant backlog — all queue, no arrival process. Re-based, each
    /// repetition replays the arrival schedule against its own start.
    #[test]
    fn warm_repeats_rebase_trace_arrivals() {
        let trace = kv_trace(600, 0.5e6); // underloaded on 8 workers
        let runs = crate::engine::Run::new(&topo())
            .tasks(8)
            .repeat(2)
            .verify(true)
            .run_repeated(
                || Box::new(LocalCachePolicy),
                || Box::new(ServeKvScenario::new(10_000, trace.clone())),
            );
        let horizon = trace.last_arrival_ns();
        for (i, run) in runs.iter().enumerate() {
            // The arrival process was replayed: the run spans the
            // arrival horizon instead of draining a day-old backlog at
            // full tilt.
            assert!(
                run.report.makespan_ns >= horizon,
                "rep {i}: makespan {} under the arrival horizon {horizon}",
                run.report.makespan_ns
            );
            let l = run.report.request_latency.clone().unwrap();
            assert!(
                l.mean_queue_ns < 5.0 * l.mean_service_ns,
                "rep {i}: queue {} vs service {} — arrivals were not re-based",
                l.mean_queue_ns,
                l.mean_service_ns
            );
        }
    }

    /// Under overload with an SLO budget, Background is shed (and only
    /// Background), and admission control conserves the trace length.
    #[test]
    fn overload_sheds_background_only_and_conserves_requests() {
        let trace = Arc::new(Trace::synth(&TraceConfig {
            requests: 2_000,
            rate_rps: 100.0e6, // far past capacity: queue wait explodes
            keyspace: 10_000,
            seed: 3,
            priority_mix: Some(PriorityMix {
                critical: 0.2,
                background: 0.4,
            }),
            ..Default::default()
        }));
        let mut s = ServeKvScenario::new(10_000, trace.clone()).with_opts(ServeOpts {
            slo_shed_ns: Some(50_000),
            closed_loop_think_ns: None,
        });
        let run = Driver::new(&topo(), Box::new(LocalCachePolicy), 4)
            .with_verify(true)
            .run(&mut s);
        assert!(run.report.request_shed > 0, "overload must shed");
        assert_eq!(
            s.served() + run.report.request_shed,
            trace.len() as u64,
            "admitted + shed must equal the trace length"
        );
        // Per-class reports cover the classes that were served.
        let classes: Vec<&str> = run
            .report
            .class_latency
            .iter()
            .map(|(n, _)| *n)
            .collect();
        assert!(classes.contains(&"critical") && classes.contains(&"normal"));
        // Critical never waits behind the shed Background backlog.
        let crit = &run.report.class_latency[0];
        assert_eq!(crit.0, "critical");
    }

    /// Open- vs closed-loop overload: the open loop's tail diverges with
    /// the backlog; the closed loop saturates (queue wait is 0 by
    /// construction and the tail stays service-shaped).
    #[test]
    fn closed_loop_saturates_where_open_loop_diverges() {
        let trace = kv_trace(1_000, 100.0e6);
        let mut open = ServeKvScenario::new(10_000, trace.clone());
        let open_run = Driver::new(&topo(), Box::new(LocalCachePolicy), 4)
            .with_verify(true)
            .run(&mut open);
        let open_l = open_run.report.request_latency.unwrap();
        assert!(open_l.mean_queue_ns > 10.0 * open_l.mean_service_ns);

        let mut closed = ServeKvScenario::new(10_000, trace).with_opts(ServeOpts {
            slo_shed_ns: None,
            closed_loop_think_ns: Some(500),
        });
        let closed_run = Driver::new(&topo(), Box::new(LocalCachePolicy), 4)
            .with_verify(true)
            .run(&mut closed);
        let closed_l = closed_run.report.request_latency.unwrap();
        assert_eq!(closed_run.report.request_shed, 0, "closed loop never sheds");
        assert_eq!(closed_l.count, 1_000);
        assert!(closed_l.mean_queue_ns == 0.0, "no arrival queue to wait in");
        assert!(
            closed_l.p99_ns * 5 < open_l.p99_ns,
            "closed loop p99 {} must stay far below the diverged open loop {}",
            closed_l.p99_ns,
            open_l.p99_ns
        );
    }

    /// Priority tiers under load: Critical's tail stays below
    /// Background's, and the tiered default path (all-Normal trace)
    /// matches the historical FCFS behavior bit-for-bit.
    #[test]
    fn critical_tail_beats_background_under_load() {
        let trace = Arc::new(Trace::synth(&TraceConfig {
            requests: 2_000,
            rate_rps: 20.0e6,
            keyspace: 10_000,
            seed: 3,
            priority_mix: Some(PriorityMix {
                critical: 0.2,
                background: 0.3,
            }),
            ..Default::default()
        }));
        let mut s = ServeKvScenario::new(10_000, trace);
        let run = Driver::new(&topo(), Box::new(LocalCachePolicy), 4)
            .with_verify(true)
            .run(&mut s);
        let by_class: std::collections::HashMap<&str, _> = run
            .report
            .class_latency
            .iter()
            .map(|(n, l)| (*n, l.clone()))
            .collect();
        let crit = &by_class["critical"];
        let bg = &by_class["background"];
        assert!(
            crit.p99_ns <= bg.p99_ns,
            "critical p99 {} must not exceed background p99 {}",
            crit.p99_ns,
            bg.p99_ns
        );
    }
}
