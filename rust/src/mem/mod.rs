//! Memory regions and NUMA-aware placement — the `numactl`/`mbind`
//! substitute the runtime manages (§4.1 "task and memory manager").
//!
//! Workloads allocate named [`Region`]s with a [`Placement`] policy; the
//! cache model tracks residency per region, and the DRAM side of an access
//! is charged against the region's home NUMA node(s). Algorithm 2's
//! `set_mempolicy(MPOL_BIND, …)` maps to [`MemoryManager::rebind`].
//!
//! Under the sharded accounting layout ([`crate::coordinator`]) a
//! region's state is owned piecewise by the shards: each chiplet shard
//! tracks its own L3 residency slice of the region, and the DRAM home
//! computed by [`MemoryManager::dram_home`] selects which *socket
//! shard*'s DDR tracker a miss is charged to
//! (`Topology::socket_of_numa`). The registry itself is read-mostly:
//! every access reads it (size + placement) under a shared lock; only
//! alloc/free/rebind take the write side.

use std::collections::HashMap;

/// Opaque region handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RegionId(pub u32);

/// NUMA placement policy for a region.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    /// All pages on one NUMA node (MPOL_BIND).
    Bind(usize),
    /// Pages interleaved across all NUMA nodes (MPOL_INTERLEAVE).
    Interleave,
    /// Logically replicated per NUMA node (Shoal-style array replication —
    /// reads are always node-local, writes pay a broadcast).
    Replicated,
}

/// A named allocation.
#[derive(Clone, Debug)]
pub struct Region {
    pub id: RegionId,
    pub label: String,
    pub size: u64,
    pub placement: Placement,
}

/// Region registry + placement bookkeeping.
#[derive(Clone, Debug, Default)]
pub struct MemoryManager {
    regions: HashMap<RegionId, Region>,
    next: u32,
}

impl MemoryManager {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a region; returns its handle.
    pub fn alloc(&mut self, label: &str, size: u64, placement: Placement) -> RegionId {
        self.next += 1;
        let id = RegionId(self.next);
        self.regions.insert(
            id,
            Region {
                id,
                label: label.to_string(),
                size: size.max(1),
                placement,
            },
        );
        id
    }

    pub fn free(&mut self, id: RegionId) -> Option<Region> {
        self.regions.remove(&id)
    }

    pub fn get(&self, id: RegionId) -> Option<&Region> {
        self.regions.get(&id)
    }

    pub fn size(&self, id: RegionId) -> u64 {
        self.regions.get(&id).map(|r| r.size).unwrap_or(1)
    }

    pub fn placement(&self, id: RegionId) -> Placement {
        self.regions
            .get(&id)
            .map(|r| r.placement)
            .unwrap_or(Placement::Interleave)
    }

    /// Re-bind a region to a NUMA node (Algorithm 2 line 14:
    /// `set_mempolicy(MPOL_BIND, 1 << numa_node)`). Returns whether the
    /// region exists — a miss is a caller bug everywhere except the
    /// adaptive path, where a policy's move can race a free.
    #[must_use]
    pub fn rebind(&mut self, id: RegionId, numa: usize) -> bool {
        match self.regions.get_mut(&id) {
            Some(r) => {
                r.placement = Placement::Bind(numa);
                true
            }
            None => false,
        }
    }

    /// Dense `(size, placement)` snapshot indexed by raw region id, for
    /// the lock-free region-table published by [`crate::sim::Machine`].
    /// Ids are allocated sequentially from 1, so the vec stays compact.
    pub fn snapshot_entries(&self) -> Vec<Option<(u64, Placement)>> {
        let mut entries = vec![None; self.next as usize + 1];
        for (id, r) in &self.regions {
            entries[id.0 as usize] = Some((r.size, r.placement));
        }
        entries
    }

    /// Expected DRAM-latency multiplier context: which NUMA node serves a
    /// DRAM access to `region` issued from `core_numa`, under the region's
    /// placement. Returns `(serving_numa, local_fraction)`:
    /// for `Interleave` the access is split across nodes.
    pub fn dram_home(&self, id: RegionId, core_numa: usize, num_numa: usize) -> (usize, f64) {
        match self.placement(id) {
            Placement::Bind(n) => (n, if n == core_numa { 1.0 } else { 0.0 }),
            Placement::Replicated => (core_numa, 1.0),
            Placement::Interleave => (core_numa, 1.0 / num_numa.max(1) as f64),
        }
    }

    pub fn total_allocated(&self) -> u64 {
        self.regions.values().map(|r| r.size).sum()
    }

    pub fn len(&self) -> usize {
        self.regions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.regions.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_assigns_unique_ids() {
        let mut m = MemoryManager::new();
        let a = m.alloc("a", 100, Placement::Bind(0));
        let b = m.alloc("b", 200, Placement::Interleave);
        assert_ne!(a, b);
        assert_eq!(m.size(a), 100);
        assert_eq!(m.size(b), 200);
        assert_eq!(m.total_allocated(), 300);
        assert_eq!(m.len(), 2);
    }

    #[test]
    fn rebind_changes_placement() {
        let mut m = MemoryManager::new();
        let a = m.alloc("a", 100, Placement::Bind(0));
        assert!(m.rebind(a, 1));
        assert_eq!(m.placement(a), Placement::Bind(1));
    }

    #[test]
    fn rebind_unknown_region_reports_miss() {
        let mut m = MemoryManager::new();
        let a = m.alloc("a", 100, Placement::Bind(0));
        assert!(!m.rebind(RegionId(a.0 + 7), 1));
        assert_eq!(m.placement(a), Placement::Bind(0));
    }

    #[test]
    fn snapshot_entries_mirror_registry() {
        let mut m = MemoryManager::new();
        let a = m.alloc("a", 100, Placement::Bind(0));
        let b = m.alloc("b", 200, Placement::Interleave);
        m.free(a);
        let entries = m.snapshot_entries();
        assert_eq!(entries[a.0 as usize], None);
        assert_eq!(entries[b.0 as usize], Some((200, Placement::Interleave)));
    }

    #[test]
    fn dram_home_bind() {
        let mut m = MemoryManager::new();
        let a = m.alloc("a", 100, Placement::Bind(1));
        assert_eq!(m.dram_home(a, 1, 2), (1, 1.0));
        assert_eq!(m.dram_home(a, 0, 2), (1, 0.0));
    }

    #[test]
    fn dram_home_interleave_splits() {
        let mut m = MemoryManager::new();
        let a = m.alloc("a", 100, Placement::Interleave);
        let (_, frac) = m.dram_home(a, 0, 2);
        assert!((frac - 0.5).abs() < 1e-12);
    }

    #[test]
    fn dram_home_replicated_is_local() {
        let mut m = MemoryManager::new();
        let a = m.alloc("a", 100, Placement::Replicated);
        assert_eq!(m.dram_home(a, 1, 2), (1, 1.0));
    }

    #[test]
    fn free_removes() {
        let mut m = MemoryManager::new();
        let a = m.alloc("a", 100, Placement::Bind(0));
        assert!(m.free(a).is_some());
        assert!(m.get(a).is_none());
        assert!(m.is_empty());
    }
}
