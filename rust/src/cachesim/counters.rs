//! Hierarchical access counters — the libpfm substitute.
//!
//! Counts are kept per chiplet (of the *issuing* core) and in aggregate,
//! using the same taxonomy the paper reports in Tab. 1 and Tab. 2.
//! `fill_events()` — remote-chiplet cache fills — is the signal Algorithm 1
//! polls via `getEventCounter()`.

use super::Outcome;

/// Counts for one class bucket.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ClassCounts {
    /// L3 hits in the issuing core's own chiplet.
    pub local: f64,
    /// L3 hits in a sibling chiplet within the same NUMA domain.
    pub near: f64,
    /// L3 hits in a chiplet on another NUMA domain / socket.
    pub far: f64,
    /// DRAM accesses.
    pub dram: f64,
}

impl ClassCounts {
    pub fn total_ops(&self) -> f64 {
        self.local + self.near + self.far + self.dram
    }

    /// Remote-chiplet fill events: everything served from outside the
    /// local chiplet's L3 other than DRAM (the paper's "cache fill events
    /// ... remote memory accesses between chiplets").
    pub fn fill_events(&self) -> f64 {
        self.near + self.far
    }

    pub fn add(&mut self, o: &Outcome) {
        self.local += o.local_hits;
        self.near += o.near_hits;
        self.far += o.far_hits;
        self.dram += o.dram_lines;
    }

    pub fn merge(&mut self, other: &ClassCounts) {
        self.local += other.local;
        self.near += other.near;
        self.far += other.far;
        self.dram += other.dram;
    }
}

/// Per-chiplet + aggregate counters with snapshot/delta support.
#[derive(Clone, Debug)]
pub struct Counters {
    per_chiplet: Vec<ClassCounts>,
}

impl Counters {
    pub fn new(num_chiplets: usize) -> Self {
        Self {
            per_chiplet: vec![ClassCounts::default(); num_chiplets],
        }
    }

    /// Assemble from per-chiplet slices (the sharded machine keeps each
    /// chiplet's `ClassCounts` in its own shard and snapshots them here).
    pub fn from_parts(per_chiplet: Vec<ClassCounts>) -> Self {
        Self { per_chiplet }
    }

    pub fn record(&mut self, chiplet: usize, o: &Outcome) {
        self.per_chiplet[chiplet].add(o);
    }

    pub fn chiplet(&self, chiplet: usize) -> &ClassCounts {
        &self.per_chiplet[chiplet]
    }

    pub fn total(&self) -> ClassCounts {
        let mut t = ClassCounts::default();
        for c in &self.per_chiplet {
            t.merge(c);
        }
        t
    }

    pub fn reset(&mut self) {
        for c in &mut self.per_chiplet {
            *c = ClassCounts::default();
        }
    }

    /// Aggregate remote-chiplet fill events (Algorithm 1's counter).
    pub fn fill_events(&self) -> f64 {
        self.total().fill_events()
    }

    pub fn num_chiplets(&self) -> usize {
        self.per_chiplet.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(local: f64, near: f64, far: f64, dram: f64) -> Outcome {
        Outcome {
            local_hits: local,
            near_hits: near,
            far_hits: far,
            dram_lines: dram,
            latency_ns: 0.0,
            dram_bytes: dram * 64.0,
        }
    }

    #[test]
    fn record_and_total() {
        let mut c = Counters::new(4);
        c.record(0, &outcome(10.0, 5.0, 1.0, 2.0));
        c.record(3, &outcome(1.0, 0.0, 0.0, 9.0));
        let t = c.total();
        assert_eq!(t.local, 11.0);
        assert_eq!(t.near, 5.0);
        assert_eq!(t.far, 1.0);
        assert_eq!(t.dram, 11.0);
        assert_eq!(t.total_ops(), 28.0);
    }

    #[test]
    fn fill_events_exclude_local_and_dram() {
        let mut c = Counters::new(2);
        c.record(1, &outcome(100.0, 7.0, 3.0, 50.0));
        assert_eq!(c.fill_events(), 10.0);
    }

    #[test]
    fn reset_zeroes() {
        let mut c = Counters::new(2);
        c.record(0, &outcome(1.0, 1.0, 1.0, 1.0));
        c.reset();
        assert_eq!(c.total().total_ops(), 0.0);
    }

    #[test]
    fn per_chiplet_isolation() {
        let mut c = Counters::new(2);
        c.record(0, &outcome(5.0, 0.0, 0.0, 0.0));
        assert_eq!(c.chiplet(0).local, 5.0);
        assert_eq!(c.chiplet(1).local, 0.0);
    }
}
