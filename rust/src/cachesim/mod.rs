//! Analytic per-chiplet L3 cache model.
//!
//! This is the substitute for the libpfm hardware counters of the paper's
//! testbed. Tasks do not issue individual loads; they issue *access
//! summaries* (`Pattern` over a `Region`). The model tracks per-chiplet
//! residency (segment-LRU over regions) and computes the expected split of
//! line accesses across the hierarchy:
//!
//! - **local chiplet** L3 hit          (paper: "Local Chiplet"),
//! - **sibling chiplet, same NUMA** L3 hit ("Local NUMA Chiplet"),
//! - **chiplet on another NUMA/socket** L3 hit ("Remote NUMA Chiplet"),
//! - **DRAM** access                    ("Main Memory").
//!
//! The split drives both the virtual-time cost (latency × accesses +
//! bandwidth terms via [`crate::memsim`]) and the event counters that
//! Algorithm 1's `getEventCounter()` reads (remote-chiplet cache-fill
//! events). Expected-value accounting keeps the model deterministic and
//! fast — billions of modeled line accesses cost a few arithmetic ops.

mod counters;
pub use counters::{ClassCounts, Counters};

use std::collections::HashMap;

use crate::mem::RegionId;
use crate::topology::Topology;

/// Cache line size in bytes.
pub const LINE: u64 = 64;

/// Access pattern summary for one task step.
#[derive(Clone, Copy, Debug)]
pub enum Pattern {
    /// Stream `bytes` sequentially (scan / write of a contiguous chunk).
    Seq { bytes: u64 },
    /// `ops` line-sized accesses uniformly distributed over `span` bytes.
    Rand { ops: u64, span: u64 },
}

impl Pattern {
    /// Number of line accesses this pattern issues.
    pub fn ops(&self) -> u64 {
        match *self {
            Pattern::Seq { bytes } => crate::util::div_ceil(bytes.max(1), LINE),
            Pattern::Rand { ops, .. } => ops,
        }
    }

    /// Expected number of *unique* bytes touched.
    pub fn unique_bytes(&self) -> u64 {
        match *self {
            Pattern::Seq { bytes } => bytes,
            Pattern::Rand { ops, span } => {
                let lines = (span / LINE).max(1);
                // E[unique lines] = L * (1 - (1 - 1/L)^ops) ≈ L(1-e^{-ops/L}).
                let frac = 1.0 - (-(ops as f64) / lines as f64).exp();
                ((lines as f64 * frac) * LINE as f64) as u64
            }
        }
    }
}

/// One modeled access: a pattern over a region, issued from a core.
#[derive(Clone, Copy, Debug)]
pub struct Access {
    pub region: RegionId,
    pub pattern: Pattern,
    pub write: bool,
    /// Memory-level parallelism: how many accesses overlap (1.0 =
    /// dependent pointer chase, 8–16 = streaming with prefetch).
    pub mlp: f64,
}

impl Access {
    pub fn seq_read(region: RegionId, bytes: u64) -> Self {
        Self { region, pattern: Pattern::Seq { bytes }, write: false, mlp: 8.0 }
    }

    pub fn seq_write(region: RegionId, bytes: u64) -> Self {
        Self { region, pattern: Pattern::Seq { bytes }, write: true, mlp: 8.0 }
    }

    pub fn rand_read(region: RegionId, ops: u64, span: u64) -> Self {
        Self { region, pattern: Pattern::Rand { ops, span }, write: false, mlp: 2.0 }
    }

    pub fn rand_write(region: RegionId, ops: u64, span: u64) -> Self {
        Self { region, pattern: Pattern::Rand { ops, span }, write: true, mlp: 2.0 }
    }

    pub fn with_mlp(mut self, mlp: f64) -> Self {
        self.mlp = mlp.max(1.0);
        self
    }
}

/// Expected outcome of one modeled access.
#[derive(Clone, Copy, Debug, Default)]
pub struct Outcome {
    pub local_hits: f64,
    pub near_hits: f64,
    pub far_hits: f64,
    pub dram_lines: f64,
    /// Latency-weighted cost in ns (excluding DRAM bandwidth queueing,
    /// which the memsim adds on top).
    pub latency_ns: f64,
    /// Bytes that must come from DRAM.
    pub dram_bytes: f64,
}

impl Outcome {
    pub fn total_ops(&self) -> f64 {
        self.local_hits + self.near_hits + self.far_hits + self.dram_lines
    }
}

/// Per-region residency in one chiplet's L3.
#[derive(Clone, Debug)]
struct Segment {
    bytes: u64,
    stamp: u64,
}

/// One chiplet's shared L3.
#[derive(Clone, Debug)]
struct ChipletL3 {
    capacity: u64,
    used: u64,
    segments: HashMap<RegionId, Segment>,
}

impl ChipletL3 {
    fn new(capacity: u64) -> Self {
        Self { capacity, used: 0, segments: HashMap::new() }
    }

    fn resident(&self, region: RegionId) -> u64 {
        self.segments.get(&region).map(|s| s.bytes).unwrap_or(0)
    }

    /// Bring `bytes` of `region` into this L3, evicting LRU segments.
    fn fill(&mut self, region: RegionId, bytes: u64, stamp: u64, region_size: u64) {
        let have = self.resident(region);
        let want = (have + bytes).min(region_size).min(self.capacity);
        if want <= have {
            if let Some(s) = self.segments.get_mut(&region) {
                s.stamp = stamp; // refresh recency only
            }
            return;
        }
        let mut delta = want - have;
        // Evict LRU segments until there is room.
        while self.used + delta > self.capacity {
            let victim = self
                .segments
                .iter()
                .filter(|(id, _)| **id != region)
                .min_by_key(|(_, s)| s.stamp)
                .map(|(id, _)| *id);
            match victim {
                Some(id) => {
                    let seg = self.segments.remove(&id).unwrap();
                    self.used -= seg.bytes;
                }
                None => {
                    // Only this region resides here; shrink the fill.
                    delta = self.capacity - self.used;
                    break;
                }
            }
        }
        let e = self
            .segments
            .entry(region)
            .or_insert(Segment { bytes: 0, stamp });
        e.bytes += delta;
        e.stamp = stamp;
        self.used += delta;
    }

    /// Drop `frac` of the resident bytes of `region` (coherence
    /// invalidation on remote writes).
    fn invalidate_frac(&mut self, region: RegionId, frac: f64) {
        if let Some(s) = self.segments.get_mut(&region) {
            let drop = (s.bytes as f64 * frac.clamp(0.0, 1.0)) as u64;
            s.bytes -= drop;
            self.used -= drop;
            if s.bytes == 0 {
                self.segments.remove(&region);
            }
        }
    }

    fn flush(&mut self) {
        self.segments.clear();
        self.used = 0;
    }
}

/// The machine-wide cache model.
#[derive(Clone, Debug)]
pub struct CacheSim {
    topo: Topology,
    chiplets: Vec<ChipletL3>,
    region_sizes: HashMap<RegionId, u64>,
    stamp: u64,
    /// Hierarchical access counters (the libpfm substitute).
    pub counters: Counters,
}

impl CacheSim {
    pub fn new(topo: &Topology) -> Self {
        let chiplets = (0..topo.num_chiplets())
            .map(|_| ChipletL3::new(topo.l3_per_chiplet))
            .collect();
        Self {
            topo: topo.clone(),
            chiplets,
            region_sizes: HashMap::new(),
            stamp: 0,
            counters: Counters::new(topo.num_chiplets()),
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    pub fn register_region(&mut self, region: RegionId, size: u64) {
        self.region_sizes.insert(region, size.max(1));
    }

    pub fn drop_region(&mut self, region: RegionId) {
        self.region_sizes.remove(&region);
        for ch in &mut self.chiplets {
            ch.invalidate_frac(region, 1.0);
        }
    }

    pub fn region_size(&self, region: RegionId) -> u64 {
        *self.region_sizes.get(&region).unwrap_or(&1)
    }

    /// Resident bytes of `region` in `chiplet`'s L3.
    pub fn resident(&self, chiplet: usize, region: RegionId) -> u64 {
        self.chiplets[chiplet].resident(region)
    }

    /// Flush every chiplet's L3 (between experiment repetitions).
    pub fn flush_all(&mut self) {
        for ch in &mut self.chiplets {
            ch.flush();
        }
    }

    /// Model one access issued by `core`; returns the expected outcome and
    /// updates residency + counters.
    pub fn access(&mut self, core: usize, acc: Access) -> Outcome {
        self.stamp += 1;
        let my_chiplet = self.topo.chiplet_of(core);
        let my_numa = self.topo.numa_of_core(core);
        let size = self.region_size(acc.region) as f64;
        let ops = acc.pattern.ops() as f64;
        if ops == 0.0 {
            return Outcome::default();
        }

        // Probability a touched line is resident in a given chiplet's L3.
        // Residency is tracked per-region; resident bytes are assumed
        // uniformly spread over the region.
        let frac_of = |resident: u64| -> f64 { (resident as f64 / size).min(1.0) };

        let p_local = frac_of(self.chiplets[my_chiplet].resident(acc.region));

        // Fraction available from sibling chiplets in the same NUMA domain
        // (union bound, capped by what is not already local).
        let mut p_near = 0.0;
        for ch in self.topo.chiplets_of_numa(my_numa) {
            if ch != my_chiplet {
                p_near += frac_of(self.chiplets[ch].resident(acc.region));
            }
        }
        p_near = p_near.min(1.0 - p_local).max(0.0);

        // Fraction available from chiplets on other NUMA domains.
        let mut p_far = 0.0;
        for numa in 0..self.topo.num_numa() {
            if numa == my_numa {
                continue;
            }
            for ch in self.topo.chiplets_of_numa(numa) {
                p_far += frac_of(self.chiplets[ch].resident(acc.region));
            }
        }
        p_far = p_far.min((1.0 - p_local - p_near).max(0.0));

        let p_dram = (1.0 - p_local - p_near - p_far).max(0.0);

        let local_hits = ops * p_local;
        let near_hits = ops * p_near;
        let far_hits = ops * p_far;
        let dram_lines = ops * p_dram;

        // Latency per class; overlapped by MLP.
        let lat = &self.topo.lat;
        let near_ns = lat.l3_hit_ns + lat.inter_chiplet_near_ns;
        let far_ns = lat.l3_hit_ns + lat.cross_socket_ns;
        let dram_ns = self.topo.dram_access_ns(core, my_numa);
        let raw_ns = local_hits * lat.l3_hit_ns
            + near_hits * near_ns
            + far_hits * far_ns
            + dram_lines * dram_ns;
        let latency_ns = raw_ns / acc.mlp.max(1.0);

        // Residency update: fills land in the local chiplet's L3.
        let unique = acc.pattern.unique_bytes().min(size as u64);
        let fill_bytes = ((unique as f64) * (1.0 - p_local)) as u64;
        self.chiplets[my_chiplet].fill(acc.region, fill_bytes, self.stamp, size as u64);

        // Coherence: a write invalidates the written fraction elsewhere.
        if acc.write {
            let written_frac = (unique as f64 / size).min(1.0);
            for ch in 0..self.chiplets.len() {
                if ch != my_chiplet {
                    self.chiplets[ch].invalidate_frac(acc.region, written_frac);
                }
            }
        }

        let out = Outcome {
            local_hits,
            near_hits,
            far_hits,
            dram_lines,
            latency_ns,
            dram_bytes: dram_lines * LINE as f64,
        };
        self.counters.record(my_chiplet, &out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::RegionId;

    fn setup() -> (CacheSim, RegionId) {
        let topo = Topology::milan_2s();
        let mut sim = CacheSim::new(&topo);
        let r = RegionId(1);
        sim.register_region(r, 16 << 20); // 16 MiB, fits one chiplet L3
        (sim, r)
    }

    #[test]
    fn cold_access_goes_to_dram() {
        let (mut sim, r) = setup();
        let out = sim.access(0, Access::seq_read(r, 16 << 20));
        assert!(out.dram_lines > 0.9 * out.total_ops());
        assert!(out.local_hits < 0.1 * out.total_ops());
    }

    #[test]
    fn warm_access_hits_local_l3() {
        let (mut sim, r) = setup();
        sim.access(0, Access::seq_read(r, 16 << 20)); // warm
        let out = sim.access(0, Access::seq_read(r, 16 << 20));
        assert!(
            out.local_hits > 0.95 * out.total_ops(),
            "local={} total={}",
            out.local_hits,
            out.total_ops()
        );
    }

    #[test]
    fn sibling_chiplet_hit_counts_as_near() {
        let (mut sim, r) = setup();
        sim.access(0, Access::seq_read(r, 16 << 20)); // chiplet 0 warm
        // Core 8 is chiplet 1 (same NUMA): should mostly hit chiplet 0's L3.
        let out = sim.access(8, Access::rand_read(r, 1000, 16 << 20));
        assert!(out.near_hits > 0.8 * out.total_ops(), "near={:?}", out);
    }

    #[test]
    fn cross_socket_hit_counts_as_far() {
        let (mut sim, r) = setup();
        sim.access(0, Access::seq_read(r, 16 << 20));
        // Core 64 is on socket 1.
        let out = sim.access(64, Access::rand_read(r, 1000, 16 << 20));
        assert!(out.far_hits > 0.8 * out.total_ops(), "far={:?}", out);
    }

    #[test]
    fn oversized_region_misses() {
        let topo = Topology::milan_2s();
        let mut sim = CacheSim::new(&topo);
        let r = RegionId(2);
        sim.register_region(r, 256 << 20); // 8x one chiplet's L3
        sim.access(0, Access::seq_read(r, 256 << 20));
        let out = sim.access(0, Access::rand_read(r, 10_000, 256 << 20));
        // At most 32/256 can be resident locally.
        assert!(out.local_hits < 0.2 * out.total_ops(), "{out:?}");
        assert!(out.dram_lines > 0.5 * out.total_ops(), "{out:?}");
    }

    #[test]
    fn latency_orders_local_faster_than_remote() {
        let (mut sim, r) = setup();
        sim.access(0, Access::seq_read(r, 16 << 20));
        let local = sim.access(0, Access::rand_read(r, 1000, 16 << 20));
        let mut sim2 = CacheSim::new(&Topology::milan_2s());
        sim2.register_region(r, 16 << 20);
        sim2.access(0, Access::seq_read(r, 16 << 20));
        let remote = sim2.access(40, Access::rand_read(r, 1000, 16 << 20));
        assert!(local.latency_ns < remote.latency_ns);
    }

    #[test]
    fn write_invalidates_remote_copies() {
        let (mut sim, r) = setup();
        sim.access(0, Access::seq_read(r, 16 << 20));
        assert!(sim.resident(0, r) > 0);
        // Full overwrite from chiplet 2.
        sim.access(16, Access::seq_write(r, 16 << 20));
        assert_eq!(sim.resident(0, r), 0, "writer must invalidate readers");
        assert!(sim.resident(2, r) > 0);
    }

    #[test]
    fn lru_eviction_respects_capacity() {
        let topo = Topology::milan_2s();
        let mut sim = CacheSim::new(&topo);
        let a = RegionId(10);
        let b = RegionId(11);
        sim.register_region(a, 24 << 20);
        sim.register_region(b, 24 << 20);
        sim.access(0, Access::seq_read(a, 24 << 20));
        sim.access(0, Access::seq_read(b, 24 << 20));
        let used = sim.chiplets[0].used;
        assert!(used <= topo.l3_per_chiplet);
        // b is more recent; a must have been (partially) evicted.
        assert!(sim.resident(0, b) > sim.resident(0, a));
    }

    #[test]
    fn counters_accumulate() {
        let (mut sim, r) = setup();
        sim.access(0, Access::seq_read(r, 1 << 20));
        sim.access(8, Access::rand_read(r, 100, 1 << 20));
        assert!(sim.counters.total().dram > 0.0);
        assert!(sim.counters.total().total_ops() > 0.0);
    }

    #[test]
    fn pattern_unique_bytes() {
        let p = Pattern::Seq { bytes: 4096 };
        assert_eq!(p.unique_bytes(), 4096);
        let r = Pattern::Rand { ops: 1_000_000, span: 1 << 20 };
        // ops >> lines: nearly all lines touched.
        assert!(r.unique_bytes() > (1u64 << 20) * 9 / 10); // > 90% of 1 MiB
        let few = Pattern::Rand { ops: 10, span: 1 << 30 };
        assert!(few.unique_bytes() <= 10 * LINE);
    }

    #[test]
    fn flush_clears_residency() {
        let (mut sim, r) = setup();
        sim.access(0, Access::seq_read(r, 16 << 20));
        sim.flush_all();
        assert_eq!(sim.resident(0, r), 0);
    }
}
