//! Analytic per-chiplet L3 cache model.
//!
//! This is the substitute for the libpfm hardware counters of the paper's
//! testbed. Tasks do not issue individual loads; they issue *access
//! summaries* (`Pattern` over a `Region`). The model tracks per-chiplet
//! residency (segment-LRU over regions) and computes the expected split of
//! line accesses across the hierarchy:
//!
//! - **local chiplet** L3 hit          (paper: "Local Chiplet"),
//! - **sibling chiplet, same NUMA** L3 hit ("Local NUMA Chiplet"),
//! - **chiplet on another NUMA/socket** L3 hit ("Remote NUMA Chiplet"),
//! - **DRAM** access                    ("Main Memory").
//!
//! The split drives both the virtual-time cost (latency × accesses +
//! bandwidth terms via [`crate::memsim`]) and the event counters that
//! Algorithm 1's `getEventCounter()` reads (remote-chiplet cache-fill
//! events). Expected-value accounting keeps the model deterministic and
//! fast — billions of modeled line accesses cost a few arithmetic ops.
//!
//! Since the sharded-accounting refactor this module holds the *model
//! pieces*, not machine-wide state: [`ChipletL3`] is one chiplet's
//! residency tracker (owned by that chiplet's shard in
//! [`crate::coordinator`]), and [`classify`] is the pure hit/miss split
//! over per-chiplet residency queries. The wiring — which shard to lock, in what
//! order — lives in [`crate::sim::Machine`], so the model math itself
//! cannot depend on how the state is partitioned.

mod counters;
pub use counters::{ClassCounts, Counters};

use std::collections::HashMap;

use crate::mem::RegionId;
use crate::topology::Topology;

/// Cache line size in bytes.
pub const LINE: u64 = 64;

/// Access pattern summary for one task step.
#[derive(Clone, Copy, Debug)]
pub enum Pattern {
    /// Stream `bytes` sequentially (scan / write of a contiguous chunk).
    Seq { bytes: u64 },
    /// `ops` line-sized accesses uniformly distributed over `span` bytes.
    Rand { ops: u64, span: u64 },
}

impl Pattern {
    /// Number of line accesses this pattern issues.
    pub fn ops(&self) -> u64 {
        match *self {
            Pattern::Seq { bytes } => crate::util::div_ceil(bytes.max(1), LINE),
            Pattern::Rand { ops, .. } => ops,
        }
    }

    /// Expected number of *unique* bytes touched.
    pub fn unique_bytes(&self) -> u64 {
        match *self {
            Pattern::Seq { bytes } => bytes,
            Pattern::Rand { ops, span } => {
                let lines = (span / LINE).max(1);
                // E[unique lines] = L * (1 - (1 - 1/L)^ops) ≈ L(1-e^{-ops/L}).
                let frac = 1.0 - (-(ops as f64) / lines as f64).exp();
                ((lines as f64 * frac) * LINE as f64) as u64
            }
        }
    }
}

/// One modeled access: a pattern over a region, issued from a core.
#[derive(Clone, Copy, Debug)]
pub struct Access {
    pub region: RegionId,
    pub pattern: Pattern,
    pub write: bool,
    /// Memory-level parallelism: how many accesses overlap (1.0 =
    /// dependent pointer chase, 8–16 = streaming with prefetch).
    pub mlp: f64,
}

impl Access {
    pub fn seq_read(region: RegionId, bytes: u64) -> Self {
        Self { region, pattern: Pattern::Seq { bytes }, write: false, mlp: 8.0 }
    }

    pub fn seq_write(region: RegionId, bytes: u64) -> Self {
        Self { region, pattern: Pattern::Seq { bytes }, write: true, mlp: 8.0 }
    }

    pub fn rand_read(region: RegionId, ops: u64, span: u64) -> Self {
        Self { region, pattern: Pattern::Rand { ops, span }, write: false, mlp: 2.0 }
    }

    pub fn rand_write(region: RegionId, ops: u64, span: u64) -> Self {
        Self { region, pattern: Pattern::Rand { ops, span }, write: true, mlp: 2.0 }
    }

    pub fn with_mlp(mut self, mlp: f64) -> Self {
        self.mlp = mlp.max(1.0);
        self
    }
}

/// Expected outcome of one modeled access.
#[derive(Clone, Copy, Debug, Default)]
pub struct Outcome {
    pub local_hits: f64,
    pub near_hits: f64,
    pub far_hits: f64,
    pub dram_lines: f64,
    /// Latency-weighted cost in ns (excluding DRAM bandwidth queueing,
    /// which the memsim adds on top).
    pub latency_ns: f64,
    /// Bytes that must come from DRAM.
    pub dram_bytes: f64,
}

impl Outcome {
    pub fn total_ops(&self) -> f64 {
        self.local_hits + self.near_hits + self.far_hits + self.dram_lines
    }
}

/// Per-region residency in one chiplet's L3.
#[derive(Clone, Debug)]
struct Segment {
    bytes: u64,
    stamp: u64,
}

/// One chiplet's shared L3: per-region resident bytes under segment-LRU.
///
/// Owned by that chiplet's shard ([`crate::coordinator::ChipletShard`]);
/// the recency `stamp` passed to [`ChipletL3::fill`] only ever needs to
/// be monotone *per chiplet*, which is why a per-shard counter replaced
/// the old machine-global one without changing any eviction decision.
#[derive(Clone, Debug)]
pub struct ChipletL3 {
    capacity: u64,
    used: u64,
    segments: HashMap<RegionId, Segment>,
}

impl ChipletL3 {
    pub fn new(capacity: u64) -> Self {
        Self { capacity, used: 0, segments: HashMap::new() }
    }

    /// Resident bytes of `region` in this L3.
    pub fn resident(&self, region: RegionId) -> u64 {
        self.segments.get(&region).map(|s| s.bytes).unwrap_or(0)
    }

    /// Total resident bytes (≤ capacity).
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Bring `bytes` of `region` into this L3, evicting LRU segments.
    pub fn fill(&mut self, region: RegionId, bytes: u64, stamp: u64, region_size: u64) {
        let have = self.resident(region);
        let want = (have + bytes).min(region_size).min(self.capacity);
        if want <= have {
            if let Some(s) = self.segments.get_mut(&region) {
                s.stamp = stamp; // refresh recency only
            }
            return;
        }
        let mut delta = want - have;
        // Evict LRU segments until there is room.
        while self.used + delta > self.capacity {
            let victim = self
                .segments
                .iter()
                .filter(|(id, _)| **id != region)
                .min_by_key(|(_, s)| s.stamp)
                .map(|(id, _)| *id);
            match victim {
                Some(id) => {
                    let seg = self.segments.remove(&id).unwrap();
                    self.used -= seg.bytes;
                }
                None => {
                    // Only this region resides here; shrink the fill.
                    delta = self.capacity - self.used;
                    break;
                }
            }
        }
        let e = self
            .segments
            .entry(region)
            .or_insert(Segment { bytes: 0, stamp });
        e.bytes += delta;
        e.stamp = stamp;
        self.used += delta;
    }

    /// Drop `frac` of the resident bytes of `region` (coherence
    /// invalidation on remote writes).
    pub fn invalidate_frac(&mut self, region: RegionId, frac: f64) {
        if let Some(s) = self.segments.get_mut(&region) {
            let drop = (s.bytes as f64 * frac.clamp(0.0, 1.0)) as u64;
            s.bytes -= drop;
            self.used -= drop;
            if s.bytes == 0 {
                self.segments.remove(&region);
            }
        }
    }

    /// Clear all residency (between experiment repetitions).
    pub fn flush(&mut self) {
        self.segments.clear();
        self.used = 0;
    }
}

/// [`classify`]'s result: the expected outcome plus the local-residency
/// fraction the caller needs for the residency update (fill size).
#[derive(Clone, Copy, Debug, Default)]
pub struct Classified {
    pub out: Outcome,
    /// Probability a touched line was already resident in the issuing
    /// core's own chiplet.
    pub p_local: f64,
}

/// Pure hit/miss classification of one access over per-chiplet residency.
///
/// `resident_of(ch)` returns the resident-byte count of `acc.region` in
/// chiplet `ch`'s L3; each chiplet is queried **exactly once**, in a
/// fixed order (own chiplet, same-NUMA siblings, then remote NUMA
/// domains). The caller decides how a query is answered — one brief
/// shard-lock per chiplet in the sharded machine (never nested, and
/// skippable when the answer is known to be irrelevant), a per-step
/// probe cache ([`crate::sim::ProbeCache`]) that remembers remote
/// answers across the accesses of one coroutine step, direct `Vec`
/// indexing in a monolithic oracle — so no allocation or snapshot
/// buffer is needed. The arithmetic, including float summation order
/// over sibling and remote chiplets, is exactly the pre-refactor
/// `CacheSim::access` math, so every arrangement produces bit-identical
/// outcomes.
pub fn classify(
    topo: &Topology,
    core: usize,
    acc: Access,
    region_size: u64,
    mut resident_of: impl FnMut(usize) -> u64,
) -> Classified {
    let my_chiplet = topo.chiplet_of(core);
    let my_numa = topo.numa_of_core(core);
    let size = region_size.max(1) as f64;
    let ops = acc.pattern.ops() as f64;
    if ops == 0.0 {
        return Classified::default();
    }

    // Probability a touched line is resident in a given chiplet's L3.
    // Residency is tracked per-region; resident bytes are assumed
    // uniformly spread over the region.
    let mut frac_of = |ch: usize| -> f64 { (resident_of(ch) as f64 / size).min(1.0) };

    let p_local = frac_of(my_chiplet);

    // Fraction available from sibling chiplets in the same NUMA domain
    // (union bound, capped by what is not already local).
    let mut p_near = 0.0;
    for ch in topo.chiplets_of_numa(my_numa) {
        if ch != my_chiplet {
            p_near += frac_of(ch);
        }
    }
    p_near = p_near.min(1.0 - p_local).max(0.0);

    // Fraction available from chiplets on other NUMA domains.
    let mut p_far = 0.0;
    for numa in 0..topo.num_numa() {
        if numa == my_numa {
            continue;
        }
        for ch in topo.chiplets_of_numa(numa) {
            p_far += frac_of(ch);
        }
    }
    p_far = p_far.min((1.0 - p_local - p_near).max(0.0));

    let p_dram = (1.0 - p_local - p_near - p_far).max(0.0);

    let local_hits = ops * p_local;
    let near_hits = ops * p_near;
    let far_hits = ops * p_far;
    let dram_lines = ops * p_dram;

    // Latency per class; overlapped by MLP.
    let lat = &topo.lat;
    let near_ns = lat.l3_hit_ns + lat.inter_chiplet_near_ns;
    let far_ns = lat.l3_hit_ns + lat.cross_socket_ns;
    let dram_ns = topo.dram_access_ns(core, my_numa);
    let raw_ns = local_hits * lat.l3_hit_ns
        + near_hits * near_ns
        + far_hits * far_ns
        + dram_lines * dram_ns;
    let latency_ns = raw_ns / acc.mlp.max(1.0);

    Classified {
        out: Outcome {
            local_hits,
            near_hits,
            far_hits,
            dram_lines,
            latency_ns,
            dram_bytes: dram_lines * LINE as f64,
        },
        p_local,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::RegionId;

    #[test]
    fn pattern_unique_bytes() {
        let p = Pattern::Seq { bytes: 4096 };
        assert_eq!(p.unique_bytes(), 4096);
        let r = Pattern::Rand { ops: 1_000_000, span: 1 << 20 };
        // ops >> lines: nearly all lines touched.
        assert!(r.unique_bytes() > (1u64 << 20) * 9 / 10); // > 90% of 1 MiB
        let few = Pattern::Rand { ops: 10, span: 1 << 30 };
        assert!(few.unique_bytes() <= 10 * LINE);
    }

    #[test]
    fn l3_fill_and_lru_eviction_respect_capacity() {
        let mut l3 = ChipletL3::new(32 << 20);
        let a = RegionId(10);
        let b = RegionId(11);
        l3.fill(a, 24 << 20, 1, 24 << 20);
        l3.fill(b, 24 << 20, 2, 24 << 20);
        assert!(l3.used() <= 32 << 20);
        // b is more recent; a must have been (partially) evicted.
        assert!(l3.resident(b) > l3.resident(a));
    }

    #[test]
    fn l3_invalidate_and_flush() {
        let mut l3 = ChipletL3::new(1 << 20);
        let r = RegionId(1);
        l3.fill(r, 1 << 19, 1, 1 << 19);
        l3.invalidate_frac(r, 0.5);
        assert_eq!(l3.resident(r), 1 << 18);
        l3.flush();
        assert_eq!(l3.resident(r), 0);
        assert_eq!(l3.used(), 0);
    }

    #[test]
    fn l3_sole_region_fill_is_capped_at_capacity() {
        let mut l3 = ChipletL3::new(1 << 20);
        let r = RegionId(2);
        l3.fill(r, 8 << 20, 1, 8 << 20);
        assert_eq!(l3.resident(r), 1 << 20);
        assert_eq!(l3.used(), 1 << 20);
    }

    #[test]
    fn classify_cold_access_goes_to_dram() {
        let topo = crate::topology::Topology::milan_2s();
        let r = RegionId(1);
        let residency = vec![0u64; topo.num_chiplets()];
        let c = classify(&topo, 0, Access::seq_read(r, 16 << 20), 16 << 20, |ch| residency[ch]);
        assert!(c.out.dram_lines > 0.99 * c.out.total_ops());
        assert_eq!(c.p_local, 0.0);
    }

    #[test]
    fn classify_splits_by_residency_location() {
        let topo = crate::topology::Topology::milan_2s();
        let r = RegionId(1);
        let size = 16u64 << 20;
        // Fully resident in chiplet 0.
        let mut residency = vec![0u64; topo.num_chiplets()];
        residency[0] = size;
        // Core 0 (chiplet 0): all local.
        let local = classify(&topo, 0, Access::rand_read(r, 1000, size), size, |ch| residency[ch]);
        assert!(local.out.local_hits > 0.99 * local.out.total_ops());
        // Core 8 (chiplet 1, same NUMA): all near.
        let near = classify(&topo, 8, Access::rand_read(r, 1000, size), size, |ch| residency[ch]);
        assert!(near.out.near_hits > 0.99 * near.out.total_ops());
        // Core 64 (socket 1): all far.
        let far = classify(&topo, 64, Access::rand_read(r, 1000, size), size, |ch| residency[ch]);
        assert!(far.out.far_hits > 0.99 * far.out.total_ops());
        // Latency ordering follows the hierarchy.
        assert!(local.out.latency_ns < near.out.latency_ns);
        assert!(near.out.latency_ns < far.out.latency_ns);
    }

    #[test]
    fn classify_zero_ops_is_default() {
        let topo = crate::topology::Topology::milan_2s();
        let r = RegionId(1);
        let residency = vec![0u64; topo.num_chiplets()];
        let acc = Access {
            region: r,
            pattern: Pattern::Rand { ops: 0, span: 64 },
            write: false,
            mlp: 1.0,
        };
        let c = classify(&topo, 0, acc, 1 << 20, |ch| residency[ch]);
        assert_eq!(c.out.total_ops(), 0.0);
        assert_eq!(c.out.latency_ns, 0.0);
    }
}
