//! Real PJRT backend over the `xla` bindings (xla_extension).
//!
//! Only built with the `pjrt` cargo feature, which additionally requires
//! adding `xla` to `[dependencies]` (it is not in the offline crate set —
//! see `rust/Cargo.toml`).

use std::collections::HashMap;

use super::{ArtifactSpec, Result, RuntimeError};

fn err(e: impl std::fmt::Display) -> RuntimeError {
    RuntimeError::new(e.to_string())
}

/// A compiled executable + its spec.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 inputs (row-major, shapes per the spec); returns
    /// one f32 vec per output.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(RuntimeError::new(format!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&self.spec.inputs) {
            let expect: usize = shape.iter().product::<usize>().max(1);
            if data.len() != expect {
                return Err(RuntimeError::new(format!(
                    "{}: input length {} != shape {:?}",
                    self.spec.name,
                    data.len(),
                    shape
                )));
            }
            let lit = xla::Literal::vec1(data);
            let lit = if shape.is_empty() {
                lit.reshape(&[]).map_err(err)?
            } else {
                lit.reshape(&shape.iter().map(|&d| d as i64).collect::<Vec<_>>())
                    .map_err(err)?
            };
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(err)?[0][0]
            .to_literal_sync()
            .map_err(err)?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result.to_tuple().map_err(err)?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>().map_err(err)?);
        }
        Ok(out)
    }
}

/// The PJRT CPU runtime: one compiled executable per manifest entry.
pub struct PjrtRuntime {
    pub platform: String,
    execs: HashMap<String, Executable>,
}

impl PjrtRuntime {
    /// Whether a real PJRT backend was compiled in.
    pub const fn backend_available() -> bool {
        true
    }

    /// Compile every artifact in `dir`. Fails cleanly if the directory or
    /// manifest is missing (callers fall back to the rust engines).
    pub fn load(dir: &str) -> Result<Self> {
        let specs = super::load_manifest(dir)?;
        let client = xla::PjRtClient::cpu().map_err(err)?;
        let platform = client.platform_name();
        let mut execs = HashMap::new();
        for spec in specs {
            let path = format!("{dir}/{}", spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| RuntimeError::new(format!("parsing {path}: {e}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| RuntimeError::new(format!("compiling {}: {e}", spec.name)))?;
            execs.insert(spec.name.clone(), Executable { spec, exe });
        }
        Ok(Self { platform, execs })
    }

    pub fn get(&self, name: &str) -> Option<&Executable> {
        self.execs.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.execs.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn len(&self) -> usize {
        self.execs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.execs.is_empty()
    }

    /// Default artifact directory (repo layout).
    pub fn default_dir() -> String {
        std::env::var("ARCAS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
    }
}
