//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and executes
//! them from the rust request path (python is never invoked at runtime).
//!
//! Interchange is HLO *text* (`artifacts/*.hlo.txt` + `manifest.txt`):
//! the bundled xla_extension 0.5.1 rejects jax≥0.5's serialized protos
//! with 64-bit instruction ids, while the text parser reassigns ids (see
//! DESIGN.md and /opt/xla-example/README.md).
//!
//! [`PjrtRuntime`] compiles every manifest entry once at startup;
//! [`PjrtGrad`] adapts the `logreg_loss_grad_*` executables to the SGD
//! workload's [`GradEngine`] so Fig. 10/11 run real XLA numerics.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::config::Config;
use crate::workloads::sgd::{GradEngine, RustGrad};

/// Parsed manifest entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// Input shapes (empty vec = scalar).
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// Parse `inputs = 128x1024;128;scalar` shape lists.
pub fn parse_shapes(s: &str) -> Vec<Vec<usize>> {
    s.split(';')
        .filter(|p| !p.trim().is_empty())
        .map(|p| {
            let p = p.trim();
            if p == "scalar" {
                vec![]
            } else {
                p.split('x')
                    .map(|d| d.parse().expect("bad shape dim"))
                    .collect()
            }
        })
        .collect()
}

/// Load and parse `manifest.txt` from an artifact directory.
pub fn load_manifest(dir: &str) -> Result<Vec<ArtifactSpec>> {
    let path = format!("{dir}/manifest.txt");
    let cfg = Config::load(&path).map_err(|e| anyhow!("{e}"))?;
    let mut specs = Vec::new();
    for section in cfg.sections() {
        if section == "global" {
            continue;
        }
        specs.push(ArtifactSpec {
            name: section.to_string(),
            file: cfg
                .get(section, "file")
                .context("manifest entry missing file")?
                .to_string(),
            inputs: parse_shapes(cfg.get(section, "inputs").unwrap_or("")),
            outputs: parse_shapes(cfg.get(section, "outputs").unwrap_or("")),
        });
    }
    Ok(specs)
}

/// A compiled executable + its spec.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with f32 inputs (row-major, shapes per the spec); returns
    /// one f32 vec per output.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, shape) in inputs.iter().zip(&self.spec.inputs) {
            let expect: usize = shape.iter().product::<usize>().max(1);
            if data.len() != expect {
                bail!(
                    "{}: input length {} != shape {:?}",
                    self.spec.name,
                    data.len(),
                    shape
                );
            }
            let lit = xla::Literal::vec1(data);
            let lit = if shape.is_empty() {
                lit.reshape(&[])?
            } else {
                lit.reshape(&shape.iter().map(|&d| d as i64).collect::<Vec<_>>())?
            };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f32>()?);
        }
        Ok(out)
    }
}

/// The PJRT CPU runtime: one compiled executable per manifest entry.
pub struct PjrtRuntime {
    pub platform: String,
    execs: HashMap<String, Executable>,
}

impl PjrtRuntime {
    /// Compile every artifact in `dir`. Fails cleanly if the directory or
    /// manifest is missing (callers fall back to the rust engines).
    pub fn load(dir: &str) -> Result<Self> {
        let specs = load_manifest(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let platform = client.platform_name();
        let mut execs = HashMap::new();
        for spec in specs {
            let path = format!("{dir}/{}", spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .with_context(|| format!("parsing {path}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.name))?;
            execs.insert(spec.name.clone(), Executable { spec, exe });
        }
        Ok(Self { platform, execs })
    }

    pub fn get(&self, name: &str) -> Option<&Executable> {
        self.execs.get(name)
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.execs.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    pub fn len(&self) -> usize {
        self.execs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.execs.is_empty()
    }

    /// Default artifact directory (repo layout).
    pub fn default_dir() -> String {
        std::env::var("ARCAS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
    }
}

/// [`GradEngine`] backed by the AOT `logreg_loss_grad_b{B}_f{F}`
/// executable: the L2/L1 numerics on the rust request path.
///
/// Minibatches must match the compiled batch size; callers (the SGD
/// workload) are configured accordingly. PJRT execution is serialized
/// behind a mutex — the simulator charges virtual time independently of
/// wall time, so this does not distort the experiments.
pub struct PjrtGrad {
    exec_name: String,
    batch: usize,
    feats: usize,
    rt: Mutex<PjrtRuntime>,
}

// SAFETY: the xla crate's client/executable handles hold raw pointers and
// `Rc`s, making them !Send/!Sync. All access from `PjrtGrad` goes through
// the internal `Mutex`, so at most one thread touches the PJRT objects at
// a time, and the `Rc`s are never cloned outside the lock. The simulator
// is single-threaded; the host executor serializes on the same mutex.
unsafe impl Send for PjrtGrad {}
unsafe impl Sync for PjrtGrad {}

impl PjrtGrad {
    /// Pick an artifact matching `batch`/`feats`.
    pub fn new(rt: PjrtRuntime, batch: usize, feats: usize) -> Result<Self> {
        let name = format!("logreg_loss_grad_b{batch}_f{feats}");
        if rt.get(&name).is_none() {
            bail!("no artifact {name}; available: {:?}", rt.names());
        }
        Ok(Self {
            exec_name: name,
            batch,
            feats,
            rt: Mutex::new(rt),
        })
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.batch, self.feats)
    }
}

impl GradEngine for PjrtGrad {
    fn loss_grad(&self, x: &[f32], y: &[f32], w: &[f32], nf: usize) -> (f64, Vec<f32>) {
        if nf != self.feats || y.len() != self.batch {
            // Shape mismatch (remainder minibatches, oversubscribed shard
            // splits): fall back to the rust oracle — same semantics.
            return RustGrad.loss_grad(x, y, w, nf);
        }
        let rt = self.rt.lock().unwrap();
        let exe = rt.get(&self.exec_name).unwrap();
        let outs = exe.run_f32(&[x, y, w]).expect("PJRT execution failed");
        let loss = outs[0][0] as f64;
        let grad = outs[1].clone();
        (loss, grad)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_parsing() {
        assert_eq!(
            parse_shapes("128x1024;128;scalar"),
            vec![vec![128, 1024], vec![128], vec![]]
        );
        assert_eq!(parse_shapes(""), Vec::<Vec<usize>>::new());
        assert_eq!(parse_shapes("7"), vec![vec![7]]);
    }

    #[test]
    fn manifest_parsing_from_text() {
        let dir = std::env::temp_dir().join("arcas-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "[foo]\nfile = foo.hlo.txt\ninputs = 2x2;2\noutputs = scalar\n",
        )
        .unwrap();
        let specs = load_manifest(dir.to_str().unwrap()).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].name, "foo");
        assert_eq!(specs[0].inputs, vec![vec![2, 2], vec![2]]);
        assert_eq!(specs[0].outputs, vec![Vec::<usize>::new()]);
    }

    #[test]
    fn missing_dir_is_clean_error() {
        assert!(PjrtRuntime::load("/nonexistent/artifacts").is_err());
    }

    // Full PJRT round-trip tests live in rust/tests/integration_pjrt.rs
    // (they need `make artifacts` to have run).
}
