//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts and executes
//! them from the rust request path (python is never invoked at runtime).
//!
//! Interchange is HLO *text* (`artifacts/*.hlo.txt` + `manifest.txt`):
//! the bundled xla_extension 0.5.1 rejects jax≥0.5's serialized protos
//! with 64-bit instruction ids, while the text parser reassigns ids (see
//! DESIGN.md and /opt/xla-example/README.md).
//!
//! The manifest/shape front-end is dependency-free and always built; the
//! backend that actually compiles and executes HLO needs the `xla`
//! bindings, which are not in the offline crate set. It lives behind the
//! `pjrt` cargo feature (see `rust/Cargo.toml`): without it,
//! [`PjrtRuntime::load`] reports a clean error and every caller falls
//! back to the pure-rust numeric oracles ([`RustGrad`] et al.), so the
//! full experiment suite still runs.
//!
//! [`PjrtRuntime`] compiles every manifest entry once at startup;
//! [`PjrtGrad`] adapts the `logreg_loss_grad_*` executables to the SGD
//! workload's [`GradEngine`] so Fig. 10/11 run real XLA numerics.

use std::sync::Mutex;

use crate::util::config::Config;
use crate::workloads::sgd::{GradEngine, RustGrad};

#[cfg(feature = "pjrt")]
mod xla_backend;
#[cfg(feature = "pjrt")]
pub use xla_backend::{Executable, PjrtRuntime};
#[cfg(not(feature = "pjrt"))]
mod stub_backend;
#[cfg(not(feature = "pjrt"))]
pub use stub_backend::{Executable, PjrtRuntime};

/// Runtime-layer error: a message plus optional context chain, rendered
/// as `context: cause` (the offline crate set has no `anyhow`).
#[derive(Debug)]
pub struct RuntimeError {
    msg: String,
}

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }

    /// Prefix the error with `context` (anyhow's `.context()` shape).
    pub fn context(self, context: impl std::fmt::Display) -> Self {
        Self {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for RuntimeError {}

impl From<String> for RuntimeError {
    fn from(msg: String) -> Self {
        Self { msg }
    }
}

impl From<&str> for RuntimeError {
    fn from(msg: &str) -> Self {
        Self { msg: msg.to_string() }
    }
}

pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Parsed manifest entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    /// Input shapes (empty vec = scalar).
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// Parse `inputs = 128x1024;128;scalar` shape lists.
///
/// A malformed dimension is an error (a bad manifest must not take the
/// runtime down — callers fall back to the rust engines).
pub fn parse_shapes(s: &str) -> Result<Vec<Vec<usize>>> {
    let mut shapes = Vec::new();
    for part in s.split(';').filter(|p| !p.trim().is_empty()) {
        let part = part.trim();
        if part == "scalar" {
            shapes.push(Vec::new());
            continue;
        }
        let mut dims = Vec::new();
        for d in part.split('x') {
            let dim: usize = d.trim().parse().map_err(|_| {
                RuntimeError::new(format!("bad shape dim {d:?} in {s:?}"))
            })?;
            dims.push(dim);
        }
        shapes.push(dims);
    }
    Ok(shapes)
}

/// Load and parse `manifest.txt` from an artifact directory.
pub fn load_manifest(dir: &str) -> Result<Vec<ArtifactSpec>> {
    let path = format!("{dir}/manifest.txt");
    let cfg = Config::load(&path).map_err(RuntimeError::from)?;
    let mut specs = Vec::new();
    for section in cfg.sections() {
        if section == "global" {
            continue;
        }
        let file = cfg
            .get(section, "file")
            .ok_or_else(|| RuntimeError::new(format!("manifest entry [{section}] missing file")))?
            .to_string();
        let inputs = parse_shapes(cfg.get(section, "inputs").unwrap_or(""))
            .map_err(|e| e.context(format!("manifest entry [{section}] inputs")))?;
        let outputs = parse_shapes(cfg.get(section, "outputs").unwrap_or(""))
            .map_err(|e| e.context(format!("manifest entry [{section}] outputs")))?;
        specs.push(ArtifactSpec {
            name: section.to_string(),
            file,
            inputs,
            outputs,
        });
    }
    Ok(specs)
}

/// [`GradEngine`] backed by the AOT `logreg_loss_grad_b{B}_f{F}`
/// executable: the L2/L1 numerics on the rust request path.
///
/// Minibatches must match the compiled batch size; callers (the SGD
/// workload) are configured accordingly. PJRT execution is serialized
/// behind a mutex — the simulator charges virtual time independently of
/// wall time, so this does not distort the experiments.
pub struct PjrtGrad {
    exec_name: String,
    batch: usize,
    feats: usize,
    rt: Mutex<PjrtRuntime>,
}

// SAFETY: the xla crate's client/executable handles hold raw pointers and
// `Rc`s, making them !Send/!Sync. All access from `PjrtGrad` goes through
// the internal `Mutex`, so at most one thread touches the PJRT objects at
// a time, and the `Rc`s are never cloned outside the lock. The simulator
// is single-threaded; the host executor serializes on the same mutex.
// (The stub backend holds no handles at all.)
unsafe impl Send for PjrtGrad {}
unsafe impl Sync for PjrtGrad {}

impl PjrtGrad {
    /// Pick an artifact matching `batch`/`feats`.
    pub fn new(rt: PjrtRuntime, batch: usize, feats: usize) -> Result<Self> {
        let name = format!("logreg_loss_grad_b{batch}_f{feats}");
        if rt.get(&name).is_none() {
            return Err(RuntimeError::new(format!(
                "no artifact {name}; available: {:?}",
                rt.names()
            )));
        }
        Ok(Self {
            exec_name: name,
            batch,
            feats,
            rt: Mutex::new(rt),
        })
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.batch, self.feats)
    }
}

impl GradEngine for PjrtGrad {
    fn loss_grad(&self, x: &[f32], y: &[f32], w: &[f32], nf: usize) -> (f64, Vec<f32>) {
        if nf != self.feats || y.len() != self.batch {
            // Shape mismatch (remainder minibatches, oversubscribed shard
            // splits): fall back to the rust oracle — same semantics.
            return RustGrad.loss_grad(x, y, w, nf);
        }
        let rt = self.rt.lock().unwrap();
        let exe = rt.get(&self.exec_name).unwrap();
        let outs = exe.run_f32(&[x, y, w]).expect("PJRT execution failed");
        let loss = outs[0][0] as f64;
        let grad = outs[1].clone();
        (loss, grad)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_parsing() {
        assert_eq!(
            parse_shapes("128x1024;128;scalar").unwrap(),
            vec![vec![128, 1024], vec![128], vec![]]
        );
        assert_eq!(parse_shapes("").unwrap(), Vec::<Vec<usize>>::new());
        assert_eq!(parse_shapes("7").unwrap(), vec![vec![7]]);
    }

    #[test]
    fn malformed_shape_is_an_error_not_a_panic() {
        let err = parse_shapes("128xbogus").unwrap_err();
        assert!(err.to_string().contains("bogus"), "{err}");
        assert!(parse_shapes("12x-4").is_err());
        assert!(parse_shapes("x").is_err());
    }

    #[test]
    fn manifest_parsing_from_text() {
        let dir = std::env::temp_dir().join("arcas-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "[foo]\nfile = foo.hlo.txt\ninputs = 2x2;2\noutputs = scalar\n",
        )
        .unwrap();
        let specs = load_manifest(dir.to_str().unwrap()).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].name, "foo");
        assert_eq!(specs[0].inputs, vec![vec![2, 2], vec![2]]);
        assert_eq!(specs[0].outputs, vec![Vec::<usize>::new()]);
    }

    #[test]
    fn malformed_manifest_propagates_the_shape_error() {
        let dir = std::env::temp_dir().join("arcas-manifest-bad-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "[foo]\nfile = foo.hlo.txt\ninputs = 2xoops\noutputs = scalar\n",
        )
        .unwrap();
        let err = load_manifest(dir.to_str().unwrap()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("[foo]"), "{msg}");
        assert!(msg.contains("oops"), "{msg}");
    }

    #[test]
    fn missing_dir_is_clean_error() {
        assert!(PjrtRuntime::load("/nonexistent/artifacts").is_err());
    }

    // Full PJRT round-trip tests live in rust/tests/integration_pjrt.rs
    // (they need `make artifacts` and the `pjrt` feature).
}
