//! Stub PJRT backend — the default in the offline build.
//!
//! The `xla` bindings are not in the offline crate set, so this backend
//! keeps the public surface of the real one ([`PjrtRuntime`],
//! [`Executable`]) while refusing to load: callers detect the error and
//! fall back to the pure-rust engines. Enable the `pjrt` cargo feature
//! (and add the `xla` dependency) for the real thing.

use super::{ArtifactSpec, Result, RuntimeError};

/// A compiled executable + its spec (stub: never constructed — loading
/// fails first — but the type keeps call sites compiling unchanged).
pub struct Executable {
    pub spec: ArtifactSpec,
}

impl Executable {
    /// Execute with f32 inputs (row-major, shapes per the spec); returns
    /// one f32 vec per output.
    pub fn run_f32(&self, _inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        Err(RuntimeError::new(
            "PJRT backend unavailable (built without the `pjrt` feature)",
        ))
    }
}

/// The PJRT CPU runtime front: in the stub build, [`PjrtRuntime::load`]
/// validates the manifest and then reports the missing backend.
pub struct PjrtRuntime {
    pub platform: String,
    execs: Vec<Executable>,
}

impl PjrtRuntime {
    /// Whether a real PJRT backend was compiled in.
    pub const fn backend_available() -> bool {
        false
    }

    /// Compile every artifact in `dir`. The stub validates the manifest
    /// (so a malformed one is still reported precisely) and then fails
    /// cleanly; callers fall back to the rust engines.
    pub fn load(dir: &str) -> Result<Self> {
        let specs = super::load_manifest(dir)?;
        Err(RuntimeError::new(format!(
            "cannot compile {} artifact(s) from {dir}: built without the `pjrt` \
             feature (the offline crate set has no `xla` bindings)",
            specs.len()
        )))
    }

    pub fn get(&self, _name: &str) -> Option<&Executable> {
        None
    }

    pub fn names(&self) -> Vec<&str> {
        Vec::new()
    }

    pub fn len(&self) -> usize {
        self.execs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.execs.is_empty()
    }

    /// Default artifact directory (repo layout).
    pub fn default_dir() -> String {
        std::env::var("ARCAS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
    }
}
