//! Cluster scale-out: a fleet of machine shards behind the engine seam.
//!
//! ARCAS models one chiplet-based machine; the serving story
//! ("millions of users") needs the tier *above* the socket — several
//! independent machines behind one front end. This module adds that
//! tier without touching the per-machine runtime:
//!
//! - **Key-sharded routing.** The front end hashes every request key
//!   through the same splitmix64 finalizer the trace generator uses for
//!   priority classing, into one of [`CLUSTER_SLOTS`] key-range slots;
//!   a slot table maps slots to shards (initially `slot % n`). The
//!   input trace is never mutated — routing is a deterministic pre-pass
//!   that splits it into per-shard sub-traces, so per-shard request
//!   streams are reproducible on both backends and `n = 1` reproduces
//!   the single-machine run byte-for-byte.
//! - **An inter-machine link tier.** Shard 0 is colocated with the
//!   front end; a request routed to any other shard crosses a
//!   [`ClusterLink`] (NIC + ToR switch) and pays per-link latency plus
//!   serialized bandwidth, exactly like the IF-link/DDR `BwTracker`
//!   tiers one level down: each shard's ingress link keeps a busy-until
//!   horizon, and a request departs at
//!   `max(arrival, link_busy)`, arriving `xfer + lat` later.
//! - **A front-end dispatcher over per-shard queues.** Each shard runs
//!   the serve family's [`TieredQueue`] dispatch loop on its own
//!   machine with its own per-chiplet queue-wait
//!   [`SloSignal`](crate::engine::SloSignal) — the cluster extends the
//!   tiered-dispatch model across machines rather than replacing it.
//! - **Rebalancing** ([`Policy::plan_shard_moves`]). At every routing
//!   window boundary the front-end policy sees per-slot load
//!   ([`ShardHeat`]) and may re-home hot key ranges to colder shards —
//!   the cluster-level mirror of `plan_region_moves`. Each applied move
//!   ships [`SLOT_STATE_BYTES`] of key-range state across the link
//!   tier and is recorded in [`RunReport::shard_decisions`].
//!
//! Entry points: [`crate::engine::Run::cluster`] (`--machines N` on the
//! CLI); scenarios opt in via
//! [`crate::engine::Scenario::cluster_parts`]. See
//! `rust/src/engine/README.md` for the box art.

use std::sync::Arc;

use crate::engine::{run_once, Run, ScenarioMetrics, ScenarioRun};
use crate::policy::{LocalCachePolicy, Policy, ShardHeat};
use crate::sched::{LatencyReport, RunReport, ShardStat};
use crate::sim::Machine;
use crate::topology::ClusterLink;
use crate::util::stats::LogHistogram;
use crate::workloads::serve::{Request, ServeKvScenario, ServeOpts, Trace};

/// Number of key-range slots the keyspace is hashed into. Slots are the
/// unit of rebalancing: fine enough that a hot range can move without
/// dragging half the keyspace along, coarse enough that the slot table
/// stays a cache-line-scale array.
pub const CLUSTER_SLOTS: usize = 64;

/// Routing window: the front end aggregates per-slot load over this
/// much virtual time, then offers the window's heat to
/// [`Policy::plan_shard_moves`] at the boundary.
pub const WINDOW_NS: u64 = 1_000_000;

/// Wire size of one routed request (header + key + small payload).
pub const REQ_BYTES: u64 = 128;

/// Key-range state shipped when a slot is re-homed to another shard
/// (the slot's share of a cache-warm working set, not the full table).
pub const SLOT_STATE_BYTES: u64 = 64 << 10;

/// Hash a request key to its key-range slot — the same splitmix64
/// finalizer the trace generator uses for priority classing, so slot
/// membership is uncorrelated with key magnitude (a drifting hotspot
/// walks *across* slots instead of staying in one).
pub fn slot_of_key(key: u64) -> usize {
    let mut x = key.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    (x % CLUSTER_SLOTS as u64) as usize
}

/// The ingredients a cluster run needs to rebuild a serve scenario per
/// shard: the trace to route and the knobs to replay on every shard.
#[derive(Clone, Debug)]
pub struct ClusterParts {
    /// KV table size per shard (each shard owns a full replica of the
    /// table; only the *traffic* is sharded — cross-shard transactions
    /// and partial replicas are recorded follow-ups in the ROADMAP).
    pub records: usize,
    /// The undivided request trace the front end routes.
    pub trace: Arc<Trace>,
    /// Serving knobs replayed on each shard.
    pub opts: ServeOpts,
}

/// What the routing pre-pass produced: per-shard sub-traces plus the
/// link-tier and rebalance accounting for the merged report.
struct RoutedTrace {
    sub_traces: Vec<Trace>,
    hops: u64,
    link_bytes: u64,
    decisions: Vec<(u64, usize, usize)>,
}

/// Deterministic routing pre-pass: walk the trace in arrival order,
/// charge the link tier on every cross-shard hop, and offer each
/// window's slot heat to the front-end policy. Backend-independent —
/// the same trace, policy and `n` always yield the same sub-traces and
/// the same shard moves on Sim and Host.
fn route_trace(
    trace: &Trace,
    n: usize,
    link: ClusterLink,
    policy: &mut dyn Policy,
) -> RoutedTrace {
    let mut table: Vec<usize> = (0..CLUSTER_SLOTS).map(|s| s % n).collect();
    let mut slot_load = vec![0.0f64; CLUSTER_SLOTS];
    // Per-shard ingress-link busy-until horizon (index 0 unused: the
    // front end is colocated with shard 0, so that hop is free).
    let mut link_busy = vec![0u64; n];
    let mut subs: Vec<Vec<Request>> = (0..n).map(|_| Vec::new()).collect();
    let mut window_end = WINDOW_NS;
    let mut hops = 0u64;
    let mut link_bytes = 0u64;
    let mut decisions: Vec<(u64, usize, usize)> = Vec::new();
    for r in &trace.requests {
        while r.arrival_ns >= window_end {
            if n > 1 {
                let heat = ShardHeat {
                    slot_load: slot_load.clone(),
                    table: table.clone(),
                    shards: n,
                };
                for mv in policy.plan_shard_moves(window_end, &heat) {
                    if mv.slot >= CLUSTER_SLOTS || mv.to_shard >= n || table[mv.slot] == mv.to_shard
                    {
                        continue;
                    }
                    // Re-homing ships the slot's working-set state:
                    // serialize it on both endpoints' links (the free
                    // front-end/shard-0 hop excepted).
                    for shard in [table[mv.slot], mv.to_shard] {
                        if shard != 0 {
                            let depart = link_busy[shard].max(window_end);
                            link_busy[shard] = depart + link.xfer_ns(SLOT_STATE_BYTES);
                        }
                    }
                    link_bytes += SLOT_STATE_BYTES;
                    table[mv.slot] = mv.to_shard;
                    decisions.push((window_end, mv.slot, mv.to_shard));
                }
            }
            slot_load.iter_mut().for_each(|l| *l = 0.0);
            window_end += WINDOW_NS;
        }
        let slot = slot_of_key(r.key);
        slot_load[slot] += 1.0;
        let shard = table[slot];
        if shard == 0 {
            subs[0].push(*r);
        } else {
            // FCFS link serialization: a request can't start its wire
            // transfer before the previous one to the same shard
            // finished. `depart` is non-decreasing in arrival order, so
            // every sub-trace stays sorted by (shifted) arrival.
            let depart = r.arrival_ns.max(link_busy[shard]);
            let xfer = link.xfer_ns(REQ_BYTES);
            link_busy[shard] = depart + xfer;
            hops += 1;
            link_bytes += REQ_BYTES;
            subs[shard].push(Request {
                arrival_ns: depart + xfer + link.lat_ns,
                ..*r
            });
        }
    }
    RoutedTrace {
        sub_traces: subs.into_iter().map(|requests| Trace { requests }).collect(),
        hops,
        link_bytes,
        decisions,
    }
}

/// Merge per-shard sojourn aggregates into one fleet-level
/// [`LatencyReport`]: quantiles from the merged log-scaled histogram
/// (≤3.2% relative error, same as any single shard), count/max exact,
/// means count-weighted from the per-shard exact means.
fn merge_latency(parts: &[(LatencyReport, LogHistogram)]) -> Option<LatencyReport> {
    if parts.is_empty() {
        return None;
    }
    let mut hist = LogHistogram::new();
    let (mut count, mut sum, mut q_sum, mut s_sum) = (0u64, 0.0f64, 0.0f64, 0.0f64);
    for (rep, h) in parts {
        hist.merge(h);
        count += rep.count;
        sum += rep.mean_ns * rep.count as f64;
        q_sum += rep.mean_queue_ns * rep.count as f64;
        s_sum += rep.mean_service_ns * rep.count as f64;
    }
    if count == 0 {
        return None;
    }
    Some(LatencyReport {
        count,
        mean_ns: sum / count as f64,
        p50_ns: hist.quantile(0.50),
        p95_ns: hist.quantile(0.95),
        p99_ns: hist.quantile(0.99),
        max_ns: hist.max(),
        mean_queue_ns: q_sum / count as f64,
        mean_service_ns: s_sum / count as f64,
    })
}

/// Drive one scenario over `n` machine shards: route the trace, run
/// each shard through the ordinary single-machine engine path (one
/// executor pool per shard on the host backend), and merge the reports.
/// Called from [`Run::run`] when [`Run::cluster`] armed the fan-out.
pub(crate) fn run_cluster(
    mut run: Run,
    n: usize,
    scenario: &mut dyn crate::engine::Scenario,
) -> ScenarioRun {
    let parts = scenario.cluster_parts().unwrap_or_else(|| {
        panic!(
            "scenario {:?} does not support --machines (no cluster_parts)",
            scenario.name()
        )
    });
    let topo = run.machine.topo.clone();
    let link = topo.cluster_link();
    // The front-end policy plans shard moves during routing, then runs
    // shard 0 (it is colocated with the front end) — with n = 1 that
    // degenerates to exactly the single-machine path.
    let mut front_policy = run.take_policy();
    let routed = route_trace(&parts.trace, n, link, front_policy.as_mut());

    let mut front_policy = Some(front_policy);
    let mut machine0 = Some(run.machine);
    let mut shard_runs: Vec<ScenarioRun> = Vec::with_capacity(n);
    let mut shard_scens: Vec<ServeKvScenario> = Vec::with_capacity(n);
    for sub in routed.sub_traces {
        let policy: Box<dyn Policy> = match front_policy.take() {
            Some(p) => p, // shard 0
            None => match &run.policy_each {
                Some(make) => make(),
                None => Box::new(LocalCachePolicy),
            },
        };
        let machine = machine0.take().unwrap_or_else(|| Machine::new(topo.clone()));
        let mut scen = ServeKvScenario::new(parts.records, Arc::new(sub)).with_opts(parts.opts);
        let shard_run = run_once(
            machine,
            policy,
            run.tasks,
            run.timer_ns,
            run.verify,
            run.backend,
            run.batch_steps,
            &mut scen,
        );
        shard_runs.push(shard_run);
        shard_scens.push(scen);
    }

    let per_shard: Vec<ShardStat> = shard_runs
        .iter()
        .map(|sr| ShardStat {
            requests: sr.report.request_latency.as_ref().map_or(0, |l| l.count)
                + sr.report.request_shed,
            shed: sr.report.request_shed,
            makespan_ns: sr.report.makespan_ns,
            p99_ns: sr.report.request_latency.as_ref().map_or(0, |l| l.p99_ns),
        })
        .collect();

    let mut out = if n == 1 {
        // Single shard: nothing was routed or merged — pass the run
        // through untouched so reports stay byte-identical to the
        // non-cluster path (only the cluster counters below are added).
        shard_runs.pop().unwrap()
    } else {
        let served: u64 = shard_scens.iter().map(ServeKvScenario::served).sum();
        let conflicts: u64 = shard_scens.iter().map(ServeKvScenario::conflicts).sum();
        let lat_parts: Vec<(LatencyReport, LogHistogram)> = shard_runs
            .iter()
            .zip(&shard_scens)
            .filter_map(|(sr, s)| {
                Some((sr.report.request_latency.clone()?, s.latency_histogram()?))
            })
            .collect();
        let request_latency = merge_latency(&lat_parts);
        let first = &shard_runs[0].report;
        let mut report = RunReport {
            policy: first.policy.clone(),
            spread_rate: first.spread_rate,
            ..RunReport::default()
        };
        for sr in &shard_runs {
            let r = &sr.report;
            // Shards run concurrently in the modeled fleet: the cluster
            // makespan is the slowest shard; work counters sum.
            report.makespan_ns = report.makespan_ns.max(r.makespan_ns);
            report.counts.local += r.counts.local;
            report.counts.near += r.counts.near;
            report.counts.far += r.counts.far;
            report.counts.dram += r.counts.dram;
            report.dispatches += r.dispatches;
            report.steals += r.steals;
            report.migrations += r.migrations;
            report.barrier_epochs += r.barrier_epochs;
            report.avg_concurrency += r.avg_concurrency;
            report.peak_concurrency += r.peak_concurrency;
            report.region_moves += r.region_moves;
            report.dram_bytes += r.dram_bytes;
            report.host_steals += r.host_steals;
            report.request_shed += r.request_shed;
            // This driver executes shards back to back, so real elapsed
            // time sums. concurrency/decisions/class_latency samples
            // are per-shard timelines with no meaningful merge — the
            // merged report leaves them empty (per-shard detail lives
            // in `per_shard`).
            report.wall_ns += r.wall_ns;
        }
        report.request_latency = request_latency;
        let p99 = report.request_latency.as_ref().map_or(0.0, |l| l.p99_ns as f64);
        let metrics = ScenarioMetrics::new(served as f64, "reqs")
            .with("reqs_per_s", report.throughput(served as f64))
            .with("update_conflicts", conflicts as f64)
            .with("p99_sojourn_ns", p99)
            .with("shed", report.request_shed as f64);
        let machine = shard_runs.swap_remove(0).machine;
        ScenarioRun {
            report,
            metrics,
            machine,
        }
    };
    out.report.machines = n;
    out.report.cross_link_hops = routed.hops;
    out.report.cross_link_bytes = routed.link_bytes;
    out.report.shard_moves = routed.decisions.len() as u64;
    out.report.shard_decisions = routed.decisions;
    out.report.per_shard = per_shard;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::ShardMove;
    use crate::workloads::serve::TraceConfig;

    fn trace(requests: usize, rate_rps: f64) -> Trace {
        Trace::synth(&TraceConfig {
            requests,
            rate_rps,
            keyspace: 4_096,
            ..Default::default()
        })
    }

    #[test]
    fn slot_of_key_is_stable_and_in_range() {
        for key in 0..10_000u64 {
            let s = slot_of_key(key);
            assert!(s < CLUSTER_SLOTS);
            assert_eq!(s, slot_of_key(key), "must be a pure function");
        }
        // The finalizer actually spreads keys: all slots get traffic.
        let mut seen = vec![false; CLUSTER_SLOTS];
        for key in 0..10_000u64 {
            seen[slot_of_key(key)] = true;
        }
        assert!(seen.iter().all(|&s| s), "every slot must be reachable");
    }

    #[test]
    fn routing_partitions_the_trace_and_keeps_shards_sorted() {
        let t = trace(8_000, 2.0e6);
        let mut policy = LocalCachePolicy;
        let link = crate::topology::Topology::milan_1s().cluster_link();
        let routed = route_trace(&t, 4, link, &mut policy);
        assert_eq!(routed.sub_traces.len(), 4);
        let total: usize = routed.sub_traces.iter().map(Trace::len).sum();
        assert_eq!(total, t.len(), "routing must not drop or duplicate");
        for (i, sub) in routed.sub_traces.iter().enumerate() {
            assert!(!sub.is_empty(), "shard {i} starved by the hash");
            for w in sub.requests.windows(2) {
                assert!(
                    w[0].arrival_ns <= w[1].arrival_ns,
                    "shard {i} arrivals out of order"
                );
            }
        }
        // Keys route by slot table, deterministically.
        let mut policy2 = LocalCachePolicy;
        let routed2 = route_trace(&t, 4, link, &mut policy2);
        assert_eq!(routed.sub_traces, routed2.sub_traces);
        assert_eq!(routed.hops, routed2.hops);
        // Cross-shard requests paid the link: ~3/4 of traffic hopped,
        // and each hop was delayed by at least lat + xfer.
        assert!(routed.hops > t.len() as u64 / 2);
        assert_eq!(
            routed.link_bytes,
            routed.hops * REQ_BYTES,
            "a static LocalCachePolicy front end plans no state moves"
        );
        let min_delay = link.lat_ns + link.xfer_ns(REQ_BYTES);
        let orig_of = |key: u64, arr_max: u64| {
            t.requests
                .iter()
                .filter(|r| r.key == key && r.arrival_ns + min_delay <= arr_max)
                .count()
        };
        for sub in &routed.sub_traces[1..] {
            for r in &sub.requests {
                assert!(
                    orig_of(r.key, r.arrival_ns) > 0,
                    "routed request must be an original delayed by >= {min_delay}ns"
                );
            }
        }
    }

    #[test]
    fn routing_for_one_shard_is_the_identity() {
        let t = trace(2_000, 2.0e6);
        let mut policy = LocalCachePolicy;
        let link = crate::topology::Topology::milan_1s().cluster_link();
        let routed = route_trace(&t, 1, link, &mut policy);
        assert_eq!(routed.sub_traces.len(), 1);
        assert_eq!(routed.sub_traces[0], t, "n=1 must not touch the trace");
        assert_eq!(routed.hops, 0);
        assert_eq!(routed.link_bytes, 0);
        assert!(routed.decisions.is_empty());
    }

    /// A front-end policy that re-homes one fixed slot at the first
    /// window boundary — exercises the state-transfer accounting
    /// without depending on ArcasPolicy thresholds.
    struct OneMovePolicy {
        moved: bool,
    }

    impl Policy for OneMovePolicy {
        fn name(&self) -> &'static str {
            "one-move"
        }

        fn initial_placement(&mut self, topo: &crate::topology::Topology, n: usize) -> Vec<usize> {
            LocalCachePolicy.initial_placement(topo, n)
        }

        fn plan_shard_moves(&mut self, _now_ns: u64, heat: &ShardHeat) -> Vec<ShardMove> {
            if self.moved || heat.shards < 2 {
                return Vec::new();
            }
            self.moved = true;
            vec![ShardMove {
                slot: 0,
                to_shard: 1,
            }]
        }
    }

    #[test]
    fn rebalance_moves_recolor_the_slot_table_and_ship_state() {
        let t = trace(6_000, 2.0e6); // ~3ms: crosses >= 2 window ticks
        let link = crate::topology::Topology::milan_1s().cluster_link();
        let mut policy = OneMovePolicy { moved: false };
        let routed = route_trace(&t, 2, link, &mut policy);
        assert_eq!(routed.decisions, vec![(WINDOW_NS, 0, 1)]);
        // Slot 0 lived on shard 0 before the tick and shard 1 after:
        // post-move slot-0 requests must appear delayed on shard 1.
        let moved_after: usize = routed.sub_traces[1]
            .requests
            .iter()
            .filter(|r| slot_of_key(r.key) == 0)
            .count();
        let orig_slot0_after: usize = t
            .requests
            .iter()
            .filter(|r| slot_of_key(r.key) == 0 && r.arrival_ns >= WINDOW_NS)
            .count();
        assert_eq!(moved_after, orig_slot0_after);
        assert!(orig_slot0_after > 0, "slot 0 must see post-move traffic");
        // Accounting: the hops' payload plus one slot-state transfer.
        assert_eq!(routed.link_bytes, routed.hops * REQ_BYTES + SLOT_STATE_BYTES);
    }

    #[test]
    fn merged_latency_is_count_weighted() {
        let mut ha = LogHistogram::new();
        let mut hb = LogHistogram::new();
        for _ in 0..300 {
            ha.record(1_000);
        }
        for _ in 0..100 {
            hb.record(9_000);
        }
        let ra = LatencyReport {
            count: 300,
            mean_ns: 1_000.0,
            p50_ns: 1_000,
            p95_ns: 1_000,
            p99_ns: 1_000,
            max_ns: 1_000,
            mean_queue_ns: 400.0,
            mean_service_ns: 600.0,
        };
        let rb = LatencyReport {
            count: 100,
            mean_ns: 9_000.0,
            p50_ns: 9_000,
            p95_ns: 9_000,
            p99_ns: 9_000,
            max_ns: 9_000,
            mean_queue_ns: 8_000.0,
            mean_service_ns: 1_000.0,
        };
        let m = merge_latency(&[(ra, ha), (rb, hb)]).unwrap();
        assert_eq!(m.count, 400);
        assert!((m.mean_ns - 3_000.0).abs() < 1e-9);
        assert!((m.mean_queue_ns - 2_300.0).abs() < 1e-9);
        assert_eq!(m.max_ns, 9_000);
        // p99 over 400 samples: the slow shard owns the tail.
        assert!(m.p99_ns >= 8_000, "merged p99 {} lost the tail", m.p99_ns);
        // p50: 3/4 of samples are fast.
        assert!(m.p50_ns <= 1_100, "merged p50 {} lost the body", m.p50_ns);
        assert!(merge_latency(&[]).is_none());
    }
}
