//! Adaptive controller: the paper's Algorithm 1 (Chiplet Scheduling
//! Policy) and Algorithm 2 (Update Location).
//!
//! The controller periodically reads the remote-chiplet cache-fill event
//! rate from the profiler. If the rate exceeds `RMT_CHIP_ACCESS_RATE`
//! (default 300 events per `SCHEDULER_TIMER`, the value the paper's §4.6
//! sensitivity analysis selects), tasks are *spread* over more chiplets
//! (more aggregate L3); otherwise they are *compacted* onto fewer chiplets
//! (better locality). `update_location` maps task ranks to concrete cores
//! for a given spread rate and binds their memory to the right NUMA node.
//!
//! Two drivers tick the same controller: the simulator fires it on
//! **virtual** time (`SCHEDULER_TIMER` of simulated ns), and the host
//! backend (`engine::host_backend`) fires it on **real elapsed** time
//! between batch boundaries, applying the resulting rank → core map as
//! online migrations. The algorithm is identical either way — only the
//! clock feeding `now_ns` differs.

use crate::topology::Topology;

/// Defaults from the paper (§4.6).
pub const DEFAULT_SCHEDULER_TIMER_NS: u64 = 10_000_000; // 10 ms
pub const DEFAULT_RMT_CHIP_ACCESS_RATE: f64 = 300.0;

/// Scheduling approach (§4.1: "the controller generates adaptive policies
/// that switch between location-centric and cache-size-centric
/// approaches").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Approach {
    /// Minimize cross-chiplet communication: bias toward compaction
    /// (higher threshold before spreading).
    LocationCentric,
    /// Maximize aggregate cache: bias toward spreading (lower threshold).
    CacheSizeCentric,
    /// Paper default: threshold as configured.
    Balanced,
}

impl Approach {
    /// Threshold multiplier implementing the bias.
    fn threshold_factor(self) -> f64 {
        match self {
            Approach::LocationCentric => 2.0,
            Approach::CacheSizeCentric => 0.5,
            Approach::Balanced => 1.0,
        }
    }
}

/// Algorithm 1 state.
#[derive(Clone, Debug)]
pub struct AdaptiveController {
    pub timer_ns: u64,
    pub rmt_chip_access_rate: f64,
    pub approach: Approach,
    pub spread_rate: usize,
    /// Chiplets available (Algorithm 1's `CHIPLETS`).
    pub max_chiplets: usize,
    last_decision_ns: u64,
    /// Windows remaining in which compaction is suppressed (set when a
    /// compaction immediately had to be undone — breaks thrash cycles).
    compact_backoff: u32,
    /// Did the previous decision compact?
    last_was_compact: bool,
    /// Decision log for diagnostics: (t_ns, rate, new_spread).
    pub decisions: Vec<(u64, f64, usize)>,
}

impl AdaptiveController {
    pub fn new(topo: &Topology) -> Self {
        Self {
            timer_ns: DEFAULT_SCHEDULER_TIMER_NS,
            rmt_chip_access_rate: DEFAULT_RMT_CHIP_ACCESS_RATE,
            approach: Approach::Balanced,
            spread_rate: 1,
            max_chiplets: topo.num_chiplets(),
            last_decision_ns: 0,
            compact_backoff: 0,
            last_was_compact: false,
            decisions: Vec::new(),
        }
    }

    pub fn with_timer(mut self, timer_ns: u64) -> Self {
        self.timer_ns = timer_ns;
        self
    }

    pub fn with_threshold(mut self, rate: f64) -> Self {
        self.rmt_chip_access_rate = rate;
        self
    }

    pub fn with_approach(mut self, approach: Approach) -> Self {
        self.approach = approach;
        self
    }

    pub fn with_spread(mut self, spread: usize) -> Self {
        self.spread_rate = spread.clamp(1, self.max_chiplets);
        self
    }

    /// Grace period: suppress compaction for the first `windows` decision
    /// windows (cold caches always look like "low remote traffic" before
    /// the working set has been pulled in once).
    pub fn with_warmup(mut self, windows: u32) -> Self {
        self.compact_backoff = windows;
        self
    }

    /// Is a scheduling decision due at `now_ns`? (Algorithm 1 line 4.)
    pub fn due(&self, now_ns: u64) -> bool {
        now_ns.saturating_sub(self.last_decision_ns) >= self.timer_ns
    }

    /// Algorithm 1: consume the windowed fill-event rate; returns the new
    /// spread rate if it changed.
    ///
    /// `rate` must already be normalized to events per `timer_ns`
    /// (the profiler does `counter × SCHEDULER_TIMER / elapsed`).
    pub fn tick(&mut self, now_ns: u64, rate: f64) -> Option<usize> {
        if !self.due(now_ns) {
            return None;
        }
        self.last_decision_ns = now_ns;
        let threshold = self.rmt_chip_access_rate * self.approach.threshold_factor();
        let old = self.spread_rate;
        self.compact_backoff = self.compact_backoff.saturating_sub(1);
        if rate >= threshold {
            // High inter-chiplet traffic: spread for aggregate cache.
            if self.spread_rate < self.max_chiplets {
                self.spread_rate += 1;
            }
            if self.last_was_compact {
                // The compaction we just did caused this spike: the
                // working set needs those chiplets. Back off further
                // compaction attempts for a while (thrash breaker).
                self.compact_backoff = 16;
            }
            self.last_was_compact = false;
        } else if rate < threshold * 0.5 && self.compact_backoff == 0 {
            // Low traffic: compact for locality. The 0.5 hysteresis band
            // (rates in [thr/2, thr) hold steady) prevents spread-rate
            // oscillation when the fill rate hovers near the threshold —
            // the stability role the paper assigns to choosing a "higher
            // value [that] would delay changes to the scheduling" (§4.2).
            if self.spread_rate > 1 {
                self.spread_rate -= 1;
                self.last_was_compact = true;
            }
        } else {
            self.last_was_compact = false;
        }
        self.decisions.push((now_ns, rate, self.spread_rate));
        if self.spread_rate != old {
            Some(self.spread_rate)
        } else {
            None
        }
    }
}

/// Result of Algorithm 2 for one rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Location {
    pub core: usize,
    pub numa: usize,
}

/// Algorithm 2 (Update Location): map `rank` of `group_size` threads onto
/// a core, given the spread rate.
///
/// With spread rate `s`, consecutive ranks are packed into blocks of
/// `cores_per_chiplet / s` per chiplet — so a group of `n` threads
/// occupies `n·s / cores_per_chiplet` chiplets: `s = 1` compacts the
/// group onto the fewest chiplets, `s = CHIPLETS` gives maximal spread.
/// Overflowing chiplet indices wrap around, shifting to unused slots
/// (Algorithm 2 lines 7–9).
///
/// NOTE: the paper computes `core = chiplet × CHIPLETS + slot`, which is
/// only correct when `CHIPLETS == CORES_PER_CHIPLET` (both are 8 on the
/// Milan testbed). We use `chiplet × cores_per_chiplet + slot`, which is
/// the general form.
pub fn update_location(
    topo: &Topology,
    spread_rate: usize,
    rank: usize,
    group_size: usize,
) -> Option<Location> {
    update_location_bounded(topo, spread_rate, rank, group_size, topo.num_chiplets())
}

/// [`update_location`] restricted to the first `chiplets` chiplets — the
/// socket-confined variant ARCAS uses when the group fits fewer sockets
/// (§5.2: "ARCAS fully occupies all cores in a single socket").
pub fn update_location_bounded(
    topo: &Topology,
    spread_rate: usize,
    rank: usize,
    group_size: usize,
    chiplets: usize,
) -> Option<Location> {
    let chiplets = chiplets.clamp(1, topo.num_chiplets());
    let cpc = topo.cores_per_chiplet;
    // Bounds check (Algorithm 2 line 2).
    if spread_rate == 0 || spread_rate > chiplets || group_size > topo.num_cores() {
        return None;
    }
    let block = (cpc / spread_rate).max(1);
    let mut chiplet = rank / block;
    let mut slot = rank % block;
    if chiplet >= chiplets {
        // Wrap: move to the next slot group on the wrapped chiplet.
        let wrap = chiplet / chiplets;
        chiplet %= chiplets;
        slot = (slot + wrap * block) % cpc;
    }
    let core = chiplet * cpc + slot;
    let numa = topo.numa_of_core(core);
    Some(Location { core, numa })
}

/// Compute the full rank→core map for a group (deduplicated fallback: if
/// two ranks collide after wrap-around, later ranks move to the next free
/// core — affinity must stay one-task-per-core whenever group ≤ cores).
pub fn placement_map(topo: &Topology, spread_rate: usize, group_size: usize) -> Vec<usize> {
    placement_map_bounded(topo, spread_rate, group_size, topo.num_chiplets())
}

/// [`placement_map`] restricted to the first `chiplets` chiplets.
pub fn placement_map_bounded(
    topo: &Topology,
    spread_rate: usize,
    group_size: usize,
    chiplets: usize,
) -> Vec<usize> {
    let n_cores = topo.num_cores();
    let mut used = vec![false; n_cores];
    let mut map = Vec::with_capacity(group_size);
    for rank in 0..group_size {
        let want = update_location_bounded(topo, spread_rate, rank, group_size, chiplets)
            .map(|l| l.core)
            .unwrap_or(rank % n_cores);
        let mut core = want;
        // Linear-probe to the next free core on collision.
        if group_size <= n_cores {
            while used[core] {
                core = (core + 1) % n_cores;
            }
            used[core] = true;
        }
        map.push(core);
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::milan_2s() // 16 chiplets x 8 cores
    }

    #[test]
    fn spread_one_compacts_onto_first_chiplets() {
        let t = topo();
        // 8 threads, spread 1: all on chiplet 0.
        for rank in 0..8 {
            let l = update_location(&t, 1, rank, 8).unwrap();
            assert_eq!(t.chiplet_of(l.core), 0, "rank {rank} -> {:?}", l);
        }
        // 16 threads fill chiplets 0 and 1.
        let l = update_location(&t, 1, 15, 16).unwrap();
        assert_eq!(t.chiplet_of(l.core), 1);
    }

    #[test]
    fn max_spread_uses_one_core_per_chiplet() {
        let t = topo();
        let s = t.cores_per_chiplet; // spread = 8 -> block = 1
        let mut chiplets_seen = std::collections::BTreeSet::new();
        for rank in 0..8 {
            let l = update_location(&t, s, rank, 8).unwrap();
            chiplets_seen.insert(t.chiplet_of(l.core));
        }
        assert_eq!(chiplets_seen.len(), 8, "8 ranks on 8 distinct chiplets");
    }

    #[test]
    fn spread_two_uses_twice_the_chiplets() {
        let t = topo();
        let used = |s: usize| -> usize {
            (0..16)
                .map(|r| t.chiplet_of(update_location(&t, s, r, 16).unwrap().core))
                .collect::<std::collections::BTreeSet<_>>()
                .len()
        };
        assert_eq!(used(1), 2);
        assert_eq!(used(2), 4);
        assert_eq!(used(4), 8);
    }

    #[test]
    fn wrap_around_stays_in_bounds() {
        let t = topo();
        for rank in 0..t.num_cores() {
            let l = update_location(&t, 8, rank, t.num_cores()).unwrap();
            assert!(l.core < t.num_cores());
            assert_eq!(l.numa, t.numa_of_core(l.core));
        }
    }

    #[test]
    fn bounds_checks_reject_invalid() {
        let t = topo();
        assert!(update_location(&t, 0, 0, 8).is_none());
        assert!(update_location(&t, 17, 0, 8).is_none());
        assert!(update_location(&t, 1, 0, 1000).is_none());
    }

    #[test]
    fn placement_map_is_injective_when_it_fits() {
        let t = topo();
        for s in [1, 2, 4, 8] {
            for n in [8, 16, 64, 128] {
                let map = placement_map(&t, s, n);
                let uniq: std::collections::BTreeSet<_> = map.iter().collect();
                assert_eq!(uniq.len(), n, "spread={s} n={n} must be 1:1");
            }
        }
    }

    #[test]
    fn controller_spreads_on_high_rate() {
        let t = topo();
        let mut c = AdaptiveController::new(&t);
        assert_eq!(c.spread_rate, 1);
        let changed = c.tick(c.timer_ns, 500.0);
        assert_eq!(changed, Some(2));
        // Not due yet: no change.
        assert_eq!(c.tick(c.timer_ns + 1, 500.0), None);
        // Next interval: spreads again.
        assert_eq!(c.tick(2 * c.timer_ns, 500.0), Some(3));
    }

    #[test]
    fn controller_compacts_on_low_rate() {
        let t = topo();
        let mut c = AdaptiveController::new(&t).with_spread(4);
        assert_eq!(c.tick(c.timer_ns, 10.0), Some(3));
        assert_eq!(c.tick(2 * c.timer_ns, 10.0), Some(2));
    }

    #[test]
    fn controller_clamps_at_bounds() {
        let t = topo();
        let mut c = AdaptiveController::new(&t).with_spread(1);
        assert_eq!(c.tick(c.timer_ns, 0.0), None); // already at 1
        let mut c = AdaptiveController::new(&t).with_spread(16);
        assert_eq!(c.tick(c.timer_ns, 1e9), None); // already at max
    }

    #[test]
    fn approaches_shift_threshold() {
        let t = topo();
        // Rate of 300 is exactly at the default threshold.
        let mut balanced = AdaptiveController::new(&t);
        assert_eq!(balanced.tick(balanced.timer_ns, 300.0), Some(2));
        // Location-centric doubles the threshold: 250 < 600/2 -> compact,
        // while balanced would hold (250 in [150, 300)).
        let mut loc = AdaptiveController::new(&t)
            .with_approach(Approach::LocationCentric)
            .with_spread(4);
        assert_eq!(loc.tick(loc.timer_ns, 250.0), Some(3));
        let mut bal = AdaptiveController::new(&t).with_spread(4);
        assert_eq!(bal.tick(bal.timer_ns, 250.0), None, "hysteresis band holds");
        // Cache-size-centric halves it: 200 >= 150 -> spread.
        let mut cache = AdaptiveController::new(&t).with_approach(Approach::CacheSizeCentric);
        assert_eq!(cache.tick(cache.timer_ns, 200.0), Some(2));
    }

    #[test]
    fn decision_log_records() {
        let t = topo();
        let mut c = AdaptiveController::new(&t);
        c.tick(c.timer_ns, 400.0);
        c.tick(2 * c.timer_ns, 100.0);
        assert_eq!(c.decisions.len(), 2);
        assert_eq!(c.decisions[0].2, 2);
        assert_eq!(c.decisions[1].2, 1);
    }
}
