//! ASCII renderers for reproduced tables and figures.
//!
//! Every bench prints its result as (a) a formatted table or line-series
//! matching the paper's rows/columns and (b) a machine-readable CSV block
//! that can be piped into plotting tools.

use std::fmt::Write as _;

/// A simple table: header + rows of strings.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table {}",
            self.title
        );
        self.rows.push(cells);
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "== {} ==", self.title);
        let line: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<width$} ", c, width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let _ = writeln!(s, "{}", fmt_row(&self.header));
        let _ = writeln!(s, "{}", line);
        for row in &self.rows {
            let _ = writeln!(s, "{}", fmt_row(row));
        }
        s
    }

    /// Render as CSV (header + rows).
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(
            s,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                s,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        s
    }

    /// Print table and CSV block to stdout, and optionally persist the CSV
    /// under `results/<slug>.csv`.
    pub fn emit(&self, slug: &str) {
        println!("{}", self.render());
        println!("--- CSV ({slug}) ---\n{}", self.to_csv());
        let _ = std::fs::create_dir_all("results");
        let _ = std::fs::write(format!("results/{slug}.csv"), self.to_csv());
    }
}

/// A named line series for figure reproductions (x -> multiple named ys).
#[derive(Clone, Debug)]
pub struct SeriesSet {
    pub title: String,
    pub x_label: String,
    pub series_names: Vec<String>,
    /// Rows of (x, y-per-series).
    pub points: Vec<(f64, Vec<f64>)>,
}

impl SeriesSet {
    pub fn new(title: &str, x_label: &str, series: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            x_label: x_label.to_string(),
            series_names: series.iter().map(|s| s.to_string()).collect(),
            points: Vec::new(),
        }
    }

    pub fn point(&mut self, x: f64, ys: Vec<f64>) {
        assert_eq!(ys.len(), self.series_names.len());
        self.points.push((x, ys));
    }

    pub fn to_table(&self) -> Table {
        let mut header = vec![self.x_label.as_str()];
        header.extend(self.series_names.iter().map(|s| s.as_str()));
        let mut t = Table::new(&self.title, &header);
        for (x, ys) in &self.points {
            let mut row = vec![trim_float(*x)];
            row.extend(ys.iter().map(|y| format!("{:.4}", y)));
            t.row(row);
        }
        t
    }

    /// Simple ASCII line chart (one char column per point, `#` per series
    /// index letter) — enough to eyeball the shape of a figure.
    pub fn render_ascii_plot(&self, height: usize) -> String {
        if self.points.is_empty() {
            return String::new();
        }
        let ymax = self
            .points
            .iter()
            .flat_map(|(_, ys)| ys.iter().copied())
            .fold(f64::NEG_INFINITY, f64::max)
            .max(1e-12);
        let mut grid = vec![vec![b' '; self.points.len()]; height];
        for (si, _) in self.series_names.iter().enumerate() {
            let glyph = b"abcdefghij"[si % 10];
            for (pi, (_, ys)) in self.points.iter().enumerate() {
                let y = ys[si].max(0.0) / ymax;
                let row = ((1.0 - y) * (height - 1) as f64).round() as usize;
                grid[row.min(height - 1)][pi] = glyph;
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, ".. {} (ymax={:.3}) ..", self.title, ymax);
        for row in grid {
            let _ = writeln!(s, "|{}|", String::from_utf8_lossy(&row));
        }
        for (si, name) in self.series_names.iter().enumerate() {
            let _ = writeln!(s, "  {} = {}", b"abcdefghij"[si % 10] as char, name);
        }
        s
    }

    pub fn emit(&self, slug: &str) {
        self.to_table().emit(slug);
        println!("{}", self.render_ascii_plot(12));
    }
}

fn trim_float(x: f64) -> String {
    if (x - x.round()).abs() < 1e-9 {
        format!("{}", x.round() as i64)
    } else {
        format!("{:.4}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Tab 1", &["App", "ARCAS", "RING"]);
        t.row(vec!["BFS".into(), "3".into(), "20876".into()]);
        t.row(vec!["SSSP".into(), "6".into(), "230939".into()]);
        let r = t.render();
        assert!(r.contains("Tab 1"));
        assert!(r.contains("BFS"));
        assert!(r.contains("230939"));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"q\"\"q\""));
    }

    #[test]
    fn series_to_table() {
        let mut s = SeriesSet::new("Fig 7 BFS", "cores", &["ARCAS", "RING"]);
        s.point(1.0, vec![1.0, 1.0]);
        s.point(64.0, vec![40.0, 22.0]);
        let t = s.to_table();
        assert_eq!(t.header, vec!["cores", "ARCAS", "RING"]);
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.rows[1][0], "64");
    }

    #[test]
    fn ascii_plot_has_legend() {
        let mut s = SeriesSet::new("f", "x", &["one"]);
        s.point(0.0, vec![0.5]);
        s.point(1.0, vec![1.0]);
        let p = s.render_ascii_plot(5);
        assert!(p.contains("a = one"));
    }
}
