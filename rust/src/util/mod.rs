//! Self-contained utility substrate.
//!
//! The offline crate set available to this reproduction does not include
//! `rand`, `clap`, `serde`, `criterion` or `log`, so this module provides
//! the pieces of those we need, from scratch:
//!
//! - [`prng`] — SplitMix64 / Xoshiro256** pseudo-random generators and
//!   distribution helpers (deterministic, seedable — every experiment in
//!   the paper reproduction is bit-reproducible),
//! - [`stats`] — summary statistics, percentiles and CDFs used by the
//!   harness and the profiler,
//! - [`cli`] — a small declarative command-line argument parser for the
//!   `arcas` binary, examples and benches,
//! - [`config`] — an INI/TOML-subset parser for machine and experiment
//!   config files,
//! - [`table`] — ASCII table / series renderers for the figure and table
//!   reproductions,
//! - [`logger`] — a tiny leveled logger,
//! - [`bench`] — a micro-benchmark timing harness (criterion substitute),
//! - [`proptest`] — a miniature property-based testing helper with
//!   random input generation and iteration shrinking,
//! - [`json`] — a minimal JSON parser (serde substitute) for reading the
//!   `BENCH_*.json` files the benches emit,
//! - [`baseline`] — the CI bench-regression gate logic behind
//!   `arcas bench-check` (tolerance-band comparison vs `ci/baselines/`).
pub mod prng;
pub mod stats;
pub mod cli;
pub mod config;
pub mod table;
pub mod logger;
pub mod bench;
pub mod proptest;
pub mod json;
pub mod baseline;

pub use prng::Rng;
pub use stats::Summary;

/// Format a byte count with binary units (the paper mixes `38 B`..`38 GB`).
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", b, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format nanoseconds into a human-readable duration.
pub fn fmt_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{} ns", ns)
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

/// Integer ceiling division.
#[inline]
pub fn div_ceil(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(fmt_bytes(38), "38 B");
        assert_eq!(fmt_bytes(1024), "1.00 KiB");
        assert_eq!(fmt_bytes(32 * 1024 * 1024), "32.00 MiB");
    }

    #[test]
    fn ns_formatting() {
        assert_eq!(fmt_ns(25), "25 ns");
        assert_eq!(fmt_ns(1_500), "1.50 us");
        assert_eq!(fmt_ns(2_000_000), "2.00 ms");
        assert_eq!(fmt_ns(3_500_000_000), "3.500 s");
    }

    #[test]
    fn ceil_div() {
        assert_eq!(div_ceil(10, 3), 4);
        assert_eq!(div_ceil(9, 3), 3);
        assert_eq!(div_ceil(1, 64), 1);
    }
}
