//! A minimal JSON parser.
//!
//! `serde`/`serde_json` are not in the offline crate set. The benches
//! emit `BENCH_*.json` by hand-formatting strings; this module adds the
//! other direction so the CI bench-regression gate (`arcas bench-check`,
//! see [`crate::util::baseline`]) can read those files back. It parses
//! standard JSON (objects, arrays, strings with escapes, numbers,
//! booleans, null) into a small value tree; it is a consumer for files
//! this repository produces, not a general-purpose validator (it accepts
//! a few superset quirks, e.g. lone surrogates are replaced).

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Key order preserved (insertion order of the document).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse a JSON document. Errors carry the byte offset.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing input at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// `self[key]` as a number.
    pub fn num(&self, key: &str) -> Option<f64> {
        self.get(key)?.as_f64()
    }

    /// `self[key]` as a string.
    pub fn str_of(&self, key: &str) -> Option<&str> {
        self.get(key)?.as_str()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.i
            )),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {s:?} at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else {
                return Err("unterminated string".into());
            };
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(e) = self.peek() else {
                        return Err("unterminated escape".into());
                    };
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        other => return Err(format!("bad escape \\{}", other as char)),
                    }
                }
                // Multi-byte UTF-8: copy the raw bytes through.
                _ => {
                    // Back up to slice the full char.
                    let rest = std::str::from_utf8(&self.b[self.i - 1..])
                        .map_err(|_| "invalid utf-8 in string".to_string())?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.i += ch.len_utf8() - 1;
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            fields.push((key, val));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(fields));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }
}

/// Escape a string for embedding in hand-formatted JSON output (the
/// benches' emit path).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parses_nested_documents() {
        let doc = r#"{
            "bench": "serving_latency",
            "pinned": false,
            "series": [
                {"policy": "local", "backend": "sim", "p99_ns": 1234.5, "tol": 0.1},
                {"policy": "arcas", "backend": "host", "p99_ns": 99, "cdf": [[1, 0.5], [2, 1.0]]}
            ]
        }"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.str_of("bench"), Some("serving_latency"));
        assert_eq!(v.get("pinned").unwrap().as_bool(), Some(false));
        let series = v.get("series").unwrap().as_arr().unwrap();
        assert_eq!(series.len(), 2);
        assert_eq!(series[0].num("p99_ns"), Some(1234.5));
        assert_eq!(series[1].str_of("policy"), Some("arcas"));
        let cdf = series[1].get("cdf").unwrap().as_arr().unwrap();
        assert_eq!(cdf[0].as_arr().unwrap()[1].as_f64(), Some(0.5));
    }

    #[test]
    fn roundtrips_the_bench_emit_format() {
        // The exact shape micro_runtime writes.
        let doc = "{\n  \"bench\": \"host_scaling\",\n  \"scenario\": \"gups\",\n  \
                   \"backend\": \"host\",\n  \"total_updates\": 2000000,\n  \
                   \"points\": [{\"workers\": 1, \"wall_ns\": 100}, {\"workers\": 8, \"wall_ns\": 50}],\n  \
                   \"speedup_max_vs_1\": 2.000\n}\n";
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.num("speedup_max_vs_1"), Some(2.0));
        assert_eq!(v.get("points").unwrap().as_arr().unwrap().len(), 2);
        // A null speedup (no 1-worker point) parses too.
        let v = Json::parse("{\"speedup_max_vs_1\": null}").unwrap();
        assert_eq!(v.num("speedup_max_vs_1"), None);
        assert_eq!(v.get("speedup_max_vs_1"), Some(&Json::Null));
    }

    #[test]
    fn rejects_malformed_input() {
        for bad in ["", "{", "{\"a\" 1}", "[1,", "\"unterminated", "{} extra", "tru"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn escape_sequences_cover_the_full_table() {
        // Every escape the parser claims to handle, in one string.
        let v = Json::parse(r#""\"\\\/\n\t\r\b\fAé""#).unwrap();
        assert_eq!(v.as_str(), Some("\"\\/\n\t\r\u{8}\u{c}Aé"));
        // \u escapes of control characters round-trip through escape().
        let s = "bell\u{7}end";
        let round = format!("\"{}\"", escape(s));
        assert_eq!(Json::parse(&round).unwrap().as_str(), Some(s));
        // A lone surrogate is replaced, not a crash or a mangled string.
        let v = Json::parse(r#""\ud800""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{FFFD}"));
        // Malformed escapes are errors.
        for bad in [r#""\q""#, r#""\u12""#, r#""\u12g4""#, r#""\"#] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn nested_empty_containers() {
        assert_eq!(Json::parse("[[]]").unwrap(), Json::Arr(vec![Json::Arr(vec![])]));
        assert_eq!(
            Json::parse(r#"{"a": {}}"#).unwrap(),
            Json::Obj(vec![("a".into(), Json::Obj(vec![]))])
        );
        assert_eq!(
            Json::parse("[{}, [], {}]").unwrap(),
            Json::Arr(vec![Json::Obj(vec![]), Json::Arr(vec![]), Json::Obj(vec![])])
        );
        // Whitespace inside empty containers is fine.
        assert_eq!(Json::parse("[ \n ]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{ \t }").unwrap(), Json::Obj(vec![]));
        // Deep nesting parses and indexes.
        let v = Json::parse(r#"{"a": [{"b": [[1]]}]}"#).unwrap();
        let inner = v.get("a").unwrap().as_arr().unwrap()[0]
            .get("b")
            .unwrap()
            .as_arr()
            .unwrap()[0]
            .as_arr()
            .unwrap()[0]
            .as_f64();
        assert_eq!(inner, Some(1.0));
    }

    #[test]
    fn exponent_form_numbers() {
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(Json::parse("2.5E-2").unwrap(), Json::Num(0.025));
        assert_eq!(Json::parse("-1E+2").unwrap(), Json::Num(-100.0));
        assert_eq!(Json::parse("0.0").unwrap(), Json::Num(0.0));
        assert_eq!(Json::parse("[1e0, 1e1]").unwrap().as_arr().unwrap().len(), 2);
        // Degenerate exponent/sign soup must not parse as a number.
        for bad in ["1e", "1e+", "--1", "1.2.3", "+1"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        for bad in [
            "{} extra",
            "{}{}",
            "[1, 2]]",
            "null null",
            "42 ,",
            "\"s\" trailing",
            "true}",
        ] {
            let err = Json::parse(bad).unwrap_err();
            assert!(
                err.contains("trailing") || err.contains("expected"),
                "{bad:?}: {err}"
            );
        }
        // …but trailing whitespace is not garbage.
        assert_eq!(Json::parse("{} \n\t ").unwrap(), Json::Obj(vec![]));
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse("\"caf\u{e9} \\u00e9 \\\"q\\\"\"").unwrap();
        assert_eq!(v.as_str(), Some("café é \"q\""));
        assert_eq!(escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        let round = format!("\"{}\"", escape("x\ty\n\"z\""));
        assert_eq!(Json::parse(&round).unwrap().as_str(), Some("x\ty\n\"z\""));
    }
}
