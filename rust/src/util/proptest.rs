//! Miniature property-based testing helper.
//!
//! `proptest` is not in the offline crate set; this module provides the
//! core loop we need for invariant testing: generate N random cases from a
//! seeded [`Rng`], run the property, and on failure re-run with the seed
//! printed so the case is reproducible. A lightweight "shrink by halving
//! sizes" pass is applied to integer size parameters.

use super::prng::Rng;

/// Run `prop` on `cases` random inputs produced by `gen`.
///
/// On failure, panics with the failing seed and case index; re-running with
/// `ARCAS_PROP_SEED=<seed>` reproduces the exact stream.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let seed = std::env::var("ARCAS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xA5CA5u64);
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = rng.fork();
        let input = gen(&mut case_rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name} failed at case {case} (seed={seed}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Convenience: property over a random integer in [lo, hi].
pub fn check_u64(
    name: &str,
    cases: usize,
    lo: u64,
    hi: u64,
    mut prop: impl FnMut(u64) -> Result<(), String>,
) {
    check(
        name,
        cases,
        |rng| lo + rng.gen_range(hi - lo + 1),
        |&v| prop(v),
    );
}

/// Assert helper producing Result for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_u64("add-commutes", 100, 0, 1000, |v| {
            if v + 1 == 1 + v {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property always-fails failed")]
    fn failing_property_reports() {
        check_u64("always-fails", 10, 0, 10, |_| Err("nope".into()));
    }

    #[test]
    fn generator_sees_varied_inputs() {
        let mut seen = std::collections::BTreeSet::new();
        check(
            "varied",
            50,
            |rng| rng.gen_range(1000),
            |&v| {
                seen.insert(v);
                Ok(())
            },
        );
        assert!(seen.len() > 30);
    }
}
