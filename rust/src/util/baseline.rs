//! Bench-regression gate: compare emitted `BENCH_*.json` files against
//! checked-in baselines (`ci/baselines/*.json`) with a tolerance band.
//!
//! Policy (the CI contract):
//! - **regression** (worse than baseline by more than the tolerance) —
//!   the gate **fails**;
//! - **improvement** beyond the tolerance — the gate passes with a
//!   warning telling the operator to re-pin the baseline (copy the
//!   uploaded artifact over `ci/baselines/` and keep `"pinned": true`);
//! - a baseline marked `"pinned": false` is a bootstrap placeholder:
//!   comparisons are reported but never fail, so the very first CI run
//!   on a new bench can mint the numbers to pin.
//!
//! Gated metric families: the per-series latency metrics of
//! `fig_serving` (`BENCH_serving_latency.json` / `BENCH_serving_slo.json`,
//! lower is better; `BENCH_serving_throughput.json` entries flip the
//! direction with `"higher_is_better": true`), the host-scaling speedup
//! of `micro_runtime` (`BENCH_host_scaling.json`, higher is better) and
//! the zero-work scheduler throughput of the same bench
//! (`BENCH_sched_overhead.json`, steps/sec per backend × batch budget,
//! higher is better), the adaptive-vs-best-static makespan ratio on
//! the phase-shifting scenario (`BENCH_adaptive.json`, higher is
//! better), and the region-moves-vs-task-move-only makespan ratio on
//! the stranded-region scenario (`BENCH_mem_follow.json`, higher is
//! better). Each baseline entry may carry its own `"tol"`
//! (relative band, e.g. `0.25`); entries without one use the caller's
//! default — keep simulator series tight (they are deterministic) and
//! host series loose (shared-runner noise).
//!
//! [`pin_payload`] backs `arcas bench-check --pin`: one command that
//! copies fresh artifacts over their baselines (forcing
//! `"pinned": true`) instead of hand-editing placeholders.

use super::json::Json;

/// Outcome of one metric comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verdict {
    /// Within the tolerance band.
    Ok,
    /// Better than baseline by more than the tolerance: warn + re-pin.
    Improved,
    /// Worse than baseline by more than the tolerance: fail.
    Regressed,
    /// The baseline entry has no counterpart in the current results.
    Missing,
}

/// One gated metric.
#[derive(Clone, Debug)]
pub struct Check {
    /// Human-readable series label, e.g. `local/sim p99_ns`.
    pub label: String,
    pub base: f64,
    /// NaN when the series is missing from the current results.
    pub current: f64,
    /// Relative tolerance band.
    pub tol: f64,
    pub verdict: Verdict,
}

/// All checks of one gate run.
#[derive(Clone, Debug)]
pub struct GateResult {
    pub checks: Vec<Check>,
    /// Baseline had `"pinned": false` — report, never fail.
    pub unpinned: bool,
}

impl GateResult {
    /// True when the gate must fail the build.
    pub fn failed(&self) -> bool {
        !self.unpinned
            && self
                .checks
                .iter()
                .any(|c| matches!(c.verdict, Verdict::Regressed | Verdict::Missing))
    }

    /// True when any series improved beyond tolerance (re-pin nudge).
    pub fn improved(&self) -> bool {
        self.checks.iter().any(|c| c.verdict == Verdict::Improved)
    }

    /// One line per check, stable format for CI logs.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for c in &self.checks {
            let delta = if c.current.is_nan() {
                "     -  ".to_string()
            } else {
                format!("{:+7.1}%", (c.current / c.base - 1.0) * 100.0)
            };
            out.push_str(&format!(
                "  {:<28} base {:>14.1}  current {:>14.1}  {delta} (tol ±{:.0}%)  {:?}\n",
                c.label,
                c.base,
                c.current,
                c.tol * 100.0,
                c.verdict
            ));
        }
        if self.unpinned {
            out.push_str(
                "  baseline is marked \"pinned\": false — bootstrap mode, comparisons do not fail.\n  \
                 Re-pin: copy the current BENCH json over ci/baselines/ and set \"pinned\": true.\n",
            );
        }
        out
    }
}

fn verdict(base: f64, current: f64, tol: f64, higher_is_better: bool) -> Verdict {
    let (lo, hi) = (base * (1.0 - tol), base * (1.0 + tol));
    let worse = if higher_is_better {
        current < lo
    } else {
        current > hi
    };
    let better = if higher_is_better {
        current > hi
    } else {
        current < lo
    };
    if worse {
        Verdict::Regressed
    } else if better {
        Verdict::Improved
    } else {
        Verdict::Ok
    }
}

fn is_unpinned(baseline: &Json) -> bool {
    baseline.get("pinned").and_then(Json::as_bool) == Some(false)
}

/// Config-drift guard: when both files carry a `"config"` object, every
/// baseline key must match the current run's value. A p99 minted under
/// one invocation (request count, offered rate, arrival model, workers,
/// seed, …) is not comparable to another's — gating across configs
/// would report phantom regressions or mask real ones. Files without a
/// config block (e.g. the host-scaling bench) skip the guard. Nested
/// config objects are walked recursively; the error names the exact
/// dotted key path (e.g. `arrivals.depth`) and both values.
fn check_config(baseline: &Json, current: &Json) -> Result<(), String> {
    let (Some(base @ Json::Obj(_)), Some(cur)) = (baseline.get("config"), current.get("config"))
    else {
        return Ok(());
    };
    config_drift(base, cur, "")
}

fn config_drift(want: &Json, got: &Json, path: &str) -> Result<(), String> {
    if let Json::Obj(fields) = want {
        if matches!(got, Json::Obj(_)) {
            for (key, sub_want) in fields {
                let sub_path = if path.is_empty() {
                    key.clone()
                } else {
                    format!("{path}.{key}")
                };
                match got.get(key) {
                    Some(sub_got) => config_drift(sub_want, sub_got, &sub_path)?,
                    None => return Err(drift_msg(&sub_path, sub_want, None)),
                }
            }
            return Ok(());
        }
    }
    if want != got {
        return Err(drift_msg(path, want, Some(got)));
    }
    Ok(())
}

fn drift_msg(path: &str, want: &Json, got: Option<&Json>) -> String {
    format!(
        "bench config drift on \"{path}\": baseline {want:?} vs current {got:?} — \
         the files come from different bench invocations; re-pin the baseline \
         from the current invocation instead of gating across configs"
    )
}

/// Load a `BENCH_*.json` artifact for gating, distinguishing the three
/// failure shapes CI actually hits: the bench never ran (no file at the
/// path — the actionable one), the file is unreadable, and the file is
/// not valid JSON. Backs `arcas bench-check --baseline/--current`.
pub fn load_artifact(path: &str) -> Result<Json, String> {
    if !std::path::Path::new(path).exists() {
        return Err(format!(
            "bench did not run — no artifact at {path} \
             (run the matching `cargo bench` or `make bench-regression` first)"
        ));
    }
    let text = std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
    Json::parse(&text).map_err(|e| format!("{path} is not valid JSON: {e}"))
}

/// Gate `BENCH_serving_latency.json` (and `BENCH_serving_slo.json` /
/// `BENCH_serving_throughput.json`): per-(policy, backend) metrics,
/// lower is better unless the baseline entry says
/// `"higher_is_better": true` (throughput series). Each baseline series
/// entry may carry a `"metric"` key naming the gated field (default
/// `"p99_ns"`), so one file can gate overall p99, per-class p99s, shed
/// rates and requests/sec side by side. Series without a `"tol"` use
/// `default_tol`.
pub fn check_serving(
    baseline: &Json,
    current: &Json,
    default_tol: f64,
) -> Result<GateResult, String> {
    check_config(baseline, current)?;
    let base_series = baseline
        .get("series")
        .and_then(Json::as_arr)
        .ok_or("baseline has no \"series\" array")?;
    let cur_series = current
        .get("series")
        .and_then(Json::as_arr)
        .ok_or("current results have no \"series\" array")?;
    let metric_of = |entry: &Json| entry.str_of("metric").unwrap_or("p99_ns").to_string();
    let mut checks = Vec::new();
    for b in base_series {
        let policy = b.str_of("policy").ok_or("baseline series missing \"policy\"")?;
        let backend = b.str_of("backend").ok_or("baseline series missing \"backend\"")?;
        let metric = metric_of(b);
        let base = b.num(&metric).ok_or_else(|| {
            format!("baseline series {policy}/{backend} missing numeric \"{metric}\"")
        })?;
        let tol = b.num("tol").unwrap_or(default_tol);
        let label = format!("{policy}/{backend} {metric}");
        let cur = cur_series
            .iter()
            .find(|c| {
                c.str_of("policy") == Some(policy)
                    && c.str_of("backend") == Some(backend)
                    && metric_of(c) == metric
            })
            .and_then(|c| c.num(&metric));
        // Latency-shaped metrics default to lower-is-better; throughput
        // entries flip the direction in the baseline.
        let hib = b.get("higher_is_better").and_then(Json::as_bool) == Some(true);
        let (current, verdict) = match cur {
            Some(v) => (v, verdict(base, v, tol, hib)),
            None => (f64::NAN, Verdict::Missing),
        };
        checks.push(Check {
            label,
            base,
            current,
            tol,
            verdict,
        });
    }
    if checks.is_empty() {
        return Err("baseline has an empty \"series\" array — nothing to gate".into());
    }
    Ok(GateResult {
        checks,
        unpinned: is_unpinned(baseline),
    })
}

/// Gate `BENCH_host_scaling.json`: the max-workers-vs-1 speedup, higher
/// is better. A current file with a null/absent speedup (no 1-worker
/// point) is a missing metric, which fails a pinned gate.
pub fn check_scaling(
    baseline: &Json,
    current: &Json,
    default_tol: f64,
) -> Result<GateResult, String> {
    check_config(baseline, current)?;
    let base = baseline
        .num("speedup_max_vs_1")
        .ok_or("baseline missing numeric \"speedup_max_vs_1\"")?;
    let tol = baseline.num("tol").unwrap_or(default_tol);
    let (cur, verdict) = match current.num("speedup_max_vs_1") {
        Some(v) => (v, verdict(base, v, tol, true)),
        None => (f64::NAN, Verdict::Missing),
    };
    Ok(GateResult {
        checks: vec![Check {
            label: "host_scaling speedup_max_vs_1".into(),
            base,
            current: cur,
            tol,
            verdict,
        }],
        unpinned: is_unpinned(baseline),
    })
}

/// Gate `BENCH_adaptive.json`: the adaptive policy's makespan advantage
/// over the best *static* policy on the phase-shifting scenario
/// (`speedup_adaptive_vs_best_static`, higher is better; ≥ 1.0 means
/// adaptation actually pays for itself). The bench also emits the raw
/// per-policy makespans and the migration count for diagnosis, but only
/// the headline ratio is gated — absolute host makespans are
/// runner-noise territory.
pub fn check_adaptive(
    baseline: &Json,
    current: &Json,
    default_tol: f64,
) -> Result<GateResult, String> {
    check_config(baseline, current)?;
    let base = baseline
        .num("speedup_adaptive_vs_best_static")
        .ok_or("baseline missing numeric \"speedup_adaptive_vs_best_static\"")?;
    let tol = baseline.num("tol").unwrap_or(default_tol);
    let (cur, verdict) = match current.num("speedup_adaptive_vs_best_static") {
        Some(v) => (v, verdict(base, v, tol, true)),
        None => (f64::NAN, Verdict::Missing),
    };
    Ok(GateResult {
        checks: vec![Check {
            label: "adaptive speedup_vs_best_static".into(),
            base,
            current: cur,
            tol,
            verdict,
        }],
        unpinned: is_unpinned(baseline),
    })
}

/// Gate `BENCH_mem_follow.json`: the makespan advantage of online
/// region re-placement over the task-move-only adaptive baseline on the
/// stranded-region scenario (`speedup_moves_vs_task_only`, higher is
/// better; ≥ 1.0 means letting data follow tasks pays for itself). The
/// bench also emits both raw makespans and the region-move count for
/// diagnosis, but only the headline ratio is gated.
pub fn check_mem_follow(
    baseline: &Json,
    current: &Json,
    default_tol: f64,
) -> Result<GateResult, String> {
    check_config(baseline, current)?;
    let base = baseline
        .num("speedup_moves_vs_task_only")
        .ok_or("baseline missing numeric \"speedup_moves_vs_task_only\"")?;
    let tol = baseline.num("tol").unwrap_or(default_tol);
    let (cur, verdict) = match current.num("speedup_moves_vs_task_only") {
        Some(v) => (v, verdict(base, v, tol, true)),
        None => (f64::NAN, Verdict::Missing),
    };
    Ok(GateResult {
        checks: vec![Check {
            label: "mem_follow speedup_moves_vs_task_only".into(),
            base,
            current: cur,
            tol,
            verdict,
        }],
        unpinned: is_unpinned(baseline),
    })
}

/// Gate `BENCH_cluster_scaling.json`: the rps-at-p99 advantage of a
/// 4-shard cluster over the single machine on the `serve-cluster`
/// scenario (`speedup_n4_vs_n1`, higher is better; > 1.0 means the
/// fleet actually scales through the cross-machine link tier). The
/// bench also emits per-N rps-at-p99 points for diagnosis, but only the
/// headline ratio is gated — the sweep grid may change.
pub fn check_cluster(
    baseline: &Json,
    current: &Json,
    default_tol: f64,
) -> Result<GateResult, String> {
    check_config(baseline, current)?;
    let base = baseline
        .num("speedup_n4_vs_n1")
        .ok_or("baseline missing numeric \"speedup_n4_vs_n1\"")?;
    let tol = baseline.num("tol").unwrap_or(default_tol);
    let (cur, verdict) = match current.num("speedup_n4_vs_n1") {
        Some(v) => (v, verdict(base, v, tol, true)),
        None => (f64::NAN, Verdict::Missing),
    };
    Ok(GateResult {
        checks: vec![Check {
            label: "cluster speedup_n4_vs_n1".into(),
            base,
            current: cur,
            tol,
            verdict,
        }],
        unpinned: is_unpinned(baseline),
    })
}

/// Gate `BENCH_sched_overhead.json`: zero-work scheduler throughput in
/// steps/sec per `(backend, batch_steps)` point, higher is better, plus
/// the headline `speedup_batched_vs_1` ratio (batched host pipeline vs
/// `--batch-steps 1`) when the baseline carries one. Points without a
/// `"tol"` use `default_tol`.
pub fn check_overhead(
    baseline: &Json,
    current: &Json,
    default_tol: f64,
) -> Result<GateResult, String> {
    check_config(baseline, current)?;
    let base_pts = baseline
        .get("points")
        .and_then(Json::as_arr)
        .ok_or("baseline has no \"points\" array")?;
    let cur_pts = current
        .get("points")
        .and_then(Json::as_arr)
        .ok_or("current results have no \"points\" array")?;
    let mut checks = Vec::new();
    for b in base_pts {
        let backend = b
            .str_of("backend")
            .ok_or("baseline point missing \"backend\"")?;
        let batch = b
            .num("batch_steps")
            .ok_or("baseline point missing \"batch_steps\"")? as u64;
        let base = b.num("steps_per_sec").ok_or_else(|| {
            format!("baseline point {backend}/batch{batch} missing \"steps_per_sec\"")
        })?;
        let tol = b.num("tol").unwrap_or(default_tol);
        let cur = cur_pts
            .iter()
            .find(|c| {
                c.str_of("backend") == Some(backend)
                    && c.num("batch_steps").map(|v| v as u64) == Some(batch)
            })
            .and_then(|c| c.num("steps_per_sec"));
        let (current_v, verdict) = match cur {
            Some(v) => (v, verdict(base, v, tol, true)),
            None => (f64::NAN, Verdict::Missing),
        };
        checks.push(Check {
            label: format!("{backend} batch={batch} steps_per_sec"),
            base,
            current: current_v,
            tol,
            verdict,
        });
    }
    if checks.is_empty() {
        return Err("baseline has an empty \"points\" array — nothing to gate".into());
    }
    // The headline claim behind run-until-yield batching: batched host
    // steps/sec over the step-per-job pipeline must not erode.
    if let Some(base_sp) = baseline.num("speedup_batched_vs_1") {
        let tol = baseline.num("tol").unwrap_or(default_tol);
        let (cur, verdict) = match current.num("speedup_batched_vs_1") {
            Some(v) => (v, verdict(base_sp, v, tol, true)),
            None => (f64::NAN, Verdict::Missing),
        };
        checks.push(Check {
            label: "sched_overhead speedup_batched_vs_1".into(),
            base: base_sp,
            current: cur,
            tol,
            verdict,
        });
    }
    Ok(GateResult {
        checks,
        unpinned: is_unpinned(baseline),
    })
}

/// Validate a baseline/artifact pair for `bench-check --pin` and return
/// the text to write over the baseline: the fresh artifact with
/// `"pinned"` forced to `true`. Errors (instead of silently pinning)
/// when either side fails to parse or the `"bench"` names disagree —
/// catching an artifact written over the wrong baseline file.
pub fn pin_payload(baseline_text: &str, current_text: &str) -> Result<String, String> {
    let base = Json::parse(baseline_text).map_err(|e| format!("baseline is not valid JSON: {e}"))?;
    let cur =
        Json::parse(current_text).map_err(|e| format!("fresh artifact is not valid JSON: {e}"))?;
    if let (Some(b), Some(c)) = (base.str_of("bench"), cur.str_of("bench")) {
        if b != c {
            return Err(format!(
                "bench name mismatch: baseline is \"{b}\" but the artifact is \"{c}\" — \
                 wrong artifact for this baseline"
            ));
        }
    }
    // Benches emit "pinned": true already; force it in case the
    // artifact came from an older bench build.
    Ok(current_text.replacen("\"pinned\": false", "\"pinned\": true", 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn serving_json(p99_local_sim: f64, p99_arcas_host: f64, pinned: bool) -> Json {
        Json::parse(&format!(
            r#"{{
                "bench": "serving_latency",
                "pinned": {pinned},
                "series": [
                    {{"policy": "local", "backend": "sim", "p99_ns": {p99_local_sim}, "tol": 0.10}},
                    {{"policy": "arcas", "backend": "host", "p99_ns": {p99_arcas_host}, "tol": 0.50}}
                ]
            }}"#
        ))
        .unwrap()
    }

    #[test]
    fn within_tolerance_passes() {
        let base = serving_json(10_000.0, 20_000.0, true);
        let cur = serving_json(10_500.0, 25_000.0, true);
        let r = check_serving(&base, &cur, 0.25).unwrap();
        assert!(!r.failed());
        assert!(!r.improved());
        assert!(r.checks.iter().all(|c| c.verdict == Verdict::Ok));
    }

    #[test]
    fn seeded_p99_regression_fails_the_gate() {
        // local/sim regresses 50% against a 10% band: the gate must fail.
        let base = serving_json(10_000.0, 20_000.0, true);
        let cur = serving_json(15_000.0, 20_000.0, true);
        let r = check_serving(&base, &cur, 0.25).unwrap();
        assert!(r.failed());
        assert_eq!(r.checks[0].verdict, Verdict::Regressed);
        assert_eq!(r.checks[1].verdict, Verdict::Ok);
        assert!(r.render().contains("Regressed"), "{}", r.render());
    }

    #[test]
    fn improvement_warns_but_passes() {
        let base = serving_json(10_000.0, 20_000.0, true);
        let cur = serving_json(5_000.0, 20_000.0, true);
        let r = check_serving(&base, &cur, 0.25).unwrap();
        assert!(!r.failed());
        assert!(r.improved());
        assert_eq!(r.checks[0].verdict, Verdict::Improved);
    }

    #[test]
    fn missing_series_fails_a_pinned_gate() {
        let base = serving_json(10_000.0, 20_000.0, true);
        let cur = Json::parse(
            r#"{"series": [{"policy": "local", "backend": "sim", "p99_ns": 10000}]}"#,
        )
        .unwrap();
        let r = check_serving(&base, &cur, 0.25).unwrap();
        assert!(r.failed());
        assert_eq!(r.checks[1].verdict, Verdict::Missing);
    }

    #[test]
    fn unpinned_baseline_never_fails() {
        let base = serving_json(10_000.0, 20_000.0, false);
        let cur = serving_json(99_000.0, 99_000.0, false);
        let r = check_serving(&base, &cur, 0.25).unwrap();
        assert!(r.unpinned);
        assert!(!r.failed());
        assert_eq!(r.checks[0].verdict, Verdict::Regressed); // still reported
        assert!(r.render().contains("bootstrap"));
    }

    #[test]
    fn scaling_gate_is_higher_is_better() {
        let base =
            Json::parse(r#"{"pinned": true, "speedup_max_vs_1": 1.5, "tol": 0.3}"#).unwrap();
        let good = Json::parse(r#"{"speedup_max_vs_1": 1.6}"#).unwrap();
        assert!(!check_scaling(&base, &good, 0.3).unwrap().failed());
        let bad = Json::parse(r#"{"speedup_max_vs_1": 0.9}"#).unwrap();
        let r = check_scaling(&base, &bad, 0.3).unwrap();
        assert!(r.failed());
        assert_eq!(r.checks[0].verdict, Verdict::Regressed);
        let better = Json::parse(r#"{"speedup_max_vs_1": 4.0}"#).unwrap();
        let r = check_scaling(&base, &better, 0.3).unwrap();
        assert!(!r.failed());
        assert!(r.improved());
        // Null speedup (no 1-worker point) is a missing metric.
        let null = Json::parse(r#"{"speedup_max_vs_1": null}"#).unwrap();
        assert!(check_scaling(&base, &null, 0.3).unwrap().failed());
    }

    #[test]
    fn adaptive_gate_is_higher_is_better() {
        let base = Json::parse(
            r#"{"pinned": true, "speedup_adaptive_vs_best_static": 1.2, "tol": 0.15}"#,
        )
        .unwrap();
        let good = Json::parse(r#"{"speedup_adaptive_vs_best_static": 1.25}"#).unwrap();
        assert!(!check_adaptive(&base, &good, 0.25).unwrap().failed());
        // Adaptation losing its edge over the best static policy fails.
        let bad = Json::parse(r#"{"speedup_adaptive_vs_best_static": 0.9}"#).unwrap();
        let r = check_adaptive(&base, &bad, 0.25).unwrap();
        assert!(r.failed());
        assert_eq!(r.checks[0].verdict, Verdict::Regressed);
        // A bigger win warns to re-pin, never fails.
        let better = Json::parse(r#"{"speedup_adaptive_vs_best_static": 2.0}"#).unwrap();
        let r = check_adaptive(&base, &better, 0.25).unwrap();
        assert!(!r.failed());
        assert!(r.improved());
        // Missing headline fails a pinned gate; bootstrap never fails.
        let none = Json::parse(r#"{"migrations": 12}"#).unwrap();
        assert!(check_adaptive(&base, &none, 0.25).unwrap().failed());
        let bootstrap = Json::parse(
            r#"{"pinned": false, "speedup_adaptive_vs_best_static": 1.0}"#,
        )
        .unwrap();
        let r = check_adaptive(&bootstrap, &bad, 0.25).unwrap();
        assert!(r.unpinned);
        assert!(!r.failed());
        // Malformed baseline is an error, not a panic.
        assert!(check_adaptive(&none, &good, 0.25).is_err());
    }

    #[test]
    fn mem_follow_gate_is_higher_is_better() {
        let base = Json::parse(
            r#"{"pinned": true, "speedup_moves_vs_task_only": 1.3, "tol": 0.2}"#,
        )
        .unwrap();
        let good = Json::parse(r#"{"speedup_moves_vs_task_only": 1.35}"#).unwrap();
        assert!(!check_mem_follow(&base, &good, 0.35).unwrap().failed());
        // Region moves losing their edge over task-move-only fails.
        let bad = Json::parse(r#"{"speedup_moves_vs_task_only": 0.8}"#).unwrap();
        let r = check_mem_follow(&base, &bad, 0.35).unwrap();
        assert!(r.failed());
        assert_eq!(r.checks[0].verdict, Verdict::Regressed);
        // A bigger win warns to re-pin, never fails.
        let better = Json::parse(r#"{"speedup_moves_vs_task_only": 2.5}"#).unwrap();
        let r = check_mem_follow(&base, &better, 0.35).unwrap();
        assert!(!r.failed());
        assert!(r.improved());
        // Missing headline fails a pinned gate; bootstrap never fails.
        let none = Json::parse(r#"{"region_moves": 3}"#).unwrap();
        assert!(check_mem_follow(&base, &none, 0.35).unwrap().failed());
        let bootstrap =
            Json::parse(r#"{"pinned": false, "speedup_moves_vs_task_only": 1.0}"#).unwrap();
        let r = check_mem_follow(&bootstrap, &bad, 0.35).unwrap();
        assert!(r.unpinned);
        assert!(!r.failed());
        // Malformed baseline is an error, not a panic.
        assert!(check_mem_follow(&none, &good, 0.35).is_err());
    }

    #[test]
    fn config_drift_is_an_error_not_a_comparison() {
        let with_cfg = |requests: u64, p99: f64| {
            Json::parse(&format!(
                r#"{{"pinned": true,
                     "config": {{"requests": {requests}, "arrivals": "poisson"}},
                     "series": [{{"policy": "local", "backend": "sim", "p99_ns": {p99}}}]}}"#
            ))
            .unwrap()
        };
        // Same config: gated normally.
        let r = check_serving(&with_cfg(4000, 100.0), &with_cfg(4000, 101.0), 0.25).unwrap();
        assert!(!r.failed());
        // Drifted config (different request count): error, not a verdict.
        let err = check_serving(&with_cfg(4000, 100.0), &with_cfg(20_000, 50.0), 0.25)
            .unwrap_err();
        assert!(err.contains("config drift"), "{err}");
        assert!(err.contains("requests"), "{err}");
        // A side with no config block skips the guard.
        let no_cfg = Json::parse(
            r#"{"series": [{"policy": "local", "backend": "sim", "p99_ns": 100}]}"#,
        )
        .unwrap();
        assert!(check_serving(&with_cfg(4000, 100.0), &no_cfg, 0.25).is_ok());
        assert!(check_serving(&no_cfg, &with_cfg(4000, 100.0), 0.25).is_ok());
    }

    #[test]
    fn nested_config_drift_names_the_dotted_key_path() {
        let mk = |depth: f64| {
            Json::parse(&format!(
                r#"{{"pinned": true,
                     "config": {{"requests": 4000, "arrivals": {{"model": "diurnal", "depth": {depth}}}}},
                     "series": [{{"policy": "local", "backend": "sim", "p99_ns": 100}}]}}"#
            ))
            .unwrap()
        };
        // Same nested config: gated normally.
        assert!(!check_serving(&mk(0.8), &mk(0.8), 0.25).unwrap().failed());
        // A leaf two levels down drifts: the error names the full path
        // and both values, not just the top-level key.
        let err = check_serving(&mk(0.8), &mk(0.5), 0.25).unwrap_err();
        assert!(err.contains("config drift"), "{err}");
        assert!(err.contains("\"arrivals.depth\""), "{err}");
        assert!(err.contains("0.8") && err.contains("0.5"), "{err}");
        // A key missing from the current config names the path too.
        let no_depth = Json::parse(
            r#"{"config": {"requests": 4000, "arrivals": {"model": "diurnal"}},
                "series": [{"policy": "local", "backend": "sim", "p99_ns": 100}]}"#,
        )
        .unwrap();
        let err = check_serving(&mk(0.8), &no_depth, 0.25).unwrap_err();
        assert!(err.contains("\"arrivals.depth\"") && err.contains("None"), "{err}");
        // Extra keys on the current side are fine (baseline drives).
        let extra = Json::parse(
            r#"{"config": {"requests": 4000,
                           "arrivals": {"model": "diurnal", "depth": 0.8, "burst": 64}},
                "series": [{"policy": "local", "backend": "sim", "p99_ns": 100}]}"#,
        )
        .unwrap();
        assert!(check_serving(&mk(0.8), &extra, 0.25).is_ok());
    }

    #[test]
    fn cluster_gate_is_higher_is_better() {
        let base =
            Json::parse(r#"{"pinned": true, "speedup_n4_vs_n1": 2.0, "tol": 0.25}"#).unwrap();
        let good = Json::parse(r#"{"speedup_n4_vs_n1": 2.1}"#).unwrap();
        assert!(!check_cluster(&base, &good, 0.25).unwrap().failed());
        // The fleet losing its edge over one machine fails.
        let bad = Json::parse(r#"{"speedup_n4_vs_n1": 1.0}"#).unwrap();
        let r = check_cluster(&base, &bad, 0.25).unwrap();
        assert!(r.failed());
        assert_eq!(r.checks[0].verdict, Verdict::Regressed);
        // A bigger win warns to re-pin, never fails.
        let better = Json::parse(r#"{"speedup_n4_vs_n1": 3.5}"#).unwrap();
        let r = check_cluster(&base, &better, 0.25).unwrap();
        assert!(!r.failed());
        assert!(r.improved());
        // Missing headline fails a pinned gate; bootstrap never fails.
        let none = Json::parse(r#"{"points": []}"#).unwrap();
        assert!(check_cluster(&base, &none, 0.25).unwrap().failed());
        let bootstrap = Json::parse(r#"{"pinned": false, "speedup_n4_vs_n1": 1.0}"#).unwrap();
        let r = check_cluster(&bootstrap, &bad, 0.25).unwrap();
        assert!(r.unpinned);
        assert!(!r.failed());
        // Malformed baseline is an error, not a panic.
        assert!(check_cluster(&none, &good, 0.25).is_err());
    }

    #[test]
    fn load_artifact_distinguishes_not_run_from_parse_failure() {
        // No file: the distinct "bench did not run" error.
        let err = load_artifact("/nonexistent/BENCH_cluster_scaling.json").unwrap_err();
        assert!(err.contains("bench did not run"), "{err}");
        assert!(err.contains("/nonexistent/BENCH_cluster_scaling.json"), "{err}");
        // Present but not JSON: a parse error naming the path.
        let dir = std::env::temp_dir();
        let bad = dir.join("arcas_load_artifact_bad.json");
        std::fs::write(&bad, "not json").unwrap();
        let err = load_artifact(bad.to_str().unwrap()).unwrap_err();
        assert!(err.contains("not valid JSON"), "{err}");
        assert!(!err.contains("bench did not run"), "{err}");
        // Valid artifact loads.
        let ok = dir.join("arcas_load_artifact_ok.json");
        std::fs::write(&ok, r#"{"speedup_n4_vs_n1": 2.0}"#).unwrap();
        let v = load_artifact(ok.to_str().unwrap()).unwrap();
        assert_eq!(v.num("speedup_n4_vs_n1"), Some(2.0));
        std::fs::remove_file(&bad).ok();
        std::fs::remove_file(&ok).ok();
    }

    #[test]
    fn metric_key_selects_the_gated_field_per_entry() {
        // One file gates the overall p99 (implicit metric) and a
        // per-class p99 + shed rate (explicit metrics) side by side.
        let mk = |crit_p99: f64, shed: f64| {
            Json::parse(&format!(
                r#"{{"pinned": true, "series": [
                    {{"policy": "arcas", "backend": "sim", "p99_ns": 1000, "tol": 0.10}},
                    {{"policy": "arcas", "backend": "sim", "metric": "critical_p99_ns",
                      "critical_p99_ns": {crit_p99}, "tol": 0.10}},
                    {{"policy": "arcas", "backend": "sim", "metric": "shed_rate",
                      "shed_rate": {shed}, "tol": 0.10}}
                ]}}"#
            ))
            .unwrap()
        };
        let base = mk(500.0, 0.20);
        let r = check_serving(&base, &mk(510.0, 0.21), 0.25).unwrap();
        assert!(!r.failed());
        assert_eq!(r.checks.len(), 3);
        assert!(r.checks[1].label.contains("critical_p99_ns"), "{}", r.checks[1].label);
        // The critical-class tail regressing fails the gate even though
        // the overall p99 entry is unchanged.
        let r = check_serving(&base, &mk(900.0, 0.20), 0.25).unwrap();
        assert!(r.failed());
        assert_eq!(r.checks[1].verdict, Verdict::Regressed);
        assert_eq!(r.checks[0].verdict, Verdict::Ok);
        // A baseline entry whose metric is absent from the current file
        // is Missing, not silently matched to another entry.
        let no_shed = Json::parse(
            r#"{"series": [
                {"policy": "arcas", "backend": "sim", "p99_ns": 1000},
                {"policy": "arcas", "backend": "sim", "metric": "critical_p99_ns",
                 "critical_p99_ns": 500}
            ]}"#,
        )
        .unwrap();
        let r = check_serving(&base, &no_shed, 0.25).unwrap();
        assert_eq!(r.checks[2].verdict, Verdict::Missing);
    }

    #[test]
    fn malformed_inputs_are_errors_not_panics() {
        let ok = serving_json(1.0, 2.0, true);
        let no_series = Json::parse("{}").unwrap();
        assert!(check_serving(&no_series, &ok, 0.25).is_err());
        assert!(check_serving(&ok, &no_series, 0.25).is_err());
        assert!(check_scaling(&no_series, &ok, 0.3).is_err());
        assert!(check_overhead(&no_series, &ok, 0.4).is_err());
        let empty = Json::parse(r#"{"series": []}"#).unwrap();
        assert!(check_serving(&empty, &ok, 0.25).is_err());
        let empty_pts = Json::parse(r#"{"points": []}"#).unwrap();
        assert!(check_overhead(&empty_pts, &empty_pts, 0.4).is_err());
    }

    fn overhead_json(sps_b1: f64, sps_b16: f64, speedup: f64, pinned: bool) -> Json {
        Json::parse(&format!(
            r#"{{
                "bench": "sched_overhead",
                "pinned": {pinned},
                "tol": 0.40,
                "points": [
                    {{"backend": "host", "batch_steps": 1, "steps_per_sec": {sps_b1}, "tol": 0.50}},
                    {{"backend": "host", "batch_steps": 16, "steps_per_sec": {sps_b16}, "tol": 0.50}}
                ],
                "speedup_batched_vs_1": {speedup}
            }}"#
        ))
        .unwrap()
    }

    #[test]
    fn overhead_gate_matches_points_by_backend_and_batch() {
        let base = overhead_json(1e6, 4e6, 4.0, true);
        // Within the bands: passes.
        let r = check_overhead(&base, &overhead_json(0.9e6, 3.8e6, 4.2, true), 0.4).unwrap();
        assert!(!r.failed());
        assert_eq!(r.checks.len(), 3); // 2 points + the speedup headline
        // steps/sec is higher-is-better: a batched-point collapse fails.
        let r = check_overhead(&base, &overhead_json(1e6, 1.1e6, 1.1, true), 0.4).unwrap();
        assert!(r.failed());
        assert_eq!(r.checks[1].verdict, Verdict::Regressed);
        assert_eq!(r.checks[2].verdict, Verdict::Regressed);
        assert!(r.checks[2].label.contains("speedup_batched_vs_1"));
        // Faster than baseline: warn-to-repin, never fail.
        let r = check_overhead(&base, &overhead_json(1e6, 9e6, 9.0, true), 0.4).unwrap();
        assert!(!r.failed());
        assert!(r.improved());
        // A baseline point absent from the current file is Missing.
        let one_point = Json::parse(
            r#"{"points": [{"backend": "host", "batch_steps": 1, "steps_per_sec": 1e6}],
                "speedup_batched_vs_1": 4.0}"#,
        )
        .unwrap();
        let r = check_overhead(&base, &one_point, 0.4).unwrap();
        assert!(r.failed());
        assert_eq!(r.checks[1].verdict, Verdict::Missing);
    }

    #[test]
    fn overhead_gate_respects_bootstrap_and_config_guard() {
        // Unpinned bootstrap placeholder: reported, never failed.
        let base = overhead_json(1.0, 1.0, 1.0, false);
        let r = check_overhead(&base, &overhead_json(1e6, 4e6, 4.0, true), 0.4).unwrap();
        assert!(r.unpinned);
        assert!(!r.failed());
        // Config drift is an error, not a comparison.
        let with_cfg = |steps: u64| {
            Json::parse(&format!(
                r#"{{"config": {{"steps_per_rank": {steps}}},
                     "points": [{{"backend": "host", "batch_steps": 1, "steps_per_sec": 1e6}}]}}"#
            ))
            .unwrap()
        };
        let err = check_overhead(&with_cfg(10_000), &with_cfg(2_000), 0.4).unwrap_err();
        assert!(err.contains("config drift"), "{err}");
    }

    #[test]
    fn throughput_entries_flip_direction_with_higher_is_better() {
        let mk = |rps: f64| {
            Json::parse(&format!(
                r#"{{"pinned": true, "series": [
                    {{"policy": "arcas", "backend": "sim", "metric": "rps_at_p99",
                      "rps_at_p99": {rps}, "higher_is_better": true, "tol": 0.10}}
                ]}}"#
            ))
            .unwrap()
        };
        let base = mk(8_000_000.0);
        // Higher throughput is an improvement, not a regression.
        let r = check_serving(&base, &mk(16_000_000.0), 0.25).unwrap();
        assert!(!r.failed());
        assert!(r.improved());
        // Lower throughput fails.
        let r = check_serving(&base, &mk(4_000_000.0), 0.25).unwrap();
        assert!(r.failed());
        assert_eq!(r.checks[0].verdict, Verdict::Regressed);
    }

    #[test]
    fn pin_payload_validates_and_forces_pinned() {
        let base = r#"{"bench": "sched_overhead", "pinned": false, "note": "bootstrap"}"#;
        let cur = r#"{"bench": "sched_overhead", "pinned": true, "points": []}"#;
        assert_eq!(pin_payload(base, cur).unwrap(), cur);
        // An artifact minted with "pinned": false gets the flag forced.
        let cur_unpinned = r#"{"bench": "sched_overhead", "pinned": false, "points": []}"#;
        let pinned = pin_payload(base, cur_unpinned).unwrap();
        assert!(pinned.contains(r#""pinned": true"#), "{pinned}");
        // Wrong artifact for this baseline: an error, not a silent pin.
        let wrong = r#"{"bench": "host_scaling", "pinned": true}"#;
        let err = pin_payload(base, wrong).unwrap_err();
        assert!(err.contains("mismatch"), "{err}");
        // Garbage never overwrites a baseline.
        assert!(pin_payload(base, "not json").is_err());
        assert!(pin_payload("not json", cur).is_err());
    }
}
