//! Minimal leveled logger (the `log` facade is not wired to anything in
//! the offline crate set, so we keep our own).
//!
//! Level comes from `ARCAS_LOG` (error|warn|info|debug|trace), default
//! `info`. Output goes to stderr so bench CSV on stdout stays clean.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

fn init_from_env() -> u8 {
    let lvl = match std::env::var("ARCAS_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

#[inline]
pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 255 {
        cur = init_from_env();
    }
    (level as u8) <= cur
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($($arg:tt)*) => {
        $crate::util::logger::log($crate::util::logger::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_are_ordered() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default-ish for other tests
    }
}
