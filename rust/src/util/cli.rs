//! A small declarative command-line argument parser.
//!
//! `clap` is not in the offline crate set, so the `arcas` binary, the
//! examples and every bench use this parser instead. Supports
//! `--flag`, `--key value`, `--key=value`, positional arguments, defaults
//! and auto-generated `--help`.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Clone, Debug)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument parser.
#[derive(Clone, Debug)]
pub struct Cli {
    program: String,
    about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            positionals: Vec::new(),
        }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare `--name <value>` with no default (optional).
    pub fn opt_nodefault(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Declare a positional argument (documentation only; all positionals
    /// are collected in order).
    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.program, self.about);
        let _ = writeln!(s, "\nUSAGE:\n  {} [OPTIONS] {}", self.program,
            self.positionals.iter().map(|(n, _)| format!("<{}>", n)).collect::<Vec<_>>().join(" "));
        if !self.positionals.is_empty() {
            let _ = writeln!(s, "\nARGS:");
            for (n, h) in &self.positionals {
                let _ = writeln!(s, "  <{:<14}> {}", n, h);
            }
        }
        let _ = writeln!(s, "\nOPTIONS:");
        for o in &self.opts {
            let head = if o.is_flag {
                format!("--{}", o.name)
            } else {
                format!("--{} <v>", o.name)
            };
            let def = match &o.default {
                Some(d) => format!(" [default: {}]", d),
                None => String::new(),
            };
            let _ = writeln!(s, "  {:<22} {}{}", head, o.help, def);
        }
        let _ = writeln!(s, "  {:<22} {}", "--help", "print this help");
        s
    }

    /// Parse from an iterator of argument strings (no program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(&self, args: I) -> Result<Args, String> {
        let mut out = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                out.values.insert(o.name.clone(), d.clone());
            }
            if o.is_flag {
                out.flags.insert(o.name.clone(), false);
            }
        }
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(self.usage());
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?;
                if spec.is_flag {
                    if inline.is_some() {
                        return Err(format!("flag --{name} takes no value"));
                    }
                    out.flags.insert(name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("option --{name} requires a value"))?,
                    };
                    out.values.insert(name, v);
                }
            } else {
                out.positionals.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse `std::env::args()`, printing help/errors and exiting on failure.
    pub fn parse(&self) -> Args {
        match self.parse_from(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> String {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("missing option --{name}"))
            .clone()
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.parse_num(name)
    }

    pub fn usize(&self, name: &str) -> usize {
        self.parse_num(name)
    }

    pub fn f64(&self, name: &str) -> f64 {
        let v = self.str(name);
        v.parse()
            .unwrap_or_else(|_| panic!("option --{name}={v} is not a number"))
    }

    pub fn flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    /// Parse comma-separated u64 list, e.g. `--cores 1,2,4,8`.
    pub fn u64_list(&self, name: &str) -> Vec<u64> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .unwrap_or_else(|_| panic!("bad list item {s} in --{name}"))
            })
            .collect()
    }

    fn parse_num<T: std::str::FromStr>(&self, name: &str) -> T {
        let v = self.str(name);
        // Allow suffixes k/m/g on integer-ish options.
        let (body, mult) = match v.to_ascii_lowercase().chars().last() {
            Some('k') => (&v[..v.len() - 1], 1024u64),
            Some('m') => (&v[..v.len() - 1], 1024 * 1024),
            Some('g') => (&v[..v.len() - 1], 1024 * 1024 * 1024),
            _ => (v.as_str(), 1),
        };
        if mult > 1 {
            if let Ok(base) = body.parse::<u64>() {
                if let Ok(t) = (base * mult).to_string().parse::<T>() {
                    return t;
                }
            }
        }
        v.parse()
            .unwrap_or_else(|_| panic!("option --{name}={v} is not a valid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("t", "test")
            .opt("cores", "8", "core count")
            .opt("name", "bfs", "algorithm")
            .flag("verbose", "verbosity")
            .opt_nodefault("out", "output file")
    }

    fn parse(args: &[&str]) -> Args {
        cli()
            .parse_from(args.iter().map(|s| s.to_string()))
            .unwrap()
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.u64("cores"), 8);
        assert_eq!(a.str("name"), "bfs");
        assert!(!a.flag("verbose"));
        assert!(a.get("out").is_none());
    }

    #[test]
    fn values_and_flags() {
        let a = parse(&["--cores", "64", "--verbose", "--name=pr", "pos1"]);
        assert_eq!(a.u64("cores"), 64);
        assert_eq!(a.str("name"), "pr");
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals(), &["pos1".to_string()]);
    }

    #[test]
    fn size_suffixes() {
        let a = parse(&["--cores", "4k"]);
        assert_eq!(a.u64("cores"), 4096);
    }

    #[test]
    fn list_parsing() {
        let a = cli()
            .opt("list", "1,2,4", "list")
            .parse_from(["--list".to_string(), "8, 16,32".to_string()])
            .unwrap();
        assert_eq!(a.u64_list("list"), vec![8, 16, 32]);
    }

    #[test]
    fn unknown_option_is_error() {
        assert!(cli()
            .parse_from(["--nope".to_string()])
            .is_err());
    }

    #[test]
    fn help_is_error_with_usage() {
        let err = cli().parse_from(["--help".to_string()]).unwrap_err();
        assert!(err.contains("USAGE"));
        assert!(err.contains("--cores"));
    }
}
