//! Summary statistics, percentiles and CDFs.
//!
//! Used by the profiler (per-window counter summaries), the bench harness
//! (timing distributions) and the figure reproductions (e.g. Fig. 3's
//! core-to-core latency CDF).

/// Streaming summary over f64 samples (Welford mean/variance + min/max).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample set (nearest-rank on a sorted copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty(), "percentile of empty sample set");
    assert!((0.0..=100.0).contains(&p));
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
    xs[rank]
}

/// An empirical CDF: sorted values with cumulative fractions.
#[derive(Clone, Debug)]
pub struct Cdf {
    /// Sorted sample values.
    pub values: Vec<f64>,
}

impl Cdf {
    pub fn from_samples(samples: &[f64]) -> Self {
        let mut values = samples.to_vec();
        values.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self { values }
    }

    /// Fraction of samples `<= x`.
    pub fn at(&self, x: f64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        let idx = self.values.partition_point(|&v| v <= x);
        idx as f64 / self.values.len() as f64
    }

    /// Inverse CDF (quantile).
    pub fn quantile(&self, q: f64) -> f64 {
        assert!(!self.values.is_empty());
        let rank = ((q.clamp(0.0, 1.0)) * (self.values.len() - 1) as f64).round() as usize;
        self.values[rank]
    }

    /// Downsample to `n` (x, fraction) points for plotting / printing.
    pub fn points(&self, n: usize) -> Vec<(f64, f64)> {
        if self.values.is_empty() {
            return vec![];
        }
        let len = self.values.len();
        (0..n)
            .map(|i| {
                let idx = (i * (len - 1)) / (n - 1).max(1);
                (self.values[idx], (idx + 1) as f64 / len as f64)
            })
            .collect()
    }
}

/// Geometric mean of positive values (used for Fig. 1 speedup summary).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Linear histogram with fixed-width buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub buckets: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Self {
            lo,
            hi,
            buckets: vec![0; nbuckets],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            let w = (self.hi - self.lo) / n as f64;
            let i = (((x - self.lo) / w) as usize).min(n - 1);
            self.buckets[i] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.variance() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn summary_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Summary::new();
        for &x in &xs {
            all.add(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.add(x)
            } else {
                b.add(x)
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        let p50 = percentile(&xs, 50.0);
        assert!((p50 - 50.0).abs() <= 1.0);
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let xs = [25.0, 25.0, 85.0, 90.0, 150.0, 160.0, 220.0];
        let cdf = Cdf::from_samples(&xs);
        assert_eq!(cdf.at(10.0), 0.0);
        assert!((cdf.at(25.0) - 2.0 / 7.0).abs() < 1e-12);
        assert_eq!(cdf.at(1000.0), 1.0);
        let pts = cdf.points(5);
        assert_eq!(pts.len(), 5);
        for w in pts.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(11.0);
        assert_eq!(h.buckets, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }
}
