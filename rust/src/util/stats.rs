//! Summary statistics, percentiles and CDFs.
//!
//! Used by the profiler (per-window counter summaries), the bench harness
//! (timing distributions) and the figure reproductions (e.g. Fig. 3's
//! core-to-core latency CDF).

/// Streaming summary over f64 samples (Welford mean/variance + min/max).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn merge(&mut self, other: &Summary) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample set (nearest-rank on a sorted copy).
pub fn percentile(samples: &[f64], p: f64) -> f64 {
    assert!(!samples.is_empty(), "percentile of empty sample set");
    assert!((0.0..=100.0).contains(&p));
    let mut xs = samples.to_vec();
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
    xs[rank]
}

/// An empirical CDF: sorted values with cumulative fractions.
#[derive(Clone, Debug)]
pub struct Cdf {
    /// Sorted sample values.
    pub values: Vec<f64>,
}

impl Cdf {
    pub fn from_samples(samples: &[f64]) -> Self {
        let mut values = samples.to_vec();
        values.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self { values }
    }

    /// Fraction of samples `<= x`.
    pub fn at(&self, x: f64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        let idx = self.values.partition_point(|&v| v <= x);
        idx as f64 / self.values.len() as f64
    }

    /// Inverse CDF (quantile).
    pub fn quantile(&self, q: f64) -> f64 {
        assert!(!self.values.is_empty());
        let rank = ((q.clamp(0.0, 1.0)) * (self.values.len() - 1) as f64).round() as usize;
        self.values[rank]
    }

    /// Downsample to `n` (x, fraction) points for plotting / printing.
    pub fn points(&self, n: usize) -> Vec<(f64, f64)> {
        if self.values.is_empty() {
            return vec![];
        }
        let len = self.values.len();
        (0..n)
            .map(|i| {
                let idx = (i * (len - 1)) / (n - 1).max(1);
                (self.values[idx], (idx + 1) as f64 / len as f64)
            })
            .collect()
    }
}

/// Geometric mean of positive values (used for Fig. 1 speedup summary).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Linear histogram with fixed-width buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub buckets: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Self {
        assert!(hi > lo && nbuckets > 0);
        Self {
            lo,
            hi,
            buckets: vec![0; nbuckets],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            let w = (self.hi - self.lo) / n as f64;
            let i = (((x - self.lo) / w) as usize).min(n - 1);
            self.buckets[i] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.buckets.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

/// Log-scaled histogram for latency-style `u64` nanosecond values:
/// power-of-two octaves split into 32 sub-buckets each, HdrHistogram
/// style, so quantiles carry a bounded relative error of at most
/// 1/32 ≈ 3.2% while the footprint stays a fixed ~15 KiB regardless of
/// sample count. Exact count/sum/min/max ride alongside, so `mean()` and
/// the extreme quantiles (`p0`, `p100`) are exact.
///
/// This is the aggregation behind per-request latency accounting in the
/// serving subsystem (`engine::dispatch::LatencyRecorder`): millions of
/// request sojourn times fold into one mergeable, allocation-free
/// structure instead of a sample vector.
#[derive(Clone, Debug, PartialEq)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    min: u64,
    max: u64,
}

/// Sub-bucket resolution: 2^5 = 32 sub-buckets per octave.
const LOG_SUB_BITS: u32 = 5;
const LOG_SUB: usize = 1 << LOG_SUB_BITS;

impl Default for LogHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        // Max index is ((63-SUB_BITS)+1)<<SUB_BITS | (SUB-1); +1 sizes it.
        let n_buckets = (64 - LOG_SUB_BITS as usize + 1) * LOG_SUB;
        Self {
            counts: vec![0; n_buckets],
            count: 0,
            sum: 0.0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Bucket index of `v`: identity below 32, then 32 sub-buckets per
    /// power-of-two octave.
    #[inline]
    fn bucket(v: u64) -> usize {
        if v < LOG_SUB as u64 {
            return v as usize;
        }
        let top = 63 - v.leading_zeros(); // MSB position, >= LOG_SUB_BITS
        let shift = top - LOG_SUB_BITS;
        let mantissa = (v >> shift) as usize - LOG_SUB;
        ((shift as usize + 1) << LOG_SUB_BITS) + mantissa
    }

    /// Smallest value mapping to bucket `idx` (quantile representative).
    #[inline]
    fn bucket_lo(idx: usize) -> u64 {
        if idx < LOG_SUB {
            return idx as u64;
        }
        let shift = (idx >> LOG_SUB_BITS) - 1;
        let mantissa = (idx & (LOG_SUB - 1)) + LOG_SUB;
        (mantissa as u64) << shift
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket(v)] += 1;
        self.count += 1;
        self.sum += v as f64;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Exact minimum (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Exact maximum (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Nearest-rank quantile with ≤3.2% relative error (0 when empty;
    /// the extremes are exact because the result is clamped to the
    /// recorded min/max).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * (self.count - 1) as f64).round() as u64;
        if target >= self.count - 1 {
            return self.max;
        }
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if c > 0 && cum > target {
                return Self::bucket_lo(idx).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// The empirical CDF as (bucket lower bound, cumulative fraction)
    /// points over the non-empty buckets — the plotting/JSON form.
    pub fn cdf_points(&self) -> Vec<(u64, f64)> {
        let mut pts = Vec::new();
        if self.count == 0 {
            return pts;
        }
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                cum += c;
                pts.push((
                    Self::bucket_lo(idx).clamp(self.min, self.max),
                    cum as f64 / self.count as f64,
                ));
            }
        }
        pts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert!((s.variance() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn summary_merge_equals_combined() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Summary::new();
        for &x in &xs {
            all.add(x);
        }
        let mut a = Summary::new();
        let mut b = Summary::new();
        for (i, &x) in xs.iter().enumerate() {
            if i % 2 == 0 {
                a.add(x)
            } else {
                b.add(x)
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        let p50 = percentile(&xs, 50.0);
        assert!((p50 - 50.0).abs() <= 1.0);
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let xs = [25.0, 25.0, 85.0, 90.0, 150.0, 160.0, 220.0];
        let cdf = Cdf::from_samples(&xs);
        assert_eq!(cdf.at(10.0), 0.0);
        assert!((cdf.at(25.0) - 2.0 / 7.0).abs() < 1e-12);
        assert_eq!(cdf.at(1000.0), 1.0);
        let pts = cdf.points(5);
        assert_eq!(pts.len(), 5);
        for w in pts.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn geomean_of_speedups() {
        let g = geomean(&[2.0, 8.0]);
        assert!((g - 4.0).abs() < 1e-12);
    }

    #[test]
    fn log_histogram_empty() {
        let h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert!(h.cdf_points().is_empty());
    }

    #[test]
    fn log_histogram_single_sample_is_exact() {
        for v in [0u64, 1, 31, 32, 100, 1_000_000, u64::MAX / 2] {
            let mut h = LogHistogram::new();
            h.record(v);
            for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
                assert_eq!(h.quantile(q), v, "v={v} q={q}");
            }
            assert_eq!(h.min(), v);
            assert_eq!(h.max(), v);
            assert_eq!(h.mean(), v as f64);
            assert_eq!(h.cdf_points(), vec![(v, 1.0)]);
        }
    }

    #[test]
    fn log_histogram_known_uniform_distribution() {
        // 1..=100_000 uniformly: quantiles within the 1/32 error bound.
        let mut h = LogHistogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 100_000);
        for (q, expect) in [(0.5, 50_000.0), (0.95, 95_000.0), (0.99, 99_000.0)] {
            let got = h.quantile(q) as f64;
            let rel = (got - expect).abs() / expect;
            assert!(rel <= 1.0 / 32.0 + 1e-9, "q={q}: got {got}, want ~{expect}");
        }
        assert_eq!(h.quantile(0.0), 1);
        assert_eq!(h.quantile(1.0), 100_000);
        assert!((h.mean() - 50_000.5).abs() < 1e-6);
    }

    #[test]
    fn log_histogram_small_values_are_exact() {
        // Below 32 the buckets are identity: quantiles are exact.
        let mut h = LogHistogram::new();
        for v in 0..32u64 {
            h.record(v);
        }
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(0.5), 16); // round(0.5 * 31) = 16
        assert_eq!(h.quantile(1.0), 31);
    }

    #[test]
    fn log_histogram_merge_equals_combined() {
        let mut all = LogHistogram::new();
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        for i in 0..10_000u64 {
            let v = i * i % 777_777;
            all.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a, all);
    }

    #[test]
    fn log_histogram_cdf_is_monotone() {
        let mut h = LogHistogram::new();
        for i in 0..1000u64 {
            h.record(i * 37 % 9999);
        }
        let pts = h.cdf_points();
        assert!(!pts.is_empty());
        for w in pts.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 < w[1].1);
        }
        assert!((pts.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(11.0);
        assert_eq!(h.buckets, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }
}
