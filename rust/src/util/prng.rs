//! Deterministic pseudo-random number generation.
//!
//! Every stochastic element of the reproduction (Kronecker edges, YCSB key
//! draws, task arrival jitter, StreamCluster points, ...) draws from these
//! generators so that every experiment is bit-reproducible from a seed.
//!
//! `SplitMix64` is used for seeding; `Xoshiro256**` for the main stream
//! (same family the Graph500 reference generator and `rand` use).

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Build a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // Avoid the all-zero state (cannot occur from SplitMix64 in
        // practice, but be safe).
        if s == [0, 0, 0, 0] {
            s[0] = 0x1;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Lemire's nearly-divisionless method.
    #[inline]
    pub fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn gen_index(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box-Muller (one value; the pair's twin is
    /// discarded — simplicity over speed, this is not on a hot path).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda`.
    pub fn gen_exp(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.gen_f64();
        -u.ln() / lambda
    }

    /// Zipfian draw over `[0, n)` with exponent `theta` (YCSB-style, using
    /// the rejection-inversion method of Hörmann).
    pub fn gen_zipf(&mut self, n: u64, theta: f64) -> u64 {
        debug_assert!(n > 0);
        if theta <= 0.0 || n == 1 {
            return self.gen_range(n);
        }
        // Simple inverse-CDF on the harmonic approximation: adequate for
        // workload generation (YCSB uses a similar approximation).
        let zetan = zeta_approx(n, theta);
        let u = self.gen_f64() * zetan;
        let mut sum = 0.0;
        // Head items dominate; scan the head then approximate the tail.
        let head = 64.min(n);
        for i in 0..head {
            sum += 1.0 / ((i + 1) as f64).powf(theta);
            if sum >= u {
                return i;
            }
        }
        // Tail: invert the integral approximation of the zeta partial sum.
        let rem = u - sum;
        let a = 1.0 - theta;
        let x = ((head as f64).powf(a) + rem * a).powf(1.0 / a);
        (x as u64).min(n - 1)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (for per-task streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

fn zeta_approx(n: u64, theta: f64) -> f64 {
    // Exact head + integral tail approximation of sum_{i=1..n} i^-theta.
    let head = 64.min(n);
    let mut z = 0.0;
    for i in 0..head {
        z += 1.0 / ((i + 1) as f64).powf(theta);
    }
    if n > head {
        let a = 1.0 - theta;
        z += ((n as f64).powf(a) - (head as f64).powf(a)) / a;
    }
    z
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn range_is_in_bounds_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.gen_range(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_uniform_mean() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_is_skewed_and_bounded() {
        let mut r = Rng::new(13);
        let n = 10_000u64;
        let mut count0 = 0usize;
        for _ in 0..10_000 {
            let v = r.gen_zipf(n, 0.99);
            assert!(v < n);
            if v == 0 {
                count0 += 1;
            }
        }
        // The hottest key should receive far more than uniform share (~1).
        assert!(count0 > 200, "count0={count0}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut r = Rng::new(19);
        let mut c1 = r.fork();
        let mut c2 = r.fork();
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
