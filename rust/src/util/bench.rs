//! Micro-benchmark timing harness (criterion substitute).
//!
//! `criterion` is not in the offline crate set. This harness provides the
//! part we need: warmup, repeated timed runs, and a robust summary
//! (median + MAD) printed in a stable format. Used by `micro_runtime` and
//! the wall-clock side of the §Perf pass; the paper-figure benches report
//! *virtual* time from the simulator and use this only for harness timing.

use std::time::Instant;

use super::stats::percentile;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub p05_ns: f64,
    pub p95_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns / 1e9)
    }
}

/// Timing harness.
pub struct Bencher {
    warmup_iters: u64,
    samples: u64,
    min_sample_ms: f64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Self {
            warmup_iters: 3,
            samples: 15,
            min_sample_ms: 5.0,
            results: Vec::new(),
        }
    }

    pub fn with_samples(mut self, samples: u64) -> Self {
        self.samples = samples.max(3);
        self
    }

    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            samples: 5,
            min_sample_ms: 1.0,
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly; `f` should perform one unit of work and return a
    /// value (blackboxed to defeat dead-code elimination).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        // Calibrate inner iteration count so each sample >= min_sample_ms.
        let t0 = Instant::now();
        std::hint::black_box(f());
        let single_ns = t0.elapsed().as_nanos().max(1) as f64;
        let inner = ((self.min_sample_ms * 1e6 / single_ns).ceil() as u64).clamp(1, 1_000_000);

        let mut per_iter = Vec::with_capacity(self.samples as usize);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..inner {
                std::hint::black_box(f());
            }
            per_iter.push(t.elapsed().as_nanos() as f64 / inner as f64);
        }
        let median = percentile(&per_iter, 50.0);
        let res = BenchResult {
            name: name.to_string(),
            iters: inner * self.samples,
            median_ns: median,
            p05_ns: percentile(&per_iter, 5.0),
            p95_ns: percentile(&per_iter, 95.0),
            mean_ns: per_iter.iter().sum::<f64>() / per_iter.len() as f64,
        };
        println!(
            "bench {:<40} {:>12.1} ns/iter (p05 {:>10.1}, p95 {:>10.1}, n={})",
            res.name, res.median_ns, res.p05_ns, res.p95_ns, res.iters
        );
        self.results.push(res.clone());
        res
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Measure one closure once, returning (result, elapsed ns).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, u64) {
    let t = Instant::now();
    let r = f();
    (r, t.elapsed().as_nanos() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let mut b = Bencher::quick();
        let r = b.bench("noop-ish", || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.median_ns > 0.0);
        assert!(r.p05_ns <= r.p95_ns * 1.001);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn time_once_measures() {
        let (v, ns) = time_once(|| (0..1000u64).sum::<u64>());
        assert_eq!(v, 499500);
        assert!(ns > 0);
    }
}
