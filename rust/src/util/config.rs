//! INI/TOML-subset configuration parser.
//!
//! Machine topologies and experiment parameters are plain-text config files
//! (`[section]` headers, `key = value` pairs, `#` comments). `serde`/`toml`
//! are not in the offline crate set, so this is hand-rolled. Values are
//! stored as strings and converted on access with typed getters.

use std::collections::BTreeMap;

/// A parsed config: section -> key -> value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

#[derive(Debug)]
pub struct ConfigError {
    pub line: usize,
    pub message: String,
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut cfg = Config::new();
        let mut section = String::from("global");
        for (i, raw) in text.lines().enumerate() {
            let line = raw.trim();
            let line = match line.find('#') {
                Some(pos) => line[..pos].trim(),
                None => line,
            };
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body.strip_suffix(']').ok_or(ConfigError {
                    line: i + 1,
                    message: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim().to_string();
                let mut val = v.trim().to_string();
                // Strip matching quotes.
                if val.len() >= 2
                    && ((val.starts_with('"') && val.ends_with('"'))
                        || (val.starts_with('\'') && val.ends_with('\'')))
                {
                    val = val[1..val.len() - 1].to_string();
                }
                cfg.sections
                    .entry(section.clone())
                    .or_default()
                    .insert(key, val);
            } else {
                return Err(ConfigError {
                    line: i + 1,
                    message: format!("expected `key = value` or `[section]`, got {line:?}"),
                });
            }
        }
        Ok(cfg)
    }

    /// Load from a file path.
    pub fn load(path: &str) -> Result<Self, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("cannot read {path}: {e}"))?;
        Self::parse(&text).map_err(|e| format!("{path}: {e}"))
    }

    pub fn set(&mut self, section: &str, key: &str, value: &str) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value.to_string());
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections
            .get(section)
            .and_then(|s| s.get(key))
            .map(|s| s.as_str())
    }

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        self.get(section, key).unwrap_or(default).to_string()
    }

    pub fn u64_or(&self, section: &str, key: &str, default: u64) -> u64 {
        self.typed_or(section, key, default)
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.typed_or(section, key, default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.typed_or(section, key, default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        match self.get(section, key) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(other) => panic!("config {section}.{key}={other} is not a bool"),
            None => default,
        }
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|s| s.keys().map(|k| k.as_str()).collect())
            .unwrap_or_default()
    }

    fn typed_or<T: std::str::FromStr + Copy>(&self, section: &str, key: &str, default: T) -> T {
        match self.get(section, key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("config {section}.{key}={v}: bad value")),
            None => default,
        }
    }

    /// Serialize back to text (stable ordering; used to dump presets).
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for (sec, kv) in &self.sections {
            out.push_str(&format!("[{}]\n", sec));
            for (k, v) in kv {
                out.push_str(&format!("{} = {}\n", k, v));
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# machine preset
[topology]
sockets = 2
chiplets_per_numa = 8
l3_per_chiplet = 33554432
name = "milan_2s"

[scheduler]
timer_ms = 10
rmt_chip_access_rate = 300
adaptive = true
"#;

    #[test]
    fn parse_sections_and_values() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.u64_or("topology", "sockets", 0), 2);
        assert_eq!(c.str_or("topology", "name", ""), "milan_2s");
        assert_eq!(c.u64_or("scheduler", "rmt_chip_access_rate", 0), 300);
        assert!(c.bool_or("scheduler", "adaptive", false));
    }

    #[test]
    fn defaults_when_missing() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.u64_or("topology", "nope", 7), 7);
        assert_eq!(c.f64_or("nosec", "nokey", 1.5), 1.5);
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let c = Config::parse("# only a comment\n\n[a]\nx = 1 # inline\n").unwrap();
        assert_eq!(c.u64_or("a", "x", 0), 1);
    }

    #[test]
    fn bad_line_is_error() {
        let e = Config::parse("[a]\nthis is not a kv\n").unwrap_err();
        assert_eq!(e.line, 2);
    }

    #[test]
    fn roundtrip() {
        let c = Config::parse(SAMPLE).unwrap();
        let c2 = Config::parse(&c.to_text()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn global_section_for_bare_keys() {
        let c = Config::parse("x = 5\n[s]\ny = 6\n").unwrap();
        assert_eq!(c.u64_or("global", "x", 0), 5);
        assert_eq!(c.u64_or("s", "y", 0), 6);
    }
}
