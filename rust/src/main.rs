//! `arcas` — CLI for the ARCAS runtime reproduction.
//!
//! Subcommands:
//!   topology    — print a machine preset and its latency classes
//!   run         — run one scenario under a policy and print the report
//!   scenarios   — list the scenario registry
//!   artifacts   — list + smoke-test the AOT PJRT artifacts
//!   policies    — list available scheduling policies
//!   bench-check — CI gate: compare BENCH_*.json against a baseline

use arcas::engine::{self, RunConfig};
use arcas::policy;
use arcas::sched::RunReport;
use arcas::topology::Topology;
use arcas::util::table::Table;

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if args.is_empty() {
        "help".to_string()
    } else {
        args.remove(0)
    };
    match cmd.as_str() {
        "topology" => cmd_topology(args),
        "run" => cmd_run(args),
        "scenarios" => cmd_scenarios(),
        "artifacts" => cmd_artifacts(),
        "policies" => cmd_policies(),
        "bench-check" => cmd_bench_check(args),
        _ => {
            println!(
                "arcas — Adaptive Runtime System for Chiplet-Aware Scheduling\n\n\
                 USAGE: arcas <topology|run|scenarios|artifacts|policies|bench-check> [options]\n\n\
                   topology [preset]       print machine layout + latency classes\n\
                   run [options]           run a scenario (see `arcas run --help`)\n\
                   scenarios               list the scenario registry\n\
                   artifacts               list + smoke-test AOT artifacts\n\
                   policies                list scheduling policies\n\
                   bench-check [options]   gate BENCH_*.json vs ci/baselines (see --help)\n\n\
                 Figures/tables of the paper: `cargo bench --bench fig07_graph_scaling` etc."
            );
        }
    }
}

fn cmd_topology(args: Vec<String>) {
    let preset = args.first().map(|s| s.as_str()).unwrap_or("milan_2s");
    let Some(t) = Topology::preset(preset) else {
        eprintln!("unknown preset {preset} (milan_2s|milan_1s|genoa_1s|monolithic_64)");
        std::process::exit(2);
    };
    println!("{}", t.summary());
    let mut tab = Table::new(
        "latency classes (ns)",
        &["class", "latency", "example core pair"],
    );
    let pairs = [
        (0usize, 1usize),
        (0, t.cores_per_chiplet),
        (0, 5 * t.cores_per_chiplet),
        (0, t.cores_per_socket().min(t.num_cores() - 1)),
    ];
    for (a, b) in pairs {
        if a == b || b >= t.num_cores() {
            continue;
        }
        tab.row(vec![
            t.latency_class(a, b).label().to_string(),
            format!("{:.0}", t.core_to_core_ns(a, b)),
            format!("core {a} <-> core {b}"),
        ]);
    }
    println!("{}", tab.render());
}

fn print_report(name: &str, r: &RunReport) {
    println!("== {name} ({} policy) ==", r.policy);
    println!("  makespan          {}", arcas::util::fmt_ns(r.makespan_ns));
    println!("  dispatches        {}", r.dispatches);
    println!("  steals            {}", r.steals);
    println!("  migrations        {}", r.migrations);
    if r.region_moves > 0 {
        println!(
            "  region moves      {} (data re-homed toward its accessors)",
            r.region_moves
        );
    }
    println!("  barrier epochs    {}", r.barrier_epochs);
    println!("  final spread rate {}", r.spread_rate);
    let c = &r.counts;
    println!(
        "  accesses          local {:.0} | near {:.0} | far {:.0} | dram {:.0}",
        c.local, c.near, c.far, c.dram
    );
    println!("  dram bytes        {}", arcas::util::fmt_bytes(r.dram_bytes as u64));
    println!(
        "  avg threads       {:.2} (peak {})",
        r.avg_concurrency, r.peak_concurrency
    );
    println!("  wall clock        {}", arcas::util::fmt_ns(r.wall_ns));
    if r.host_steals > 0 {
        println!("  host steals       {}", r.host_steals);
    }
    if let Some(l) = &r.request_latency {
        println!(
            "  req sojourn       p50 {} | p95 {} | p99 {} | max {} ({} reqs)",
            arcas::util::fmt_ns(l.p50_ns),
            arcas::util::fmt_ns(l.p95_ns),
            arcas::util::fmt_ns(l.p99_ns),
            arcas::util::fmt_ns(l.max_ns),
            l.count,
        );
        println!(
            "  req breakdown     mean queue {} + mean service {}",
            arcas::util::fmt_ns(l.mean_queue_ns.round() as u64),
            arcas::util::fmt_ns(l.mean_service_ns.round() as u64),
        );
    }
    if r.request_shed > 0 {
        println!(
            "  req shed          {} (background past the SLO queue-wait budget)",
            r.request_shed
        );
    }
    if r.machines > 1 {
        println!(
            "  machines          {} shards | {} cross-link hops | {} on the wire",
            r.machines,
            r.cross_link_hops,
            arcas::util::fmt_bytes(r.cross_link_bytes),
        );
        if r.shard_moves > 0 {
            println!(
                "  shard moves       {} (hot key ranges re-homed by the front end)",
                r.shard_moves
            );
        }
        for (i, s) in r.per_shard.iter().enumerate() {
            println!(
                "  shard {i:<11} {} reqs | shed {} | makespan {} | p99 {}",
                s.requests,
                s.shed,
                arcas::util::fmt_ns(s.makespan_ns),
                arcas::util::fmt_ns(s.p99_ns),
            );
        }
    }
    // Per-class tails only matter once the trace actually has tiers;
    // an all-normal run would just repeat the overall line.
    if r.class_latency.iter().any(|(n, _)| *n != "normal") {
        for (class, l) in &r.class_latency {
            println!(
                "  class {class:<11} p50 {} | p99 {} ({} reqs)",
                arcas::util::fmt_ns(l.p50_ns),
                arcas::util::fmt_ns(l.p99_ns),
                l.count,
            );
        }
    }
}

fn cmd_run(args: Vec<String>) {
    // Parsing + validation (unknown backend, --repeat 0, …) live in the
    // library so they are unit-tested; this function only wires and prints.
    let rc = RunConfig::from_args(args).unwrap_or_else(|msg| {
        eprintln!("{msg}");
        std::process::exit(2);
    });
    if rc.deprecated_workload {
        // The old `--workload` CLI took `--scale` as a 2^N vertex
        // exponent; the registry takes a dataset *fraction*. Warn so
        // pre-refactor invocations don't silently build huge graphs.
        eprintln!(
            "warning: --workload is deprecated (use --scenario); note that --scale \
             is now a dataset fraction of the paper's sizes (e.g. 0.02), not a 2^N exponent"
        );
    }
    let topo = Topology::preset(&rc.topology).unwrap_or_else(Topology::milan_2s);
    if policy::by_name(&rc.policy, &topo).is_none() {
        eprintln!("unknown policy {}", rc.policy);
        std::process::exit(2);
    }
    // Rebuilt per repetition: a policy is consumed by each run.
    let adaptive = rc.policy == "arcas" || rc.policy == "adaptive";
    let make_policy = || -> Box<dyn policy::Policy> {
        if adaptive {
            Box::new(
                policy::ArcasPolicy::new(&topo)
                    .with_timer(rc.timer_us * 1000)
                    .with_region_moves(rc.region_moves),
            )
        } else {
            policy::by_name(&rc.policy, &topo).unwrap()
        }
    };

    // One code path for every workload×policy×backend combination:
    // resolve the scenario in the registry, build it, drive it.
    let Some(spec) = engine::by_name(&rc.scenario) else {
        let names: Vec<&str> = engine::registry().iter().map(|s| s.name).collect();
        eprintln!(
            "unknown scenario {} (available: {})",
            rc.scenario,
            names.join(", ")
        );
        std::process::exit(2);
    };
    if let Err(msg) = spec.validate(&rc.params) {
        eprintln!("{msg}");
        eprintln!("{}", engine::scenarios_table());
        std::process::exit(2);
    }
    println!(
        "scenario {} [{}]: {} | {} cores on {} | {} backend",
        spec.name, spec.family, spec.about, rc.cores, topo.name, rc.backend
    );
    let mut run = engine::Run::new(&topo)
        .tasks(rc.cores)
        .backend(rc.backend)
        .batch_steps(rc.batch_steps)
        .verify(rc.verify)
        .repeat(rc.repeat);
    // On the host backend the run-level timer arms the real-elapsed-time
    // adaptation loop; arm it only for adaptive policies so static runs
    // keep the pre-adaptive execution byte for byte. (On sim the policy
    // carries its own virtual-time timer via `with_timer` above.)
    if adaptive && rc.backend == engine::ExecBackend::Host {
        run = run.timer_ns(rc.timer_us * 1000);
    }
    if rc.machines > 1 {
        // Cluster fan-out: the CLI policy becomes the front-end planner
        // (and shard 0's scheduler); other shards get a fresh policy
        // from the same factory. The factory owns its captures — the
        // run builder outlives this scope's borrows.
        let (topo2, name2) = (topo.clone(), rc.policy.clone());
        let (timer, region_moves) = (rc.timer_us * 1000, rc.region_moves);
        let shard_policy = move || -> Box<dyn policy::Policy> {
            if adaptive {
                Box::new(
                    policy::ArcasPolicy::new(&topo2)
                        .with_timer(timer)
                        .with_region_moves(region_moves),
                )
            } else {
                policy::by_name(&name2, &topo2).unwrap()
            }
        };
        let mut scenario = spec.build(&rc.params);
        let run = run
            .policy(shard_policy())
            .cluster(rc.machines)
            .cluster_policy(shard_policy)
            .run(scenario.as_mut());
        print_report(spec.name, &run.report);
        println!(
            "  throughput        {:.3} M {}/s",
            run.throughput() / 1e6,
            run.metrics.unit
        );
        for (key, value) in &run.metrics.extras {
            println!("  {key:<17} {value:.4}");
        }
        if rc.verify {
            println!("  verified          ok (matches the serial reference)");
        }
        return;
    }
    let runs = run.run_repeated(make_policy, || spec.build(&rc.params));
    if rc.repeat > 1 {
        for (i, run) in runs.iter().enumerate() {
            println!(
                "  rep {i}: makespan {} | wall {} | {:.3} M {}/s{}",
                arcas::util::fmt_ns(run.report.makespan_ns),
                arcas::util::fmt_ns(run.report.wall_ns),
                run.throughput() / 1e6,
                run.metrics.unit,
                if i == 0 { " (cold)" } else { " (warm)" },
            );
        }
    }
    let run = runs.last().expect("repeat >= 1");
    print_report(spec.name, &run.report);
    println!(
        "  throughput        {:.3} M {}/s",
        run.throughput() / 1e6,
        run.metrics.unit
    );
    for (key, value) in &run.metrics.extras {
        println!("  {key:<17} {value:.4}");
    }
    if rc.verify {
        println!("  verified          ok (matches the serial reference)");
    }
}

fn cmd_scenarios() {
    println!("{}", engine::scenarios_table());
}

fn cmd_artifacts() {
    let dir = arcas::runtime::PjrtRuntime::default_dir();
    match arcas::runtime::PjrtRuntime::load(&dir) {
        Ok(rt) => {
            println!("platform: {}", rt.platform);
            println!("{} artifacts in {dir}:", rt.len());
            for n in rt.names() {
                println!("  {n}");
            }
        }
        Err(e) => {
            eprintln!("cannot load artifacts from {dir}: {e:#}");
            eprintln!("run `make artifacts` first");
            std::process::exit(1);
        }
    }
}

/// The CI bench-regression gate: compare an emitted `BENCH_*.json`
/// against its checked-in baseline. Exit 0 = within tolerance (or the
/// baseline is an unpinned bootstrap placeholder), exit 1 = regression
/// or missing series, exit 2 = usage/parse error. Improvements beyond
/// tolerance pass with a re-pin nudge.
///
/// `--pin` flips the gate into pinning mode: every baseline under
/// `--baselines-dir` with a freshly emitted counterpart under
/// `--artifacts-dir` is overwritten by that artifact (with
/// `"pinned": true` forced), turning bootstrap placeholders into real
/// gates in one command after a bench run.
fn cmd_bench_check(args: Vec<String>) {
    use arcas::util::baseline::{
        check_adaptive, check_cluster, check_mem_follow, check_overhead, check_scaling,
        check_serving, load_artifact,
    };
    use arcas::util::json::Json;

    // Single source of truth for the kinds this gate understands; the
    // unknown-kind error prints it so CI failures are self-explanatory.
    const KINDS: &str = "serving|scaling|overhead|adaptive|mem-follow|cluster";

    let cli = arcas::util::cli::Cli::new(
        "arcas bench-check",
        "compare a BENCH_*.json against a checked-in baseline with a tolerance band",
    )
    .opt(
        "kind",
        "serving",
        "metric family: serving (latency, lower=better unless the entry says otherwise) | \
         scaling (speedup, higher=better) | overhead (steps/sec, higher=better) | \
         adaptive (speedup vs best static, higher=better) | \
         mem-follow (speedup of region moves vs task-move-only, higher=better) | \
         cluster (rps-at-p99 of 4 shards vs 1 machine, higher=better)",
    )
    .opt_nodefault("baseline", "checked-in baseline json (ci/baselines/...)")
    .opt_nodefault("current", "freshly emitted BENCH_*.json")
    .opt(
        "tolerance",
        "0.25",
        "default relative tolerance for entries without their own \"tol\"",
    )
    .flag(
        "pin",
        "copy fresh BENCH_*.json artifacts over their baselines (forces \"pinned\": true)",
    )
    .opt(
        "baselines-dir",
        "ci/baselines",
        "with --pin: directory of checked-in baselines to overwrite",
    )
    .opt(
        "artifacts-dir",
        "rust",
        "with --pin: directory where the benches emitted fresh BENCH_*.json",
    );
    let a = match cli.parse_from(args) {
        Ok(a) => a,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    if a.flag("pin") {
        cmd_bench_pin(&a.str("baselines-dir"), &a.str("artifacts-dir"));
        return;
    }
    // load_artifact keeps "the bench never ran" (no file) distinct from
    // "the file is broken" — the former is the common CI mistake of
    // gating before the matching bench step.
    let load = |opt: &str| -> Json {
        let Some(path) = a.get(opt) else {
            eprintln!("bench-check: --{opt} is required");
            std::process::exit(2);
        };
        load_artifact(path).unwrap_or_else(|e| {
            eprintln!("bench-check: {e}");
            std::process::exit(2);
        })
    };
    let baseline = load("baseline");
    let current = load("current");
    let tol = a.f64("tolerance");
    let kind = a.str("kind");
    let result = match kind.as_str() {
        "serving" => check_serving(&baseline, &current, tol),
        "scaling" => check_scaling(&baseline, &current, tol),
        "overhead" => check_overhead(&baseline, &current, tol),
        "adaptive" => check_adaptive(&baseline, &current, tol),
        "mem-follow" => check_mem_follow(&baseline, &current, tol),
        "cluster" => check_cluster(&baseline, &current, tol),
        other => {
            eprintln!("bench-check: unknown --kind {other} ({KINDS})");
            std::process::exit(2);
        }
    };
    let result = result.unwrap_or_else(|e| {
        eprintln!("bench-check: {e}");
        std::process::exit(2);
    });
    println!("bench-check ({kind}):");
    print!("{}", result.render());
    if result.failed() {
        eprintln!("bench-check: REGRESSION — current results exceed the baseline tolerance band");
        std::process::exit(1);
    }
    if result.improved() {
        println!(
            "bench-check: improvement beyond tolerance — re-pin the baseline \
             (copy the current json into ci/baselines/ and keep \"pinned\": true)"
        );
    }
    println!("bench-check: OK");
}

/// `bench-check --pin`: for every `BENCH_*.json` baseline, copy its
/// freshly emitted artifact over it (validated: both parse, bench names
/// match, `"pinned"` forced true). Baselines without a fresh artifact
/// are reported and left alone. Exit 1 when nothing could be pinned.
fn cmd_bench_pin(baselines_dir: &str, artifacts_dir: &str) {
    let entries = std::fs::read_dir(baselines_dir).unwrap_or_else(|e| {
        eprintln!("bench-check --pin: cannot read {baselines_dir}: {e}");
        std::process::exit(2);
    });
    let mut pinned = 0usize;
    let mut missing = Vec::new();
    let mut names: Vec<String> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        .collect();
    names.sort();
    for name in &names {
        let base_path = format!("{baselines_dir}/{name}");
        let cur_path = format!("{artifacts_dir}/{name}");
        let Ok(cur_text) = std::fs::read_to_string(&cur_path) else {
            missing.push(cur_path);
            continue;
        };
        let base_text = std::fs::read_to_string(&base_path).unwrap_or_else(|e| {
            eprintln!("bench-check --pin: cannot read {base_path}: {e}");
            std::process::exit(2);
        });
        match arcas::util::baseline::pin_payload(&base_text, &cur_text) {
            Ok(text) => {
                std::fs::write(&base_path, text).unwrap_or_else(|e| {
                    eprintln!("bench-check --pin: cannot write {base_path}: {e}");
                    std::process::exit(2);
                });
                println!("pinned {base_path} <- {cur_path}");
                pinned += 1;
            }
            Err(e) => {
                eprintln!("bench-check --pin: {base_path}: {e}");
                std::process::exit(2);
            }
        }
    }
    for m in &missing {
        println!("no fresh artifact at {m} — baseline left as-is (run the bench first)");
    }
    if pinned == 0 {
        eprintln!(
            "bench-check --pin: nothing pinned ({} baselines, 0 fresh artifacts under {artifacts_dir})",
            names.len()
        );
        std::process::exit(1);
    }
    println!("bench-check --pin: {pinned} baseline(s) pinned");
}

fn cmd_policies() {
    let topo = Topology::milan_2s();
    println!("available policies:");
    for name in [
        "arcas",
        "adaptive",
        "ring",
        "shoal",
        "local",
        "distributed",
        "os_async",
        "slo",
    ] {
        let p = policy::by_name(name, &topo).unwrap();
        println!("  {:<12} {}", name, p.name());
    }
    let _ = arcas::harness::cores_vs_channels();
}
