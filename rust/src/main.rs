//! `arcas` — CLI for the ARCAS runtime reproduction.
//!
//! Subcommands:
//!   topology   — print a machine preset and its latency classes
//!   run        — run one workload under a policy and print the report
//!   artifacts  — list + smoke-test the AOT PJRT artifacts
//!   policies   — list available scheduling policies

use std::sync::Arc;

use arcas::harness;
use arcas::policy;
use arcas::sched::RunReport;
use arcas::topology::Topology;
use arcas::util::cli::Cli;
use arcas::util::table::Table;
use arcas::workloads::{graph, oltp, sgd, streamcluster};

fn main() {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = if args.is_empty() {
        "help".to_string()
    } else {
        args.remove(0)
    };
    match cmd.as_str() {
        "topology" => cmd_topology(args),
        "run" => cmd_run(args),
        "artifacts" => cmd_artifacts(),
        "policies" => cmd_policies(),
        _ => {
            println!(
                "arcas — Adaptive Runtime System for Chiplet-Aware Scheduling\n\n\
                 USAGE: arcas <topology|run|artifacts|policies> [options]\n\n\
                   topology [preset]       print machine layout + latency classes\n\
                   run [options]           run a workload (see `arcas run --help`)\n\
                   artifacts               list + smoke-test AOT artifacts\n\
                   policies                list scheduling policies\n\n\
                 Figures/tables of the paper: `cargo bench --bench fig07_graph_scaling` etc."
            );
        }
    }
}

fn cmd_topology(args: Vec<String>) {
    let preset = args.first().map(|s| s.as_str()).unwrap_or("milan_2s");
    let Some(t) = Topology::preset(preset) else {
        eprintln!("unknown preset {preset} (milan_2s|milan_1s|genoa_1s|monolithic_64)");
        std::process::exit(2);
    };
    println!("{}", t.summary());
    let mut tab = Table::new(
        "latency classes (ns)",
        &["class", "latency", "example core pair"],
    );
    let pairs = [
        (0usize, 1usize),
        (0, t.cores_per_chiplet),
        (0, 5 * t.cores_per_chiplet),
        (0, t.cores_per_socket().min(t.num_cores() - 1)),
    ];
    for (a, b) in pairs {
        if a == b || b >= t.num_cores() {
            continue;
        }
        tab.row(vec![
            t.latency_class(a, b).label().to_string(),
            format!("{:.0}", t.core_to_core_ns(a, b)),
            format!("core {a} <-> core {b}"),
        ]);
    }
    println!("{}", tab.render());
}

fn print_report(name: &str, r: &RunReport) {
    println!("== {name} ({} policy) ==", r.policy);
    println!("  makespan          {}", arcas::util::fmt_ns(r.makespan_ns));
    println!("  dispatches        {}", r.dispatches);
    println!("  steals            {}", r.steals);
    println!("  migrations        {}", r.migrations);
    println!("  barrier epochs    {}", r.barrier_epochs);
    println!("  final spread rate {}", r.spread_rate);
    let c = &r.counts;
    println!(
        "  accesses          local {:.0} | near {:.0} | far {:.0} | dram {:.0}",
        c.local, c.near, c.far, c.dram
    );
    println!("  dram bytes        {}", arcas::util::fmt_bytes(r.dram_bytes as u64));
    println!(
        "  avg threads       {:.2} (peak {})",
        r.avg_concurrency, r.peak_concurrency
    );
}

fn cmd_run(args: Vec<String>) {
    let cli = Cli::new("arcas run", "run one workload under a policy")
        .opt("workload", "bfs", "bfs|pr|cc|sssp|gups|streamcluster|sgd|ycsb|tpcc")
        .opt("policy", "arcas", "arcas|ring|shoal|local|distributed|os_async")
        .opt("cores", "16", "worker count")
        .opt("scale", "12", "graph scale (2^N vertices) or workload scale")
        .opt("topology", "milan_2s", "machine preset")
        .opt("timer-us", "100", "ARCAS controller timer (us)")
        .opt("seed", "42", "PRNG seed");
    let a = cli.parse_from(args).unwrap_or_else(|msg| {
        eprintln!("{msg}");
        std::process::exit(2);
    });
    let topo = Topology::preset(&a.str("topology")).unwrap_or_else(Topology::milan_2s);
    let cores = a.usize("cores");
    let seed = a.u64("seed");
    let mk_policy = || -> Box<dyn policy::Policy> {
        if a.str("policy") == "arcas" {
            Box::new(policy::ArcasPolicy::new(&topo).with_timer(a.u64("timer-us") * 1000))
        } else {
            policy::by_name(&a.str("policy"), &topo).unwrap_or_else(|| {
                eprintln!("unknown policy");
                std::process::exit(2);
            })
        }
    };
    let wl = a.str("workload");
    match wl.as_str() {
        "bfs" | "pr" | "cc" | "sssp" | "gups" => {
            let scale = a.u64("scale") as u32;
            if wl == "gups" {
                let (run, _) =
                    graph::run_gups(&topo, mk_policy(), cores, 1 << scale, 100_000, seed);
                print_report("GUPS", &run.report);
                println!("  GUPS              {:.4} Gup/s", run.teps() / 1e9);
                return;
            }
            let g = Arc::new(graph::kronecker::kronecker(scale, 16, seed));
            println!(
                "graph: 2^{scale} vertices, {} edges ({})",
                g.num_edges(),
                arcas::util::fmt_bytes(g.bytes())
            );
            let src = g.max_degree_vertex();
            let (run, _result_size) = match wl.as_str() {
                "bfs" => {
                    let (r, d) = graph::run_bfs(&topo, mk_policy(), cores, g, src);
                    (r, d.iter().filter(|&&x| x != u32::MAX).count())
                }
                "pr" => {
                    let (r, pr) = graph::run_pagerank(&topo, mk_policy(), cores, g, 10);
                    (r, pr.len())
                }
                "cc" => {
                    let (r, l) = graph::run_cc(&topo, mk_policy(), cores, g);
                    (r, graph::algos::component_count(&l))
                }
                _ => {
                    let (r, d) = graph::run_sssp(&topo, mk_policy(), cores, g, src);
                    (r, d.iter().filter(|&&x| x != u64::MAX).count())
                }
            };
            print_report(&wl, &run.report);
            println!("  TEPS              {:.3} M/s", run.teps() / 1e6);
        }
        "streamcluster" => {
            let cfg = streamcluster::ScConfig::bench(0.05);
            let pts = Arc::new(streamcluster::generate_points(&cfg));
            let res = streamcluster::run_streamcluster(&topo, mk_policy(), cores, &cfg, pts);
            print_report("streamcluster", &res.report);
            println!("  centers           {}", res.n_centers);
            println!("  final cost        {:.1}", res.final_cost);
        }
        "sgd" => {
            let cfg = sgd::SgdConfig::bench(0.05);
            let data = sgd::generate_data(&cfg);
            let run = sgd::run_sgd(
                &topo,
                mk_policy(),
                cores,
                &cfg,
                &data,
                sgd::DwStrategy::PerCore,
                sgd::SgdMode::Grad,
                Arc::new(sgd::RustGrad),
            );
            print_report("sgd", &run.report);
            println!("  throughput        {:.1} GB/s", run.gbps());
            println!("  loss trace        {:?}", run.loss_trace);
        }
        "ycsb" | "tpcc" => {
            let wl_spec = if wl == "ycsb" {
                oltp::OltpWorkload::ycsb_scaled(0.01)
            } else {
                oltp::OltpWorkload::tpcc_scaled(0.2)
            };
            let run = oltp::run_oltp(&topo, mk_policy(), cores, &wl_spec, 20_000, seed);
            print_report(&wl, &run.report);
            println!("  commits/s         {:.0}", run.commits_per_sec());
            println!("  aborts            {}", run.aborts);
        }
        other => {
            eprintln!("unknown workload {other}");
            std::process::exit(2);
        }
    }
}

fn cmd_artifacts() {
    let dir = arcas::runtime::PjrtRuntime::default_dir();
    match arcas::runtime::PjrtRuntime::load(&dir) {
        Ok(rt) => {
            println!("platform: {}", rt.platform);
            println!("{} artifacts in {dir}:", rt.len());
            for n in rt.names() {
                println!("  {n}");
            }
        }
        Err(e) => {
            eprintln!("cannot load artifacts from {dir}: {e:#}");
            eprintln!("run `make artifacts` first");
            std::process::exit(1);
        }
    }
}

fn cmd_policies() {
    let topo = Topology::milan_2s();
    println!("available policies:");
    for name in ["arcas", "ring", "shoal", "local", "distributed", "os_async"] {
        let p = policy::by_name(name, &topo).unwrap();
        println!("  {:<12} {}", name, p.name());
    }
    let _ = harness::cores_vs_channels();
}
