//! # ARCAS — Adaptive Runtime System for Chiplet-Aware Scheduling
//!
//! A from-scratch reproduction of the ARCAS runtime system (Fogli et al.,
//! CS.AR 2025) for chiplet-based CPUs, built as a three-layer
//! rust + JAX + Pallas stack (AOT via xla/PJRT).
//!
//! The crate contains:
//! - the simulated chiplet machine substrate ([`topology`], [`cachesim`],
//!   [`memsim`], [`coordinator`], [`sim`]) standing in for the paper's
//!   dual-socket AMD EPYC Milan 7713 testbed — accounting state is
//!   sharded per chiplet/socket ([`coordinator`]) so host-backend steps
//!   charge concurrently with no whole-machine lock,
//! - the ARCAS runtime proper ([`task`], [`deque`], [`sched`],
//!   [`profiler`], [`controller`], [`policy`], [`mem`], [`api`]),
//! - the unified workload [`engine`]: the [`engine::Scenario`] trait,
//!   the [`engine::Driver`] that owns machine construction and the run
//!   loop, the [`engine::ExecBackend`] seam selecting the deterministic
//!   simulator or the real host-thread pool (`arcas run --backend
//!   sim|host`, with `--repeat N` warm-cache repetitions), and the
//!   name-keyed [`engine::registry`] through which the CLI, harness and
//!   benches enumerate every workload×policy×backend combination,
//! - all baseline systems the paper compares against (RING, Shoal,
//!   DimmWitted native strategies, std::async, static Local/Distributed
//!   cache policies) in [`policy`] and [`workloads`],
//! - every evaluation workload ([`workloads`]): the graph suite,
//!   StreamCluster, DimmWitted-style SGD, a mini OLAP engine (TPC-H-shaped)
//!   and a mini OLTP engine (YCSB / TPC-C-lite),
//! - the PJRT bridge ([`runtime`]) that loads the AOT-compiled JAX/Pallas
//!   artifacts and runs them on the request path, and
//! - the experiment [`harness`] regenerating every figure and table of the
//!   paper's evaluation.
pub mod util;
pub mod topology;
pub mod cachesim;
pub mod memsim;
pub mod coordinator;
pub mod sim;
pub mod task;
pub mod deque;
pub mod sched;
pub mod profiler;
pub mod controller;
pub mod policy;
pub mod mem;
pub mod api;
pub mod engine;
pub mod cluster;
pub mod runtime;
pub mod workloads;
pub mod harness;
