//! Sharded machine accounting — the paper's L3 coordination contribution
//! turned into the runtime's own state layout.
//!
//! The pre-refactor [`crate::sim::Machine`] was a monolith: one
//! `CacheSim` (all chiplets' residency + counters), one `MemSim` (all
//! DDR channels + IF links) and one clock vector. That was fine for the
//! single-threaded simulator, but the host backend had to wrap the whole
//! struct in a `Mutex`, so *entire* coroutine steps — real workload
//! computation included — serialized on one lock and multi-worker runs
//! proved thread-safety, not speedup.
//!
//! This module shards that state the way the hardware shards it:
//!
//! - [`ChipletShard`] — one per CCD. Owns the chiplet's cores' virtual
//!   clocks, its L3 residency tracker ([`crate::cachesim::ChipletL3`]),
//!   its slice of the hierarchical access counters, its LRU recency
//!   stamp, and its Infinity-Fabric link tracker
//!   ([`crate::memsim::BwTracker`]).
//! - [`SocketShard`] — one per socket. Owns the socket's DDR-channel
//!   tracker (memory channels are a socket-level resource, §2.2).
//! - [`Shards`] — the collection plus the locking discipline.
//!
//! ## Locking discipline
//!
//! Every lock in this module is leaf-level: a caller holds **at most one
//! shard lock at a time**, never nested, so cross-shard deadlock is
//! impossible by construction. Classification
//! ([`crate::cachesim::classify`]) probes residency lazily, one shard at
//! a time (a chiplet's resident byte count is a single `u64` read under
//! its lock, and remote probes are skipped entirely for regions fully
//! resident locally); only the *issuing* chiplet's
//! shard is re-locked for the residency fill + counter record. Virtual
//! clocks are relaxed atomics, not locked at all: a core's clock is only
//! ever advanced by the worker currently running that core's step (the
//! simulator is single-threaded; the host backend charges
//! `current_worker()`'s own core, and barrier releases run while every
//! rank is parked).
//!
//! The result: steps on different chiplets touch disjoint locks except
//! where the *hardware* would contend too — sibling/remote L3 probes,
//! shared DDR channels, coherence invalidations. Cross-chiplet traffic
//! is the only contention, which is exactly the behaviour the paper's
//! chiplet-local accounting argument predicts.
//!
//! ## Determinism contract
//!
//! Driven single-threaded (the Sim backend), the sharded arrangement is
//! byte-for-byte identical to the old monolith: same float summation
//! order (chiplet 0..n), same LRU decisions (the recency stamp only needs
//! to be monotone per chiplet, so per-shard stamps preserve every
//! eviction choice), same bandwidth-window evolution (each tracker sees
//! the same charge sequence it saw as a `Vec` entry). The
//! `rust/tests/shard_equivalence.rs` property suite pins this against a
//! monolithic oracle rebuilt from the same primitives.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::cachesim::{ChipletL3, ClassCounts, Counters, Outcome};
use crate::mem::RegionId;
use crate::memsim::{BwTracker, BW_WINDOW_NS};
use crate::topology::Topology;

/// The lock-protected accounting state of one chiplet.
#[derive(Clone, Debug)]
struct ChipletAcct {
    /// This chiplet's L3 residency (segment-LRU over regions).
    l3: ChipletL3,
    /// This chiplet's slice of the hierarchical access counters.
    counts: ClassCounts,
    /// LRU recency stamp, monotone per chiplet (see module docs).
    stamp: u64,
    /// Per-CCD Infinity-Fabric link to the IO die.
    if_link: BwTracker,
    /// Per-region access heat (classified ops issued from this chiplet
    /// since the last reset) — the raw signal behind the profiler's
    /// windowed region-heat deltas and the policy's online region moves.
    heat: HashMap<RegionId, f64>,
}

/// One chiplet's shard: clocks outside the lock, accounting inside.
#[derive(Debug)]
pub struct ChipletShard {
    /// Virtual clocks of this chiplet's cores (relaxed atomics; see the
    /// module docs for why plain stores/loads are race-free here).
    clocks: Vec<AtomicU64>,
    acct: Mutex<ChipletAcct>,
}

/// One socket's shard: the DDR-channel bandwidth tracker.
#[derive(Debug)]
pub struct SocketShard {
    ddr: Mutex<BwTracker>,
}

/// All shards of one machine, plus the core→shard mapping.
#[derive(Debug)]
pub struct Shards {
    chiplets: Vec<ChipletShard>,
    sockets: Vec<SocketShard>,
    cores_per_chiplet: usize,
}

impl Shards {
    pub fn new(topo: &Topology) -> Self {
        let chiplets = (0..topo.num_chiplets())
            .map(|_| ChipletShard {
                clocks: (0..topo.cores_per_chiplet)
                    .map(|_| AtomicU64::new(0))
                    .collect(),
                acct: Mutex::new(ChipletAcct {
                    l3: ChipletL3::new(topo.l3_per_chiplet),
                    counts: ClassCounts::default(),
                    stamp: 0,
                    if_link: BwTracker::new(topo.if_bw_per_chiplet, BW_WINDOW_NS),
                    heat: HashMap::new(),
                }),
            })
            .collect();
        let sockets = (0..topo.sockets)
            .map(|_| SocketShard {
                ddr: Mutex::new(BwTracker::new(topo.mem_bw_per_socket(), BW_WINDOW_NS)),
            })
            .collect();
        Self {
            chiplets,
            sockets,
            cores_per_chiplet: topo.cores_per_chiplet,
        }
    }

    pub fn num_chiplets(&self) -> usize {
        self.chiplets.len()
    }

    pub fn num_cores(&self) -> usize {
        self.chiplets.len() * self.cores_per_chiplet
    }

    #[inline]
    fn clock(&self, core: usize) -> &AtomicU64 {
        &self.chiplets[core / self.cores_per_chiplet].clocks[core % self.cores_per_chiplet]
    }

    // --- clocks (lock-free) ----------------------------------------------

    #[inline]
    pub fn now(&self, core: usize) -> u64 {
        self.clock(core).load(Ordering::Relaxed)
    }

    #[inline]
    pub fn advance(&self, core: usize, ns: u64) {
        self.clock(core).fetch_add(ns, Ordering::Relaxed);
    }

    /// Move `core`'s clock forward to at least `t` (never rewinds).
    #[inline]
    pub fn advance_to(&self, core: usize, t: u64) {
        self.clock(core).fetch_max(t, Ordering::Relaxed);
    }

    /// Latest clock across all cores (= makespan when a run finishes).
    pub fn max_time(&self) -> u64 {
        self.chiplets
            .iter()
            .flat_map(|sh| sh.clocks.iter())
            .map(|c| c.load(Ordering::Relaxed))
            .max()
            .unwrap_or(0)
    }

    // --- residency + counters (chiplet shard lock) -----------------------

    /// Resident bytes of `region` in `chiplet`'s L3 — one brief shard
    /// lock per call; `classify`'s residency queries route through this,
    /// one chiplet at a time (never nested).
    pub fn resident(&self, chiplet: usize, region: RegionId) -> u64 {
        self.chiplets[chiplet].acct.lock().unwrap().l3.resident(region)
    }

    /// Apply the local-chiplet side of one classified access: bump the
    /// shard's recency stamp, fill `fill_bytes` of `region` into its L3
    /// and record the outcome in its counter slice — one lock, one visit.
    pub fn fill_and_record(
        &self,
        chiplet: usize,
        region: RegionId,
        fill_bytes: u64,
        region_size: u64,
        out: &Outcome,
    ) {
        let mut acct = self.chiplets[chiplet].acct.lock().unwrap();
        acct.stamp += 1;
        let stamp = acct.stamp;
        acct.l3.fill(region, fill_bytes, stamp, region_size);
        acct.counts.add(out);
        *acct.heat.entry(region).or_insert(0.0) += out.total_ops();
    }

    /// Coherence: drop `frac` of `region`'s residency in `chiplet`.
    pub fn invalidate(&self, chiplet: usize, region: RegionId, frac: f64) {
        self.chiplets[chiplet]
            .acct
            .lock()
            .unwrap()
            .l3
            .invalidate_frac(region, frac);
    }

    /// Drop a freed (or just-moved) region everywhere: residency *and*
    /// accumulated heat, so a region move starts a cold heat window at
    /// its new home instead of instantly re-triggering on stale counts.
    pub fn drop_region(&self, region: RegionId) {
        for sh in &self.chiplets {
            let mut acct = sh.acct.lock().unwrap();
            acct.l3.invalidate_frac(region, 1.0);
            acct.heat.remove(&region);
        }
    }

    /// Per-region, per-chiplet access heat: cumulative classified ops
    /// issued from each chiplet, sorted by region id with one slot per
    /// chiplet in chiplet order — a deterministic snapshot the profiler
    /// turns into windowed deltas.
    pub fn region_heat(&self) -> Vec<(RegionId, Vec<f64>)> {
        let n = self.chiplets.len();
        let mut by_region: BTreeMap<RegionId, Vec<f64>> = BTreeMap::new();
        for (ch, sh) in self.chiplets.iter().enumerate() {
            let acct = sh.acct.lock().unwrap();
            for (&region, &ops) in &acct.heat {
                by_region.entry(region).or_insert_with(|| vec![0.0; n])[ch] += ops;
            }
        }
        by_region.into_iter().collect()
    }

    // --- bandwidth (socket / chiplet shard lock) --------------------------

    /// Charge `bytes` against `socket`'s DDR channels at `now_ns`.
    pub fn charge_ddr(&self, socket: usize, now_ns: f64, bytes: f64) -> f64 {
        self.sockets[socket].ddr.lock().unwrap().charge(now_ns, bytes)
    }

    /// Charge `bytes` against `chiplet`'s IF link at `now_ns`.
    pub fn charge_if_link(&self, chiplet: usize, now_ns: f64, bytes: f64) -> f64 {
        self.chiplets[chiplet]
            .acct
            .lock()
            .unwrap()
            .if_link
            .charge(now_ns, bytes)
    }

    /// Total DRAM bytes ever served by `socket`.
    pub fn dram_bytes_of_socket(&self, socket: usize) -> f64 {
        self.sockets[socket].ddr.lock().unwrap().total_bytes()
    }

    /// Total DRAM bytes across sockets (summed in socket order, matching
    /// the pre-refactor report arithmetic).
    pub fn dram_total_bytes(&self) -> f64 {
        (0..self.sockets.len())
            .map(|s| self.dram_bytes_of_socket(s))
            .sum()
    }

    // --- aggregation ------------------------------------------------------

    /// Machine-wide class totals, merged in chiplet order (same float
    /// summation order as the old machine-global `Counters::total`).
    pub fn class_totals(&self) -> ClassCounts {
        let mut t = ClassCounts::default();
        for sh in &self.chiplets {
            t.merge(&sh.acct.lock().unwrap().counts);
        }
        t
    }

    /// Per-chiplet counter snapshot (Tab. 1/2-style reporting).
    pub fn counters(&self) -> Counters {
        Counters::from_parts(
            self.chiplets
                .iter()
                .map(|sh| sh.acct.lock().unwrap().counts)
                .collect(),
        )
    }

    // --- lifecycle --------------------------------------------------------

    /// Reset clocks and dynamic state between experiment repetitions
    /// (caches cold, counters and bandwidth windows zeroed).
    pub fn reset_dynamic(&self) {
        for sh in &self.chiplets {
            for c in &sh.clocks {
                c.store(0, Ordering::Relaxed);
            }
            let mut acct = sh.acct.lock().unwrap();
            acct.l3.flush();
            acct.counts = ClassCounts::default();
            acct.if_link.reset();
            acct.heat.clear();
        }
        for s in &self.sockets {
            s.ddr.lock().unwrap().reset();
        }
    }
}

impl Clone for Shards {
    fn clone(&self) -> Self {
        Self {
            chiplets: self
                .chiplets
                .iter()
                .map(|sh| ChipletShard {
                    clocks: sh
                        .clocks
                        .iter()
                        .map(|c| AtomicU64::new(c.load(Ordering::Relaxed)))
                        .collect(),
                    acct: Mutex::new(sh.acct.lock().unwrap().clone()),
                })
                .collect(),
            sockets: self
                .sockets
                .iter()
                .map(|s| SocketShard {
                    ddr: Mutex::new(s.ddr.lock().unwrap().clone()),
                })
                .collect(),
            cores_per_chiplet: self.cores_per_chiplet,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::Access;
    use crate::mem::Placement;
    use crate::sim::Machine;

    // The monolithic `CacheSim` test suite, ported to the sharded
    // arrangement driven through `Machine` (single-threaded here, so the
    // expected splits are unchanged; see rust/tests/shard_equivalence.rs
    // for the oracle-backed equivalence property).

    fn machine() -> Machine {
        Machine::new(Topology::milan_2s())
    }

    #[test]
    fn cold_access_goes_to_dram() {
        let m = machine();
        let r = m.alloc("d", 16 << 20, Placement::Bind(0));
        let out = m.access(0, Access::seq_read(r, 16 << 20));
        assert!(out.dram_lines > 0.9 * out.total_ops());
        assert!(out.local_hits < 0.1 * out.total_ops());
    }

    #[test]
    fn warm_access_hits_local_l3() {
        let m = machine();
        let r = m.alloc("d", 16 << 20, Placement::Bind(0));
        m.access(0, Access::seq_read(r, 16 << 20)); // warm
        let out = m.access(0, Access::seq_read(r, 16 << 20));
        assert!(
            out.local_hits > 0.95 * out.total_ops(),
            "local={} total={}",
            out.local_hits,
            out.total_ops()
        );
    }

    #[test]
    fn sibling_chiplet_hit_counts_as_near() {
        let m = machine();
        let r = m.alloc("d", 16 << 20, Placement::Bind(0));
        m.access(0, Access::seq_read(r, 16 << 20)); // chiplet 0 warm
        // Core 8 is chiplet 1 (same NUMA): should mostly hit chiplet 0's L3.
        let out = m.access(8, Access::rand_read(r, 1000, 16 << 20));
        assert!(out.near_hits > 0.8 * out.total_ops(), "near={:?}", out);
    }

    #[test]
    fn cross_socket_hit_counts_as_far() {
        let m = machine();
        let r = m.alloc("d", 16 << 20, Placement::Bind(0));
        m.access(0, Access::seq_read(r, 16 << 20));
        // Core 64 is on socket 1.
        let out = m.access(64, Access::rand_read(r, 1000, 16 << 20));
        assert!(out.far_hits > 0.8 * out.total_ops(), "far={:?}", out);
    }

    #[test]
    fn oversized_region_misses() {
        let m = machine();
        let r = m.alloc("big", 256 << 20, Placement::Bind(0)); // 8x one L3
        m.access(0, Access::seq_read(r, 256 << 20));
        let out = m.access(0, Access::rand_read(r, 10_000, 256 << 20));
        // At most 32/256 can be resident locally.
        assert!(out.local_hits < 0.2 * out.total_ops(), "{out:?}");
        assert!(out.dram_lines > 0.5 * out.total_ops(), "{out:?}");
    }

    #[test]
    fn write_invalidates_remote_copies() {
        let m = machine();
        let r = m.alloc("d", 16 << 20, Placement::Bind(0));
        m.access(0, Access::seq_read(r, 16 << 20));
        assert!(m.resident(0, r) > 0);
        // Full overwrite from chiplet 2.
        m.access(16, Access::seq_write(r, 16 << 20));
        assert_eq!(m.resident(0, r), 0, "writer must invalidate readers");
        assert!(m.resident(2, r) > 0);
    }

    #[test]
    fn counters_accumulate_across_shards() {
        let m = machine();
        let r = m.alloc("d", 1 << 20, Placement::Bind(0));
        m.access(0, Access::seq_read(r, 1 << 20));
        m.access(8, Access::rand_read(r, 100, 1 << 20));
        let totals = m.class_totals();
        assert!(totals.dram > 0.0);
        assert!(totals.total_ops() > 0.0);
        // Per-chiplet slices land on the issuing chiplet.
        let counters = m.counters();
        assert!(counters.chiplet(0).total_ops() > 0.0);
        assert!(counters.chiplet(1).total_ops() > 0.0);
        assert_eq!(counters.chiplet(2).total_ops(), 0.0);
    }

    #[test]
    fn region_heat_tracks_issuing_chiplet() {
        let m = machine();
        let r = m.alloc("d", 1 << 20, Placement::Bind(0));
        m.access(0, Access::rand_read(r, 100, 1 << 20)); // chiplet 0
        m.access(8, Access::rand_read(r, 300, 1 << 20)); // chiplet 1
        let heat = m.region_heat();
        assert_eq!(heat.len(), 1);
        let (id, per_chiplet) = &heat[0];
        assert_eq!(*id, r);
        assert!((per_chiplet[0] - 100.0).abs() < 1e-9);
        assert!((per_chiplet[1] - 300.0).abs() < 1e-9);
        assert_eq!(per_chiplet[2], 0.0);
        // free drops heat along with residency; reset clears everything.
        m.free(r);
        assert!(m.region_heat().is_empty());
    }

    #[test]
    fn clocks_are_per_core_and_shard_local() {
        let topo = Topology::milan_2s();
        let shards = Shards::new(&topo);
        shards.advance(0, 100);
        shards.advance(9, 50); // chiplet 1
        assert_eq!(shards.now(0), 100);
        assert_eq!(shards.now(9), 50);
        assert_eq!(shards.now(1), 0);
        assert_eq!(shards.max_time(), 100);
        shards.advance_to(9, 40); // never rewinds
        assert_eq!(shards.now(9), 50);
    }

    #[test]
    fn reset_dynamic_cools_every_shard() {
        let m = machine();
        let r = m.alloc("d", 1 << 20, Placement::Bind(0));
        m.access(0, Access::seq_read(r, 1 << 20));
        m.reset_dynamic();
        assert_eq!(m.max_time(), 0);
        assert_eq!(m.class_totals().total_ops(), 0.0);
        assert_eq!(m.resident(0, r), 0);
        assert_eq!(m.dram_total_bytes(), 0.0);
        // Region registration survives.
        assert_eq!(m.region_size(r), 1 << 20);
    }

    #[test]
    fn clone_deep_copies_shard_state() {
        let m = machine();
        let r = m.alloc("d", 4 << 20, Placement::Bind(0));
        m.access(0, Access::seq_read(r, 4 << 20));
        let copy = m.clone();
        assert_eq!(copy.resident(0, r), m.resident(0, r));
        // Charging the copy must not touch the original.
        copy.access(0, Access::seq_write(r, 4 << 20));
        assert!(copy.max_time() > m.max_time());
    }

    #[test]
    fn shards_are_sync_for_concurrent_charging() {
        use std::sync::Arc;
        let m = Arc::new(machine());
        let r = m.alloc("shared", 8 << 20, Placement::Interleave);
        let mut handles = Vec::new();
        for t in 0..4usize {
            let m = m.clone();
            // One worker per chiplet: disjoint clock + shard ownership.
            let core = t * 8;
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    m.access(core, Access::rand_read(r, 100, 8 << 20));
                    m.compute(core, 10);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Every access was recorded exactly once.
        let totals = m.class_totals();
        assert!((totals.total_ops() - 4.0 * 50.0 * 100.0).abs() < 1e-6);
        for t in 0..4usize {
            assert!(m.now(t * 8) >= 500);
        }
    }
}
