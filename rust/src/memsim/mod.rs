//! Memory-channel bandwidth contention model (§2.2: "more cores, limited
//! memory channels").
//!
//! Each socket has `mem_channels_per_socket × mem_bw_per_channel` bytes/ns
//! of peak DRAM bandwidth. The model tracks demanded bytes per socket in a
//! sliding window of virtual time and inflates DRAM service time by an
//! M/M/1-style queueing factor `1/(1-u)` as utilization `u` approaches 1.
//! This is what makes high core counts memory-bound in the reproduction —
//! the exact effect Fig. 4 motivates and Fig. 7/10 exhibit.

use crate::topology::Topology;

/// Per-socket bandwidth accounting over a sliding window.
#[derive(Clone, Debug)]
struct SocketChannel {
    peak_bw: f64, // bytes/ns
    window_ns: f64,
    window_start: f64,
    bytes_in_window: f64,
    total_bytes: f64,
}

impl SocketChannel {
    fn new(peak_bw: f64, window_ns: f64) -> Self {
        Self {
            peak_bw,
            window_ns,
            window_start: 0.0,
            bytes_in_window: 0.0,
            total_bytes: 0.0,
        }
    }

    fn roll(&mut self, now_ns: f64) {
        if now_ns >= self.window_start + self.window_ns {
            // Decay rather than hard reset: keep half of the carry-over so
            // sustained load does not oscillate at window edges.
            let windows_passed = ((now_ns - self.window_start) / self.window_ns).floor();
            let decay = 0.5f64.powf(windows_passed);
            self.bytes_in_window *= decay;
            self.window_start = now_ns;
        }
    }

    fn utilization(&self, now_ns: f64) -> f64 {
        let span = (now_ns - self.window_start).max(1.0) + self.window_ns * 0.5;
        (self.bytes_in_window / (self.peak_bw * span)).min(1.0)
    }

    /// Charge `bytes` at `now_ns`; returns the service time in ns.
    fn charge(&mut self, now_ns: f64, bytes: f64) -> f64 {
        self.roll(now_ns);
        self.bytes_in_window += bytes;
        self.total_bytes += bytes;
        // Cap the inflation at 8x: under sustained saturation a requester
        // waits for its fair share among the ~8 cores of a chiplet (or
        // channel group), not an unbounded M/M/1 queue.
        let u = self.utilization(now_ns).min(0.875);
        let base = bytes / self.peak_bw;
        base / (1.0 - u)
    }
}

/// Machine-wide DRAM bandwidth model: per-socket DDR channels plus the
/// per-CCD Infinity-Fabric link every chiplet funnels its DRAM traffic
/// through (§2.3: why spreading keeps paying off past cache capacity).
#[derive(Clone, Debug)]
pub struct MemSim {
    sockets: Vec<SocketChannel>,
    chiplet_links: Vec<SocketChannel>,
    numa_per_socket: usize,
}

impl MemSim {
    pub fn new(topo: &Topology) -> Self {
        // Window: 10 µs of virtual time — long enough to smooth bursts,
        // short enough to adapt within a scheduler interval.
        let window_ns = 10_000.0;
        Self {
            sockets: (0..topo.sockets)
                .map(|_| SocketChannel::new(topo.mem_bw_per_socket(), window_ns))
                .collect(),
            chiplet_links: (0..topo.num_chiplets())
                .map(|_| SocketChannel::new(topo.if_bw_per_chiplet, window_ns))
                .collect(),
            numa_per_socket: topo.numa_per_socket,
        }
    }

    /// Charge a DRAM transfer of `bytes` homed on `numa`, requested from
    /// `chiplet`, at virtual time `now_ns`. Returns the bandwidth-term
    /// service time in ns (added on top of the cache model's latency
    /// term): the max of the DDR-channel and IF-link service times (the
    /// two stages pipeline, so the slower one dominates).
    pub fn charge(&mut self, now_ns: f64, numa: usize, chiplet: usize, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        let socket = numa / self.numa_per_socket;
        let ddr = self.sockets[socket].charge(now_ns, bytes);
        let link = self.chiplet_links[chiplet].charge(now_ns, bytes);
        ddr.max(link)
    }

    /// Current utilization of `socket`'s memory channels, 0..1.
    pub fn utilization(&self, socket: usize, now_ns: f64) -> f64 {
        self.sockets[socket].utilization(now_ns)
    }

    /// Total bytes ever served per socket (for the bandwidth-utilization
    /// measurement the paper reports).
    pub fn total_bytes(&self, socket: usize) -> f64 {
        self.sockets[socket].total_bytes
    }

    pub fn reset(&mut self) {
        for s in self.sockets.iter_mut().chain(self.chiplet_links.iter_mut()) {
            s.window_start = 0.0;
            s.bytes_in_window = 0.0;
            s.total_bytes = 0.0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn memsim() -> MemSim {
        MemSim::new(&Topology::milan_2s())
    }

    #[test]
    fn light_load_gets_near_peak_bandwidth() {
        let mut m = memsim();
        // 1 KiB at t=0 on an idle socket.
        let ns = m.charge(0.0, 0, 0 * 8, 1024.0);
        // A single chiplet is IF-link limited (32 B/ns on Milan), even
        // though the socket's DDR channels could go faster.
        let ideal = 1024.0 / Topology::milan_2s().if_bw_per_chiplet;
        assert!(ns < ideal * 1.2, "ns={ns} ideal={ideal}");
        // Spread across chiplets, the same bytes stream nearer DDR peak.
        let mut m2 = memsim();
        let per = 1024.0 / 8.0;
        let total: f64 = (0..8).map(|c| m2.charge(0.0, 0, c, per)).sum();
        assert!(total < ns, "spread {total} must beat single-link {ns}");
    }

    #[test]
    fn heavy_load_inflates_service_time() {
        let mut m = memsim();
        // Saturate the window.
        for _ in 0..200 {
            m.charge(100.0, 0, 0 * 8, 4.0 * 1024.0 * 1024.0);
        }
        let loaded = m.charge(100.0, 0, 0 * 8, 1024.0);
        let mut fresh = memsim();
        let idle = fresh.charge(100.0, 0, 0, 1024.0);
        assert!(
            loaded > idle * 3.0,
            "loaded={loaded} idle={idle} (queueing must inflate)"
        );
    }

    #[test]
    fn sockets_are_independent() {
        let mut m = memsim();
        for _ in 0..200 {
            m.charge(100.0, 0, 0 * 8, 4.0 * 1024.0 * 1024.0);
        }
        let s0 = m.charge(100.0, 0, 0 * 8, 1024.0);
        let s1 = m.charge(100.0, 1, 1 * 8, 1024.0);
        assert!(s1 < s0, "socket 1 must be idle: s0={s0} s1={s1}");
    }

    #[test]
    fn window_rolls_and_decays() {
        let mut m = memsim();
        for _ in 0..200 {
            m.charge(0.0, 0, 0 * 8, 4.0 * 1024.0 * 1024.0);
        }
        let hot = m.utilization(0, 0.0);
        // Far in the future the window has decayed.
        m.charge(1_000_000.0, 0, 0 * 8, 64.0);
        let cooled = m.utilization(0, 1_000_000.0);
        assert!(cooled < hot * 0.5, "hot={hot} cooled={cooled}");
    }

    #[test]
    fn zero_bytes_is_free() {
        let mut m = memsim();
        assert_eq!(m.charge(0.0, 0, 0 * 8, 0.0), 0.0);
    }

    #[test]
    fn total_bytes_accumulates() {
        let mut m = memsim();
        m.charge(0.0, 1, 1 * 8, 100.0);
        m.charge(5.0, 1, 1 * 8, 50.0);
        // NUMA 1 maps to socket 1 under NPS1.
        assert_eq!(m.total_bytes(1), 150.0);
        assert_eq!(m.total_bytes(0), 0.0);
    }
}
