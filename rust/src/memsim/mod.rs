//! Memory-channel bandwidth contention model (§2.2: "more cores, limited
//! memory channels").
//!
//! The unit of modeling is one [`BwTracker`]: a bandwidth-limited pipe
//! (a socket's DDR channels, or one CCD's Infinity-Fabric link to the IO
//! die) that tracks demanded bytes in a sliding window of virtual time
//! and inflates service time by an M/M/1-style queueing factor `1/(1-u)`
//! as utilization `u` approaches 1. This is what makes high core counts
//! memory-bound in the reproduction — the exact effect Fig. 4 motivates
//! and Fig. 7/10 exhibit.
//!
//! Ownership of the trackers is *sharded* (see [`crate::coordinator`]):
//! each socket shard owns its DDR tracker, each chiplet shard owns its
//! IF-link tracker, and [`crate::sim::Machine::access`] combines the two
//! stages as `max(ddr, link)` (they pipeline, so the slower dominates).
//! This module only defines the tracker itself, so the monolithic-vs-
//! sharded arrangements stay byte-for-byte comparable.

/// Sliding window length: 10 µs of virtual time — long enough to smooth
/// bursts, short enough to adapt within a scheduler interval.
pub const BW_WINDOW_NS: f64 = 10_000.0;

/// Bandwidth accounting for one pipe over a sliding virtual-time window.
#[derive(Clone, Debug)]
pub struct BwTracker {
    peak_bw: f64, // bytes/ns
    window_ns: f64,
    window_start: f64,
    bytes_in_window: f64,
    total_bytes: f64,
}

impl BwTracker {
    pub fn new(peak_bw: f64, window_ns: f64) -> Self {
        Self {
            peak_bw,
            window_ns,
            window_start: 0.0,
            bytes_in_window: 0.0,
            total_bytes: 0.0,
        }
    }

    fn roll(&mut self, now_ns: f64) {
        if now_ns >= self.window_start + self.window_ns {
            // Decay rather than hard reset: keep half of the carry-over so
            // sustained load does not oscillate at window edges.
            let windows_passed = ((now_ns - self.window_start) / self.window_ns).floor();
            let decay = 0.5f64.powf(windows_passed);
            self.bytes_in_window *= decay;
            self.window_start = now_ns;
        }
    }

    pub fn utilization(&self, now_ns: f64) -> f64 {
        let span = (now_ns - self.window_start).max(1.0) + self.window_ns * 0.5;
        (self.bytes_in_window / (self.peak_bw * span)).min(1.0)
    }

    /// Charge `bytes` at `now_ns`; returns the service time in ns.
    pub fn charge(&mut self, now_ns: f64, bytes: f64) -> f64 {
        self.roll(now_ns);
        self.bytes_in_window += bytes;
        self.total_bytes += bytes;
        // Cap the inflation at 8x: under sustained saturation a requester
        // waits for its fair share among the ~8 cores of a chiplet (or
        // channel group), not an unbounded M/M/1 queue.
        let u = self.utilization(now_ns).min(0.875);
        let base = bytes / self.peak_bw;
        base / (1.0 - u)
    }

    /// Total bytes ever served (for the bandwidth-utilization measurement
    /// the paper reports).
    pub fn total_bytes(&self) -> f64 {
        self.total_bytes
    }

    /// Clear dynamic state between experiment repetitions.
    pub fn reset(&mut self) {
        self.window_start = 0.0;
        self.bytes_in_window = 0.0;
        self.total_bytes = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    fn ddr() -> BwTracker {
        BwTracker::new(Topology::milan_2s().mem_bw_per_socket(), BW_WINDOW_NS)
    }

    fn if_link() -> BwTracker {
        BwTracker::new(Topology::milan_2s().if_bw_per_chiplet, BW_WINDOW_NS)
    }

    #[test]
    fn light_load_gets_near_peak_bandwidth() {
        // 1 KiB at t=0 on an idle pipe serves near the pipe's peak; the
        // IF link (32-80 B/ns) is the narrow stage a single chiplet sees,
        // even though the socket's DDR channels could go faster.
        let mut link = if_link();
        let ns = link.charge(0.0, 1024.0);
        let ideal = 1024.0 / Topology::milan_2s().if_bw_per_chiplet;
        assert!(ns < ideal * 1.2, "ns={ns} ideal={ideal}");
        let mut d = ddr();
        assert!(d.charge(0.0, 1024.0) < ns, "DDR channels outrun one IF link");
    }

    #[test]
    fn heavy_load_inflates_service_time() {
        let mut t = ddr();
        for _ in 0..200 {
            t.charge(100.0, 4.0 * 1024.0 * 1024.0);
        }
        let loaded = t.charge(100.0, 1024.0);
        let idle = ddr().charge(100.0, 1024.0);
        assert!(
            loaded > idle * 3.0,
            "loaded={loaded} idle={idle} (queueing must inflate)"
        );
    }

    #[test]
    fn trackers_are_independent() {
        // Independence is structural now: every socket/chiplet shard owns
        // its own tracker, so saturating one cannot slow another.
        let mut hot = ddr();
        for _ in 0..200 {
            hot.charge(100.0, 4.0 * 1024.0 * 1024.0);
        }
        let s0 = hot.charge(100.0, 1024.0);
        let s1 = ddr().charge(100.0, 1024.0);
        assert!(s1 < s0, "fresh tracker must be idle: s0={s0} s1={s1}");
    }

    #[test]
    fn window_rolls_and_decays() {
        let mut t = ddr();
        for _ in 0..200 {
            t.charge(0.0, 4.0 * 1024.0 * 1024.0);
        }
        let hot = t.utilization(0.0);
        // Far in the future the window has decayed.
        t.charge(1_000_000.0, 64.0);
        let cooled = t.utilization(1_000_000.0);
        assert!(cooled < hot * 0.5, "hot={hot} cooled={cooled}");
    }

    #[test]
    fn total_bytes_accumulates() {
        let mut t = ddr();
        t.charge(0.0, 100.0);
        t.charge(5.0, 50.0);
        assert_eq!(t.total_bytes(), 150.0);
    }

    #[test]
    fn reset_clears_dynamic_state() {
        let mut t = ddr();
        t.charge(0.0, (1u64 << 20) as f64);
        t.reset();
        assert_eq!(t.total_bytes(), 0.0);
        assert_eq!(t.utilization(0.0), 0.0);
    }
}
