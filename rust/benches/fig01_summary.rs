//! Fig. 1 reproduction: headline ARCAS speedups over the NUMA-aware
//! baselines across the benchmark suite (the paper's opening bar chart).

use std::sync::Arc;

use arcas::harness;
use arcas::util::table::Table;
use arcas::workloads::graph::{self, kronecker::kronecker};
use arcas::workloads::olap::{all_queries, run_query, Db};
use arcas::workloads::sgd::{generate_data, run_sgd, DwStrategy, RustGrad, SgdConfig, SgdMode};
use arcas::workloads::streamcluster::{generate_points, run_streamcluster, ScConfig};

fn main() {
    let args = harness::bench_cli("fig01_summary", "headline speedups").parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("Fig 1: ARCAS speedups vs NUMA-aware systems", &args, &topo);
    let cores = 32.min(topo.num_cores());
    let seed = args.u64("seed");

    let mut t = Table::new(
        "Fig 1: ARCAS speedup over NUMA-aware baseline",
        &["benchmark", "baseline", "speedup"],
    );
    let mut speedups = Vec::new();
    let mut push = |t: &mut Table, name: &str, base: &str, s: f64| {
        t.row(vec![name.to_string(), base.to_string(), format!("{s:.2}x")]);
        speedups.push(s);
    };

    // Graph suite vs RING.
    let scale = ((16_777_216.0 * args.f64("scale")) as u64).max(1024).ilog2();
    let g = Arc::new(kronecker(scale, 16, seed));
    let src = g.max_degree_vertex();
    let bfs_r = graph::run_bfs(&topo, harness::baseline("ring", &topo), cores, g.clone(), src)
        .0
        .report
        .makespan_ns;
    let bfs_a = graph::run_bfs(&topo, harness::arcas(&topo, &args), cores, g.clone(), src)
        .0
        .report
        .makespan_ns;
    push(&mut t, "BFS", "RING", bfs_r as f64 / bfs_a as f64);
    let sssp_r = graph::run_sssp(&topo, harness::baseline("ring", &topo), cores, g.clone(), src)
        .0
        .report
        .makespan_ns;
    let sssp_a = graph::run_sssp(&topo, harness::arcas(&topo, &args), cores, g.clone(), src)
        .0
        .report
        .makespan_ns;
    push(&mut t, "SSSP", "RING", sssp_r as f64 / sssp_a as f64);

    // StreamCluster vs Shoal at 16 cores (the paper's biggest-gap point);
    // batch sized to ~5 chiplets' L3 as in fig08.
    let dims = 64usize;
    let batch = ((5 * topo.l3_per_chiplet) as usize / (dims * 4)).max(1024);
    let sc = ScConfig {
        n_points: batch * 2,
        dims,
        batch_size: batch,
        k_min: 10,
        k_max: 20,
        max_centers: 5_000,
        local_iters: 3,
        seed: 7,
    };
    let pts = Arc::new(generate_points(&sc));
    let sc_s = run_streamcluster(&topo, harness::baseline("shoal", &topo), 16, &sc, pts.clone())
        .report
        .makespan_ns;
    let sc_a = run_streamcluster(&topo, harness::arcas(&topo, &args), 16, &sc, pts)
        .report
        .makespan_ns;
    push(&mut t, "StreamCluster", "Shoal", sc_s as f64 / sc_a as f64);

    // SGD vs DimmWitted-NUMA-node.
    let cfg = SgdConfig {
        n_samples: ((10_000.0 * args.f64("scale") * 10.0) as usize).max(512),
        n_features: 1024,
        minibatch: 128,
        epochs: 2,
        lr: 0.1,
        seed,
    };
    let data = generate_data(&cfg);
    let dw = run_sgd(&topo, harness::baseline("ring", &topo), cores, &cfg, &data,
                     DwStrategy::PerNode, SgdMode::Grad, Arc::new(RustGrad));
    let dwa = run_sgd(&topo, harness::arcas(&topo, &args), cores, &cfg, &data,
                      DwStrategy::PerCore, SgdMode::Grad, Arc::new(RustGrad));
    push(&mut t, "SGD", "DimmWitted", dwa.gbps() / dw.gbps());

    // TPC-H Q5 (join-heavy) vs chiplet-agnostic default.
    let db = Arc::new(Db::generate(args.f64("scale"), seed));
    let q5 = &all_queries()[4];
    let q_base = run_query(&topo, harness::baseline("ring", &topo), 8, db.clone(), q5)
        .report
        .makespan_ns;
    let q_arc = run_query(&topo, harness::arcas(&topo, &args), 8, db, q5)
        .report
        .makespan_ns;
    push(&mut t, "TPC-H Q5", "default", q_base as f64 / q_arc as f64);

    t.emit("fig01_summary");
    println!(
        "geomean speedup {:.2}x; max {:.2}x (paper headline: up to 3.85x in graph processing)",
        arcas::util::stats::geomean(&speedups),
        speedups.iter().cloned().fold(f64::MIN, f64::max)
    );
}
