//! Fig. 9 reproduction: ARCAS speedup over RING as the graph size grows
//! (paper: 19 MB → 5,300 MB by raising the vertex count), at 32 and 64
//! cores, across the six benchmarks.
//!
//! Paper shape: speedups are stable across dataset sizes (the working
//! set, not total size, is what matters) and larger at 64 cores.

use std::sync::Arc;

use arcas::harness;
use arcas::util::table::Table;
use arcas::workloads::graph::{self, kronecker::kronecker};

fn main() {
    let args = harness::bench_cli("fig09_datasize", "speedup vs graph size").parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("Fig 9: ARCAS/RING speedup vs graph size", &args, &topo);

    // Paper scales 2^16..2^24; we sweep 4 sizes around the configured
    // scale (each step quadruples the dataset).
    let base_scale = ((16_777_216.0 * args.f64("scale")) as u64).max(512).ilog2();
    let scales: Vec<u32> = if args.flag("quick") {
        vec![base_scale.saturating_sub(2), base_scale]
    } else {
        vec![
            base_scale.saturating_sub(3),
            base_scale.saturating_sub(2),
            base_scale.saturating_sub(1),
            base_scale,
        ]
    };
    let core_counts: Vec<usize> = [32usize, 64]
        .iter()
        .copied()
        .filter(|&c| c <= topo.num_cores())
        .collect();

    for &cores in &core_counts {
        let mut t = Table::new(
            &format!("Fig 9 @{cores} cores: ARCAS speedup over RING"),
            &["graph", "MB", "BFS", "PR", "CC", "SSSP", "GUPS", "Graph500"],
        );
        for &sc in &scales {
            let g = Arc::new(kronecker(sc, 16, args.u64("seed")));
            let src = g.max_degree_vertex();
            let mb = g.bytes() as f64 / 1e6;
            let speedup = |name: &str| -> f64 {
                let run = |p: Box<dyn arcas::policy::Policy>| -> u64 {
                    match name {
                        "BFS" => graph::run_bfs(&topo, p, cores, g.clone(), src).0.report.makespan_ns,
                        "PR" => graph::run_pagerank(&topo, p, cores, g.clone(), 5).0.report.makespan_ns,
                        "CC" => graph::run_cc(&topo, p, cores, g.clone()).0.report.makespan_ns,
                        "SSSP" => graph::run_sssp(&topo, p, cores, g.clone(), src).0.report.makespan_ns,
                        "GUPS" => {
                            graph::run_gups(&topo, p, cores, g.num_vertices() * 4, 20_000, 7)
                                .0
                                .report
                                .makespan_ns
                        }
                        _ => graph::run_bfs(&topo, p, cores, g.clone(), src).0.report.makespan_ns,
                    }
                };
                let ring = run(harness::baseline("ring", &topo));
                let arcas = run(harness::arcas(&topo, &args));
                ring as f64 / arcas as f64
            };
            let mut row = vec![format!("2^{sc}"), format!("{mb:.0}")];
            for name in ["BFS", "PR", "CC", "SSSP", "GUPS", "Graph500"] {
                row.push(format!("{:.2}", speedup(name)));
            }
            t.row(row);
        }
        t.emit(&format!("fig09_datasize_{cores}c"));
    }
    println!("paper shape: speedups stable across sizes; larger at 64 cores than 32");
}
