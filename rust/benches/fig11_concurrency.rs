//! Fig. 11 reproduction: thread concurrency during SGD at 32 cores,
//! DimmWitted+std::async (a) vs DimmWitted+ARCAS (b).
//!
//! Paper shape: std::async fluctuates around an average of 16.23 live
//! threads after creating 641 threads total; ARCAS holds a stable ~31.16
//! (34 threads for 32 workers).

use std::sync::Arc;

use arcas::harness;
use arcas::util::table::SeriesSet;
use arcas::workloads::sgd::{generate_data, run_sgd, DwStrategy, RustGrad, SgdConfig, SgdMode};

fn main() {
    let args = harness::bench_cli("fig11_concurrency", "SGD thread concurrency").parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("Fig 11: thread concurrency @32 cores", &args, &topo);
    let cores = 32.min(topo.num_cores());

    let cfg = SgdConfig {
        n_samples: ((10_000.0 * args.f64("scale") * 20.0) as usize).max(2048),
        n_features: 512,
        minibatch: 64,
        epochs: 3,
        lr: 0.1,
        seed: args.u64("seed"),
    };
    let data = Arc::new(generate_data(&cfg));

    let arcas_run = run_sgd(
        &topo,
        harness::arcas(&topo, &args),
        cores,
        &cfg,
        &data,
        DwStrategy::PerCore,
        SgdMode::Grad,
        Arc::new(RustGrad),
    );
    // std::async: ~20 shards (threads) per core, like the paper's 641
    // threads on 32 cores.
    let os_run = run_sgd(
        &topo,
        Box::new(arcas::policy::OsAsyncPolicy::confined(cores)),
        cores * 20,
        &cfg,
        &data,
        DwStrategy::PerCore,
        SgdMode::Grad,
        Arc::new(RustGrad),
    );

    for (label, run, slug) in [
        ("Fig 11a: DimmWitted+std::async", &os_run, "fig11a_async"),
        ("Fig 11b: DimmWitted+ARCAS", &arcas_run, "fig11b_arcas"),
    ] {
        let mut series = SeriesSet::new(
            &format!("{label} live threads over time"),
            "t_ms",
            &["threads"],
        );
        // Normalize the timeline to ms and subsample to <=50 points.
        let pts = &run.report.concurrency;
        let step = (pts.len() / 50).max(1);
        for (t, live) in pts.iter().step_by(step) {
            series.point(*t as f64 / 1e6, vec![*live as f64]);
        }
        series.emit(slug);
        println!(
            "{label}: avg {:.2} threads, peak {} (created tasks: {})",
            run.report.avg_concurrency,
            run.report.peak_concurrency,
            if slug.contains("async") { cores * 20 } else { cores }
        );
    }

    println!(
        "paper: std::async avg 16.23 fluctuating / 641 created; ARCAS stable avg 31.16 / 34 threads"
    );
    assert!(
        os_run.report.peak_concurrency > arcas_run.report.peak_concurrency,
        "std::async must show thread explosion"
    );
    assert!(
        arcas_run.report.makespan_ns < os_run.report.makespan_ns,
        "coroutines must beat OS threads"
    );
    println!(
        "ARCAS {:.1} ms vs std::async {:.1} ms ({}x)",
        arcas_run.report.makespan_ns as f64 / 1e6,
        os_run.report.makespan_ns as f64 / 1e6,
        os_run.report.makespan_ns / arcas_run.report.makespan_ns.max(1)
    );
}
