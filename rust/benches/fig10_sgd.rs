//! Fig. 10 reproduction: SGD (logistic regression) throughput, 8–64
//! cores, five systems:
//!
//!   DimmWitted+ARCAS, DimmWitted+ARCAS+std::async, DimmWitted-per-core,
//!   DimmWitted-NUMA-node, DimmWitted-per-machine.
//!
//! Two panels: (a) loss computation, (b) gradient computation. Paper
//! shape: ARCAS scales to ~165 GB/s (loss) / ~106 GB/s (grad); the best
//! native strategy (NUMA-node) plateaus ~50 / ~40 GB/s; std::async is
//! worse than NUMA-node.
//!
//! When `make artifacts` has run and the minibatch matches a compiled
//! shape, the gradient math executes through PJRT (real XLA numerics).

use std::sync::Arc;

use arcas::harness;
use arcas::runtime::{PjrtGrad, PjrtRuntime};
use arcas::util::table::SeriesSet;
use arcas::workloads::sgd::{
    generate_data, run_sgd, DwStrategy, GradEngine, RustGrad, SgdConfig, SgdMode, SgdRun,
};

fn main() {
    let args = harness::bench_cli("fig10_sgd", "SGD throughput, 5 systems").parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("Fig 10: SGD throughput", &args, &topo);

    // Paper: 10,000 samples x 8,192 features (~320 MB). Scaled; the
    // feature dim is pinned to 1024 so the PJRT artifact applies.
    let cfg = SgdConfig {
        n_samples: ((10_000.0 * args.f64("scale") * 20.0) as usize).max(512),
        n_features: 1024,
        minibatch: 128,
        epochs: 2,
        lr: 0.1,
        seed: args.u64("seed"),
    };
    println!(
        "# {} x {} (data {})",
        cfg.n_samples,
        cfg.n_features,
        arcas::util::fmt_bytes(cfg.data_bytes())
    );
    let data = generate_data(&cfg);

    // PJRT engine if artifacts are available.
    let engine: Arc<dyn GradEngine> =
        match PjrtRuntime::load(&PjrtRuntime::default_dir())
            .ok()
            .and_then(|rt| PjrtGrad::new(rt, cfg.minibatch, cfg.n_features).ok())
        {
            Some(g) => {
                println!("# gradient engine: PJRT (AOT JAX/Pallas artifact)");
                Arc::new(g)
            }
            None => {
                println!("# gradient engine: rust fallback (run `make artifacts` for PJRT)");
                Arc::new(RustGrad)
            }
        };

    let cores = harness::core_sweep(&args, &[8, 16, 32, 48, 64]);
    let data = Arc::new(data);

    // (name, policy, tasks-per-core factor, strategy)
    let systems: Vec<(&str, &str, usize, DwStrategy)> = vec![
        ("DW+ARCAS", "arcas", 1, DwStrategy::PerCore),
        // Thread-per-shard explosion: ~20 shards per core (paper: 641
        // threads on 32 cores).
        ("DW+ARCAS+std::async", "os_async", 20, DwStrategy::PerCore),
        ("DW-per-core", "shoal", 1, DwStrategy::PerCore),
        ("DW-NUMA-node", "ring", 1, DwStrategy::PerNode),
        ("DW-per-machine", "shoal", 1, DwStrategy::PerMachine),
    ];
    let run_one = |policy: &str, cores: usize, tasks: usize, strategy: DwStrategy, mode: SgdMode| -> SgdRun {
        let p: Box<dyn arcas::policy::Policy> = match policy {
            "arcas" => harness::arcas(&topo, &args),
            // taskset-confined OS threads (the paper sweeps allotted cores).
            "os_async" => Box::new(arcas::policy::OsAsyncPolicy::confined(cores)),
            other => harness::baseline(other, &topo),
        };
        run_sgd(&topo, p, tasks, &cfg, &data, strategy, mode, engine.clone())
    };

    for (mode, label) in [(SgdMode::Loss, "a: logistic loss"), (SgdMode::Grad, "b: gradient")] {
        let names: Vec<&str> = systems.iter().map(|(n, _, _, _)| *n).collect();
        let mut series = SeriesSet::new(
            &format!("Fig 10{label} throughput (GB/s)"),
            "cores",
            &names,
        );
        for &c in &cores {
            if c > topo.num_cores() {
                continue;
            }
            let mut ys = Vec::new();
            for (_, policy, factor, strategy) in &systems {
                let r = run_one(policy, c, c * factor, *strategy, mode);
                ys.push(r.gbps());
            }
            println!(
                "{label} cores {c:>3}: {}",
                names
                    .iter()
                    .zip(&ys)
                    .map(|(n, y)| format!("{n}={y:.1}"))
                    .collect::<Vec<_>>()
                    .join("  ")
            );
            series.point(c as f64, ys);
        }
        series.emit(&format!(
            "fig10{}",
            if mode == SgdMode::Loss { "a_loss" } else { "b_grad" }
        ));
    }
    println!("paper shape: ARCAS scales with cores; native strategies plateau; std::async < NUMA-node");
}
