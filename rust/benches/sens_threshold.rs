//! §4.6 sensitivity analysis reproduction: sweep RMT_CHIP_ACCESS_RATE and
//! measure its impact (the paper selects 300 events per SCHEDULER_TIMER).
//!
//! Also sweeps the SCHEDULER_TIMER itself and the approach bias — the
//! ablation DESIGN.md calls out for Algorithm 1's two knobs.

use std::sync::Arc;

use arcas::controller::Approach;
use arcas::harness;
use arcas::policy::ArcasPolicy;
use arcas::util::table::Table;
use arcas::workloads::graph::{self, kronecker::kronecker};

fn main() {
    let args = harness::bench_cli("sens_threshold", "RMT_CHIP_ACCESS_RATE sweep").parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("§4.6 sensitivity: threshold + timer + approach", &args, &topo);
    let cores = 32.min(topo.num_cores());
    let scale = ((16_777_216.0 * args.f64("scale")) as u64).max(1024).ilog2();
    let g = Arc::new(kronecker(scale, 16, args.u64("seed")));
    let src = g.max_degree_vertex();
    let timer = args.u64("timer-us") * 1_000;

    // --- threshold sweep.
    let mut t = Table::new(
        "RMT_CHIP_ACCESS_RATE sweep (BFS + GUPS makespans, ms)",
        &["threshold", "BFS ms", "GUPS ms", "final spread (BFS)"],
    );
    let mut best = (f64::INFINITY, 0u64);
    for thr in [25u64, 50, 100, 200, 300, 500, 1000, 5000] {
        let policy = || {
            Box::new(
                ArcasPolicy::new(&topo)
                    .with_timer(timer)
                    .with_threshold(thr as f64),
            )
        };
        let bfs = graph::run_bfs(&topo, policy(), cores, g.clone(), src).0.report;
        let gups =
            graph::run_gups(&topo, policy(), cores, g.num_vertices() * 4, 30_000, 7).0.report;
        let total = (bfs.makespan_ns + gups.makespan_ns) as f64 / 1e6;
        if total < best.0 {
            best = (total, thr);
        }
        t.row(vec![
            thr.to_string(),
            format!("{:.2}", bfs.makespan_ns as f64 / 1e6),
            format!("{:.2}", gups.makespan_ns as f64 / 1e6),
            bfs.spread_rate.to_string(),
        ]);
    }
    t.emit("sens_threshold");
    println!("best combined threshold: {} (paper selects 300)\n", best.1);

    // --- timer sweep ablation.
    let mut t = Table::new(
        "SCHEDULER_TIMER sweep (BFS makespan, ms)",
        &["timer_us", "BFS ms", "migrations"],
    );
    for timer_us in [10u64, 25, 50, 100, 500, 2000] {
        let policy = Box::new(ArcasPolicy::new(&topo).with_timer(timer_us * 1000));
        let r = graph::run_bfs(&topo, policy, cores, g.clone(), src).0.report;
        t.row(vec![
            timer_us.to_string(),
            format!("{:.2}", r.makespan_ns as f64 / 1e6),
            r.migrations.to_string(),
        ]);
    }
    t.emit("sens_timer");

    // --- approach ablation (location-centric vs cache-size-centric).
    let mut t = Table::new(
        "approach ablation (BFS makespan, ms)",
        &["approach", "BFS ms", "final spread"],
    );
    for (name, a) in [
        ("location-centric", Approach::LocationCentric),
        ("balanced", Approach::Balanced),
        ("cache-size-centric", Approach::CacheSizeCentric),
    ] {
        let policy = Box::new(
            ArcasPolicy::new(&topo)
                .with_timer(timer)
                .with_approach(a),
        );
        let r = graph::run_bfs(&topo, policy, cores, g.clone(), src).0.report;
        t.row(vec![
            name.to_string(),
            format!("{:.2}", r.makespan_ns as f64 / 1e6),
            r.spread_rate.to_string(),
        ]);
    }
    t.emit("sens_approach");
}
