//! Fig. 12 reproduction: TPC-H queries on the mini OLAP engine, default
//! scheduling vs +ARCAS, at 8 cores (one chiplet's worth).
//!
//! Paper shape: every query improves; join-heavy queries (Q3, Q4, Q5,
//! Q7, Q9, Q10, Q21) improve most (1.24x–1.51x on lineitem⋈orders);
//! small-working-set queries (Q1, Q2, Q6, Q11) gain from compaction;
//! hash group-by heavy Q18 gains least.

use std::sync::Arc;

use arcas::harness;
use arcas::util::table::Table;
use arcas::workloads::olap::{all_queries, run_query, Db};

fn main() {
    let args = harness::bench_cli("fig12_tpch", "TPC-H ±ARCAS @8 cores").parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("Fig 12: TPC-H on the mini engine", &args, &topo);
    let cores = 8.min(topo.num_cores());

    // Paper: SF 100. Scaled down via --scale (default 0.02 => SF 2-ish
    // shape at 1/100 the rows).
    let sf = args.f64("scale");
    let db = Arc::new(Db::generate(sf, args.u64("seed")));
    println!(
        "# db: sf={sf} lineitem rows={} total {}",
        db.rows(arcas::workloads::olap::Table::Lineitem),
        arcas::util::fmt_bytes(db.total_bytes())
    );

    let mut t = Table::new(
        "Fig 12: query runtime (ms), default vs +ARCAS",
        &["query", "default", "+ARCAS", "speedup", "class"],
    );
    let queries = all_queries();
    let queries: Vec<_> = if args.flag("quick") {
        queries.into_iter().take(8).collect()
    } else {
        queries
    };
    let li_rows = db.rows(arcas::workloads::olap::Table::Lineitem);
    let mut join_heavy_speedups = Vec::new();
    let mut other_speedups = Vec::new();
    for q in &queries {
        // "DuckDB default": NUMA-aware but chiplet-agnostic placement.
        let base = run_query(&topo, harness::baseline("ring", &topo), cores, db.clone(), q);
        let arc = run_query(&topo, harness::arcas(&topo, &args), cores, db.clone(), q);
        // Sanity: same results regardless of policy.
        assert_eq!(base.rows_out, arc.rows_out, "Q{} result mismatch", q.id);
        let speedup = base.report.makespan_ns as f64 / arc.report.makespan_ns as f64;
        let class = if q.join_heavy() {
            join_heavy_speedups.push(speedup);
            "join-heavy"
        } else if q.small_working_set(li_rows) {
            other_speedups.push(speedup);
            "small-ws"
        } else {
            other_speedups.push(speedup);
            "mixed"
        };
        t.row(vec![
            format!("Q{}", q.id),
            format!("{:.2}", base.report.makespan_ns as f64 / 1e6),
            format!("{:.2}", arc.report.makespan_ns as f64 / 1e6),
            format!("{:.2}x", speedup),
            class.to_string(),
        ]);
    }
    t.emit("fig12_tpch");

    let gm = |xs: &[f64]| -> f64 {
        if xs.is_empty() {
            1.0
        } else {
            arcas::util::stats::geomean(xs)
        }
    };
    println!(
        "geomean speedup: join-heavy {:.2}x, others {:.2}x (paper: joins 1.24-1.51x, all queries improve)",
        gm(&join_heavy_speedups),
        gm(&other_speedups)
    );
}
