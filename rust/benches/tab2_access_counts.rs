//! Tab. 2 reproduction: memory/cache access counts (×10³) for
//! StreamCluster, ARCAS vs Shoal, at 8/16/32/64 cores.
//!
//! Paper shape: at 8 cores Shoal shows >7× ARCAS's main-memory accesses
//! (one chiplet's L3 vs eight); the gap narrows as core counts grow and
//! Shoal spills onto more chiplets, converging by 64 cores.

use std::sync::Arc;

use arcas::harness;
use arcas::util::table::Table;
use arcas::workloads::streamcluster::{generate_points, run_streamcluster, ScConfig};

fn main() {
    let args = harness::bench_cli("tab2_access_counts", "Tab 2: access counts").parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("Tab 2: StreamCluster accesses by level", &args, &topo);

    // Batch sized from the machine: ~5 chiplets' worth of L3, so the
    // batch fits when spread across 8 chiplets but spills to DRAM on the
    // 2 chiplets Shoal fills at 16 cores (the paper's 512 MB vs 2x32 MB).
    let dims = 64usize;
    let batch = ((5 * topo.l3_per_chiplet) as usize / (dims * 4)).max(1024);
    let cfg = ScConfig {
        n_points: batch * 2,
        dims,
        batch_size: batch,
        k_min: 10,
        k_max: 20,
        max_centers: 5_000,
        local_iters: 3,
        seed: 7,
    };
    let pts = Arc::new(generate_points(&cfg));

    let mut t = Table::new(
        "Tab 2: accesses (x10^3) ARCAS vs Shoal",
        &[
            "Cores",
            "LocalChiplet A",
            "LocalChiplet S",
            "LocalNUMAChiplet A",
            "LocalNUMAChiplet S",
            "MainMemory A",
            "MainMemory S",
        ],
    );
    let mut mem_ratio_8 = 0.0;
    for cores in [8usize, 16, 32, 64] {
        if cores > topo.num_cores() {
            continue;
        }
        let a = run_streamcluster(&topo, harness::arcas(&topo, &args), cores, &cfg, pts.clone())
            .report
            .counts;
        let s = run_streamcluster(
            &topo,
            harness::baseline("shoal", &topo),
            cores,
            &cfg,
            pts.clone(),
        )
        .report
        .counts;
        if cores == 8 {
            mem_ratio_8 = s.dram / a.dram.max(0.001);
        }
        t.row(vec![
            cores.to_string(),
            format!("{:.0}", a.local / 1e3),
            format!("{:.0}", s.local / 1e3),
            format!("{:.0}", a.near / 1e3),
            format!("{:.0}", s.near / 1e3),
            format!("{:.0}", a.dram / 1e3),
            format!("{:.0}", s.dram / 1e3),
        ]);
    }
    t.emit("tab2_access_counts");
    println!("Shoal/ARCAS main-memory ratio at 8 cores: {mem_ratio_8:.1}x (paper: >7x)");
}
