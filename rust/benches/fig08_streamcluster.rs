//! Fig. 8 reproduction: StreamCluster speedup vs single core, ARCAS vs
//! Shoal, 1..64 cores.
//!
//! Paper shape: ARCAS peaks ~21x around 24 cores, Shoal ~16x at 32; the
//! biggest gap (~2x) is at 16 cores, where Shoal's sequential placement
//! confines compute to 2 of 8 chiplets (2×32 MB of L3 for a ~512 MB
//! dataset) while ARCAS spreads over all 8.

use std::sync::Arc;

use arcas::harness;
use arcas::util::table::SeriesSet;
use arcas::workloads::streamcluster::{generate_points, run_streamcluster, ScConfig};

fn main() {
    let args = harness::bench_cli("fig08_streamcluster", "StreamCluster vs Shoal").parse();
    let topo = harness::bench_topology(&args);
    harness::print_header("Fig 8: StreamCluster scalability", &args, &topo);

    // Batch sized from the machine: ~5 chiplets' worth of L3, so the
    // batch fits when spread across 8 chiplets but spills to DRAM on the
    // 2 chiplets Shoal fills at 16 cores (the paper's 512 MB vs 2x32 MB).
    let dims = 64usize;
    let batch = ((5 * topo.l3_per_chiplet) as usize / (dims * 4)).max(1024);
    let cfg = ScConfig {
        n_points: batch * 2,
        dims,
        batch_size: batch,
        k_min: 10,
        k_max: 20,
        max_centers: 5_000,
        local_iters: 3,
        seed: 7,
    };
    println!(
        "# {} points x {} dims, batch {} ({} per batch)",
        cfg.n_points,
        cfg.dims,
        cfg.batch_size,
        arcas::util::fmt_bytes(cfg.batch_bytes())
    );
    let pts = Arc::new(generate_points(&cfg));
    let cores = harness::core_sweep(&args, &[1, 2, 4, 8, 16, 24, 32, 40, 48, 64]);

    // Single-core baseline (policy-independent).
    let base = run_streamcluster(
        &topo,
        harness::baseline("local", &topo),
        1,
        &cfg,
        pts.clone(),
    )
    .report
    .makespan_ns as f64;

    let mut series = SeriesSet::new(
        "Fig 8: StreamCluster speedup over 1 core",
        "cores",
        &["ARCAS", "Shoal"],
    );
    let mut gap_at_16 = 0.0;
    for &c in &cores {
        if c > topo.num_cores() {
            continue;
        }
        let a = base
            / run_streamcluster(&topo, harness::arcas(&topo, &args), c, &cfg, pts.clone())
                .report
                .makespan_ns as f64;
        let s = base
            / run_streamcluster(
                &topo,
                harness::baseline("shoal", &topo),
                c,
                &cfg,
                pts.clone(),
            )
            .report
            .makespan_ns as f64;
        if c == 16 {
            gap_at_16 = a / s;
        }
        println!("cores {c:>3}: ARCAS {a:.2}x  Shoal {s:.2}x");
        series.point(c as f64, vec![a, s]);
    }
    series.emit("fig08_streamcluster");
    println!("gap at 16 cores: {gap_at_16:.2}x (paper: ~2x)");
}
